//! Fuzz target for the WATERMARK control-frame codec.
//!
//! Same contract as `message_decode`: `Watermark::decode` is total on
//! arbitrary bytes (stats payload length bounded by the remaining
//! buffer before allocation, trailing bytes rejected) and accepted
//! frames are canonical under re-encode.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    if let Ok(wm) = dsba::comm::Watermark::decode(data) {
        assert_eq!(
            wm.encode(),
            data,
            "accepted WATERMARK frame is not canonical: decode(b).encode() != b"
        );
    }
});
