//! Fuzz target for the telemetry JSONL line parser.
//!
//! `TelemetryLine::parse` promises to be *total* on arbitrary text:
//! every input either parses as a row / summary / event line or returns
//! `Err` — no panic. Accepted lines are additionally canonicalizable:
//! `to_json_line()` must reparse to the same value, and its render must
//! be a fixed point (canonical form renders to itself). The input line
//! itself need not be canonical — key order, whitespace, and float
//! spellings are free — which is exactly why the law is stated on the
//! re-render, not the raw bytes.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else {
        return;
    };
    if let Ok(line) = dsba::telemetry::TelemetryLine::parse(text) {
        let canonical = line.to_json_line();
        let back = dsba::telemetry::TelemetryLine::parse(&canonical)
            .expect("canonical render of an accepted line must reparse");
        assert_eq!(back, line, "reparse of the canonical render changed the value");
        assert_eq!(
            back.to_json_line(),
            canonical,
            "canonical render is not a fixed point"
        );
    }
});
