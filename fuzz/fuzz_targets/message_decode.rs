//! Fuzz target for the MSG payload codec.
//!
//! `Message::decode` promises to be *total* on arbitrary bytes: every
//! input either parses or returns `Err` — no panic, no unbounded
//! allocation (length prefixes are capped by the remaining buffer
//! before any `Vec::with_capacity`). Accepted frames are additionally
//! canonical, so re-encoding must reproduce the input bit-for-bit —
//! the same two laws the corrupt-frame property tests in
//! `rust/tests/properties.rs` sample, explored exhaustively here.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    if let Ok(msg) = dsba::comm::Message::decode(data) {
        assert_eq!(
            msg.encode(),
            data,
            "accepted MSG frame is not canonical: decode(b).encode() != b"
        );
    }
});
