# Repo task entry points. `make verify` is the tier-1 gate CI runs.

CARGO ?= cargo

.PHONY: verify build test fmt lint doc bench-engine bench-transport bench-saddle \
        smoke report trace bench-compare fuzz-list artifacts clean

## tier-1: release build + full test suite
verify:
	$(CARGO) build --release && $(CARGO) test -q

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

## clippy over lib + bins + tests + benches, warnings are errors (CI gate)
lint:
	$(CARGO) clippy --all-targets -- -D warnings

## rustdoc with warnings denied (broken intra-doc links fail; CI gate)
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

## parallel-engine scaling table (wall-clock vs thread count), plus the
## sync vs async:{1,2} round-clock sweep that writes
## results/BENCH_engine.json
bench-engine:
	$(CARGO) bench --bench engine_scaling

## local vs loopback-TCP transport throughput (DOUBLEs/sec), plus the
## compression-ratio sweep that writes results/BENCH_transport.json
bench-transport:
	$(CARGO) bench --bench transport_overhead

## saddle-workload figure bench (robust-ls + dro-bilinear, fig3-style)
bench-saddle:
	$(CARGO) bench --bench fig_saddle

## one tiny end-to-end run per registered problem, enumerated from the
## live registry (`dsba problems`) — the CI smoke gate for new entries
smoke: build
	set -e; for p in $$(target/release/dsba problems); do \
	  echo "--- smoke: $$p ---"; \
	  target/release/dsba run --problem $$p --dataset tiny --nodes 4 \
	    --passes 1 --engine parallel --threads 2; \
	done
	# lossy wire compression end-to-end, once per transport (the
	# sequential oracle rejects --compress by design)
	echo "--- smoke: elastic-net + topk:4 (local) ---"
	target/release/dsba run --problem elastic-net --dataset tiny --nodes 4 \
	  --passes 1 --engine parallel --threads 2 --compress topk:4
	echo "--- smoke: elastic-net + topk:4 (tcp) ---"
	target/release/dsba run --problem elastic-net --dataset tiny --nodes 4 \
	  --passes 1 --engine parallel --threads 2 --transport tcp --compress topk:4
	# bounded-staleness async round clock end-to-end, once per transport
	echo "--- smoke: logistic + mode async:1 (local) ---"
	target/release/dsba run --problem logistic --dataset tiny --nodes 4 \
	  --passes 1 --engine parallel --threads 2 --mode async:1
	echo "--- smoke: logistic + mode async:1 (tcp) ---"
	target/release/dsba run --problem logistic --dataset tiny --nodes 4 \
	  --passes 1 --engine parallel --threads 2 --transport tcp --mode async:1
	# fault injection + telemetry end-to-end: drop faults on the TCP
	# link layer must not change the result, and the emitted JSONL
	# stream must pass the schema check
	echo "--- smoke: logistic + fault drop:0.05 + telemetry (tcp) ---"
	mkdir -p results && rm -f results/smoke_telemetry.jsonl*
	target/release/dsba run --problem logistic --dataset tiny --nodes 4 \
	  --passes 1 --engine parallel --threads 2 --transport tcp \
	  --fault drop:0.05,dup:0.05 --telemetry results/smoke_telemetry.jsonl
	target/release/dsba telemetry-check results/smoke_telemetry.jsonl
	# ...and the analysis layer must be able to read what the run wrote:
	# fitted convergence rate, phase breakdown, straggler attribution
	target/release/dsba report results/smoke_telemetry.jsonl
	# ...and the faulted run's stream must export as a Chrome/Perfetto
	# trace (uploaded as a CI artifact for eyeball debugging)
	target/release/dsba trace export results/smoke_telemetry.jsonl \
	  --format chrome --out results/smoke_trace.json

## analyze a telemetry stream (default: the one `make smoke` leaves
## behind). RUN=path/to/stream.jsonl overrides; add JSON=1 for the
## machine-readable form
RUN ?= results/smoke_telemetry.jsonl
report: build
	target/release/dsba report $(RUN) $(if $(JSON),--json)

## export a telemetry stream as Chrome trace-event JSON (default: the
## one `make smoke` leaves behind). RUN=path/to/stream.jsonl overrides;
## OUT=path/to/trace.json redirects (default: results/smoke_trace.json).
## Load the output in https://ui.perfetto.dev or chrome://tracing
OUT ?= results/smoke_trace.json
trace: build
	target/release/dsba trace export $(RUN) --format chrome --out $(OUT)

## perf trajectory gate (the CI regression job): stash the committed
## snapshots, re-run the bench sweeps (which overwrite
## results/BENCH_*.json), then diff fresh vs committed. TOL is generous
## while the committed snapshots are hand-seeded bootstrap values —
## tighten it after regenerating on pinned hardware (run the two bench
## targets and commit the refreshed results/BENCH_*.json)
TOL ?= 300
bench-compare: build
	cp results/BENCH_engine.json results/BENCH_engine.committed.json
	cp results/BENCH_transport.json results/BENCH_transport.committed.json
	$(MAKE) bench-engine bench-transport
	target/release/dsba bench-compare results/BENCH_engine.committed.json \
	  results/BENCH_engine.json --tol $(TOL)
	target/release/dsba bench-compare results/BENCH_transport.committed.json \
	  results/BENCH_transport.json --tol $(TOL)
	rm -f results/BENCH_engine.committed.json results/BENCH_transport.committed.json

## list the cargo-fuzz targets and how to run them (fuzzing needs
## network + nightly, so it is documented here, not CI-gated)
fuzz-list:
	@echo "fuzz targets (run from fuzz/, needs cargo-fuzz + nightly):"
	@echo "  cargo +nightly fuzz run message_decode       corpus/message_decode"
	@echo "  cargo +nightly fuzz run watermark_decode     corpus/watermark_decode"
	@echo "  cargo +nightly fuzz run telemetry_line_parse corpus/telemetry_line_parse"
	@echo "seed corpora: fuzz/corpus/<target>/; details: fuzz/README.md"

## AOT-compile the XLA artifacts (needs the python/ toolchain: jax + pallas)
artifacts:
	python3 python/compile/aot.py

clean:
	$(CARGO) clean
