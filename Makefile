# Repo task entry points. `make verify` is the tier-1 gate CI runs.

CARGO ?= cargo

.PHONY: verify build test fmt lint doc bench-engine bench-transport artifacts clean

## tier-1: release build + full test suite
verify:
	$(CARGO) build --release && $(CARGO) test -q

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

## clippy over lib + bins + tests + benches, warnings are errors (CI gate)
lint:
	$(CARGO) clippy --all-targets -- -D warnings

## rustdoc with warnings denied (broken intra-doc links fail; CI gate)
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

## parallel-engine scaling table (wall-clock vs thread count)
bench-engine:
	$(CARGO) bench --bench engine_scaling

## local vs loopback-TCP transport throughput (DOUBLEs/sec)
bench-transport:
	$(CARGO) bench --bench transport_overhead

## AOT-compile the XLA artifacts (needs the python/ toolchain: jax + pallas)
artifacts:
	python3 python/compile/aot.py

clean:
	$(CARGO) clean
