"""Build-time compile path: JAX/Pallas → HLO text artifacts.

Nothing in this package is imported at runtime; the Rust binary consumes
only ``artifacts/*.hlo.txt`` + ``artifacts/manifest.json``.
"""
