"""Shared helpers for the Pallas kernels (block sizing, dtype policy)."""

import jax

# The whole stack is f64: the Rust L3 core does its algorithm math in f64
# (the paper's convergence plots go down to 1e-12 suboptimality, which f32
# cannot resolve), so the AOT artifacts must match.
jax.config.update("jax_enable_x64", True)

# Target block sizes.
#
# Two regimes (see DESIGN.md §Hardware-Adaptation and §Perf):
#  * TPU (compile-only target): (256, 512) f64 tiles — one A tile plus the
#    z/g slices is ~1 MiB, comfortably double-bufferable in ~16 MiB VMEM,
#    and the 256-wide rows keep the MXU systolic array saturated.
#  * CPU interpret mode (what actually executes here): every grid step of
#    the lowered while-loop round-trips the full output buffer through
#    dynamic-update-slice, so SMALL grids win by orders of magnitude
#    (measured 43 s -> 0.9 s on the (1024, 16384) bucket; EXPERIMENTS.md
#    §Perf). We therefore default to large blocks / tiny grids and expose
#    DSBA_BLOCK_{Q,D} to regenerate TPU-shaped artifacts.
import os

TARGET_BQ = int(os.environ.get("DSBA_BLOCK_Q", "1024"))
TARGET_BD = int(os.environ.get("DSBA_BLOCK_D", "8192"))


def pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target``.

    Pallas BlockSpecs require the array extent to be an exact multiple of
    the block extent; callers pad to the shape buckets in ``shapes.py``
    (powers of two), so this normally returns ``target`` itself.
    """
    if n <= target:
        return n
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return n  # unreachable: 1 always divides n


def grid_dims(q: int, d: int, bq: int = TARGET_BQ, bd: int = TARGET_BD):
    """(block_q, block_d, n_q_blocks, n_d_blocks) for a (q, d) operand."""
    bq = pick_block(q, bq)
    bd = pick_block(d, bd)
    return bq, bd, q // bq, d // bd
