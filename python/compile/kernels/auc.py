"""AUC saddle-operator coefficient kernel (paper eqs. (75)/(76)).

For the l2-relaxed AUC maximization the augmented variable is
``z = [w; a; b; theta]`` and the per-sample operator output is fully
described by FOUR scalars once the margin ``m_i = a_i^T w`` is known:

  positive sample (y=+1):
    c1 = 2(1-p)((m - a) - (1+theta))    # coefficient on a_i in the w-block
    c2 = -2(1-p)(m - a)                 # d/da component
    c3 = 0                              # d/db component
    c4 = 2p(1-p)theta + 2(1-p)m         # -d/dtheta component
  negative sample (y=-1):
    c1 = 2p((m - b) + (1+theta))
    c2 = 0
    c3 = -2p(m - b)
    c4 = 2p(1-p)theta - 2p m

Zero-padded rows (y=0) produce all-zero coefficients.  This is exactly the
"O(q) scalar SAGA table" trick of (Schmidt et al., 2017) that the paper's
storage analysis (§5.1) relies on, lifted to the saddle operator.

The kernel fuses the matvec with the coefficient epilogue: grid
(q-blocks, d-blocks), margins accumulated in the first output column, the
four columns materialized on the last d-block.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_dims


def _kernel(n_d_blocks: int):
    def kernel(a_ref, y_ref, w_ref, s_ref, o_ref):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        # accumulate margins in column 0
        o_ref[:, 0] += a_ref[...] @ w_ref[...]

        @pl.when(j == n_d_blocks - 1)
        def _fin():
            a_sc, b_sc, theta, p = s_ref[0], s_ref[1], s_ref[2], s_ref[3]
            m = o_ref[:, 0]
            y = y_ref[...]
            pos = (y > 0.0).astype(m.dtype)
            neg = (y < 0.0).astype(m.dtype)
            c1 = pos * 2.0 * (1.0 - p) * ((m - a_sc) - (1.0 + theta)) + \
                 neg * 2.0 * p * ((m - b_sc) + (1.0 + theta))
            c2 = pos * (-2.0) * (1.0 - p) * (m - a_sc)
            c3 = neg * (-2.0) * p * (m - b_sc)
            c4 = (pos + neg) * 2.0 * p * (1.0 - p) * theta + \
                 pos * 2.0 * (1.0 - p) * m - neg * 2.0 * p * m
            o_ref[:, 0] = c1
            o_ref[:, 1] = c2
            o_ref[:, 2] = c3
            o_ref[:, 3] = c4

    return kernel


def auc_coefs(a, y, w, scalars):
    """Per-sample AUC operator coefficients as a Pallas kernel.

    Args:
      a: ``(q, d)`` shard.
      y: ``(q,)`` labels in {-1, 0(=pad), +1}.
      w: ``(d,)`` linear part of the augmented iterate.
      scalars: ``(4,)`` packed ``[a, b, theta, p]``.
    Returns:
      ``(q, 4)`` coefficient matrix ``[c1 c2 c3 c4]``.
    """
    q, d = a.shape
    bq, bd, nq, nd = grid_dims(q, d)
    return pl.pallas_call(
        _kernel(nd),
        grid=(nq, nd),
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((4,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, 4), a.dtype),
        interpret=True,
    )(a, y, w, scalars)
