"""Transposed-matvec accumulation kernel: ``A^T @ g`` (unnormalized).

Second half of a full local-operator evaluation: given the scalar
coefficients ``g`` from :mod:`coef`, the node's full operator output is
``B_n(z) = (A^T g) / q`` (+ the l2 term added by the caller).  We emit the
*unnormalized* sum so that shape-bucket padding (zero rows with ``g = 0``)
is exactly neutral and the Rust side divides by the true ``q``.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_dims


def _kernel(n_q_blocks: int):
    def kernel(a_ref, g_ref, o_ref):
        j = pl.program_id(1)  # q-block index (reduction dim)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += g_ref[...] @ a_ref[...]

    return kernel


def atg(a, g):
    """``A^T @ g`` as a Pallas kernel.

    Args:
      a: ``(q, d)`` shard.
      g: ``(q,)`` coefficients.
    Returns:
      ``(d,)`` unnormalized operator direction ``sum_i g_i a_i``.
    """
    q, d = a.shape
    bq, bd, nq, nd = grid_dims(q, d)
    return pl.pallas_call(
        _kernel(nq),
        grid=(nd, nq),
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j: (j, i)),
            pl.BlockSpec((bq,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), a.dtype),
        interpret=True,
    )(a, g)
