"""Fused gossip-mixing kernel: ``Wt @ (2 Z - Z_prev)``.

The dense half of the DSBA / EXTRA update (24):
``Z^{t+1} = 2 Wt Z^t - Wt Z^{t-1} - alpha * (...)`` — the two matmuls share
the mixing matrix, so we fuse them into one ``Wt @ (2 Z - Z_prev)`` pass:
the (N, bd) tiles of Z and Z_prev are combined in registers and hit the
(MXU-shaped) matmul once.  N is the node count (tiny, <= 64), d is blocked.
"""

import jax
from jax.experimental import pallas as pl

from .common import pick_block


def _kernel(w_ref, z_ref, zp_ref, o_ref):
    o_ref[...] = w_ref[...] @ (2.0 * z_ref[...] - zp_ref[...])


def mix_step(w, z, z_prev, bd_target: int = 8192):
    """``W @ (2 Z - Z_prev)`` as a Pallas kernel.

    Args:
      w: ``(N, N)`` mixing matrix (``Wt`` in the paper).
      z: ``(N, d)`` current stacked iterates.
      z_prev: ``(N, d)`` previous stacked iterates.
    Returns:
      ``(N, d)`` mixed matrix.
    """
    n, d = z.shape
    bd = pick_block(d, bd_target)
    return pl.pallas_call(
        _kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, bd), lambda i: (0, i)),
            pl.BlockSpec((n, bd), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), z.dtype),
        interpret=True,
    )(w, z, z_prev)
