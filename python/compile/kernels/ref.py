"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth the pytest/hypothesis suite checks the kernels
against, and (transitively, through python/tests/test_model.py) the
semantics the Rust runtime assumes of the AOT artifacts.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def matvec_act_ref(a, z, y, act: str = "ridge"):
    m = a @ z
    if act == "ridge":
        return m - y
    if act == "logistic":
        return -y * jax.nn.sigmoid(-y * m)
    if act == "identity":
        return m
    raise ValueError(act)


def atg_ref(a, g):
    return a.T @ g


def mix_step_ref(w, z, z_prev):
    return w @ (2.0 * z - z_prev)


def auc_coefs_ref(a, y, w, scalars):
    a_sc, b_sc, theta, p = scalars
    m = a @ w
    pos = (y > 0.0).astype(m.dtype)
    neg = (y < 0.0).astype(m.dtype)
    c1 = pos * 2.0 * (1.0 - p) * ((m - a_sc) - (1.0 + theta)) + \
         neg * 2.0 * p * ((m - b_sc) + (1.0 + theta))
    c2 = pos * (-2.0) * (1.0 - p) * (m - a_sc)
    c3 = neg * (-2.0) * p * (m - b_sc)
    c4 = (pos + neg) * 2.0 * p * (1.0 - p) * theta + \
         pos * 2.0 * (1.0 - p) * m - neg * 2.0 * p * m
    return jnp.stack([c1, c2, c3, c4], axis=1)


# ---- composed (L2-level) references ----------------------------------

def full_op_ridge_ref(a, y, z):
    """Unnormalized ridge operator direction: A^T (A z - y)."""
    return a.T @ (a @ z - y)


def full_op_logistic_ref(a, y, z):
    g = matvec_act_ref(a, z, y, "logistic")
    return a.T @ g


def auc_full_op_ref(a, y, z_aug, p):
    """Unnormalized mean AUC operator over the shard.

    z_aug = [w (d); a; b; theta].  Returns (d+3,) = [sum c1_i a_i;
    sum c2; sum c3; sum c4].
    """
    d = a.shape[1]
    w, scalars = z_aug[:d], jnp.concatenate([z_aug[d:], jnp.array([p], z_aug.dtype)])
    c = auc_coefs_ref(a, y, w, scalars)
    w_part = a.T @ c[:, 0]
    return jnp.concatenate([w_part, jnp.sum(c[:, 1:], axis=0)])
