"""Layer-1 Pallas kernels for the DSBA reproduction.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and are written f64 end-to-end so the Rust core and the AOT
artifacts agree to <=1e-10.

Kernels:
  - ``matvec_act``  : fused ``g = act(A @ z, y)`` — the coefficient kernel.
  - ``atg``         : ``A^T @ g`` accumulation (transposed matvec).
  - ``mix_step``    : fused gossip mixing ``Wt @ (2 Z - Z_prev)``.
  - ``auc_coefs``   : per-sample AUC saddle-operator scalar coefficients.
"""

from .coef import matvec_act
from .atg import atg
from .mixing import mix_step
from .auc import auc_coefs

__all__ = ["matvec_act", "atg", "mix_step", "auc_coefs"]
