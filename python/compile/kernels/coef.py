"""Fused coefficient kernel: ``g = act(A @ z, y)``.

This is the paper's per-pass compute hot-spot for linear predictors
(§7.1/§7.2): every operator evaluation is ``B_{n,i}(z) = g_i * a_i`` with a
*scalar* coefficient ``g_i`` that only depends on the margin
``m_i = a_i^T z``.  Batched over a node's whole shard this is one matvec
plus an elementwise epilogue, which we fuse so ``A`` is read from HBM once.

Activations:
  - ``"ridge"``    : ``g = m - y``                     (ridge residual)
  - ``"logistic"`` : ``g = -y / (1 + exp(y * m))``     (logistic grad coef)
  - ``"identity"`` : ``g = m``                         (raw scores / metrics)

Zero-padded rows (``a_i = 0, y_i = 0``) produce ``g_i = 0`` for every
activation, so the Rust runtime can pad shards up to the artifact's shape
bucket and divide by the *true* q afterwards.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_dims

ACTIVATIONS = ("ridge", "logistic", "identity")


def _epilogue(act: str, m, y):
    if act == "ridge":
        return m - y
    if act == "logistic":
        # -y / (1 + exp(y m)); stable for both signs of (y m) because the
        # exp argument is clipped by the sigmoid identity below.
        return -y * jax.nn.sigmoid(-y * m)
    if act == "identity":
        return m
    raise ValueError(f"unknown activation {act!r}")


def _kernel(act: str, n_d_blocks: int):
    def kernel(a_ref, z_ref, y_ref, o_ref):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += a_ref[...] @ z_ref[...]

        @pl.when(j == n_d_blocks - 1)
        def _fin():
            o_ref[...] = _epilogue(act, o_ref[...], y_ref[...])

    return kernel


def matvec_act(a, z, y, act: str = "ridge"):
    """``act(A @ z, y)`` as a Pallas kernel.

    Args:
      a: ``(q, d)`` shard of feature rows.
      z: ``(d,)`` iterate.
      y: ``(q,)`` labels/targets (ignored by ``"identity"``).
      act: one of ``ACTIVATIONS``.
    Returns:
      ``(q,)`` coefficient vector ``g``.
    """
    q, d = a.shape
    bq, bd, nq, nd = grid_dims(q, d)
    return pl.pallas_call(
        _kernel(act, nd),
        grid=(nq, nd),
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), a.dtype),
        interpret=True,
    )(a, z, y)
