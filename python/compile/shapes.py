"""Artifact shape manifest.

AOT compilation fixes shapes, so we emit each L2 function at a small set of
(q, d) *buckets*; the Rust runtime zero-pads a shard up to the smallest
bucket that fits (all exported functions are padding-neutral by
construction) and slices the result back.

Buckets are chosen to cover the three dataset profiles of §7 scaled to CI
size (see rust/src/data):  small unit-test instances, rcv1-like mid-size,
and sector/news20-like wide shards.  Every extent is a multiple of the
Pallas block targets so BlockSpecs tile exactly.
"""

from dataclasses import dataclass, field


QD_BUCKETS = [
    (256, 1024),
    (512, 4096),
    (256, 8192),   # rcv1-profile shard at N=10 (added in the perf pass:
                   # avoids 8x padding waste through the 1024x16384 bucket)
    (1024, 16384),
]

# mixing: N nodes (padded to 16) x d buckets
MIX_BUCKETS = [
    (16, 1024),
    (16, 4096),
    (16, 16384),
]

F64 = "f64"


@dataclass
class Entry:
    """One AOT artifact: function + concrete arg shapes."""
    name: str          # artifact stem, e.g. coefs_ridge_q256_d1024
    fn: str            # function name in model.py
    args: list = field(default_factory=list)  # [(shape tuple, dtype), ...]


def manifest():
    entries = []
    for q, d in QD_BUCKETS:
        tag = f"q{q}_d{d}"
        qd = ((q, d), F64)
        v_d = ((d,), F64)
        v_q = ((q,), F64)
        entries += [
            Entry(f"coefs_ridge_{tag}", "coefs_ridge", [qd, v_d, v_q]),
            Entry(f"coefs_logistic_{tag}", "coefs_logistic", [qd, v_d, v_q]),
            Entry(f"scores_{tag}", "scores", [qd, v_d]),
            Entry(f"full_op_ridge_{tag}", "full_op_ridge", [qd, v_d, v_q]),
            Entry(f"full_op_logistic_{tag}", "full_op_logistic", [qd, v_d, v_q]),
            Entry(f"auc_coef_table_{tag}", "auc_coef_table",
                  [qd, v_q, v_d, ((4,), F64)]),
            Entry(f"auc_full_op_{tag}", "auc_full_op",
                  [qd, v_q, ((d + 3,), F64), ((), F64)]),
            Entry(f"obj_ridge_{tag}", "obj_ridge", [qd, v_d, v_q]),
            Entry(f"obj_logistic_{tag}", "obj_logistic", [qd, v_d, v_q]),
        ]
    for n, d in MIX_BUCKETS:
        entries.append(
            Entry(f"mix_n{n}_d{d}", "mix",
                  [((n, n), F64), ((n, d), F64), ((n, d), F64)]))
    return entries
