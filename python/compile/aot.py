"""AOT lowering: JAX/Pallas → HLO *text* artifacts + manifest.json.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
the published ``xla`` crate links xla_extension 0.5.1, which rejects the
64-bit instruction ids jax>=0.5 writes into serialized HloModuleProto
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
A no-op rebuild is handled by the Makefile via file timestamps.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model          # noqa: E402
from .shapes import manifest  # noqa: E402

_DTYPES = {"f64": jnp.float64, "f32": jnp.float32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(entry):
    fn = getattr(model, entry.fn)
    specs = [jax.ShapeDtypeStruct(tuple(s), _DTYPES[dt]) for s, dt in entry.args]
    return jax.jit(fn).lower(*specs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names (debugging)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    man = {"format": "hlo-text", "dtype": "f64", "entries": []}
    entries = manifest()
    if args.only:
        entries = [e for e in entries if args.only in e.name]
    for i, e in enumerate(entries):
        lowered = lower_entry(e)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{e.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = [
            {"shape": list(a.shape), "dtype": str(a.dtype)}
            for a in jax.tree_util.tree_leaves(lowered.out_info)
        ]
        man["entries"].append({
            "name": e.name,
            "fn": e.fn,
            "file": f"{e.name}.hlo.txt",
            "args": [{"shape": list(s), "dtype": dt} for s, dt in e.args],
            "outputs": out_avals,
        })
        print(f"[{i + 1}/{len(entries)}] {e.name}: {len(text)} chars")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    print(f"wrote {len(man['entries'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
