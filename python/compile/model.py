"""Layer-2 JAX compute graphs (calling the Layer-1 Pallas kernels).

Each public function here becomes one family of AOT artifacts (one HLO per
shape bucket, see ``shapes.py``).  All outputs over shards are
*unnormalized sums* so zero-padding to a shape bucket is neutral; the Rust
runtime divides by the true ``q`` and adds the l2 term.

Semantics are pinned by ``kernels/ref.py`` and the pytest suite.
"""

import jax
import jax.numpy as jnp

from .kernels import matvec_act, atg, mix_step, auc_coefs

jax.config.update("jax_enable_x64", True)


# --- coefficient families (SAGA table init / per-pass batched eval) -----

def coefs_ridge(a, z, y):
    """(q,) ridge residual coefficients ``g_i = a_i^T z - y_i``."""
    return (matvec_act(a, z, y, "ridge"),)


def coefs_logistic(a, z, y):
    """(q,) logistic gradient coefficients ``-y_i sigmoid(-y_i m_i)``."""
    return (matvec_act(a, z, y, "logistic"),)


def scores(a, z):
    """(q,) raw margins ``A z`` (metrics: AUC ranking, residuals)."""
    y = jnp.zeros(a.shape[0], a.dtype)
    return (matvec_act(a, z, y, "identity"),)


# --- full local operator evaluations (deterministic baselines) ----------

def full_op_ridge(a, z, y):
    """(d,) unnormalized ``A^T (A z - y)``."""
    return (atg(a, matvec_act(a, z, y, "ridge")),)


def full_op_logistic(a, z, y):
    """(d,) unnormalized ``A^T g_logistic``."""
    return (atg(a, matvec_act(a, z, y, "logistic")),)


# --- AUC saddle operator (eqs. 75/76) ------------------------------------

def auc_coef_table(a, y, w, scalars):
    """(q, 4) per-sample AUC operator coefficients; scalars=[a,b,theta,p]."""
    return (auc_coefs(a, y, w, scalars),)


def auc_full_op(a, y, z_aug, p):
    """(d+3,) unnormalized mean AUC operator over the shard.

    ``z_aug = [w; a; b; theta]``, ``p`` a () scalar (positive ratio).
    """
    d = a.shape[1]
    w = z_aug[:d]
    scalars = jnp.concatenate([z_aug[d:], p[None]])
    c = auc_coefs(a, y, w, scalars)
    w_part = atg(a, c[:, 0])
    return (jnp.concatenate([w_part, jnp.sum(c[:, 1:], axis=0)]),)


# --- dense gossip mixing (update (24) dense half) ------------------------

def mix(w, z, z_prev):
    """(N, d) fused ``W (2Z - Z_prev)``."""
    return (mix_step(w, z, z_prev),)


# --- objective evaluation (metrics path) ---------------------------------

def obj_ridge(a, z, y):
    """() unnormalized ``0.5 ||A z - y||^2``."""
    r = matvec_act(a, z, y, "ridge")
    return (0.5 * jnp.sum(r * r),)


def obj_logistic(a, z, y):
    """() unnormalized ``sum log(1 + exp(-y m))`` (softplus-stable).

    Masked by ``|y|`` so zero-padded rows (y=0, which would contribute
    ``softplus(0) = log 2`` each) stay neutral.
    """
    m = matvec_act(a, z, jnp.zeros(a.shape[0], a.dtype), "identity")
    return (jnp.sum(jnp.abs(y) * jax.nn.softplus(-y * m)),)
