"""L2 model graphs vs composed references + padding-neutrality.

These pin the exact semantics the Rust runtime (rust/src/runtime) assumes
of every artifact family: unnormalized sums, pad-neutral, 1-tuple outputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _mk(seed, q=24, d=40):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.normal(keys[0], (q, d), dtype=jnp.float64)
    z = jax.random.normal(keys[1], (d,), dtype=jnp.float64)
    y = jnp.sign(jax.random.normal(keys[2], (q,), dtype=jnp.float64))
    return a, z, y


@settings(max_examples=15, deadline=None)
@given(seed=SEEDS)
def test_full_op_ridge(seed):
    a, z, y = _mk(seed)
    (got,) = model.full_op_ridge(a, z, y)
    np.testing.assert_allclose(
        got, ref.full_op_ridge_ref(a, y, z), rtol=1e-10, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(seed=SEEDS)
def test_full_op_logistic(seed):
    a, z, y = _mk(seed)
    (got,) = model.full_op_logistic(a, z, y)
    np.testing.assert_allclose(
        got, ref.full_op_logistic_ref(a, y, z), rtol=1e-10, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(seed=SEEDS, p=st.floats(min_value=0.1, max_value=0.9))
def test_auc_full_op(seed, p):
    a, _, y = _mk(seed)
    d = a.shape[1]
    z_aug = jax.random.normal(jax.random.PRNGKey(seed + 1), (d + 3,),
                              dtype=jnp.float64)
    (got,) = model.auc_full_op(a, y, z_aug, jnp.float64(p))
    want = ref.auc_full_op_ref(a, y, z_aug, p)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_objectives():
    a, z, y = _mk(0)
    (o_r,) = model.obj_ridge(a, z, y)
    np.testing.assert_allclose(
        o_r, 0.5 * jnp.sum((a @ z - y) ** 2), rtol=1e-12)
    (o_l,) = model.obj_logistic(a, z, y)
    np.testing.assert_allclose(
        o_l, jnp.sum(jnp.log1p(jnp.exp(-y * (a @ z)))), rtol=1e-10)


def test_padding_neutrality_everywhere():
    """Zero rows (and zero labels) leave every exported sum unchanged —
    the contract the Rust shape-bucket padding relies on."""
    a, z, y = _mk(42, q=16, d=24)
    ap = jnp.concatenate([a, jnp.zeros((16, 24))])
    yp = jnp.concatenate([y, jnp.zeros(16)])

    for fn, args, args_p in [
        (model.full_op_ridge, (a, z, y), (ap, z, yp)),
        (model.full_op_logistic, (a, z, y), (ap, z, yp)),
        (model.coefs_ridge, (a, z, y), (ap, z, yp)),
        (model.obj_ridge, (a, z, y), (ap, z, yp)),
        (model.obj_logistic, (a, z, y), (ap, z, yp)),
    ]:
        (base,) = fn(*args)
        (pad,) = fn(*args_p)
        if pad.ndim == 1 and pad.shape[0] == 32:  # per-sample outputs
            np.testing.assert_allclose(pad[:16], base, rtol=1e-12, atol=1e-12)
            np.testing.assert_allclose(pad[16:], 0.0, atol=1e-14)
        else:
            np.testing.assert_allclose(pad, base, rtol=1e-12, atol=1e-12)

    z_aug = jnp.concatenate([z, jnp.array([0.1, -0.2, 0.3])])
    (base,) = model.auc_full_op(a, y, z_aug, jnp.float64(0.4))
    (pad,) = model.auc_full_op(ap, yp, z_aug, jnp.float64(0.4))
    np.testing.assert_allclose(pad, base, rtol=1e-12, atol=1e-12)


def test_padding_d_dimension():
    """Zero-padding feature columns embeds the problem losslessly."""
    a, z, y = _mk(11, q=16, d=24)
    ap = jnp.concatenate([a, jnp.zeros((16, 8))], axis=1)
    zp = jnp.concatenate([z, jnp.zeros(8)])
    (base,) = model.full_op_ridge(a, z, y)
    (pad,) = model.full_op_ridge(ap, zp, y)
    np.testing.assert_allclose(pad[:24], base, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(pad[24:], 0.0, atol=1e-14)
