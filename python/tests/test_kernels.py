"""Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

Hypothesis sweeps shapes (block-aligned and ragged-divisor), dtypes and
seeds; every kernel must match ref to tight f64 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec_act, atg, mix_step, auc_coefs
from compile.kernels import ref
from compile.kernels.coef import ACTIVATIONS

jax.config.update("jax_enable_x64", True)

DIMS_Q = st.sampled_from([1, 2, 3, 8, 24, 256, 300])
DIMS_D = st.sampled_from([1, 2, 5, 16, 512, 640, 1024])
DTYPES = st.sampled_from([jnp.float64, jnp.float32])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=dtype)


def _tol(dtype):
    return dict(rtol=1e-10, atol=1e-10) if dtype == jnp.float64 \
        else dict(rtol=2e-4, atol=2e-4)


class TestMatvecAct:
    @settings(max_examples=25, deadline=None)
    @given(q=DIMS_Q, d=DIMS_D, dtype=DTYPES, seed=SEEDS,
           act=st.sampled_from(ACTIVATIONS))
    def test_matches_ref(self, q, d, dtype, seed, act):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        a = _rand(k1, (q, d), dtype)
        z = _rand(k2, (d,), dtype)
        y = jnp.sign(_rand(k3, (q,), dtype)) if act == "logistic" \
            else _rand(k3, (q,), dtype)
        got = matvec_act(a, z, y, act)
        want = ref.matvec_act_ref(a, z, y, act)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_zero_pad_rows_are_neutral(self):
        key = jax.random.PRNGKey(0)
        a = _rand(key, (8, 16), jnp.float64)
        z = _rand(jax.random.PRNGKey(1), (16,), jnp.float64)
        y = jnp.sign(_rand(jax.random.PRNGKey(2), (8,), jnp.float64))
        a_pad = jnp.concatenate([a, jnp.zeros((8, 16))])
        y_pad = jnp.concatenate([y, jnp.zeros(8)])
        for act in ACTIVATIONS:
            g = matvec_act(a_pad, z, y_pad, act)
            np.testing.assert_allclose(g[8:], 0.0, atol=1e-14)
            np.testing.assert_allclose(
                g[:8], ref.matvec_act_ref(a, z, y, act), rtol=1e-12)

    def test_unknown_activation_raises(self):
        with pytest.raises(ValueError):
            matvec_act(jnp.zeros((2, 2)), jnp.zeros(2), jnp.zeros(2), "huh")

    def test_logistic_extreme_margins_stable(self):
        # huge |margin| must not overflow exp
        a = jnp.array([[1000.0], [-1000.0]])
        z = jnp.array([1.0])
        y = jnp.array([1.0, 1.0])
        g = matvec_act(a, z, y, "logistic")
        assert np.all(np.isfinite(np.asarray(g)))
        np.testing.assert_allclose(g[0], 0.0, atol=1e-12)
        np.testing.assert_allclose(g[1], -1.0, rtol=1e-12)


class TestAtg:
    @settings(max_examples=25, deadline=None)
    @given(q=DIMS_Q, d=DIMS_D, dtype=DTYPES, seed=SEEDS)
    def test_matches_ref(self, q, d, dtype, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        a = _rand(k1, (q, d), dtype)
        g = _rand(k2, (q,), dtype)
        np.testing.assert_allclose(atg(a, g), ref.atg_ref(a, g), **_tol(dtype))

    def test_linear_in_g(self):
        key = jax.random.PRNGKey(7)
        a = _rand(key, (32, 48), jnp.float64)
        g1 = _rand(jax.random.PRNGKey(8), (32,), jnp.float64)
        g2 = _rand(jax.random.PRNGKey(9), (32,), jnp.float64)
        lhs = atg(a, 2.0 * g1 - 3.0 * g2)
        rhs = 2.0 * atg(a, g1) - 3.0 * atg(a, g2)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-10, atol=1e-10)


class TestMixStep:
    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([1, 2, 4, 10, 16]), d=DIMS_D,
           dtype=DTYPES, seed=SEEDS)
    def test_matches_ref(self, n, d, dtype, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        w = _rand(k1, (n, n), dtype)
        z = _rand(k2, (n, d), dtype)
        zp = _rand(k3, (n, d), dtype)
        np.testing.assert_allclose(
            mix_step(w, z, zp), ref.mix_step_ref(w, z, zp), **_tol(dtype))

    def test_identity_mixing_is_extrapolation(self):
        n, d = 4, 32
        z = _rand(jax.random.PRNGKey(0), (n, d), jnp.float64)
        zp = _rand(jax.random.PRNGKey(1), (n, d), jnp.float64)
        got = mix_step(jnp.eye(n), z, zp)
        np.testing.assert_allclose(got, 2 * z - zp, rtol=1e-14)


class TestAucCoefs:
    @settings(max_examples=25, deadline=None)
    @given(q=DIMS_Q, d=DIMS_D, dtype=DTYPES, seed=SEEDS,
           p=st.floats(min_value=0.05, max_value=0.95))
    def test_matches_ref(self, q, d, dtype, seed, p):
        keys = jax.random.split(jax.random.PRNGKey(seed), 4)
        a = _rand(keys[0], (q, d), dtype)
        y = jnp.sign(_rand(keys[1], (q,), dtype))
        w = _rand(keys[2], (d,), dtype)
        scalars = jnp.array(
            [0.3, -0.2, 0.1, p], dtype=dtype)
        got = auc_coefs(a, y, w, scalars)
        want = ref.auc_coefs_ref(a, y, w, scalars)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_pad_labels_zero_out(self):
        q, d = 8, 16
        a = _rand(jax.random.PRNGKey(3), (q, d), jnp.float64)
        w = _rand(jax.random.PRNGKey(4), (d,), jnp.float64)
        y = jnp.zeros(q)
        scalars = jnp.array([0.5, 0.5, 0.5, 0.3])
        c = auc_coefs(a, y, w, scalars)
        # pad rows must contribute 0 to every block of the operator
        np.testing.assert_allclose(c, 0.0, atol=1e-14)

    def test_positive_sample_has_zero_b_component(self):
        q, d = 4, 8
        a = _rand(jax.random.PRNGKey(5), (q, d), jnp.float64)
        w = _rand(jax.random.PRNGKey(6), (d,), jnp.float64)
        c = auc_coefs(a, jnp.ones(q), w, jnp.array([0.1, 0.2, 0.3, 0.4]))
        np.testing.assert_allclose(c[:, 2], 0.0, atol=1e-14)

    def test_negative_sample_has_zero_a_component(self):
        q, d = 4, 8
        a = _rand(jax.random.PRNGKey(5), (q, d), jnp.float64)
        w = _rand(jax.random.PRNGKey(6), (d,), jnp.float64)
        c = auc_coefs(a, -jnp.ones(q), w, jnp.array([0.1, 0.2, 0.3, 0.4]))
        np.testing.assert_allclose(c[:, 1], 0.0, atol=1e-14)
