//! XLA/PJRT runtime integration: every artifact family must agree with
//! the pure-Rust operator implementations to f64 precision, through the
//! shape-bucket padding path. Requires `make artifacts` (skips cleanly
//! with a message when artifacts are absent).

use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::runtime::XlaRuntime;

fn runtime_or_skip() -> Option<XlaRuntime> {
    match XlaRuntime::load_default() {
        Ok(rt) if rt.has_backend() => Some(rt),
        Ok(_) => {
            eprintln!(
                "SKIP runtime_xla tests: artifacts present but the PJRT \
                 backend is not compiled in (build with --features pjrt)"
            );
            None
        }
        Err(e) => {
            eprintln!("SKIP runtime_xla tests: {e}");
            None
        }
    }
}

fn world() -> (dsba::data::Dataset, Partition) {
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(300)
        .with_dim(900) // forces padding into the (256..512, 1024..) buckets
        .generate(55);
    let part = ds.partition_seeded(2, 3);
    (ds, part)
}

#[test]
fn ridge_full_op_matches_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let (_, part) = world();
    let p = RidgeProblem::new(part, 0.0);
    let mut rng = Rng::new(9);
    let z: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
    for n in 0..p.nodes() {
        let shard = &p.partition().shards[n];
        let xla = rt
            .full_op_ridge(shard, &z, &p.partition().labels[n])
            .expect("xla exec");
        let mut rust = vec![0.0; p.dim()];
        p.full_raw_mean(n, &z, &mut rust);
        let err = xla
            .iter()
            .zip(&rust)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9, "node {n}: max err {err}");
    }
}

#[test]
fn logistic_coefs_and_full_op_match_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(200)
        .with_dim(700)
        .generate(56);
    let part = ds.partition_seeded(2, 3);
    let p = LogisticProblem::new(part, 0.0);
    let mut rng = Rng::new(10);
    let z: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
    let shard = &p.partition().shards[0];
    let y = &p.partition().labels[0];
    let coefs = rt.coefs_logistic(shard, &z, y).unwrap();
    let mut want = vec![0.0; 1];
    for i in 0..p.q() {
        p.coefs(0, i, &z, &mut want);
        assert!((coefs[i] - want[0]).abs() < 1e-10, "coef {i}");
    }
    let full = rt.full_op_logistic(shard, &z, y).unwrap();
    let mut rust = vec![0.0; p.dim()];
    p.full_raw_mean(0, &z, &mut rust);
    for (a, b) in full.iter().zip(&rust) {
        assert!((a - b).abs() < 1e-10);
    }
}

#[test]
fn auc_full_op_matches_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(200)
        .with_dim(600)
        .generate(57);
    let part = ds.partition_seeded(2, 3);
    let p = AucProblem::new(part, 0.0);
    let mut rng = Rng::new(11);
    let z: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
    let shard = &p.partition().shards[1];
    let y = &p.partition().labels[1];
    let xla = rt.auc_full_op(shard, y, &z, p.p).unwrap();
    let mut rust = vec![0.0; p.dim()];
    p.full_raw_mean(1, &z, &mut rust);
    let err = xla
        .iter()
        .zip(&rust)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    assert!(err < 1e-9, "max err {err}");
}

#[test]
fn mix_step_matches_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let topo = Topology::erdos_renyi(10, 0.4, 42);
    let mix = MixingMatrix::laplacian(&topo, 1.0);
    let d = 800;
    let mut rng = Rng::new(12);
    let z: Vec<Vec<f64>> =
        (0..10).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
    let zp: Vec<Vec<f64>> =
        (0..10).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
    let xla = rt.mix_step(&mix.wt, &z, &zp).unwrap();
    for n in 0..10 {
        let mut want = vec![0.0; d];
        mix.mix_row(n, &topo, &z, &zp, &mut want);
        for (a, b) in xla[n].iter().zip(&want) {
            assert!((a - b).abs() < 1e-10, "node {n}");
        }
    }
}

#[test]
fn objectives_match_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let (_, part) = world();
    let q = part.q;
    let ridge = RidgeProblem::new(part, 0.0);
    let mut rng = Rng::new(13);
    let z: Vec<f64> = (0..ridge.dim()).map(|_| 0.2 * rng.normal()).collect();
    // sum over shards of xla objective == rust objective (lambda = 0)
    let mut total = 0.0;
    for n in 0..ridge.nodes() {
        total += rt
            .obj_ridge(&ridge.partition().shards[n], &z, &ridge.partition().labels[n])
            .unwrap()
            / q as f64;
    }
    let want = ridge.objective(&z).unwrap();
    assert!((total - want).abs() < 1e-8 * (1.0 + want.abs()), "{total} vs {want}");
}

#[test]
fn scores_match_row_dots() {
    let Some(rt) = runtime_or_skip() else { return };
    let (_, part) = world();
    let shard = &part.shards[0];
    let mut rng = Rng::new(14);
    let z: Vec<f64> = (0..part.dim).map(|_| rng.normal()).collect();
    let scores = rt.scores(shard, &z).unwrap();
    for i in 0..shard.rows {
        assert!((scores[i] - shard.row_dot(i, &z)).abs() < 1e-10);
    }
}
