//! Bounded-staleness async-clock suite for the parallel engine.
//!
//! Three contracts pin the `--mode async:TAU` round clock:
//!
//! 1. **`async:0` is sync.** With a zero staleness window the admission
//!    rule degenerates to the barrier schedule, so every method's
//!    iterates, message counts and per-node DOUBLE accounting must be
//!    **bit-for-bit** equal to the sequential oracle — on both
//!    transports, dense gossip and the sparse relay alike.
//! 2. **Small windows still converge.** Under `tau ∈ {1, 2}` the
//!    residual to the reference optimum keeps shrinking on logistic and
//!    elastic-net (geometric envelope, same shape as the lossy
//!    compression suite), and the consumed staleness never exceeds
//!    `tau`.
//! 3. **`DSBA_ASYNC_TRACE` makes async replayable.** The trace
//!    scheduler pins a fixed per-edge staleness, so two identical runs
//!    are bit-identical round by round — the debugging story for a
//!    nondeterministic clock.
//!
//! Plus the straggler satellite: with `--fault delay:150@0` slowing one
//! node (the typed successor of the deprecated `DSBA_INJECT_DELAY_MS`
//! env alias), the sync clock drags everyone down to the straggler's
//! pace (progress watermarks never spread beyond one round) while
//! `async:2` lets the fast nodes run visibly ahead.
//!
//! The `DSBA_ASYNC_TRACE` env knob is read once at engine construction;
//! every test that touches it — or whose engine construction must NOT
//! see it — serializes on [`ENV_LOCK`] because cargo runs this binary's
//! tests on parallel threads.

use dsba::algorithms::{build, AlgoParams, AlgorithmKind};
use dsba::comm::CompressionSpec;
use dsba::operators::{ProblemRegistry, ProblemSpec};
use dsba::prelude::*;
use dsba::runtime::transport::{LocalTransport, Transport};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn ridge_world(nodes: usize, seed: u64) -> Arc<dyn Problem> {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(seed);
    Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 3), 0.05))
}

fn logistic_world(nodes: usize) -> Arc<dyn Problem> {
    let entry = ProblemRegistry::builtin()
        .resolve("logistic")
        .expect("logistic is registered");
    let ds = SyntheticSpec::tiny().generate(31);
    let spec = ProblemSpec::new("logistic", 0.05);
    entry
        .build(&spec, &ds, ds.partition_seeded(nodes, 3))
        .expect("registry builds logistic")
}

fn elastic_world(nodes: usize) -> Arc<dyn Problem> {
    use dsba::util::json::Json;
    let ds = SyntheticSpec::tiny().with_regression(true).generate(23);
    let entry = ProblemRegistry::builtin()
        .resolve("elastic-net")
        .expect("elastic-net is registered");
    let spec = ProblemSpec::new("elastic-net", 0.05)
        .with_params(Json::from_pairs(vec![("l1", Json::Num(0.02))]));
    entry
        .build(&spec, &ds, ds.partition_seeded(nodes, 3))
        .expect("registry builds elastic-net")
}

#[derive(Clone, Copy, PartialEq)]
enum Backend {
    Local,
    Tcp,
}

fn engine_with_mode(
    kind: AlgorithmKind,
    p: Arc<dyn Problem>,
    mix: &MixingMatrix,
    topo: &Topology,
    params: &AlgoParams,
    threads: usize,
    backend: Backend,
    mode: ModeSpec,
) -> ParallelEngine {
    let transport: Box<dyn Transport> = match backend {
        Backend::Local => Box::new(LocalTransport::new(topo.n)),
        Backend::Tcp => Box::new(
            TcpTransport::loopback(topo, params.seed).expect("loopback transport setup"),
        ),
    };
    ParallelEngine::new_full_mode(
        kind,
        p,
        mix,
        topo,
        params,
        threads,
        transport,
        &CompressionSpec::None,
        mode,
    )
}

/// Contract 1: `--mode async:0` is the sync schedule. Every dense-gossip
/// method plus the sparse relay, over both transports, must match the
/// sequential oracle bit-for-bit — iterates, message counts, per-node
/// sent/received DOUBLEs — with zero consumed staleness.
#[test]
fn async_zero_matches_sequential_bit_for_bit() {
    for backend in [Backend::Local, Backend::Tcp] {
        for kind in [
            AlgorithmKind::Dgd,
            AlgorithmKind::Extra,
            AlgorithmKind::Dsa,
            AlgorithmKind::Dsba,
            AlgorithmKind::Dlm,
            AlgorithmKind::DsbaSparse,
        ] {
            let topo = Topology::ring(6);
            let p = ridge_world(6, 17);
            let mix = MixingMatrix::laplacian(&topo, 1.0);
            let mut params = AlgoParams::new(0.25, p.dim(), 99);
            params.inner_tol = 1e-11;
            let mut seq = build(kind, p.clone(), &mix, &topo, &params);
            let mut par = engine_with_mode(
                kind,
                p.clone(),
                &mix,
                &topo,
                &params,
                3,
                backend,
                ModeSpec::Async(0),
            );
            assert_eq!(par.mode(), ModeSpec::Async(0));
            let mut net_s = Network::new(topo.clone(), CommCostModel::default());
            let mut net_p = Network::new(topo.clone(), CommCostModel::default());
            let rounds = if backend == Backend::Tcp { 12 } else { 30 };
            for round in 0..rounds {
                seq.step(&mut net_s);
                par.step(&mut net_p);
                for n in 0..topo.n {
                    assert_eq!(
                        seq.iterates()[n],
                        par.iterates()[n],
                        "{} async:0 round {round} node {n}: iterate != sequential",
                        kind.name()
                    );
                }
                assert_eq!(
                    net_s.messages(),
                    net_p.messages(),
                    "{} async:0 round {round}: message counts diverged",
                    kind.name()
                );
                for n in 0..topo.n {
                    assert_eq!(net_s.sent_by(n), net_p.sent_by(n));
                    assert_eq!(net_s.received_by(n), net_p.received_by(n));
                }
            }
            assert_eq!(seq.passes(), par.passes(), "{}: passes diverged", kind.name());
            let (sent, delivered) = par.message_stats();
            assert_eq!(sent, delivered, "{}: engine dropped messages", kind.name());
            let (max_staleness, _) = par.staleness_stats();
            assert_eq!(
                max_staleness, 0,
                "{}: async:0 must never consume stale iterates",
                kind.name()
            );
        }
    }
}

/// Contract 2: bounded staleness still converges. Under the replayable
/// trace scheduler, DSBA with `tau ∈ {1, 2}` keeps shrinking the
/// residual to the reference optimum on both the smooth (logistic) and
/// proximal (elastic-net) workloads, and the engine never consumes an
/// iterate staler than `tau` rounds.
#[test]
fn async_small_tau_converges_within_envelope() {
    let _guard = env_guard();
    std::env::set_var("DSBA_ASYNC_TRACE", "1");
    let worlds: [&dyn Fn(usize) -> Arc<dyn Problem>; 2] = [&elastic_world, &logistic_world];
    for world in worlds {
        let topo = Topology::ring(4);
        let p = world(topo.n);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let mut params = AlgoParams::new(0.25, p.dim(), 99);
        params.inner_tol = 1e-11;
        let z_star = dsba::coordinator::solve_optimum(p.as_ref(), 1e-11);
        let (rounds, early) = (240usize, 24usize);
        for tau in [1u32, 2] {
            let mut eng = engine_with_mode(
                AlgorithmKind::Dsba,
                p.clone(),
                &mix,
                &topo,
                &params,
                2,
                Backend::Local,
                ModeSpec::Async(tau),
            );
            let mut net = Network::new(topo.clone(), CommCostModel::default());
            let mut res_early = f64::NAN;
            for r in 0..rounds {
                eng.step(&mut net);
                if r + 1 == early {
                    res_early = dsba::metrics::suboptimality(eng.iterates(), &z_star);
                }
            }
            let res_final = dsba::metrics::suboptimality(eng.iterates(), &z_star);
            assert!(
                res_final.is_finite() && res_final <= 0.5 * res_early,
                "async:{tau}: residual {res_early:.3e} (round {early}) -> \
                 {res_final:.3e} (round {rounds}) did not keep decreasing"
            );
            let (sent, delivered) = eng.message_stats();
            assert_eq!(sent, delivered, "async:{tau} dropped messages");
            let (max_staleness, _) = eng.staleness_stats();
            assert!(
                max_staleness <= tau as u64,
                "async:{tau} consumed staleness {max_staleness} > window"
            );
        }
    }
    std::env::remove_var("DSBA_ASYNC_TRACE");
}

/// Contract 3: with `DSBA_ASYNC_TRACE` set, the async clock is a fixed
/// deterministic schedule — two identical runs produce bit-identical
/// iterates every round and identical message accounting, on both
/// transports. (Without the trace env the interleaving is real-time and
/// run-to-run results may differ; with it, async bugs replay.)
#[test]
fn async_trace_mode_is_replayable() {
    let _guard = env_guard();
    std::env::set_var("DSBA_ASYNC_TRACE", "1");
    for backend in [Backend::Local, Backend::Tcp] {
        let rounds = if backend == Backend::Tcp { 16 } else { 50 };
        let topo = Topology::erdos_renyi(5, 0.6, 7);
        let p = ridge_world(5, 17);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let mut params = AlgoParams::new(0.25, p.dim(), 99);
        params.inner_tol = 1e-11;
        let run = || {
            let mut eng = engine_with_mode(
                AlgorithmKind::Dsba,
                p.clone(),
                &mix,
                &topo,
                &params,
                2,
                backend,
                ModeSpec::Async(2),
            );
            let mut net = Network::new(topo.clone(), CommCostModel::default());
            let mut trail: Vec<Vec<Vec<f64>>> = Vec::with_capacity(rounds);
            for _ in 0..rounds {
                eng.step(&mut net);
                trail.push(eng.iterates().to_vec());
            }
            (trail, net.messages(), eng.staleness_stats().0)
        };
        let (trail_a, msgs_a, stale_a) = run();
        let (trail_b, msgs_b, stale_b) = run();
        for (round, (a, b)) in trail_a.iter().zip(trail_b.iter()).enumerate() {
            assert_eq!(
                a, b,
                "trace-mode async runs diverged at round {round} ({} transport)",
                if backend == Backend::Tcp { "tcp" } else { "local" }
            );
        }
        assert_eq!(msgs_a, msgs_b, "trace-mode message accounting diverged");
        assert_eq!(stale_a, stale_b, "trace-mode staleness diverged");
    }
    std::env::remove_var("DSBA_ASYNC_TRACE");
}

/// Straggler satellite: run a ring with node 0 slowed by
/// `--fault delay:150@0`, sampling the per-node progress watermarks
/// from outside the engine while a background thread steps it. Returns
/// the sampled watermark vectors.
fn run_with_straggler(mode: ModeSpec, rounds: usize) -> Vec<Vec<u64>> {
    let done = Arc::new(AtomicBool::new(false));
    let done2 = done.clone();
    let (ptx, prx) = std::sync::mpsc::channel();
    let stepper = std::thread::spawn(move || {
        let topo = Topology::ring(4);
        let p = ridge_world(4, 17);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(0.25, p.dim(), 99);
        let mut eng = ParallelEngine::new_faulted(
            AlgorithmKind::Dsba,
            p,
            &mix,
            &topo,
            &params,
            4,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::None,
            mode,
            &FaultSpec::parse("delay:150@0").expect("valid delay fault"),
            &dsba::telemetry::TelemetrySpec::disabled(),
        )
        .expect("delay-faulted engine builds");
        ptx.send(eng.progress_probe()).expect("probe handoff");
        let mut net = Network::new(topo.clone(), CommCostModel::default());
        for _ in 0..rounds {
            eng.step(&mut net);
        }
        done2.store(true, Ordering::SeqCst);
    });
    let probe = prx
        .recv_timeout(Duration::from_secs(30))
        .expect("engine construction stalled");
    let mut samples = Vec::new();
    let mut spins = 0usize;
    while !done.load(Ordering::SeqCst) {
        samples.push(probe.completed_rounds());
        std::thread::sleep(Duration::from_millis(10));
        spins += 1;
        assert!(spins < 6_000, "straggler run did not finish within 60s");
    }
    stepper.join().expect("stepper thread panicked");
    samples.push(probe.completed_rounds());
    samples
}

/// With one injected straggler, the sync barrier clock holds every node
/// within one round of the slowest (each sample's watermark spread is at
/// most 1), while `async:2` lets the fast nodes run ahead: some sample
/// shows a spread of at least 2 rounds with the delayed node strictly
/// last. The final watermarks agree in both modes — async changes the
/// schedule, not the amount of work. (The guard keeps concurrent tests
/// from flipping `DSBA_ASYNC_TRACE` under this timing-sensitive run.)
#[test]
fn injected_straggler_stalls_sync_but_not_async() {
    let _guard = env_guard();
    let rounds = 6usize;

    let sync_samples = run_with_straggler(ModeSpec::Sync, rounds);
    for (i, s) in sync_samples.iter().enumerate() {
        let (min, max) = (s.iter().min().unwrap(), s.iter().max().unwrap());
        assert!(
            max - min <= 1,
            "sync sample {i}: watermarks {s:?} spread beyond the barrier"
        );
    }
    assert_eq!(
        sync_samples.last().unwrap(),
        &vec![rounds as u64; 4],
        "sync run must finish every round on every node"
    );

    let async_samples = run_with_straggler(ModeSpec::Async(2), rounds);
    let ran_ahead = async_samples.iter().any(|s| {
        let (min, max) = (*s.iter().min().unwrap(), *s.iter().max().unwrap());
        max - min >= 2 && s[0] == min && s.iter().skip(1).all(|&w| w > min)
    });
    assert!(
        ran_ahead,
        "async:2 never ran ahead of the straggler; samples: {async_samples:?}"
    );
    // fast nodes may legitimately sit past `rounds` (the launcher lets
    // them run up to `tau` rounds ahead of the last sampled round), but
    // nobody may stop short of it
    assert!(
        async_samples.last().unwrap().iter().all(|&w| w >= rounds as u64),
        "async run left a node short of round {rounds}: {:?}",
        async_samples.last().unwrap()
    );
}

/// The async clock plugs into the builder/coordinator stack end to end:
/// a parallel `async:1` experiment on the trace scheduler runs to
/// completion, reports finite suboptimality, and surfaces the staleness
/// metrics columns.
#[test]
fn builder_runs_async_end_to_end_with_metrics() {
    let _guard = env_guard();
    std::env::set_var("DSBA_ASYNC_TRACE", "1");
    let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
    let topo = Topology::ring(4);
    let mut exp = Experiment::builder(
        RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
        topo,
        AlgorithmKind::Dsba,
    )
    .step_size(0.25)
    .passes(6.0)
    .record_points(6)
    .engine(EngineSpec::parallel(2).with_mode(ModeSpec::Async(1)))
    .build();
    let trace = exp.try_run().expect("async experiment runs");
    let last = trace.rows.last().expect("trace has rows");
    assert!(last.suboptimality.is_finite());
    assert!(
        last.max_staleness <= 1,
        "async:1 reported staleness {} > window",
        last.max_staleness
    );
    std::env::remove_var("DSBA_ASYNC_TRACE");
}
