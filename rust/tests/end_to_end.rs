//! Scaled-down end-to-end smoke of the full system (the example
//! `examples/end_to_end.rs` is the full-size run recorded in
//! EXPERIMENTS.md): data -> partition -> graph -> algorithm -> metrics,
//! with the XLA artifact path cross-checked when artifacts exist.

use dsba::algorithms::AlgorithmKind;
use dsba::config::ExperimentConfig;
use dsba::coordinator::Experiment;
use dsba::prelude::*;
use std::sync::Arc;

#[test]
fn full_stack_ridge_through_config() {
    let cfg = ExperimentConfig {
        problem: "ridge".into(),
        dataset: "rcv1-like".into(),
        samples: 400,
        dim: 1024,
        nodes: 10,
        algorithm: AlgorithmKind::Dsba,
        lambda: 1e-3,
        alpha: 2.0,
        passes: 70.0,
        seed: 7,
        ..Default::default()
    };
    let mut exp = cfg.build().expect("config builds");
    let trace = exp.run();
    assert!(
        trace.last_suboptimality() < 1e-5,
        "suboptimality {:.3e}",
        trace.last_suboptimality()
    );
    // communication grew linearly with rounds (dense method)
    let first = &trace.rows[1];
    let last = trace.rows.last().unwrap();
    assert!(last.comm_doubles > first.comm_doubles);
}

#[test]
fn full_stack_dsba_s_and_xla_cross_check() {
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(300)
        .with_dim(900)
        .with_regression(true)
        .generate(21);
    let part = ds.partition(6);
    let lam = 1e-3;
    let problem = Arc::new(RidgeProblem::new(part, lam));
    let topo = Topology::erdos_renyi(6, 0.4, 5);

    // XLA path must agree with the trait path when artifacts exist and
    // the PJRT backend is compiled in (feature `pjrt`)
    if let Ok(rt) = dsba::runtime::XlaRuntime::load_default() {
        if rt.has_backend() {
            let mut rng = Rng::new(3);
            let z: Vec<f64> = (0..problem.dim()).map(|_| rng.normal()).collect();
            for n in 0..problem.nodes() {
                let xla = rt
                    .full_op_ridge(
                        &problem.partition().shards[n],
                        &z,
                        &problem.partition().labels[n],
                    )
                    .unwrap();
                let mut rust = vec![0.0; problem.dim()];
                problem.full_raw_mean(n, &z, &mut rust);
                for (a, b) in xla.iter().zip(&rust) {
                    assert!((a - b).abs() < 1e-8);
                }
            }
        }
    }

    let mut exp = Experiment::builder_from_arc(problem, topo, AlgorithmKind::DsbaSparse)
        .step_size(2.0)
        .passes(30.0)
        .build();
    let trace = exp.run();
    assert!(
        trace.last_suboptimality() < 1e-4,
        "{:.3e}",
        trace.last_suboptimality()
    );
}

#[test]
fn full_stack_elastic_net_through_registry() {
    // registry-built workload end to end: DSBA's proximal backward must
    // drive the l1-aware suboptimality down against the KKT reference
    // optimum, with zero changes to algorithms/runtime/comm
    let cfg = ExperimentConfig {
        problem: "elastic-net".into(),
        problem_params: dsba::util::json::parse("{\"l1\": 0.001}").unwrap(),
        dataset: "rcv1-like".into(),
        samples: 400,
        dim: 1024,
        nodes: 10,
        algorithm: AlgorithmKind::Dsba,
        lambda: 1e-3,
        alpha: 2.0,
        passes: 70.0,
        seed: 7,
        ..Default::default()
    };
    let mut exp = cfg.build().expect("registry config builds");
    let trace = exp.run();
    assert!(
        trace.last_suboptimality() < 1e-4,
        "suboptimality {:.3e}",
        trace.last_suboptimality()
    );
    // the reference optimum of a real l1 problem carries exact zeros
    assert!(
        trace.z_star.iter().any(|&v| v == 0.0),
        "elastic-net z* should be sparse"
    );
}

#[test]
fn full_stack_smoothed_hinge_through_registry() {
    let cfg = ExperimentConfig {
        problem: "smoothed-hinge".into(),
        dataset: "rcv1-like".into(),
        samples: 400,
        dim: 1024,
        nodes: 10,
        algorithm: AlgorithmKind::Dsba,
        lambda: 1e-2,
        alpha: 1.0,
        passes: 70.0,
        seed: 11,
        ..Default::default()
    };
    let mut exp = cfg.build().expect("registry config builds");
    let trace = exp.run();
    assert!(
        trace.last_suboptimality() < 1e-3,
        "suboptimality {:.3e}",
        trace.last_suboptimality()
    );
    // hinge objective at the final averaged iterate beats the zero model
    let last = trace.rows.last().unwrap();
    assert!(last.objective < trace.rows[0].objective, "objective did not improve");
}

#[test]
fn full_stack_auc_reaches_good_ranking() {
    let cfg = ExperimentConfig {
        problem: "auc".into(),
        dataset: "sector-like".into(),
        samples: 400,
        dim: 1024,
        nodes: 5,
        algorithm: AlgorithmKind::Dsba,
        alpha: 0.5,
        passes: 15.0,
        seed: 9,
        ..Default::default()
    };
    let mut exp = cfg.build().unwrap();
    let trace = exp.run();
    assert!(trace.last_auc() > 0.75, "AUC {:.3}", trace.last_auc());
    // AUC improved over the zero model
    assert!(trace.last_auc() > trace.rows[0].auc);
}
