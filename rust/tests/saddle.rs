//! End-to-end tests of the saddle subsystem: both minimax registry
//! entries run under DSBA and DSBA-s on both engines, the reported
//! saddle residual decreases geometrically, the restricted duality gap
//! tracks it, and AUC behaves as a plain client of the same machinery.

use dsba::algorithms::AlgorithmKind;
use dsba::operators::{ProblemRegistry, SaddleStat};
use dsba::prelude::*;
use dsba::runtime::{EngineKind, TransportKind};

fn saddle_cfg(problem: &str, alg: AlgorithmKind) -> ExperimentConfig {
    ExperimentConfig {
        problem: problem.into(),
        dataset: "tiny".into(),
        nodes: 4,
        lambda: 0.1,
        algorithm: alg,
        alpha: 0.5,
        passes: 80.0,
        record_points: 10,
        seed: 42,
        ..Default::default()
    }
}

#[test]
fn saddle_residual_decreases_geometrically_under_dsba_and_dsba_s() {
    for problem in ["robust-ls", "dro-bilinear"] {
        for alg in [AlgorithmKind::Dsba, AlgorithmKind::DsbaSparse] {
            let mut exp = saddle_cfg(problem, alg).build().unwrap();
            let trace = exp.run();
            let first = trace.rows.first().unwrap();
            let last = trace.rows.last().unwrap();
            assert!(
                first.saddle_res.is_finite() && first.saddle_res > 0.0,
                "{problem}/{}: starting residual {}",
                alg.name(),
                first.saddle_res
            );
            assert!(
                last.saddle_res < first.saddle_res * 1e-2,
                "{problem}/{}: residual {} -> {} (not geometric)",
                alg.name(),
                first.saddle_res,
                last.saddle_res
            );
            // mean per-sample contraction strictly < 1: the log-residual
            // trend is a decaying line, not a plateau
            let k = (trace.rows.len() - 1) as f64;
            let rate = (last.saddle_res / first.saddle_res).powf(1.0 / k);
            assert!(
                rate < 0.9,
                "{problem}/{}: mean contraction {rate}",
                alg.name()
            );
            // the restricted duality gap is reported, nonnegative (up to
            // rounding), and collapses alongside the residual
            assert!(last.saddle_gap.is_finite());
            assert!(
                last.saddle_gap > -1e-8,
                "{problem}/{}: gap went negative: {}",
                alg.name(),
                last.saddle_gap
            );
            if first.saddle_gap > 1e-9 {
                assert!(
                    last.saddle_gap < first.saddle_gap * 1e-2,
                    "{problem}/{}: gap {} -> {}",
                    alg.name(),
                    first.saddle_gap,
                    last.saddle_gap
                );
            }
            // saddle problems have no objective; suboptimality collapses
            assert!(last.objective.is_nan());
            assert!(last.suboptimality < first.suboptimality * 1e-3);
        }
    }
}

#[test]
fn saddle_workloads_match_sequential_on_both_engines_and_transports() {
    // the engine x transport matrix on a minimax entry, driven through
    // the config layer exactly as a user would: parallel local and
    // parallel loopback-TCP traces must equal the sequential oracle's
    for problem in ["robust-ls", "dro-bilinear"] {
        let run = |engine: EngineKind, transport: TransportKind| {
            let mut cfg = saddle_cfg(problem, AlgorithmKind::DsbaSparse);
            cfg.passes = 6.0;
            cfg.record_points = 6;
            cfg.engine.kind = engine;
            cfg.engine.threads = 2;
            cfg.engine.transport = transport;
            let mut exp = cfg.build().unwrap();
            exp.run()
        };
        let seq = run(EngineKind::Sequential, TransportKind::Local);
        let par = run(EngineKind::Parallel, TransportKind::Local);
        let tcp = run(EngineKind::Parallel, TransportKind::Tcp);
        for other in [&par, &tcp] {
            assert_eq!(seq.rows.len(), other.rows.len());
            for (a, b) in seq.rows.iter().zip(&other.rows) {
                assert_eq!(a.iter, b.iter, "{problem}: sampling rounds diverged");
                assert_eq!(
                    a.suboptimality, b.suboptimality,
                    "{problem}: iterates diverged across engines"
                );
                assert_eq!(
                    a.saddle_res, b.saddle_res,
                    "{problem}: saddle residual diverged across engines"
                );
                assert_eq!(a.comm_doubles, b.comm_doubles);
            }
        }
    }
}

#[test]
fn auc_is_a_client_of_the_generic_saddle_subsystem() {
    // AUC runs through the same merit layer: the ranking statistic is
    // driven by the declared SaddleStat, and the generic residual +
    // restricted gap series are reported alongside it
    let entry = ProblemRegistry::builtin().resolve("auc").unwrap();
    assert_eq!(entry.meta.saddle_stat, Some(SaddleStat::AucRanking));
    let mut cfg = saddle_cfg("auc", AlgorithmKind::Dsba);
    cfg.lambda = 0.05;
    cfg.passes = 40.0;
    let mut exp = cfg.build().unwrap();
    let trace = exp.run();
    let first = trace.rows.first().unwrap();
    let last = trace.rows.last().unwrap();
    // the workload-specific statistic still works…
    assert!(last.auc.is_finite());
    assert!(last.auc > 0.55, "AUC {}", last.auc);
    // …and the generic saddle merit layer reports on AUC too
    assert!(last.saddle_res.is_finite());
    assert!(
        last.saddle_res < first.saddle_res * 1e-1,
        "AUC saddle residual {} -> {}",
        first.saddle_res,
        last.saddle_res
    );
    assert!(last.saddle_gap.is_finite());
    assert!(last.saddle_gap > -1e-8);
    assert!(last.objective.is_nan());
}

#[test]
fn forward_baselines_also_run_the_minimax_entries() {
    // DSA and EXTRA (the fig3 baselines) execute the new saddle entries
    // end to end with finite, decreasing residuals — the subsystem is
    // not DSBA-specific
    for problem in ["robust-ls", "dro-bilinear"] {
        for alg in [AlgorithmKind::Dsa, AlgorithmKind::Extra] {
            let mut cfg = saddle_cfg(problem, alg);
            // forward steps on a (partly skew) saddle field spiral unless
            // alpha stays below ~2 mu / (mu^2 + sigma^2); 0.08 is safely
            // inside for both entries at lambda = 0.1
            cfg.alpha = 0.08;
            cfg.passes = 60.0;
            let mut exp = cfg.build().unwrap();
            let trace = exp.run();
            let first = trace.rows.first().unwrap();
            let last = trace.rows.last().unwrap();
            assert!(last.saddle_res.is_finite());
            assert!(
                last.saddle_res < first.saddle_res,
                "{problem}/{}: residual did not decrease ({} -> {})",
                alg.name(),
                first.saddle_res,
                last.saddle_res
            );
        }
    }
}
