//! Property-based tests over the system invariants (via the in-repo
//! `testing::prop_check` harness, standing in for proptest).

use dsba::graph::MixingMatrix;
use dsba::linalg::{CsrMatrix, SparseVec};
use dsba::operators::{check_monotone, check_resolvent, check_saddle};
use dsba::prelude::*;
use dsba::testing::prop_check;

#[test]
fn prop_sparse_algebra_matches_dense() {
    prop_check("sparse ≡ dense algebra", 100, |rng| {
        let dim = 1 + rng.below(200);
        let nnz = rng.below(dim + 1);
        let pairs: Vec<(u32, f64)> = (0..nnz)
            .map(|_| (rng.below(dim) as u32, rng.normal()))
            .collect();
        let sv = SparseVec::from_pairs(dim, pairs);
        let dense = sv.to_dense();
        let x: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        // dot
        let want: f64 = dense.iter().zip(&x).map(|(a, b)| a * b).sum();
        if (sv.dot_dense(&x) - want).abs() > 1e-9 * (1.0 + want.abs()) {
            return Err(format!("dot mismatch {} vs {}", sv.dot_dense(&x), want));
        }
        // axpy
        let alpha = rng.normal();
        let mut y1 = x.clone();
        sv.axpy_into(alpha, &mut y1);
        let y2: Vec<f64> = x.iter().zip(&dense).map(|(xi, di)| xi + alpha * di).collect();
        for (a, b) in y1.iter().zip(&y2) {
            if (a - b).abs() > 1e-10 {
                return Err("axpy mismatch".into());
            }
        }
        // add
        let sv2 = SparseVec::from_dense(&x, 0.5);
        let sum = sv.add(&sv2);
        let want_sum: Vec<f64> = dense
            .iter()
            .zip(sv2.to_dense())
            .map(|(a, b)| a + b)
            .collect();
        if sum.to_dense() != want_sum {
            return Err("sparse add mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_csr_transpose_identity() {
    prop_check("<A x, g> == <x, A^T g>", 50, |rng| {
        let rows = 1 + rng.below(30);
        let cols = 1 + rng.below(40);
        let svs: Vec<SparseVec> = (0..rows)
            .map(|_| {
                let nnz = rng.below(cols + 1);
                SparseVec::from_pairs(
                    cols,
                    (0..nnz).map(|_| (rng.below(cols) as u32, rng.normal())).collect(),
                )
            })
            .collect();
        let a = CsrMatrix::from_rows(cols, &svs);
        let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
        let g: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let lhs = dsba::linalg::dot(&a.matvec(&x), &g);
        let rhs = dsba::linalg::dot(&x, &a.t_matvec(&g));
        if (lhs - rhs).abs() > 1e-8 * (1.0 + lhs.abs()) {
            return Err(format!("adjoint identity broken: {lhs} vs {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mixing_matrix_conditions_hold_across_topologies() {
    prop_check("mixing matrix (i)-(iv)", 25, |rng| {
        let n = 3 + rng.below(12);
        let topo = match rng.below(4) {
            0 => Topology::erdos_renyi(n, 0.3 + 0.4 * rng.uniform(), rng.next_u64()),
            1 => Topology::ring(n),
            2 => Topology::star(n),
            _ => Topology::grid2d(n),
        };
        let mix = if rng.bernoulli(0.5) {
            MixingMatrix::laplacian(&topo, 1.0 + rng.uniform())
        } else {
            MixingMatrix::metropolis(&topo)
        };
        mix.check_conditions(&topo, 1e-8)
    });
}

#[test]
fn prop_mixing_doubly_stochastic_and_contractive() {
    // The consensus-convergence core of §4: W is symmetric doubly
    // stochastic on every random connected topology, and the disagreement
    // operator W - (1/n) 11^T has spectral radius strictly below 1 (so
    // gossip mixing contracts toward consensus).
    use dsba::linalg::symmetric_eigenvalues;
    prop_check("W row/col sums, symmetry, rho(W - J/n) < 1", 20, |rng| {
        let n = 3 + rng.below(10);
        let topo = match rng.below(4) {
            0 => Topology::erdos_renyi(n, 0.3 + 0.4 * rng.uniform(), rng.next_u64()),
            1 => Topology::ring(n),
            2 => Topology::grid2d(n),
            // small_world needs n >= 4 for any non-ring chord to exist
            _ => Topology::small_world(n.max(4), n / 2, rng.next_u64()),
        };
        let n = topo.n;
        if !topo.is_connected() {
            return Err("generator produced a disconnected graph".into());
        }
        let mix = if rng.bernoulli(0.5) {
            MixingMatrix::laplacian(&topo, 1.0 + rng.uniform())
        } else {
            MixingMatrix::metropolis(&topo)
        };
        let w = &mix.w;
        for i in 0..n {
            let row: f64 = (0..n).map(|j| w[(i, j)]).sum();
            if (row - 1.0).abs() > 1e-8 {
                return Err(format!("row {i} sums to {row}"));
            }
            let col: f64 = (0..n).map(|j| w[(j, i)]).sum();
            if (col - 1.0).abs() > 1e-8 {
                return Err(format!("col {i} sums to {col}"));
            }
            for j in 0..n {
                if (w[(i, j)] - w[(j, i)]).abs() > 1e-10 {
                    return Err(format!("asymmetric at ({i},{j})"));
                }
            }
        }
        // spectral radius of the disagreement operator
        let mut m = w.clone();
        let inv_n = 1.0 / n as f64;
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] -= inv_n;
            }
        }
        let eig = symmetric_eigenvalues(&m, 1e-13);
        let radius = eig.iter().fold(0.0f64, |a, e| a.max(e.abs()));
        if radius >= 1.0 - 1e-9 {
            return Err(format!(
                "spectral radius {radius} not strictly < 1 on {} nodes",
                topo.n
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_message_wire_roundtrip_lossless() {
    // Engine payloads survive serialize -> deliver -> reconstruct
    // bit-for-bit (f64 via to_bits), for dense iterates, sparse relay
    // deltas, and compressed (COMP) broadcast frames.
    use dsba::comm::{CompressedVec, Message, RelayDelta};
    use std::sync::Arc;
    prop_check("message encode/decode identity", 60, |rng| {
        let msg = match rng.below(3) {
            0 => {
                let len = rng.below(300);
                Message::dense(
                    (0..len)
                        .map(|_| rng.normal() * 10f64.powi(rng.below(7) as i32 - 3))
                        .collect(),
                )
            }
            1 => {
                let dim = 1 + rng.below(500);
                let nnz = rng.below(dim.min(40) + 1);
                let pairs: Vec<(u32, f64)> =
                    (0..nnz).map(|_| (rng.below(dim) as u32, rng.normal())).collect();
                let tail_len = rng.below(4);
                Message::Sparse(RelayDelta {
                    src: rng.below(1000) as u32,
                    t: rng.below(100_000) as u32,
                    vec: SparseVec::from_pairs(dim, pairs),
                    tail: (0..tail_len).map(|_| rng.normal()).collect(),
                })
            }
            _ => {
                let dim = 1 + rng.below(300);
                let idx: Vec<u32> =
                    (0..dim).filter(|_| rng.bernoulli(0.15)).map(|i| i as u32).collect();
                let val: Vec<f64> = idx.iter().map(|_| rng.normal()).collect();
                Message::Comp(Arc::new(CompressedVec {
                    dim,
                    idx,
                    val,
                    bytes: rng.below(1 << 20) as u64,
                }))
            }
        };
        let decoded = Message::decode(&msg.encode())?;
        if decoded != msg {
            return Err("roundtrip mismatch".into());
        }
        // bit-exactness beyond PartialEq (e.g. signed zeros)
        if decoded.encode() != msg.encode() {
            return Err("re-encode not bit-identical".into());
        }
        Ok(())
    });
}

#[test]
fn prop_message_decode_total_on_corrupt_frames() {
    // Wire-codec robustness (the cross-process transport reads frames
    // from untrusted sockets): `decode` must return `Err` — never panic,
    // never blindly allocate — on every truncation of a valid frame, and
    // any mutated frame it *does* accept must be canonical (re-encoding
    // reproduces the accepted bytes exactly, so no invalid SparseVec or
    // phantom payload can enter a node).
    use dsba::comm::{CompressedVec, Message, RelayDelta};
    use std::sync::Arc;
    prop_check("decode total on corrupt frames", 40, |rng| {
        let msg = match rng.below(3) {
            0 => {
                let len = rng.below(40);
                Message::dense((0..len).map(|_| rng.normal()).collect())
            }
            1 => {
                let dim = 1 + rng.below(60);
                let nnz = rng.below(dim.min(12) + 1);
                let pairs: Vec<(u32, f64)> =
                    (0..nnz).map(|_| (rng.below(dim) as u32, rng.normal())).collect();
                Message::Sparse(RelayDelta {
                    src: rng.below(100) as u32,
                    t: rng.below(1000) as u32,
                    vec: SparseVec::from_pairs(dim, pairs),
                    tail: (0..rng.below(4)).map(|_| rng.normal()).collect(),
                })
            }
            _ => {
                let dim = 1 + rng.below(60);
                let idx: Vec<u32> =
                    (0..dim).filter(|_| rng.bernoulli(0.2)).map(|i| i as u32).collect();
                let val: Vec<f64> = idx.iter().map(|_| rng.normal()).collect();
                Message::Comp(Arc::new(CompressedVec {
                    dim,
                    idx,
                    val,
                    bytes: rng.below(1 << 16) as u64,
                }))
            }
        };
        let enc = msg.encode();
        for k in 0..enc.len() {
            if Message::decode(&enc[..k]).is_ok() {
                return Err(format!("prefix {k}/{} bytes decoded Ok", enc.len()));
            }
        }
        for _ in 0..25 {
            let mut mutated = enc.clone();
            let flips = 1 + rng.below(3);
            for _ in 0..flips {
                let pos = rng.below(mutated.len());
                mutated[pos] ^= 1u8 << rng.below(8);
            }
            if let Ok(decoded) = Message::decode(&mutated) {
                if decoded.encode() != mutated {
                    return Err(format!(
                        "accepted a non-canonical mutated frame ({flips} bit flips)"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_watermark_codec_total_on_corrupt_frames() {
    // The WATERMARK control frames (end-of-round progress + piggybacked
    // STATS hops) cross the same untrusted sockets as the payload frames,
    // so their codec owes the same contract: lossless canonical
    // roundtrip, `Err` on every truncation, and any mutated frame that
    // still decodes must re-encode to exactly the accepted bytes.
    use dsba::comm::{Watermark, WatermarkKind};
    prop_check("watermark codec total on corrupt frames", 40, |rng| {
        let wm = Watermark {
            node: rng.below(1 << 16) as u32,
            round: rng.below(1 << 30) as u64,
            kind: if rng.bernoulli(0.5) {
                WatermarkKind::RoundComplete
            } else {
                WatermarkKind::Stats {
                    hop: rng.below(64) as u32,
                    payload: (0..rng.below(80)).map(|_| rng.below(256) as u8).collect(),
                }
            },
        };
        let enc = wm.encode();
        let back = Watermark::decode(&enc)?;
        if back != wm {
            return Err("roundtrip mismatch".into());
        }
        if back.encode() != enc {
            return Err("re-encode not bit-identical".into());
        }
        for k in 0..enc.len() {
            if Watermark::decode(&enc[..k]).is_ok() {
                return Err(format!("prefix {k}/{} bytes decoded Ok", enc.len()));
            }
        }
        for _ in 0..25 {
            let mut mutated = enc.clone();
            let flips = 1 + rng.below(3);
            for _ in 0..flips {
                let pos = rng.below(mutated.len());
                mutated[pos] ^= 1u8 << rng.below(8);
            }
            if let Ok(decoded) = Watermark::decode(&mutated) {
                if decoded.encode() != mutated {
                    return Err(format!(
                        "accepted a non-canonical mutated watermark ({flips} bit flips)"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_registered_problems_resolvent_monotone_and_saddle() {
    // Every problem in the registry — including ones future PRs add —
    // passes the resolvent-identity, monotonicity, and saddle-capability
    // checks on random instances with randomized hyper-parameters.  No
    // hand-listed trio: registering a workload (saddle entries included)
    // automatically enrolls it here.
    use dsba::operators::ProblemSpec;
    use dsba::util::json::Json;
    prop_check("resolvent + monotonicity + saddle (every registered problem)", 10, |rng| {
        for entry in ProblemRegistry::builtin().entries() {
            let ds = SyntheticSpec::tiny()
                .with_samples(40 + rng.below(40))
                .with_dim(20 + rng.below(30))
                .with_regression(entry.meta.regression_targets)
                .generate(rng.next_u64());
            let part = ds.partition_seeded(2, rng.next_u64());
            let lam = rng.uniform() * 0.2;
            // generic knobs: constructors read the keys they know
            let params = Json::from_pairs(vec![
                ("l1", Json::Num(0.002 + 0.05 * rng.uniform())),
                ("gamma", Json::Num(0.2 + rng.uniform())),
                ("rho", Json::Num(1.2 + 2.0 * rng.uniform())),
                ("nu", Json::Num(0.2 + 2.0 * rng.uniform())),
            ]);
            let spec =
                ProblemSpec::new(entry.meta.name, lam).with_params(params);
            let p = entry
                .build(&spec, &ds, part)
                .map_err(|e| format!("{}: ctor failed: {e}", entry.meta.name))?;
            let alpha = 0.05 + rng.uniform() * 3.0;
            check_resolvent(p.as_ref(), alpha, rng.next_u64(), 10)
                .map_err(|e| format!("{}: {e}", entry.meta.name))?;
            check_monotone(p.as_ref(), rng.next_u64(), 30)
                .map_err(|e| format!("{}: {e}", entry.meta.name))?;
            // trivially Ok for non-saddle entries; for saddle entries it
            // validates the declared split and cross-checks the operator
            // against the saddle function's gradient field
            check_saddle(p.as_ref(), rng.next_u64(), 3)
                .map_err(|e| format!("{}: {e}", entry.meta.name))?;
            if p.saddle().is_some() != entry.meta.saddle_stat.is_some() {
                return Err(format!(
                    "{}: registry saddle metadata disagrees with the problem",
                    entry.meta.name
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stat_row_wire_roundtrip_lossless() {
    // The split-run metrics rows that ride the STATS control frames
    // survive serialize -> deliver -> reconstruct bit-for-bit, and every
    // truncation of a valid payload is rejected — same contract as the
    // message wire codec.
    use dsba::metrics::{decode_stat_rows, encode_stat_rows, NodeStatRow};
    prop_check("stat-row encode/decode identity", 40, |rng| {
        let n_rows = rng.below(6);
        let rows: Vec<NodeStatRow> = (0..n_rows)
            .map(|_| NodeStatRow {
                node: rng.below(64) as u32,
                evals: rng.below(1 << 20) as u64,
                received: rng.normal() * 10f64.powi(rng.below(7) as i32 - 3),
                received_bytes: rng.below(1 << 24) as f64,
                z: (0..rng.below(40)).map(|_| rng.normal()).collect(),
            })
            .collect();
        let enc = encode_stat_rows(&rows);
        let back = decode_stat_rows(&enc)?;
        if back != rows {
            return Err("roundtrip mismatch".into());
        }
        if encode_stat_rows(&back) != enc {
            return Err("re-encode not bit-identical".into());
        }
        for k in 0..enc.len() {
            if decode_stat_rows(&enc[..k]).is_ok() {
                return Err(format!("prefix {k}/{} decoded Ok", enc.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_error_feedback_converges_on_constant_signal() {
    // CHOCO error feedback: feeding the same target `x` into the encoder
    // drives `x_hat -> x` at each compressor's declared contraction rate.
    // Key exactness property exploited throughout: kept coordinates
    // travel as exact f64 deltas, and `0 + x_i == x_i` exactly, so a
    // coordinate first touched from the zero state is reproduced
    // bit-for-bit (top-k therefore finishes in ceil(d/k) rounds).
    use dsba::comm::{CompressionSpec, ErrorFeedback};
    prop_check("error feedback x_hat -> x within contraction", 25, |rng| {
        let d = 1 + rng.below(50);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let x2: f64 = x.iter().map(|v| v * v).sum();
        let err = |ef: &ErrorFeedback| -> f64 {
            x.iter().zip(&ef.x_hat).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        // Identity assigns: bit-for-bit after a single round
        {
            let mut comp = CompressionSpec::Identity.build_for_node(1, 0).unwrap();
            let mut ef = ErrorFeedback::new(d);
            ef.encode(comp.as_mut(), &x);
            if ef.x_hat != x {
                return Err("identity did not assign x_hat = x".into());
            }
        }
        // TopK fixes up to k fresh coordinates exactly per round (zero
        // deltas of already-exact coordinates are never preferred over
        // live residuals), so ceil(d/k) rounds reach x bit-for-bit
        {
            let k = 1 + rng.below(d);
            let mut comp = CompressionSpec::TopK(k).build_for_node(1, 0).unwrap();
            let mut ef = ErrorFeedback::new(d);
            for _ in 0..(d + k - 1) / k {
                ef.encode(comp.as_mut(), &x);
            }
            if ef.x_hat != x {
                return Err(format!("topk:{k} not exact after ceil(d/k) rounds"));
            }
        }
        // RandK: a coordinate is exact from its first draw onward; enough
        // rounds make a never-drawn coordinate astronomically unlikely
        {
            let k = 1 + rng.below(d);
            let mut comp =
                CompressionSpec::RandK(k).build_for_node(rng.next_u64(), 0).unwrap();
            let mut ef = ErrorFeedback::new(d);
            for _ in 0..40 * ((d + k - 1) / k) + 100 {
                ef.encode(comp.as_mut(), &x);
            }
            let e = err(&ef);
            if e > 1e-12 * (1.0 + x2) {
                return Err(format!("randk:{k} residual {e:.3e}"));
            }
        }
        // QSGD with s > 2 sqrt(d): per-realization contraction d/s^2 <
        // 1/4 every round, so 30 rounds shrink the residual to FP noise
        {
            let levels = 2 * ((d as f64).sqrt().ceil() as u32) + 1;
            let mut comp = CompressionSpec::Qsgd(levels)
                .build_for_node(rng.next_u64(), 0)
                .unwrap();
            let c = d as f64 / (levels as f64 * levels as f64);
            let mut ef = ErrorFeedback::new(d);
            let mut prev = x2;
            for round in 0..30 {
                ef.encode(comp.as_mut(), &x);
                let e = err(&ef);
                if e > c * prev + 1e-12 * (1.0 + x2) {
                    return Err(format!(
                        "qsgd:{levels} round {round}: residual {e:.3e} broke the \
                         c = {c:.3} envelope from {prev:.3e}"
                    ));
                }
                prev = e;
            }
            if prev > 1e-12 * (1.0 + x2) {
                return Err(format!("qsgd:{levels} final residual {prev:.3e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_experiment_config_json_roundtrip() {
    // `from_json(to_json(c)) == c` over randomized configs covering every
    // field — a field added on one side but forgotten on the other (the
    // PR 2 tcp trio was nearly droppable) fails this immediately.
    use dsba::graph::TopologyKind;
    use dsba::runtime::{EngineKind, EngineSpec, TcpSpec, TransportKind};
    use dsba::util::json::Json;
    // dyadic rationals survive decimal printing exactly
    fn dyadic(rng: &mut Rng, scale: f64) -> f64 {
        (rng.normal() * scale * 16.0).round() / 16.0
    }
    prop_check("ExperimentConfig json roundtrip", 40, |rng| {
        let problems = ProblemRegistry::builtin().names();
        let problem = problems[rng.below(problems.len())].to_string();
        let topologies = [
            TopologyKind::ErdosRenyi,
            TopologyKind::Ring,
            TopologyKind::Grid2d,
            TopologyKind::SmallWorld,
        ];
        let methods = AlgorithmKind::all();
        let engine = EngineSpec {
            kind: if rng.bernoulli(0.5) {
                EngineKind::Sequential
            } else {
                EngineKind::Parallel
            },
            threads: rng.below(8),
            transport: if rng.bernoulli(0.5) {
                TransportKind::Local
            } else {
                TransportKind::Tcp
            },
            tcp: TcpSpec {
                listen: format!("127.0.0.1:{}", rng.below(65536)),
                peers: format!("{}=10.0.0.2:{}", rng.below(8), rng.below(65536)),
                hosted: format!("0-{}", rng.below(8)),
            },
            compress: {
                use dsba::comm::CompressionSpec;
                match rng.below(5) {
                    0 => CompressionSpec::None,
                    1 => CompressionSpec::Identity,
                    2 => CompressionSpec::TopK(1 + rng.below(100)),
                    3 => CompressionSpec::RandK(1 + rng.below(100)),
                    _ => CompressionSpec::Qsgd(1 + rng.below(200) as u32),
                }
            },
            mode: {
                use dsba::runtime::ModeSpec;
                if rng.bernoulli(0.5) {
                    ModeSpec::Sync
                } else {
                    ModeSpec::Async(rng.below(5) as u32)
                }
            },
        };
        let params = if rng.bernoulli(0.5) {
            Json::Null
        } else {
            Json::from_pairs(vec![("l1", Json::Num(dyadic(rng, 0.01).abs()))])
        };
        let c = ExperimentConfig {
            problem,
            problem_params: params,
            dataset: ["tiny", "rcv1-like", "news20-like"][rng.below(3)].into(),
            samples: rng.below(5000),
            dim: rng.below(4096),
            lambda: dyadic(rng, 0.1),
            nodes: 1 + rng.below(32),
            topology: topologies[rng.below(topologies.len())],
            edge_prob: (rng.below(17) as f64) / 16.0,
            algorithm: methods[rng.below(methods.len())],
            alpha: dyadic(rng, 1.0),
            passes: dyadic(rng, 50.0).abs(),
            seed: rng.below(1 << 31) as u64,
            record_points: rng.below(500),
            charitable_sparse: rng.bernoulli(0.5),
            engine,
        };
        let back = ExperimentConfig::from_json(&c.to_json().to_string())
            .map_err(|e| format!("serialized config failed to parse: {e}"))?;
        if back != c {
            return Err(format!("roundtrip mismatch:\n  in:  {c:?}\n  out: {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_partition_conserves_samples() {
    prop_check("partition conservation", 30, |rng| {
        let q_total = 20 + rng.below(150);
        let nodes = 1 + rng.below(8.min(q_total));
        let ds = SyntheticSpec::tiny().with_samples(q_total).generate(rng.next_u64());
        let part = ds.partition_seeded(nodes, rng.next_u64());
        if part.q != q_total / nodes {
            return Err(format!("q = {} != {}", part.q, q_total / nodes));
        }
        let total_nnz: usize = part.shards.iter().map(|s| s.nnz()).sum();
        if part.total_samples() != nodes * (q_total / nodes) {
            return Err("wrong total".into());
        }
        // nnz conservation up to dropped remainder rows
        let dropped = q_total - part.total_samples();
        let full_nnz = ds.a.nnz();
        if total_nnz > full_nnz || (dropped == 0 && total_nnz != full_nnz) {
            return Err(format!("nnz {total_nnz} vs {full_nnz}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use dsba::util::json::{parse, Json};
    prop_check("json value roundtrip", 60, |rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Num((rng.normal() * 1e3).round() / 16.0),
                3 => Json::Str(format!("s{}\n\"x{}", rng.below(100), rng.below(10))),
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 0);
        let parsed = parse(&v.to_string())?;
        if parsed != v {
            return Err(format!("roundtrip mismatch: {v}"));
        }
        Ok(())
    });
}

#[test]
fn prop_auc_score_invariances() {
    use dsba::metrics::auc_score;
    prop_check("auc scale invariance + flip symmetry", 20, |rng| {
        let ds = SyntheticSpec::tiny().with_samples(60).generate(rng.next_u64());
        let part = ds.partition_seeded(2, 1);
        let mut z = vec![0.0; part.dim + 3];
        for v in z.iter_mut() {
            *v = rng.normal();
        }
        let a1 = auc_score(&part, &z);
        // positive scaling leaves AUC unchanged
        let zs: Vec<f64> = z.iter().map(|v| v * 3.7).collect();
        if (auc_score(&part, &zs) - a1).abs() > 1e-12 {
            return Err("not scale invariant".into());
        }
        // negation reflects around 1/2
        let zn: Vec<f64> = z.iter().map(|v| -v).collect();
        if (auc_score(&part, &zn) + a1 - 1.0).abs() > 1e-12 {
            return Err("flip symmetry broken".into());
        }
        Ok(())
    });
}
