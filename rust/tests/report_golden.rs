//! Golden-file contract for `dsba report`: the canned telemetry stream
//! under `tests/data/` must render to exactly the committed text.
//!
//! The canned stream is built from clean numbers so the render is
//! platform-stable: residuals halve every round (the least-squares fit
//! lands on rate 0.5000 / half-life 1.0 to well past the printed
//! precision), and every phase span is an exact integer so no percentage
//! sits on a rounding midpoint. All rendered numbers use fixed-precision
//! formatting, never float `Display`.
//!
//! If a deliberate format change breaks this test, regenerate the
//! expectation by running `dsba report tests/data/report_canned.jsonl`
//! and committing the new output — the diff IS the review surface.

use dsba::telemetry::{chrome_trace, RunReport};
use dsba::util::json::{parse, Json};

const CANNED: &str = include_str!("data/report_canned.jsonl");
const EXPECTED: &str = include_str!("data/report_expected.txt");
const TRACE_CANNED: &str = include_str!("data/trace_canned.jsonl");
const TRACE_EXPECTED: &str = include_str!("data/trace_expected.json");

#[test]
fn report_text_matches_the_golden_file() {
    let rep = RunReport::from_stream(CANNED).expect("canned stream parses");
    assert_eq!(
        rep.render_text(),
        EXPECTED,
        "report render drifted from tests/data/report_expected.txt — if \
         deliberate, regenerate the golden file and commit the diff"
    );
}

#[test]
fn canned_analysis_is_what_the_golden_text_claims() {
    // independent numeric checks, so a matched-but-wrong pair of data
    // files cannot silently agree with each other
    let rep = RunReport::from_stream(CANNED).unwrap();
    let fit = rep.convergence.expect("4 positive residual points");
    assert!((fit.rate - 0.5).abs() < 1e-12, "rate {}", fit.rate);
    assert!((fit.half_life - 1.0).abs() < 1e-9);
    assert_eq!(fit.points, 4);
    assert_eq!(rep.summary.rows, 8);
    assert_eq!(rep.summary.nodes, vec![0, 1]);
    assert!(rep.summary.missing_rounds.is_empty());
    assert_eq!(rep.bytes_per_double, 8.0);
    let st = rep.straggler.expect("wait spans present");
    assert_eq!((st.wait_node, st.slow_node), (1, 0));
    assert!((st.wait_share_pct - 87.5).abs() < 1e-9);
}

#[test]
fn chrome_export_matches_the_golden_file() {
    // `dsba trace export --format chrome` writes the trace plus a
    // trailing newline; the golden file pins that byte-for-byte
    let trace = chrome_trace(TRACE_CANNED).expect("canned trace stream parses");
    assert_eq!(
        format!("{trace}\n"),
        TRACE_EXPECTED,
        "chrome export drifted from tests/data/trace_expected.json — if \
         deliberate, regenerate via `dsba trace export \
         tests/data/trace_canned.jsonl` and commit the diff"
    );
}

#[test]
fn canned_trace_is_what_the_golden_json_claims() {
    // independent structural checks, so a matched-but-wrong pair of
    // data files cannot silently agree with each other
    let trace = chrome_trace(TRACE_CANNED).unwrap();
    let doc = parse(&trace.to_string()).expect("export is valid JSON");
    let events = doc.as_arr().expect("trace-event JSON is an array");
    // 5 phase spans x 2 rows + 1 instant; the summary line draws nothing
    assert_eq!(events.len(), 11);
    let instants: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
        .collect();
    assert_eq!(instants.len(), 1);
    assert_eq!(instants[0].get("name").and_then(Json::as_str), Some("node-kill"));
    assert_eq!(instants[0].get("ts").and_then(Json::as_usize), Some(2500));
    // round 1's first span starts where round 0's wall time ended
    let first_round1 = events
        .iter()
        .find(|e| {
            e.get("args").and_then(|a| a.get("round")).and_then(Json::as_usize) == Some(1)
        })
        .unwrap();
    assert_eq!(first_round1.get("ts").and_then(Json::as_usize), Some(1000));
}

#[test]
fn canned_report_json_roundtrips_through_the_parser() {
    let rep = RunReport::from_stream(CANNED).unwrap();
    let j = parse(&rep.to_json().to_string()).expect("--json output is valid JSON");
    assert_eq!(j.get("rows").and_then(Json::as_usize), Some(8));
    assert_eq!(
        j.get("straggler").unwrap().get("wait_node").and_then(Json::as_usize),
        Some(1)
    );
    assert_eq!(
        j.get("writer").unwrap().get("rows_dropped").and_then(Json::as_usize),
        Some(0)
    );
    assert!(j.get("convergence").unwrap().get("rate").is_some());
}
