//! Parallel-engine parity suite: the multi-threaded message-passing
//! engine must be **bit-for-bit** equal to the sequential reference
//! driver — same iterates, same per-node comm-cost accounting — for every
//! `AlgorithmKind` on several topologies, plus a concurrency stress
//! property (no deadlocks under random thread/node counts, no dropped
//! messages).

use dsba::algorithms::{build, AlgoParams, AlgorithmKind};
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::runtime::ParallelEngine;
use dsba::testing::prop_check;
use std::sync::Arc;
use std::time::Duration;

fn ridge_world(nodes: usize, seed: u64) -> Arc<dyn Problem> {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(seed);
    Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 3), 0.05))
}

/// Step both drivers `rounds` times, asserting exact iterate equality and
/// exact per-node sent/received DOUBLE totals each round.
fn assert_parity(kind: AlgorithmKind, topo: Topology, rounds: usize, threads: usize) {
    // Point-SAGA is single-node by construction (Remark 5.1); the engine
    // degenerates to one worker on the trivial topology.
    let topo = if kind == AlgorithmKind::PointSaga {
        Topology::from_edges(1, &[])
    } else {
        topo
    };
    let p = ridge_world(topo.n, 17);
    let mix = if kind == AlgorithmKind::PointSaga {
        MixingMatrix::from_w(dsba::linalg::DenseMatrix::identity(1))
    } else {
        MixingMatrix::laplacian(&topo, 1.0)
    };
    let mut params = AlgoParams::new(0.25, p.dim(), 99);
    params.inner_tol = 1e-11;
    let mut seq = build(kind, p.clone(), &mix, &topo, &params);
    let mut par = ParallelEngine::new(kind, p.clone(), &mix, &topo, &params, threads);
    let mut net_s = Network::new(topo.clone(), CommCostModel::default());
    let mut net_p = Network::new(topo.clone(), CommCostModel::default());
    for round in 0..rounds {
        seq.step(&mut net_s);
        par.step(&mut net_p);
        for n in 0..topo.n {
            assert_eq!(
                seq.iterates()[n],
                par.iterates()[n],
                "{} round {round} node {n}: parallel iterate != sequential",
                kind.name()
            );
        }
        assert_eq!(
            net_s.messages(),
            net_p.messages(),
            "{} round {round}: message counts diverged",
            kind.name()
        );
        for n in 0..topo.n {
            assert_eq!(
                net_s.received_by(n),
                net_p.received_by(n),
                "{} round {round} node {n}: received DOUBLEs diverged",
                kind.name()
            );
            assert_eq!(
                net_s.sent_by(n),
                net_p.sent_by(n),
                "{} round {round} node {n}: sent DOUBLEs diverged",
                kind.name()
            );
        }
    }
    assert_eq!(seq.passes(), par.passes(), "{}: passes diverged", kind.name());
    assert_eq!(seq.iteration(), par.iteration());
    let (sent, delivered) = par.message_stats();
    assert_eq!(sent, delivered, "{}: engine dropped messages", kind.name());
}

/// Cheap stochastic methods get the full 60 rounds; the
/// inner-solver-heavy deterministic methods (P-EXTRA, SSDA run an AGD/CG
/// oracle per node per round) still exceed the 50-round bar.
fn rounds_for(kind: AlgorithmKind) -> usize {
    match kind {
        AlgorithmKind::PExtra | AlgorithmKind::Ssda => 52,
        _ => 60,
    }
}

#[test]
fn parity_all_kinds_ring() {
    for &kind in AlgorithmKind::all() {
        assert_parity(kind, Topology::ring(6), rounds_for(kind), 3);
    }
}

#[test]
fn parity_all_kinds_grid() {
    for &kind in AlgorithmKind::all() {
        assert_parity(kind, Topology::grid2d(6), rounds_for(kind), 2);
    }
}

#[test]
fn parity_all_kinds_random_graph() {
    for &kind in AlgorithmKind::all() {
        assert_parity(kind, Topology::erdos_renyi(6, 0.5, 7), rounds_for(kind), 4);
    }
}

#[test]
fn parity_holds_at_every_thread_count() {
    // thread count must never leak into the arithmetic
    let topo = Topology::erdos_renyi(8, 0.4, 11);
    for threads in [1, 2, 3, 8] {
        assert_parity(AlgorithmKind::DsbaSparse, topo.clone(), 55, threads);
    }
}

/// Concurrency stress: random (nodes, threads, topology, method) triples
/// must complete a bounded number of rounds within a generous timeout (no
/// deadlock between the barrier protocol and channel delivery) and must
/// deliver every sent message exactly once.
#[test]
fn prop_engine_never_deadlocks_or_drops_messages() {
    prop_check("engine liveness + message conservation", 10, |rng| {
        let n = 2 + rng.below(7);
        let topo = match rng.below(4) {
            0 => Topology::ring(n),
            1 => Topology::grid2d(n),
            2 => Topology::erdos_renyi(n, 0.4 + 0.3 * rng.uniform(), rng.next_u64()),
            _ => Topology::complete(n),
        };
        let threads = 1 + rng.below(6);
        let rounds = 5 + rng.below(25);
        let kinds = [
            AlgorithmKind::Dsba,
            AlgorithmKind::DsbaSparse,
            AlgorithmKind::Extra,
            AlgorithmKind::Dgd,
        ];
        let kind = kinds[rng.below(kinds.len())];
        let seed = rng.next_u64();

        let (tx, rx) = std::sync::mpsc::channel();
        let topo2 = topo.clone();
        std::thread::spawn(move || {
            let ds = SyntheticSpec::tiny()
                .with_samples(40)
                .with_dim(20)
                .with_regression(true)
                .generate(seed);
            let p: Arc<dyn Problem> =
                Arc::new(RidgeProblem::new(ds.partition_seeded(topo2.n, 3), 0.05));
            let mix = MixingMatrix::laplacian(&topo2, 1.0);
            let params = AlgoParams::new(0.2, p.dim(), seed ^ 0xe7);
            let mut eng = ParallelEngine::new(kind, p, &mix, &topo2, &params, threads);
            let mut net = Network::new(topo2.clone(), CommCostModel::default());
            for _ in 0..rounds {
                eng.step(&mut net);
            }
            let stats = eng.message_stats();
            let finite = eng.iterates().iter().all(|z| z.iter().all(|v| v.is_finite()));
            // DSBA-s charges its one-time phibar flood (n*(n-1) dense
            // sends) into the network before round 0; those are setup
            // accounting, not engine messages
            let flood = if kind == AlgorithmKind::DsbaSparse {
                (topo2.n * (topo2.n - 1)) as u64
            } else {
                0
            };
            let _ = tx.send((stats, finite, net.messages() - flood));
        });
        // bounded-time rounds: a deadlocked engine never answers
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(((sent, delivered), finite, net_messages)) => {
                if sent != delivered {
                    return Err(format!(
                        "dropped messages: sent {sent}, delivered {delivered} \
                         (n={n}, threads={threads}, kind={})",
                        kind.name()
                    ));
                }
                if sent != net_messages {
                    return Err(format!(
                        "accounting missed messages: engine {sent} vs network {net_messages}"
                    ));
                }
                if !finite {
                    return Err("non-finite iterate".to_string());
                }
                Ok(())
            }
            Err(_) => Err(format!(
                "engine did not finish {rounds} rounds in 60s — deadlock? \
                 (n={n}, threads={threads}, kind={})",
                kind.name()
            )),
        }
    });
}
