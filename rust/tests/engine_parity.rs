//! Parallel-engine parity suite: the multi-threaded message-passing
//! engine must be **bit-for-bit** equal to the sequential reference
//! driver — same iterates, same per-node comm-cost accounting — for every
//! `AlgorithmKind` on several topologies, over BOTH transports (in-process
//! mpsc and per-edge loopback TCP sockets carrying the framed wire
//! codec), plus a concurrency stress property (no deadlocks under random
//! thread/node counts, no dropped messages) and a split-hosting test
//! pairing two TCP engines over real sockets.

use dsba::algorithms::{build, AlgoParams, AlgorithmKind};
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::operators::{ProblemRegistry, ProblemSpec};
use dsba::prelude::*;
use dsba::runtime::transport::TcpTransport;
use dsba::runtime::ParallelEngine;
use dsba::testing::prop_check;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn ridge_world(nodes: usize, seed: u64) -> Arc<dyn Problem> {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(seed);
    Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 3), 0.05))
}

/// Registry-built elastic net: parity must hold for problems constructed
/// purely through the open registry (proximal backward included), not
/// just for the hand-built seed trio.
fn elastic_world(nodes: usize) -> Arc<dyn Problem> {
    use dsba::util::json::Json;
    let ds = SyntheticSpec::tiny().with_regression(true).generate(23);
    let entry = ProblemRegistry::builtin()
        .resolve("elastic-net")
        .expect("elastic-net is registered");
    let spec = ProblemSpec::new("elastic-net", 0.05)
        .with_params(Json::from_pairs(vec![("l1", Json::Num(0.02))]));
    entry
        .build(&spec, &ds, ds.partition_seeded(nodes, 3))
        .expect("registry builds elastic-net")
}

/// Registry-built minimax workloads: the saddle subsystem's dense tail
/// coupling (adversarial shift / per-class duals) must survive both
/// transports bit-for-bit, like every other problem.
fn saddle_world(name: &'static str) -> impl Fn(usize) -> Arc<dyn Problem> {
    move |nodes| {
        let entry = ProblemRegistry::builtin()
            .resolve(name)
            .unwrap_or_else(|| panic!("{name} is registered"));
        let ds = SyntheticSpec::tiny()
            .with_regression(entry.meta.regression_targets)
            .generate(29);
        let spec = ProblemSpec::new(name, 0.05);
        entry
            .build(&spec, &ds, ds.partition_seeded(nodes, 3))
            .unwrap_or_else(|e| panic!("registry builds {name}: {e}"))
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Backend {
    Local,
    Tcp,
}

/// Step both drivers `rounds` times, asserting exact iterate equality and
/// exact per-node sent/received DOUBLE totals each round.
fn assert_parity_on(
    kind: AlgorithmKind,
    topo: Topology,
    rounds: usize,
    threads: usize,
    backend: Backend,
) {
    assert_parity_with(kind, topo, rounds, threads, backend, &|n| ridge_world(n, 17));
}

/// Problem-parametric core of the parity suite: `world(nodes)` builds the
/// problem under test (registry-built workloads plug in here too).
fn assert_parity_with(
    kind: AlgorithmKind,
    topo: Topology,
    rounds: usize,
    threads: usize,
    backend: Backend,
    world: &dyn Fn(usize) -> Arc<dyn Problem>,
) {
    // Point-SAGA is single-node by construction (Remark 5.1); the engine
    // degenerates to one worker on the trivial topology.
    let topo = if kind == AlgorithmKind::PointSaga {
        Topology::from_edges(1, &[])
    } else {
        topo
    };
    let p = world(topo.n);
    let mix = if kind == AlgorithmKind::PointSaga {
        MixingMatrix::from_w(dsba::linalg::DenseMatrix::identity(1))
    } else {
        MixingMatrix::laplacian(&topo, 1.0)
    };
    let mut params = AlgoParams::new(0.25, p.dim(), 99);
    params.inner_tol = 1e-11;
    let mut seq = build(kind, p.clone(), &mix, &topo, &params);
    let mut par = match backend {
        Backend::Local => ParallelEngine::new(kind, p.clone(), &mix, &topo, &params, threads),
        Backend::Tcp => {
            let transport = TcpTransport::loopback(&topo, params.seed)
                .expect("loopback transport setup");
            ParallelEngine::new_with_transport(
                kind,
                p.clone(),
                &mix,
                &topo,
                &params,
                threads,
                Box::new(transport),
            )
        }
    };
    let mut net_s = Network::new(topo.clone(), CommCostModel::default());
    let mut net_p = Network::new(topo.clone(), CommCostModel::default());
    for round in 0..rounds {
        seq.step(&mut net_s);
        par.step(&mut net_p);
        for n in 0..topo.n {
            assert_eq!(
                seq.iterates()[n],
                par.iterates()[n],
                "{} round {round} node {n}: parallel iterate != sequential",
                kind.name()
            );
        }
        assert_eq!(
            net_s.messages(),
            net_p.messages(),
            "{} round {round}: message counts diverged",
            kind.name()
        );
        for n in 0..topo.n {
            assert_eq!(
                net_s.received_by(n),
                net_p.received_by(n),
                "{} round {round} node {n}: received DOUBLEs diverged",
                kind.name()
            );
            assert_eq!(
                net_s.sent_by(n),
                net_p.sent_by(n),
                "{} round {round} node {n}: sent DOUBLEs diverged",
                kind.name()
            );
        }
    }
    assert_eq!(seq.passes(), par.passes(), "{}: passes diverged", kind.name());
    assert_eq!(seq.iteration(), par.iteration());
    let (sent, delivered) = par.message_stats();
    assert_eq!(sent, delivered, "{}: engine dropped messages", kind.name());
}

fn assert_parity(kind: AlgorithmKind, topo: Topology, rounds: usize, threads: usize) {
    assert_parity_on(kind, topo, rounds, threads, Backend::Local);
}

/// Cheap stochastic methods get the full 60 rounds; the
/// inner-solver-heavy deterministic methods (P-EXTRA, SSDA run an AGD/CG
/// oracle per node per round) still exceed the 50-round bar.
fn rounds_for(kind: AlgorithmKind) -> usize {
    match kind {
        AlgorithmKind::PExtra | AlgorithmKind::Ssda => 52,
        _ => 60,
    }
}

/// The TCP suite covers the same (kind x topology) grid; fewer rounds
/// (still several multiples of every diameter, so the relay pipeline is
/// exercised in steady state) keep the socket-bound suite fast.
fn tcp_rounds_for(kind: AlgorithmKind) -> usize {
    match kind {
        AlgorithmKind::PExtra | AlgorithmKind::Ssda => 16,
        _ => 24,
    }
}

#[test]
fn parity_all_kinds_ring() {
    for &kind in AlgorithmKind::all() {
        assert_parity(kind, Topology::ring(6), rounds_for(kind), 3);
    }
}

#[test]
fn parity_all_kinds_grid() {
    for &kind in AlgorithmKind::all() {
        assert_parity(kind, Topology::grid2d(6), rounds_for(kind), 2);
    }
}

#[test]
fn parity_all_kinds_random_graph() {
    for &kind in AlgorithmKind::all() {
        assert_parity(kind, Topology::erdos_renyi(6, 0.5, 7), rounds_for(kind), 4);
    }
}

#[test]
fn parity_all_kinds_ring_tcp() {
    for &kind in AlgorithmKind::all() {
        assert_parity_on(kind, Topology::ring(6), tcp_rounds_for(kind), 3, Backend::Tcp);
    }
}

#[test]
fn parity_all_kinds_grid_tcp() {
    for &kind in AlgorithmKind::all() {
        assert_parity_on(kind, Topology::grid2d(6), tcp_rounds_for(kind), 2, Backend::Tcp);
    }
}

#[test]
fn parity_all_kinds_random_graph_tcp() {
    for &kind in AlgorithmKind::all() {
        assert_parity_on(
            kind,
            Topology::erdos_renyi(6, 0.5, 7),
            tcp_rounds_for(kind),
            4,
            Backend::Tcp,
        );
    }
}

/// Registry-built elastic net, local transport: the proximal backward
/// (soft-threshold exact zeros included) must be bit-for-bit identical
/// across drivers for both the dense method and the sparse relay.
#[test]
fn parity_registry_elastic_net_local() {
    for kind in [AlgorithmKind::Dsba, AlgorithmKind::DsbaSparse] {
        assert_parity_with(kind, Topology::ring(6), 40, 3, Backend::Local, &elastic_world);
    }
}

/// Same, over loopback TCP sockets (the thresholded iterates and sparse
/// deltas cross the framed wire codec).
#[test]
fn parity_registry_elastic_net_tcp() {
    for kind in [AlgorithmKind::Dsba, AlgorithmKind::DsbaSparse] {
        assert_parity_with(kind, Topology::ring(6), 20, 3, Backend::Tcp, &elastic_world);
    }
}

/// Both minimax registry entries under DSBA and DSBA-s on the local
/// transport: parallel engine bit-for-bit equal to the sequential
/// oracle, sparse relay tails included.
#[test]
fn parity_registry_saddle_workloads_local() {
    for name in ["robust-ls", "dro-bilinear"] {
        let world = saddle_world(name);
        for kind in [AlgorithmKind::Dsba, AlgorithmKind::DsbaSparse] {
            assert_parity_with(kind, Topology::ring(6), 40, 3, Backend::Local, &world);
        }
    }
}

/// Same grid over loopback TCP sockets: the saddle tails cross the
/// framed wire codec.
#[test]
fn parity_registry_saddle_workloads_tcp() {
    for name in ["robust-ls", "dro-bilinear"] {
        let world = saddle_world(name);
        for kind in [AlgorithmKind::Dsba, AlgorithmKind::DsbaSparse] {
            assert_parity_with(kind, Topology::ring(6), 20, 3, Backend::Tcp, &world);
        }
    }
}

#[test]
fn parity_holds_at_every_thread_count() {
    // thread count must never leak into the arithmetic
    let topo = Topology::erdos_renyi(8, 0.4, 11);
    for threads in [1, 2, 3, 8] {
        assert_parity(AlgorithmKind::DsbaSparse, topo.clone(), 55, threads);
    }
}

/// Two engine instances hosting disjoint halves of one ring, wired to
/// each other over real loopback sockets (handshake, framed codec,
/// end-of-round control frames): each hosted node's iterate sequence and
/// sent-DOUBLE total must equal the sequential oracle's bit-for-bit, and
/// no message may be lost between the processes' engines. DSBA-s is the
/// hardest case — its relay deltas are forwarded multi-hop across the
/// host boundary every round.
#[test]
fn tcp_split_hosting_matches_sequential() {
    let topo = Topology::ring(6);
    let rounds = 20usize;
    let kind = AlgorithmKind::DsbaSparse;
    let p = ridge_world(6, 17);
    let mix = MixingMatrix::laplacian(&topo, 1.0);
    let mut params = AlgoParams::new(0.25, p.dim(), 99);
    params.inner_tol = 1e-11;

    // sequential oracle
    let mut seq = build(kind, p.clone(), &mix, &topo, &params);
    let mut net_s = Network::new(topo.clone(), CommCostModel::default());
    for _ in 0..rounds {
        seq.step(&mut net_s);
    }

    // bind both endpoints first so addresses are known to each other
    let l_a = TcpTransport::bind("127.0.0.1:0").unwrap();
    let l_b = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr_a = l_a.local_addr().to_string();
    let addr_b = l_b.local_addr().to_string();
    let peers_a: HashMap<usize, String> =
        [(3, addr_b.clone()), (5, addr_b.clone())].into_iter().collect();
    let peers_b: HashMap<usize, String> =
        [(0, addr_a.clone()), (2, addr_a.clone())].into_iter().collect();

    let run_half = |listener,
                    hosted: Vec<usize>,
                    peers: HashMap<usize, String>,
                    topo: Topology,
                    p: Arc<dyn Problem>,
                    mix: MixingMatrix,
                    params: AlgoParams| {
        std::thread::spawn(move || {
            let transport = TcpTransport::establish(listener, &topo, params.seed, hosted, &peers)
                .expect("split establish");
            let mut eng = ParallelEngine::new_with_transport(
                kind,
                p,
                &mix,
                &topo,
                &params,
                2,
                Box::new(transport),
            );
            let mut net = Network::new(topo.clone(), CommCostModel::default());
            // mid-run metrics aggregation: both halves exchange at the
            // same round, like the coordinator's lockstepped sampling
            let mut gs_mid = None;
            for round in 0..rounds {
                eng.step(&mut net);
                if round + 1 == rounds / 2 {
                    let recv: Vec<f64> =
                        (0..topo.n).map(|n| net.received_by(n)).collect();
                    let recv_b: Vec<f64> =
                        (0..topo.n).map(|n| net.bytes_received_by(n)).collect();
                    gs_mid = Some(
                        eng.global_stats(&recv, &recv_b)
                            .expect("split engine aggregates"),
                    );
                }
            }
            let recv: Vec<f64> = (0..topo.n).map(|n| net.received_by(n)).collect();
            let recv_b: Vec<f64> =
                (0..topo.n).map(|n| net.bytes_received_by(n)).collect();
            let gs_final =
                eng.global_stats(&recv, &recv_b).expect("split engine aggregates");
            let hosted = eng.hosted().to_vec();
            let iterates: Vec<Vec<f64>> = eng.iterates().to_vec();
            let sent: Vec<f64> = (0..topo.n).map(|n| net.sent_by(n)).collect();
            let received: Vec<f64> = (0..topo.n).map(|n| net.received_by(n)).collect();
            (
                hosted,
                iterates,
                sent,
                received,
                eng.message_stats(),
                gs_mid.unwrap(),
                gs_final,
            )
        })
    };
    let ha = run_half(
        l_a,
        vec![0, 1, 2],
        peers_a,
        topo.clone(),
        p.clone(),
        mix.clone(),
        params.clone(),
    );
    let hb = run_half(
        l_b,
        vec![3, 4, 5],
        peers_b,
        topo.clone(),
        p.clone(),
        mix.clone(),
        params.clone(),
    );
    let (hosted_a, z_a, sent_a, recv_a, stats_a, gs_mid_a, gs_a) =
        ha.join().expect("engine A panicked");
    let (hosted_b, z_b, sent_b, recv_b, stats_b, gs_mid_b, gs_b) =
        hb.join().expect("engine B panicked");

    // metrics aggregation: both halves hold the complete, identical
    // global row set — at the mid-run sample point and at the end
    assert_eq!(gs_mid_a, gs_mid_b, "mid-run aggregated rows diverged");
    assert_eq!(gs_a, gs_b, "final aggregated rows diverged");
    assert_eq!(gs_a.rows.len(), topo.n);
    for (n, row) in gs_a.rows.iter().enumerate() {
        assert_eq!(row.node as usize, n, "rows must be sorted by node");
        assert_eq!(
            row.z,
            seq.iterates()[n],
            "node {n}: aggregated iterate != sequential"
        );
        assert_eq!(
            row.received,
            net_s.received_by(n),
            "node {n}: aggregated received DOUBLEs != sequential"
        );
        assert_eq!(
            row.received_bytes,
            net_s.bytes_received_by(n),
            "node {n}: aggregated received bytes != sequential"
        );
    }
    let evals: u64 = gs_a.rows.iter().map(|r| r.evals).sum();
    assert_eq!(evals as f64 / gs_a.pass_denom, seq.passes());
    // the assembled global metrics row reproduces the single-process
    // numbers exactly (what a split coordinator reports)
    let z_star = dsba::coordinator::solve_optimum(p.as_ref(), 1e-11);
    let row = dsba::coordinator::global_metrics_row(p.as_ref(), &gs_a, &z_star, rounds, 0.0);
    assert_eq!(
        row.suboptimality,
        dsba::metrics::suboptimality(seq.iterates(), &z_star)
    );
    assert_eq!(row.comm_doubles, net_s.max_received());
    assert_eq!(row.comm_bytes, net_s.max_received_bytes());
    assert_eq!(row.passes, seq.passes());

    for (&n, z) in hosted_a.iter().map(|n| (n, &z_a)).chain(hosted_b.iter().map(|n| (n, &z_b))) {
        assert_eq!(
            seq.iterates()[n],
            z[n],
            "node {n}: split-hosted iterate != sequential"
        );
    }
    // per-node DOUBLE accounting for each engine's own share is exact:
    // outflow via send-side events, inflow from the remote half via
    // receive-side events (merged into the same canonical replay)
    for &n in hosted_a.iter() {
        assert_eq!(net_s.sent_by(n), sent_a[n], "node {n}: sent DOUBLEs diverged");
        assert_eq!(net_s.received_by(n), recv_a[n], "node {n}: received DOUBLEs diverged");
    }
    for &n in hosted_b.iter() {
        assert_eq!(net_s.sent_by(n), sent_b[n], "node {n}: sent DOUBLEs diverged");
        assert_eq!(net_s.received_by(n), recv_b[n], "node {n}: received DOUBLEs diverged");
    }
    // conservation across the pair: every sent envelope delivered once
    assert_eq!(
        stats_a.0 + stats_b.0,
        stats_a.1 + stats_b.1,
        "split engines lost or duplicated messages"
    );
    assert!(stats_a.0 > 0 && stats_b.0 > 0, "both halves must have sent messages");
}

/// Registry-built logistic regression for the lossy-compression envelope
/// (smooth non-quadratic workload next to elastic-net's proximal one).
fn logistic_world(nodes: usize) -> Arc<dyn Problem> {
    let entry = ProblemRegistry::builtin()
        .resolve("logistic")
        .expect("logistic is registered");
    let ds = SyntheticSpec::tiny().generate(31);
    let spec = ProblemSpec::new("logistic", 0.05);
    entry
        .build(&spec, &ds, ds.partition_seeded(nodes, 3))
        .expect("registry builds logistic")
}

/// `--compress none` and `--compress identity` are pinned **bit-for-bit**
/// against the sequential oracle on every dense-gossip method, over both
/// transports. `none` must additionally leave the DOUBLE cost replay
/// untouched (identity reprices messages as COMP frames, so only the
/// iterates are compared there).
#[test]
fn compression_none_and_identity_bit_for_bit() {
    use dsba::comm::CompressionSpec;
    use dsba::runtime::transport::{LocalTransport, Transport};
    for backend in [Backend::Local, Backend::Tcp] {
        for spec in [CompressionSpec::None, CompressionSpec::Identity] {
            for kind in [
                AlgorithmKind::Dgd,
                AlgorithmKind::Extra,
                AlgorithmKind::Dsa,
                AlgorithmKind::Dsba,
            ] {
                let topo = Topology::ring(6);
                let p = ridge_world(6, 17);
                let mix = MixingMatrix::laplacian(&topo, 1.0);
                let mut params = AlgoParams::new(0.25, p.dim(), 99);
                params.inner_tol = 1e-11;
                let mut seq = build(kind, p.clone(), &mix, &topo, &params);
                let transport: Box<dyn Transport> = match backend {
                    Backend::Local => Box::new(LocalTransport::new(topo.n)),
                    Backend::Tcp => Box::new(
                        TcpTransport::loopback(&topo, params.seed)
                            .expect("loopback transport setup"),
                    ),
                };
                let mut par = ParallelEngine::new_full(
                    kind, p.clone(), &mix, &topo, &params, 3, transport, &spec,
                );
                let mut net_s = Network::new(topo.clone(), CommCostModel::default());
                let mut net_p = Network::new(topo.clone(), CommCostModel::default());
                let rounds = if backend == Backend::Tcp { 12 } else { 30 };
                for round in 0..rounds {
                    seq.step(&mut net_s);
                    par.step(&mut net_p);
                    for n in 0..topo.n {
                        assert_eq!(
                            seq.iterates()[n],
                            par.iterates()[n],
                            "{} --compress {} round {round} node {n}",
                            kind.name(),
                            spec.name()
                        );
                    }
                }
                assert_eq!(net_s.messages(), net_p.messages());
                if spec == CompressionSpec::None {
                    for n in 0..topo.n {
                        assert_eq!(net_s.received_by(n), net_p.received_by(n));
                        assert_eq!(
                            net_s.bytes_received_by(n),
                            net_p.bytes_received_by(n)
                        );
                    }
                }
            }
        }
    }
}

/// Lossy compression under CHOCO error feedback still converges on the
/// dense-gossip proximal method: on elastic-net and logistic, top-k at
/// half density and QSGD both (a) move strictly fewer declared wire
/// bytes than the dense run at matched rounds, and (b) keep shrinking
/// the residual to the reference optimum (generous geometric envelope —
/// the compression error is proportional to the per-round delta, which
/// itself decays, so no bias floor blocks the decrease).
#[test]
fn lossy_compression_converges_within_envelope() {
    use dsba::comm::CompressionSpec;
    use dsba::runtime::transport::LocalTransport;
    let worlds: [&dyn Fn(usize) -> Arc<dyn Problem>; 2] =
        [&elastic_world, &logistic_world];
    for world in worlds {
        let topo = Topology::ring(4);
        let p = world(topo.n);
        let d = p.dim();
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let mut params = AlgoParams::new(0.25, d, 99);
        params.inner_tol = 1e-11;
        let z_star = dsba::coordinator::solve_optimum(p.as_ref(), 1e-11);
        let (rounds, early) = (240usize, 24usize);
        let run = |spec: &CompressionSpec| {
            let mut eng = ParallelEngine::new_full(
                AlgorithmKind::Dsba,
                p.clone(),
                &mix,
                &topo,
                &params,
                2,
                Box::new(LocalTransport::new(topo.n)),
                spec,
            );
            let mut net = Network::new(topo.clone(), CommCostModel::default());
            let mut res_early = f64::NAN;
            for r in 0..rounds {
                eng.step(&mut net);
                if r + 1 == early {
                    res_early = dsba::metrics::suboptimality(eng.iterates(), &z_star);
                }
            }
            let res_final = dsba::metrics::suboptimality(eng.iterates(), &z_star);
            (res_early, res_final, net.max_received_bytes())
        };
        let (_, _, dense_bytes) = run(&CompressionSpec::None);
        for spec in [CompressionSpec::TopK((d / 2).max(1)), CompressionSpec::Qsgd(64)] {
            let (res_early, res_final, bytes) = run(&spec);
            assert!(
                bytes < dense_bytes,
                "{}: moved {bytes} wire bytes, dense moved {dense_bytes}",
                spec.name()
            );
            assert!(
                res_final.is_finite() && res_final <= 0.5 * res_early,
                "{}: residual {res_early:.3e} (round {early}) -> {res_final:.3e} \
                 (round {rounds}) did not keep decreasing",
                spec.name()
            );
        }
    }
}

/// Mispaired endpoints must refuse each other: the handshake carries the
/// experiment seed, so two engines launched with different seeds fail
/// fast instead of silently diverging.
#[test]
fn tcp_handshake_rejects_seed_mismatch() {
    let topo = Topology::path(2);
    let l_a = TcpTransport::bind("127.0.0.1:0").unwrap();
    let l_b = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr_a = l_a.local_addr().to_string();
    let addr_b = l_b.local_addr().to_string();
    let topo_b = topo.clone();
    let hb = std::thread::spawn(move || {
        let peers: HashMap<usize, String> = [(0, addr_a)].into_iter().collect();
        TcpTransport::establish(l_b, &topo_b, 2, vec![1], &peers)
    });
    let peers: HashMap<usize, String> = [(1, addr_b)].into_iter().collect();
    let ra = TcpTransport::establish(l_a, &topo, 1, vec![0], &peers);
    let rb = hb.join().unwrap();
    assert!(
        ra.is_err() && rb.is_err(),
        "seed-mismatched endpoints must both fail (a: {}, b: {})",
        ra.is_ok(),
        rb.is_ok()
    );
}

/// Concurrency stress: random (nodes, threads, topology, method) triples
/// must complete a bounded number of rounds within a generous timeout (no
/// deadlock between the barrier protocol and channel delivery) and must
/// deliver every sent message exactly once.
#[test]
fn prop_engine_never_deadlocks_or_drops_messages() {
    prop_check("engine liveness + message conservation", 10, |rng| {
        let n = 2 + rng.below(7);
        let topo = match rng.below(4) {
            0 => Topology::ring(n),
            1 => Topology::grid2d(n),
            2 => Topology::erdos_renyi(n, 0.4 + 0.3 * rng.uniform(), rng.next_u64()),
            _ => Topology::complete(n),
        };
        let threads = 1 + rng.below(6);
        let rounds = 5 + rng.below(25);
        let kinds = [
            AlgorithmKind::Dsba,
            AlgorithmKind::DsbaSparse,
            AlgorithmKind::Extra,
            AlgorithmKind::Dgd,
        ];
        let kind = kinds[rng.below(kinds.len())];
        let seed = rng.next_u64();

        let (tx, rx) = std::sync::mpsc::channel();
        let topo2 = topo.clone();
        std::thread::spawn(move || {
            let ds = SyntheticSpec::tiny()
                .with_samples(40)
                .with_dim(20)
                .with_regression(true)
                .generate(seed);
            let p: Arc<dyn Problem> =
                Arc::new(RidgeProblem::new(ds.partition_seeded(topo2.n, 3), 0.05));
            let mix = MixingMatrix::laplacian(&topo2, 1.0);
            let params = AlgoParams::new(0.2, p.dim(), seed ^ 0xe7);
            let mut eng = ParallelEngine::new(kind, p, &mix, &topo2, &params, threads);
            let mut net = Network::new(topo2.clone(), CommCostModel::default());
            for _ in 0..rounds {
                eng.step(&mut net);
            }
            let stats = eng.message_stats();
            let finite = eng.iterates().iter().all(|z| z.iter().all(|v| v.is_finite()));
            // DSBA-s charges its one-time phibar flood (n*(n-1) dense
            // sends) into the network before round 0; those are setup
            // accounting, not engine messages
            let flood = if kind == AlgorithmKind::DsbaSparse {
                (topo2.n * (topo2.n - 1)) as u64
            } else {
                0
            };
            let _ = tx.send((stats, finite, net.messages() - flood));
        });
        // bounded-time rounds: a deadlocked engine never answers
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(((sent, delivered), finite, net_messages)) => {
                if sent != delivered {
                    return Err(format!(
                        "dropped messages: sent {sent}, delivered {delivered} \
                         (n={n}, threads={threads}, kind={})",
                        kind.name()
                    ));
                }
                if sent != net_messages {
                    return Err(format!(
                        "accounting missed messages: engine {sent} vs network {net_messages}"
                    ));
                }
                if !finite {
                    return Err("non-finite iterate".to_string());
                }
                Ok(())
            }
            Err(_) => Err(format!(
                "engine did not finish {rounds} rounds in 60s — deadlock? \
                 (n={n}, threads={threads}, kind={})",
                kind.name()
            )),
        }
    });
}
