//! Name/alias round-trip pins for every selector in the system: each
//! kind's `parse(name(k)) == Some(k)`, every documented alias parses to
//! the same kind, and the problem registry resolves every registered
//! spelling.  These tables are the single source behind the CLI listings,
//! so this suite is what keeps `dsba help`/`dsba info` truthful.

use dsba::algorithms::AlgorithmKind;
use dsba::graph::TopologyKind;
use dsba::operators::{ProblemRegistry, ProblemSpec, SaddleStat};
use dsba::prelude::*;
use dsba::util::json::Json;

#[test]
fn algorithm_kind_name_parse_roundtrip_including_aliases() {
    for &k in AlgorithmKind::all() {
        assert_eq!(
            AlgorithmKind::parse(k.name()),
            Some(k),
            "canonical name {} must parse",
            k.name()
        );
        // case-insensitive
        assert_eq!(AlgorithmKind::parse(&k.name().to_ascii_lowercase()), Some(k));
        assert_eq!(AlgorithmKind::parse(&k.name().to_ascii_uppercase()), Some(k));
        for alias in k.aliases() {
            assert_eq!(
                AlgorithmKind::parse(alias),
                Some(k),
                "alias {alias} must parse to {}",
                k.name()
            );
        }
    }
    // historical spellings stay accepted
    assert_eq!(AlgorithmKind::parse("dsba-s"), Some(AlgorithmKind::DsbaSparse));
    assert_eq!(AlgorithmKind::parse("dsba_sparse"), Some(AlgorithmKind::DsbaSparse));
    assert_eq!(AlgorithmKind::parse("p-extra"), Some(AlgorithmKind::PExtra));
    assert_eq!(AlgorithmKind::parse("point-saga"), Some(AlgorithmKind::PointSaga));
    assert_eq!(AlgorithmKind::parse("nope"), None);
}

#[test]
fn engine_transport_topology_kinds_roundtrip() {
    for k in [EngineKind::Sequential, EngineKind::Parallel] {
        assert_eq!(EngineKind::parse(k.name()), Some(k));
    }
    for k in [TransportKind::Local, TransportKind::Tcp] {
        assert_eq!(TransportKind::parse(k.name()), Some(k));
    }
    for k in [
        TopologyKind::ErdosRenyi,
        TopologyKind::Ring,
        TopologyKind::Path,
        TopologyKind::Star,
        TopologyKind::Complete,
        TopologyKind::Grid2d,
        TopologyKind::SmallWorld,
    ] {
        assert_eq!(TopologyKind::parse(k.name()), Some(k));
    }
}

#[test]
fn problem_registry_resolves_every_registered_spelling() {
    let reg = ProblemRegistry::builtin();
    // names are present and unique
    let names = reg.names();
    let mut sorted = names.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), names.len(), "duplicate canonical names");
    for e in reg.entries() {
        assert_eq!(reg.canonical(e.meta.name), Some(e.meta.name));
        assert_eq!(reg.canonical(&e.meta.name.to_ascii_uppercase()), Some(e.meta.name));
        for alias in e.meta.aliases {
            assert_eq!(
                reg.canonical(alias),
                Some(e.meta.name),
                "alias {alias} must resolve"
            );
        }
        // the describe() table covers every entry (CLI cannot drift)
        assert!(
            reg.describe().contains(e.meta.name),
            "{} missing from describe()",
            e.meta.name
        );
    }
    assert!(reg.resolve("not-a-problem").is_none());
}

#[test]
fn registry_problems_run_one_round_through_the_experiment_driver() {
    // every registered problem is actually runnable end to end (build ->
    // topology -> algorithm -> metrics) straight from a config that names
    // it — the registry is an execution surface, not just a lookup table
    for e in ProblemRegistry::builtin().entries() {
        let cfg = ExperimentConfig {
            problem: e.meta.name.into(),
            dataset: "tiny".into(),
            nodes: 4,
            passes: 1.0,
            ..Default::default()
        };
        let mut exp = cfg.build().unwrap_or_else(|err| {
            panic!("{}: config build failed: {err}", e.meta.name)
        });
        let trace = exp.run();
        assert!(!trace.rows.is_empty(), "{}: no metrics rows", e.meta.name);
        match e.meta.saddle_stat {
            Some(stat) => {
                // every saddle entry reports the generic saddle residual…
                assert!(
                    trace.last_saddle_res().is_finite(),
                    "{}: saddle problem must report the saddle residual",
                    e.meta.name
                );
                // …and only AUC-scored ones additionally report AUC
                assert_eq!(
                    trace.last_auc().is_finite(),
                    stat == SaddleStat::AucRanking,
                    "{}: AUC column disagrees with the declared saddle stat",
                    e.meta.name
                );
            }
            None => {
                let last = trace.rows.last().unwrap();
                assert!(
                    last.objective.is_finite(),
                    "{}: objective problem must report an objective",
                    e.meta.name
                );
                assert!(
                    last.saddle_res.is_nan(),
                    "{}: non-saddle problem must not report a saddle residual",
                    e.meta.name
                );
            }
        }
    }
}

#[test]
fn registry_constructors_reject_bad_params_with_clean_errors() {
    // constructors must return Err (never panic) on out-of-range knobs
    let reg = ProblemRegistry::builtin();
    let ds = SyntheticSpec::tiny().generate(3);
    for (name, key) in [
        ("elastic-net", "l1"),
        ("smoothed-hinge", "gamma"),
        ("robust-ls", "rho"),
        ("dro-bilinear", "nu"),
    ] {
        let Some(e) = reg.resolve(name) else {
            continue; // workload not registered yet in this build
        };
        let part = ds.partition_seeded(2, 1);
        let spec = ProblemSpec::new(name, 0.05)
            .with_params(Json::from_pairs(vec![(key, Json::Num(-1.0))]));
        assert!(
            e.build(&spec, &ds, part).is_err(),
            "{name}: negative {key} must be rejected"
        );
    }
}
