//! Fault-injection suite: the reliable link layer must make injected
//! wire faults *invisible* to the algorithm.
//!
//! The headline contract: under `--fault drop:0.05,dup:0.05` on loopback
//! TCP sockets, both the sync and the (trace-scheduled) async engines
//! converge **bit-identical** to their fault-free twins — every iterate,
//! every round — while the run's telemetry rows record nonzero
//! retransmit/dedup/injected-fault counters proving the faults actually
//! fired and were recovered, not silently skipped.
//!
//! Around it: the pinned `--fault` parse/name matrix, the
//! `kill:NODE@ROUND` fail-fast diagnostic surfaced through
//! `Experiment::try_run`, the coordinator guardrails (link faults need
//! TCP; any fault needs the parallel engine), and an end-to-end
//! experiment mixing drop/dup faults with a telemetry stream.

use dsba::algorithms::{AlgoParams, AlgorithmKind};
use dsba::comm::{CommCostModel, CompressionSpec, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::telemetry::{
    chrome_trace, validate_jsonl, EventKind, RunEvent, TelemetryLine, TelemetryRow,
};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Serializes tests whose engine construction must (or must not) see
/// `DSBA_ASYNC_TRACE` — cargo runs tests in this binary on parallel
/// threads.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn ridge_world(nodes: usize, seed: u64) -> Arc<dyn Problem> {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(seed);
    Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 3), 0.05))
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dsba_fault_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

/// The pinned fault matrix: input spec -> canonical name, plus the
/// parse/name inverse-pair law and the rejection set. Extending
/// `FaultSpec` means extending this table.
#[test]
fn fault_matrix_is_pinned() {
    let matrix: &[(&str, &str)] = &[
        ("none", "none"),
        ("", "none"),
        ("drop:0.05", "drop:0.05"),
        ("dup:0.05", "dup:0.05"),
        ("drop:0.05,dup:0.05", "drop:0.05,dup:0.05"),
        // clause order canonicalizes
        ("dup:0.1,drop:0.2", "drop:0.2,dup:0.1"),
        ("delay:150", "delay:150"),
        ("delay:150@2", "delay:150@2"),
        ("kill:3@10", "kill:3@10"),
        (
            "kill:1@4,delay:5@0,dup:0.02,drop:0.01",
            "drop:0.01,dup:0.02,delay:5@0,kill:1@4",
        ),
    ];
    for (input, canonical) in matrix {
        let f = FaultSpec::parse(input).unwrap_or_else(|e| panic!("{input:?}: {e}"));
        assert_eq!(&f.name(), canonical, "canonical name of {input:?}");
        assert_eq!(FaultSpec::parse(&f.name()).unwrap(), f, "{input:?} not an inverse pair");
    }
    for bad in ["drop:1.0", "dup:-0.1", "kill:3", "delay:5@", "warp:1", "drop:0.1,drop:0.2"] {
        assert!(FaultSpec::parse(bad).is_err(), "{bad:?} must be rejected");
    }
}

/// Core of the headline test: step a fault-free and a drop/dup-faulted
/// engine (same seed, same loopback-TCP transport class, same `mode`)
/// side by side, assert bit-identical iterates every round, then mine
/// the faulted run's telemetry for proof the faults fired.
fn assert_faulted_run_bit_identical(mode: ModeSpec, rounds: usize, tag: &str) {
    let topo = Topology::ring(6);
    let p = ridge_world(6, 17);
    let mix = MixingMatrix::laplacian(&topo, 1.0);
    let mut params = AlgoParams::new(0.25, p.dim(), 99);
    params.inner_tol = 1e-11;
    let fault = FaultSpec::parse("drop:0.05,dup:0.05").unwrap();
    let dir = scratch_dir(tag);
    let path = dir.join("run.jsonl");

    let build = |fault: &FaultSpec, telemetry: &TelemetrySpec| {
        let transport =
            TcpTransport::loopback(&topo, params.seed).expect("loopback transport setup");
        ParallelEngine::new_faulted(
            AlgorithmKind::Dsba,
            p.clone(),
            &mix,
            &topo,
            &params,
            3,
            Box::new(transport),
            &CompressionSpec::None,
            mode,
            fault,
            telemetry,
        )
        .expect("faulted engine builds")
    };
    let mut clean = build(&FaultSpec::none(), &TelemetrySpec::disabled());
    let mut faulty = build(&fault, &TelemetrySpec::to_path(path.to_str().unwrap()));

    let mut net_c = Network::new(topo.clone(), CommCostModel::default());
    let mut net_f = Network::new(topo.clone(), CommCostModel::default());
    for round in 0..rounds {
        clean.step(&mut net_c);
        faulty.step(&mut net_f);
        for n in 0..topo.n {
            assert_eq!(
                clean.iterates()[n],
                faulty.iterates()[n],
                "{tag} round {round} node {n}: faulted iterate != fault-free"
            );
        }
        assert_eq!(
            net_c.messages(),
            net_f.messages(),
            "{tag} round {round}: message counts diverged under faults"
        );
    }
    let (sent, delivered) = faulty.message_stats();
    assert_eq!(sent, delivered, "{tag}: engine-level messages were lost under faults");
    assert_eq!(
        faulty.telemetry_dropped(),
        Some(0),
        "{tag}: telemetry writer dropped rows"
    );

    // dropping the engine drains and joins the telemetry writer
    drop(faulty);
    let text = std::fs::read_to_string(&path).expect("telemetry stream exists");
    let n_rows = validate_jsonl(&text).expect("telemetry stream is schema-valid");
    assert!(
        n_rows >= rounds * topo.n,
        "{tag}: {n_rows} telemetry rows < {} (rounds x nodes)",
        rounds * topo.n
    );
    // link counters in a row are cumulative per node: keep each node's
    // latest row, then sum across nodes; control-plane event lines are
    // collected on the side for the attribution checks below
    let mut last: HashMap<u32, TelemetryRow> = HashMap::new();
    let mut events: Vec<RunEvent> = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let row = match TelemetryLine::parse(line).unwrap() {
            TelemetryLine::Row(row) => row,
            TelemetryLine::Summary(_) => continue,
            TelemetryLine::Event(e) => {
                events.push(e);
                continue;
            }
        };
        let keep = last.get(&row.node).map_or(true, |prev| prev.round < row.round);
        if keep {
            last.insert(row.node, row);
        }
    }
    assert_eq!(last.len(), topo.n, "{tag}: telemetry must cover every node");
    let total = |f: fn(&TelemetryRow) -> u64| last.values().map(f).sum::<u64>();
    assert!(
        total(|r| r.drops_injected) > 0,
        "{tag}: injector never dropped a frame — fault did not fire"
    );
    assert!(
        total(|r| r.dups_injected) > 0,
        "{tag}: injector never duplicated a frame — fault did not fire"
    );
    assert!(
        total(|r| r.retransmits) > 0,
        "{tag}: no NACK/retransmit recovered a dropped frame"
    );
    assert!(
        total(|r| r.dedups) > 0,
        "{tag}: no receiver deduplicated an injected duplicate"
    );
    // the event lines tell the same recovery story with per-link
    // attribution: every nack/retransmit/dedup event names both ends
    for kind in [EventKind::NackSent, EventKind::Retransmit, EventKind::Dedup] {
        let of_kind: Vec<&RunEvent> = events.iter().filter(|e| e.kind == kind).collect();
        assert!(
            !of_kind.is_empty(),
            "{tag}: counters fired but no {} event line landed",
            kind.name()
        );
        assert!(
            of_kind.iter().all(|e| e.node.is_some() && e.peer.is_some()),
            "{tag}: {} events must carry per-link (node, peer) attribution",
            kind.name()
        );
    }
    assert!(
        events.iter().any(|e| e.kind == EventKind::Handshake),
        "{tag}: link bring-up left no handshake events"
    );
    // the same stream exports as a loadable Chrome trace: an array of
    // complete/instant events, every entry with a ph and a ts
    let trace = chrome_trace(&text).expect("chrome export from the faulted stream");
    let arr = trace.as_arr().expect("trace-event JSON is an array");
    assert!(!arr.is_empty(), "{tag}: chrome trace drew nothing");
    assert!(
        arr.iter().all(|e| e.get("ph").is_some() && e.get("ts").is_some()),
        "{tag}: malformed trace-event entry"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Headline, sync clock: drop/dup on loopback TCP is bit-identical to
/// the fault-free run, and the telemetry counters prove the faults fired.
#[test]
fn drop_dup_tcp_bit_identical_sync() {
    let _guard = env_guard();
    assert_faulted_run_bit_identical(ModeSpec::Sync, 20, "sync");
}

/// Headline, async clock: same contract under `async:1` on the
/// replayable trace schedule (both runs follow the identical pinned
/// admission plan, so recovery must not perturb a single bit).
#[test]
fn drop_dup_tcp_bit_identical_async() {
    let _guard = env_guard();
    std::env::set_var("DSBA_ASYNC_TRACE", "1");
    assert_faulted_run_bit_identical(ModeSpec::Async(1), 16, "async");
    std::env::remove_var("DSBA_ASYNC_TRACE");
}

/// `kill:NODE@ROUND` through the full coordinator stack: `try_run`
/// fails fast with an error naming the node, the round, and the
/// last-seen peer watermarks — never a bare panic.
#[test]
fn kill_fault_fails_fast_with_named_diagnostic() {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
    let topo = Topology::ring(4);
    let mut exp = Experiment::builder(
        RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
        topo,
        AlgorithmKind::Dsba,
    )
    .step_size(0.25)
    .passes(6.0)
    .engine(EngineSpec::parallel(2))
    .fault(FaultSpec::parse("kill:1@2").unwrap())
    .build();
    let err = exp.try_run().expect_err("killed run must fail");
    assert!(err.contains("killed by fault injection"), "diagnostic: {err}");
    assert!(err.contains("node 1"), "diagnostic must name the node: {err}");
    assert!(err.contains("round 2"), "diagnostic must name the round: {err}");
    assert!(err.contains("watermark"), "diagnostic must carry watermarks: {err}");
}

/// A killed TCP run with telemetry leaves the flight recorder's black
/// box behind: the `<stream>.crash` sidecar is written on the fail-fast
/// path (before the panic unwinds) and contains the `node-kill` event
/// naming the killed node and round.
#[test]
fn kill_fault_dumps_the_flight_recorder() {
    let dir = scratch_dir("kill_dump");
    let path = dir.join("run.jsonl");
    let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
    let mut exp = Experiment::builder(
        RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
        Topology::ring(4),
        AlgorithmKind::Dsba,
    )
    .step_size(0.25)
    .passes(6.0)
    .engine(EngineSpec::parallel(2).with_transport(TransportKind::Tcp))
    .fault(FaultSpec::parse("kill:1@2").unwrap())
    .telemetry(TelemetrySpec::to_path(path.to_str().unwrap()))
    .build();
    let err = exp.try_run().expect_err("killed run must fail");
    assert!(err.contains("killed by fault injection"), "diagnostic: {err}");
    drop(exp); // joins the engine's telemetry writer

    let crash = PathBuf::from(format!("{}.crash", path.display()));
    let text = std::fs::read_to_string(&crash).expect("crash sidecar written on kill");
    let kills: Vec<RunEvent> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| RunEvent::from_json_line(l).expect("crash sidecar line parses"))
        .filter(|e| e.kind == EventKind::NodeKill)
        .collect();
    assert_eq!(kills.len(), 1, "exactly one node-kill event in the black box");
    assert_eq!(kills[0].node, Some(1), "dump must name the killed node");
    assert_eq!(kills[0].round, Some(2), "dump must name the kill round");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Coordinator guardrails: faults need the parallel engine, and
/// drop/dup link faults additionally need the TCP transport's reliable
/// link layer — both misconfigurations fail at `try_run` with an error
/// naming the fix.
#[test]
fn fault_guardrails_name_their_fix() {
    let build = |engine: EngineSpec, fault: &str| {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
        Experiment::builder(
            RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
            Topology::ring(4),
            AlgorithmKind::Dsba,
        )
        .step_size(0.25)
        .passes(2.0)
        .engine(engine)
        .fault(FaultSpec::parse(fault).unwrap())
        .build()
    };
    let err = build(EngineSpec::sequential(), "drop:0.1").try_run().unwrap_err();
    assert!(err.contains("parallel"), "sequential + fault: {err}");
    let err = build(EngineSpec::parallel(2), "drop:0.1").try_run().unwrap_err();
    assert!(err.contains("tcp"), "local transport + link fault: {err}");
    // delay alone is transport-agnostic: a delayed local run still works
    let trace = build(EngineSpec::parallel(2), "delay:1@0")
        .try_run()
        .expect("delay fault runs on the local transport");
    assert!(trace.rows.last().unwrap().suboptimality.is_finite());
}

/// End-to-end: a TCP experiment with drop/dup faults AND a telemetry
/// stream runs through `Experiment::try_run`, reports finite metrics,
/// and leaves a schema-valid JSONL file behind — the `make smoke`
/// scenario as an in-process test.
#[test]
fn experiment_with_faults_and_telemetry_end_to_end() {
    let dir = scratch_dir("e2e");
    let path = dir.join("run.jsonl");
    let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
    let mut exp = Experiment::builder(
        RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
        Topology::ring(4),
        AlgorithmKind::Dsba,
    )
    .step_size(0.25)
    .passes(4.0)
    .record_points(4)
    .engine(EngineSpec::parallel(2).with_transport(TransportKind::Tcp))
    .fault(FaultSpec::parse("drop:0.05,dup:0.05").unwrap())
    .telemetry(TelemetrySpec::to_path(path.to_str().unwrap()))
    .build();
    let trace = exp.try_run().expect("faulted telemetry experiment runs");
    assert!(trace.rows.last().unwrap().suboptimality.is_finite());
    drop(exp); // joins the engine's telemetry writer
    let text = std::fs::read_to_string(&path).expect("telemetry stream exists");
    let rows = validate_jsonl(&text).expect("telemetry stream is schema-valid");
    assert!(rows > 0, "telemetry stream is empty");
    let _ = std::fs::remove_dir_all(&dir);
}
