//! §5.1 integration tests: DSBA-s must produce *identical* iterates to
//! dense DSBA while moving asymptotically less data on sparse problems —
//! on every problem type and several topologies.

use dsba::algorithms::{AlgoParams, Algorithm, AlgorithmKind, Dsba, DsbaSparse};
use dsba::comm::{CommCostModel, Network};
use dsba::coordinator::Experiment;
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use std::sync::Arc;

fn check_equivalence(problem: Arc<dyn Problem>, topo: Topology, alpha: f64, rounds: usize) {
    let mix = MixingMatrix::laplacian(&topo, 1.0);
    let params = AlgoParams::new(alpha, problem.dim(), 1234);
    let mut dense = Dsba::new(problem.clone(), mix.clone(), topo.clone(), &params);
    let mut sparse = DsbaSparse::new(problem.clone(), mix, topo.clone(), &params);
    let mut net1 = Network::new(topo.clone(), CommCostModel::default());
    let mut net2 = Network::new(topo, CommCostModel::default());
    for round in 0..rounds {
        dense.step(&mut net1);
        sparse.step(&mut net2);
        for n in 0..problem.nodes() {
            let d = dsba::linalg::dist2_sq(&dense.iterates()[n], &sparse.iterates()[n]);
            assert!(
                d < 1e-16,
                "round {round}, node {n}: DSBA-s diverged from DSBA by {d:.3e}"
            );
        }
    }
}

#[test]
fn equivalence_ridge_er_graph() {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(1);
    check_equivalence(
        Arc::new(RidgeProblem::new(ds.partition_seeded(5, 2), 0.05)),
        Topology::erdos_renyi(5, 0.5, 3),
        0.7,
        150,
    );
}

#[test]
fn equivalence_logistic_ring() {
    // ring of 6 has diameter 3: deep relay pipeline
    let ds = SyntheticSpec::tiny().generate(2);
    check_equivalence(
        Arc::new(LogisticProblem::new(ds.partition_seeded(6, 2), 0.05)),
        Topology::ring(6),
        1.5,
        120,
    );
}

#[test]
fn equivalence_auc_star() {
    let ds = SyntheticSpec::tiny().generate(3);
    check_equivalence(
        Arc::new(AucProblem::new(ds.partition_seeded(5, 2), 0.05)),
        Topology::star(5),
        0.4,
        100,
    );
}

#[test]
fn equivalence_path_graph_max_diameter() {
    // worst-case pipeline depth: path of 6 has diameter 5
    let ds = SyntheticSpec::tiny().with_regression(true).generate(4);
    check_equivalence(
        Arc::new(RidgeProblem::new(ds.partition_seeded(6, 2), 0.1)),
        Topology::path(6),
        0.6,
        100,
    );
}

#[test]
fn equivalence_elastic_net_prox_replay() {
    // proximal backward (l1 soft-threshold): the sparse relay's replay
    // must apply the same resolvent when reconstructing remote rows, or
    // every reconstruction drifts by ~alpha*l1 per coordinate per round
    let ds = SyntheticSpec::tiny().with_regression(true).generate(6);
    check_equivalence(
        Arc::new(dsba::operators::ElasticNetProblem::new(
            ds.partition_seeded(5, 2),
            0.05,
            0.02,
        )),
        Topology::erdos_renyi(5, 0.5, 3),
        0.7,
        120,
    );
}

#[test]
fn equivalence_with_zero_lambda() {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(5);
    check_equivalence(
        Arc::new(RidgeProblem::new(ds.partition_seeded(4, 2), 0.0)),
        Topology::erdos_renyi(4, 0.7, 9),
        0.5,
        100,
    );
}

#[test]
fn sparse_comm_wins_on_sparse_data_loses_on_dense() {
    // Table 1's communication tradeoff: DSBA-s moves O(N rho d), dense
    // DSBA moves O(Delta d). On very sparse data sparse wins by a big
    // factor; as density grows the advantage shrinks/reverses.
    let topo = Topology::erdos_renyi(8, 0.4, 11);
    let mut ratios = Vec::new();
    for rho in [0.002, 0.3] {
        let ds = SyntheticSpec::tiny()
            .with_samples(240)
            .with_dim(1500)
            .with_density(rho)
            .with_regression(true)
            .generate(7);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(ds.partition_seeded(8, 2), 0.05));
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(0.5, p.dim(), 77);
        let mut dense = Dsba::new(p.clone(), mix.clone(), topo.clone(), &params);
        let mut sparse = DsbaSparse::new(p.clone(), mix, topo.clone(), &params);
        let mut net1 = Network::new(topo.clone(), CommCostModel::default());
        let mut net2 = Network::new(topo.clone(), CommCostModel::default());
        for _ in 0..60 {
            dense.step(&mut net1);
            sparse.step(&mut net2);
        }
        ratios.push(net2.max_received() / net1.max_received());
    }
    assert!(ratios[0] < 0.35, "sparse data: ratio {:.3} should be << 1", ratios[0]);
    assert!(
        ratios[1] > 3.0 * ratios[0],
        "dense data must erode the advantage: {:?}",
        ratios
    );
}

#[test]
fn dsba_s_through_experiment_driver() {
    let ds = SyntheticSpec::tiny().with_regression(true).generate(8);
    let topo = Topology::erdos_renyi(5, 0.5, 13);
    let mut exp = Experiment::builder(
        RidgeProblem::new(ds.partition_seeded(5, 2), 0.05),
        topo,
        AlgorithmKind::DsbaSparse,
    )
    .step_size(0.7)
    .passes(50.0)
    .build();
    let t = exp.run();
    assert!(t.last_suboptimality() < 1e-7, "{:.3e}", t.last_suboptimality());
}
