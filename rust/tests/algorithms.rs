//! Cross-algorithm integration tests: every linear-convergent method must
//! reach the same optimum; the qualitative orderings of the paper's
//! figures must hold on small instances.

use dsba::prelude::*;
use dsba::algorithms::AlgorithmKind::*;
use dsba::coordinator::Experiment;

fn ridge_world(seed: u64) -> (dsba::data::Dataset, Topology) {
    let ds = SyntheticSpec::tiny()
        .with_samples(160)
        .with_regression(true)
        .generate(seed);
    let topo = Topology::erdos_renyi(4, 0.6, seed ^ 1);
    (ds, topo)
}

#[test]
fn all_linear_methods_agree_on_the_optimum() {
    let (ds, topo) = ridge_world(101);
    let part = ds.partition_seeded(4, 2);
    let problem = RidgeProblem::new(part, 0.05);
    let z_star = dsba::coordinator::solve_optimum(&problem, 1e-12);

    let runs = [
        (Dsba, 0.8, 60.0),
        (DsbaSparse, 0.8, 60.0),
        (Dsa, 0.25, 120.0),
        (Extra, 0.4, 400.0),
        // P-EXTRA's exact resolvents burn many passes per round (the
        // computational cost DSBA is designed to avoid) — budget for it
        (PExtra, 2.0, 30_000.0),
        (Ssda, 0.9, 30_000.0), // conjugate oracle burns passes per round
        (Dlm, 0.0, 2500.0),
    ];
    for (kind, alpha, passes) in runs {
        let part = ds.partition_seeded(4, 2);
        let mut exp = Experiment::builder(RidgeProblem::new(part, 0.05), topo.clone(), kind)
            .step_size(alpha)
            .passes(passes)
            .z_star(z_star.clone())
            .params(|p| {
                p.dlm_c = 0.5;
                p.dlm_rho = 1.5;
            })
            .build();
        let trace = exp.run();
        assert!(
            trace.last_suboptimality() < 1e-6,
            "{:?} ended at {:.3e}",
            kind,
            trace.last_suboptimality()
        );
    }
}

#[test]
fn stochastic_methods_beat_deterministic_per_pass_ridge() {
    // Figure 1's left panels: at a small pass budget, DSBA < DSA < EXTRA
    // in suboptimality (same tuned steps as the figure harness)
    let (ds, topo) = ridge_world(103);
    let part = ds.partition_seeded(4, 2);
    let problem = RidgeProblem::new(part, 0.01);
    let z_star = dsba::coordinator::solve_optimum(&problem, 1e-12);
    let passes = 15.0;

    let mut results = std::collections::HashMap::new();
    for (kind, alpha) in [(Dsba, 1.0), (Dsa, 0.3), (Extra, 0.45)] {
        let part = ds.partition_seeded(4, 2);
        let mut exp = Experiment::builder(RidgeProblem::new(part, 0.01), topo.clone(), kind)
            .step_size(alpha)
            .passes(passes)
            .z_star(z_star.clone())
            .build();
        results.insert(kind.name(), exp.run().last_suboptimality());
    }
    let (dsba, dsa, extra) = (results["DSBA"], results["DSA"], results["EXTRA"]);
    assert!(dsba < dsa, "DSBA {dsba:.3e} !< DSA {dsa:.3e}");
    assert!(dsa < extra, "DSA {dsa:.3e} !< EXTRA {extra:.3e}");
}

#[test]
fn dsba_handles_logistic_and_auc() {
    let ds = SyntheticSpec::tiny().with_samples(160).generate(105);
    let topo = Topology::erdos_renyi(4, 0.6, 7);

    let mut exp = Experiment::builder(
        LogisticProblem::new(ds.partition_seeded(4, 2), 0.05),
        topo.clone(),
        Dsba,
    )
    .step_size(2.0)
    .passes(60.0)
    .build();
    let t = exp.run();
    assert!(t.last_suboptimality() < 1e-8, "logistic: {:.3e}", t.last_suboptimality());

    let mut exp = Experiment::builder(
        AucProblem::new(ds.partition_seeded(4, 2), 0.05),
        topo,
        Dsba,
    )
    .step_size(0.5)
    .passes(60.0)
    .build();
    let t = exp.run();
    assert!(t.last_suboptimality() < 1e-7, "auc: {:.3e}", t.last_suboptimality());
    assert!(t.last_auc() > 0.8, "AUC {:.3}", t.last_auc());
}

#[test]
fn dgd_stalls_where_linear_methods_converge() {
    let (ds, topo) = ridge_world(107);
    let problem = RidgeProblem::new(ds.partition_seeded(4, 2), 0.05);
    let z_star = dsba::coordinator::solve_optimum(&problem, 1e-12);
    let mut dgd = Experiment::builder(
        RidgeProblem::new(ds.partition_seeded(4, 2), 0.05),
        topo.clone(),
        Dgd,
    )
    .step_size(0.4)
    .passes(120.0)
    .z_star(z_star.clone())
    .build();
    let t_dgd = dgd.run();
    let mut extra = Experiment::builder(
        RidgeProblem::new(ds.partition_seeded(4, 2), 0.05),
        topo,
        Extra,
    )
    .step_size(0.4)
    .passes(120.0)
    .z_star(z_star)
    .build();
    let t_extra = extra.run();
    assert!(
        t_extra.last_suboptimality() < t_dgd.last_suboptimality() * 1e-2,
        "EXTRA {:.3e} should be orders below DGD {:.3e}",
        t_extra.last_suboptimality(),
        t_dgd.last_suboptimality()
    );
}

#[test]
fn larger_kappa_g_slows_dsba() {
    // Table 1: iterations scale with kappa_g. Ring (large kappa_g) must
    // need more passes to a fixed tolerance than complete graph (small).
    let ds = SyntheticSpec::tiny()
        .with_samples(240)
        .with_regression(true)
        .generate(109);
    let tol = 1e-8;
    let mut passes_needed = Vec::new();
    for topo in [Topology::complete(8), Topology::ring(8)] {
        let part = ds.partition_seeded(8, 2);
        let problem = RidgeProblem::new(part, 0.05);
        let z_star = dsba::coordinator::solve_optimum(&problem, 1e-12);
        let mut exp = Experiment::builder(problem, topo, Dsba)
            .step_size(0.8)
            .passes(300.0)
            .record_points(300)
            .z_star(z_star)
            .build();
        let trace = exp.run();
        passes_needed.push(trace.passes_to_tol(tol).unwrap_or(f64::INFINITY));
    }
    assert!(
        passes_needed[0] < passes_needed[1],
        "complete {:.1} should beat ring {:.1}",
        passes_needed[0],
        passes_needed[1]
    );
}
