//! Telemetry subsystem integration suite.
//!
//! Three contracts beyond the unit tests in `src/telemetry/`:
//!
//! 1. **Thousand-node smoke.** A 1000-node ring on the parallel engine
//!    (tiny rounds) stays bit-identical to the sequential oracle while
//!    the telemetry writer keeps up: one schema-valid row per
//!    (round, node) pair, none dropped — the writer scales with node
//!    count, not just with the 4-6 node suites.
//! 2. **Concurrent writers never tear rows.** Any number of threads
//!    hammering cloned [`TelemetrySink`]s concurrently must leave a
//!    stream where every line is a complete, schema-valid row whose
//!    payload matches exactly one emitted row (accounting for the
//!    drop-with-counter overflow contract).
//! 3. **Rotation and retention through the spec.** `telemetry.max_bytes`
//!    / `telemetry.keep` rotate the live file on whole-line boundaries,
//!    keep exactly `keep` generations, and leave every generation
//!    independently valid JSONL.

use dsba::algorithms::{build, AlgoParams, AlgorithmKind};
use dsba::comm::{CommCostModel, CompressionSpec, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::runtime::transport::LocalTransport;
use dsba::telemetry::{
    validate_jsonl, validate_jsonl_detailed, EventKind, RunEvent, TelemetryLine, TelemetryRow,
};
use dsba::testing::prop_check;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Arc;

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dsba_telem_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

/// Contract 1: 1000 nodes, ring topology, two rounds. Per-node iterates
/// pinned against the sequential oracle; the telemetry stream covers
/// every (round, node) pair exactly once with zero dropped rows.
#[test]
fn thousand_node_ring_smoke() {
    let nodes = 1000usize;
    let rounds = 2usize;
    let dir = scratch_dir("thousand");
    let path = dir.join("run.jsonl");

    let ds = SyntheticSpec::tiny()
        .with_samples(2 * nodes)
        .with_dim(8)
        .with_regression(true)
        .generate(71);
    let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 3), 0.05));
    let topo = Topology::ring(nodes);
    let mix = MixingMatrix::laplacian(&topo, 1.0);
    let params = AlgoParams::new(0.2, p.dim(), 99);

    let mut seq = build(AlgorithmKind::Dgd, p.clone(), &mix, &topo, &params);
    let mut par = ParallelEngine::new_faulted(
        AlgorithmKind::Dgd,
        p,
        &mix,
        &topo,
        &params,
        2,
        Box::new(LocalTransport::new(nodes)),
        &CompressionSpec::None,
        ModeSpec::Sync,
        &FaultSpec::none(),
        &TelemetrySpec::to_path(path.to_str().unwrap()),
    )
    .expect("thousand-node engine builds");

    let mut net_s = Network::new(topo.clone(), CommCostModel::default());
    let mut net_p = Network::new(topo.clone(), CommCostModel::default());
    for round in 0..rounds {
        seq.step(&mut net_s);
        par.step(&mut net_p);
        for n in [0, 1, nodes / 2, nodes - 1] {
            assert_eq!(
                seq.iterates()[n],
                par.iterates()[n],
                "round {round} node {n}: parallel iterate != sequential at 1000 nodes"
            );
        }
    }
    // full sweep at the end: every node's state is pinned, not a sample
    for n in 0..nodes {
        assert_eq!(seq.iterates()[n], par.iterates()[n], "node {n} diverged");
    }
    assert_eq!(par.telemetry_dropped(), Some(0), "writer fell behind at 1000 nodes");
    drop(par);

    let text = std::fs::read_to_string(&path).expect("telemetry stream exists");
    assert_eq!(
        validate_jsonl(&text),
        Ok(rounds * nodes),
        "one schema-valid row per (round, node)"
    );
    let mut seen = HashSet::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let row = match TelemetryLine::parse(line).unwrap() {
            TelemetryLine::Row(row) => row,
            TelemetryLine::Summary(s) => {
                assert_eq!(s.rows_dropped, 0, "summary disagrees with telemetry_dropped()");
                continue;
            }
            TelemetryLine::Event(_) => continue,
        };
        assert!(row.round < rounds as u64, "row for unfinished round {}", row.round);
        assert!((row.node as usize) < nodes, "row for unknown node {}", row.node);
        assert!(
            seen.insert((row.round, row.node)),
            "duplicate row for round {} node {}",
            row.round,
            row.node
        );
        // a gossip round moves data on a ring: both directions charged
        assert!(row.doubles_sent > 0.0, "node {} sent nothing", row.node);
        assert!(row.doubles_recv > 0.0, "node {} received nothing", row.node);
    }
    assert_eq!(seen.len(), rounds * nodes);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Contract 2 (property): N concurrent sinks emitting distinct payloads
/// produce a stream that is always well-formed and row-complete — every
/// line parses as a full schema row, every parsed row matches one
/// emitted row bit-for-bit, and written + dropped accounts for every
/// emit call.
#[test]
fn prop_concurrent_writers_emit_wellformed_complete_rows() {
    prop_check("concurrent telemetry writers", 8, |rng| {
        let threads = 2 + rng.below(6);
        let rows_per_thread = 50 + rng.below(200);
        let dir = scratch_dir("prop");
        let path = dir.join("t.jsonl");
        let spec = TelemetrySpec::to_path(path.to_str().unwrap());
        let writer = spec.spawn_writer()?.expect("enabled spec spawns");

        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let sink = writer.sink();
                std::thread::spawn(move || {
                    for i in 0..rows_per_thread {
                        sink.emit(TelemetryRow {
                            round: i as u64,
                            node: t as u32,
                            // payload tied to (node, round): a torn or
                            // interleaved line cannot reproduce it
                            residual: (t * 100_000 + i) as f64,
                            ..TelemetryRow::default()
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().map_err(|_| "emitter thread panicked".to_string())?;
        }
        let (written, dropped) = writer.finish()?;
        let total = (threads * rows_per_thread) as u64;
        if written + dropped != total {
            return Err(format!(
                "accounting: written {written} + dropped {dropped} != emitted {total}"
            ));
        }

        let text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
        let n = validate_jsonl(&text).map_err(|e| format!("stream not well-formed: {e}"))?;
        if n as u64 != written {
            return Err(format!("file has {n} rows, writer reported {written}"));
        }
        let mut seen = HashSet::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let row = match TelemetryLine::parse(line)? {
                TelemetryLine::Row(row) => row,
                TelemetryLine::Summary(s) => {
                    if (s.rows_written, s.rows_dropped) != (written, dropped) {
                        return Err(format!(
                            "summary line says {}/{} but writer reported {written}/{dropped}",
                            s.rows_written, s.rows_dropped
                        ));
                    }
                    continue;
                }
                TelemetryLine::Event(_) => continue,
            };
            let expect = (row.node as usize * 100_000 + row.round as usize) as f64;
            if row.residual != expect {
                return Err(format!(
                    "torn row: node {} round {} carries residual {} (expected {expect})",
                    row.node, row.round, row.residual
                ));
            }
            if !seen.insert((row.node, row.round)) {
                return Err(format!(
                    "row for node {} round {} written twice",
                    row.node, row.round
                ));
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Contract 4 (property): any v2 row roundtrips bit-for-bit through its
/// JSONL line, and a hand-written v1 line carrying the same base fields
/// (no phase spans — what PR 8 builds wrote) still parses, with the
/// spans reading as zero. Forward compatibility stays a named error:
/// bumping the same row to v3 must fail, not panic.
#[test]
fn prop_v2_rows_roundtrip_and_v1_rows_still_parse() {
    prop_check("telemetry schema v2 roundtrip + v1 back-compat", 64, |rng| {
        let mut u = |bound: usize| rng.below(bound) as u64;
        let row = TelemetryRow {
            round: u(1 << 20),
            node: u(10_000) as u32,
            residual: 0.0,
            doubles_sent: u(1 << 20) as f64,
            doubles_recv: u(1 << 20) as f64 + 0.5,
            bytes_on_wire: u(1 << 30),
            wall_micros: u(1 << 30),
            queue_depth: u(64),
            staleness: u(8),
            stalls: u(1000),
            retransmits: u(1000),
            dedups: u(1000),
            drops_injected: u(1000),
            dups_injected: u(1000),
            wait_micros: u(1 << 30),
            drain_micros: u(1 << 30),
            compute_micros: u(1 << 30),
            encode_micros: u(1 << 30),
            send_micros: u(1 << 30),
        };
        // a residual with a full mantissa must survive the text form:
        // f64 Display prints the shortest roundtripping representation
        let row = TelemetryRow { residual: rng.uniform() * 10.0, ..row };
        let line = row.to_json_line();
        let back = TelemetryRow::from_json_line(&line)
            .map_err(|e| format!("v2 roundtrip parse failed: {e}"))?;
        if back != row {
            return Err(format!("v2 roundtrip drifted:\n  {row:?}\n  {back:?}"));
        }
        // the same record as a v1 producer would have written it
        let v1_line = format!(
            "{{\"v\":1,\"round\":{},\"node\":{},\"residual\":{},\
             \"doubles_sent\":{},\"doubles_recv\":{},\"bytes_on_wire\":{},\
             \"wall_micros\":{},\"queue_depth\":{},\"staleness\":{},\
             \"stalls\":{},\"retransmits\":{},\"dedups\":{},\
             \"drops_injected\":{},\"dups_injected\":{}}}",
            row.round,
            row.node,
            row.residual,
            row.doubles_sent,
            row.doubles_recv,
            row.bytes_on_wire,
            row.wall_micros,
            row.queue_depth,
            row.staleness,
            row.stalls,
            row.retransmits,
            row.dedups,
            row.drops_injected,
            row.dups_injected,
        );
        let old = TelemetryRow::from_json_line(&v1_line)
            .map_err(|e| format!("v1 back-compat parse failed: {e}"))?;
        let expect_v1 = TelemetryRow {
            wait_micros: 0,
            drain_micros: 0,
            compute_micros: 0,
            encode_micros: 0,
            send_micros: 0,
            ..row.clone()
        };
        if old != expect_v1 {
            return Err("v1 row did not parse to zero phase spans".to_string());
        }
        // unknown future schema: named rejection, never a panic
        let v3_line = line.replace("\"v\":2", "\"v\":3");
        match TelemetryRow::from_json_line(&v3_line) {
            Err(e) if e.contains("unsupported telemetry schema v3") => Ok(()),
            Err(e) => Err(format!("v3 rejected with the wrong error: {e}")),
            Ok(_) => Err("a v3 row must not parse".to_string()),
        }
    });
}

/// Contract 5 (property): arbitrary control-plane event lines roundtrip
/// bit-for-bit, and interleaving them with v1 and v2 rows at random
/// positions leaves the stream valid — `validate_jsonl` still counts
/// exactly the data rows, with events tallied separately.
#[test]
fn prop_event_lines_roundtrip_and_interleave_with_rows() {
    prop_check("event line roundtrip + interleave", 64, |rng| {
        let kind = EventKind::ALL[rng.below(EventKind::ALL.len())];
        let mut ev = RunEvent::new(kind);
        ev.ts_micros = rng.below(1 << 40) as u64;
        if rng.below(2) == 1 {
            ev = ev.node(rng.below(10_000) as u32);
        }
        if rng.below(2) == 1 {
            ev = ev.peer(rng.below(10_000) as u32);
        }
        if rng.below(2) == 1 {
            ev = ev.round(rng.below(1 << 20) as u64);
        }
        if rng.below(2) == 1 {
            ev = ev.seq(rng.below(1 << 30) as u64);
        }
        if rng.below(2) == 1 {
            ev = ev.detail(format!("ctx \"{}\" / gap", rng.below(100)));
        }
        let line = ev.to_json_line();
        let back = RunEvent::from_json_line(&line)
            .map_err(|e| format!("event roundtrip parse failed: {e}"))?;
        if back != ev {
            return Err(format!("event roundtrip drifted:\n  {ev:?}\n  {back:?}"));
        }
        match TelemetryLine::parse(&line)? {
            TelemetryLine::Event(e) if e == ev => {}
            other => return Err(format!("stream parser misread the event: {other:?}")),
        }

        // splice events between v1 and v2 rows at random positions
        let rows = 1 + rng.below(6);
        let mut stream = String::new();
        let mut expect_rows = 0usize;
        let mut expect_events = 0usize;
        for r in 0..rows {
            if rng.below(2) == 1 {
                stream.push_str(&line);
                stream.push('\n');
                expect_events += 1;
            }
            let row = TelemetryRow { round: r as u64, node: 7, ..TelemetryRow::default() };
            let mut row_line = row.to_json_line();
            if rng.below(2) == 1 {
                // what a v1 producer wrote: no spans, version 1
                row_line = format!(
                    "{{\"v\":1,\"round\":{r},\"node\":7,\"residual\":0,\
                     \"doubles_sent\":0,\"doubles_recv\":0,\"bytes_on_wire\":0,\
                     \"wall_micros\":0,\"queue_depth\":0,\"staleness\":0,\
                     \"stalls\":0,\"retransmits\":0,\"dedups\":0,\
                     \"drops_injected\":0,\"dups_injected\":0}}"
                );
            }
            stream.push_str(&row_line);
            stream.push('\n');
            expect_rows += 1;
        }
        if validate_jsonl(&stream)? != expect_rows {
            return Err("validate_jsonl no longer counts exactly the rows".into());
        }
        match validate_jsonl_detailed(&stream)? {
            (r, e, false) if r == expect_rows && e == expect_events => Ok(()),
            other => Err(format!(
                "detailed validation saw {other:?}, expected ({expect_rows}, \
                 {expect_events}, false)"
            )),
        }
    });
}

/// Contract 3: max_bytes/keep drive rotation through the spec layer.
/// The retention chain holds exactly `keep` rotated generations, each
/// one — and the live file — independently valid JSONL, with no row
/// lost inside the retained window boundaries.
#[test]
fn rotation_keeps_generations_of_valid_jsonl() {
    let dir = scratch_dir("rotate");
    let path = dir.join("t.jsonl");
    let spec = TelemetrySpec {
        path: path.to_str().unwrap().to_string(),
        max_bytes: 2048,
        keep: 2,
    };
    let writer = spec.spawn_writer().expect("writer spawns").expect("spec is enabled");
    let sink = writer.sink();
    let total = 200u64;
    for r in 0..total {
        sink.emit(TelemetryRow { round: r, node: 0, ..TelemetryRow::default() });
    }
    let (written, dropped) = writer.finish().expect("writer finishes");
    assert_eq!(written + dropped, total);

    let gen = |i: usize| PathBuf::from(format!("{}.{i}", path.display()));
    assert!(path.exists(), "live file missing");
    assert!(gen(1).exists() && gen(2).exists(), "retained generations missing");
    assert!(!gen(3).exists(), "keep=2 must discard older generations");
    // every surviving generation is independently valid, rounds strictly
    // increase across the chain (oldest retained -> live), and at least
    // one rotation actually happened under the 2 KiB cap
    let mut rows_seen = 0usize;
    let mut last_round: Option<u64> = None;
    for file in [gen(2), gen(1), path.clone()] {
        let text = std::fs::read_to_string(&file).unwrap();
        let n = validate_jsonl(&text)
            .unwrap_or_else(|e| panic!("{} not valid JSONL: {e}", file.display()));
        assert!(n > 0, "{} is empty", file.display());
        assert!(
            text.len() as u64 <= 2048 + 512,
            "{} overshot max_bytes by more than one v2 row + summary",
            file.display()
        );
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let row = match TelemetryLine::parse(line).unwrap() {
                TelemetryLine::Row(row) => row,
                TelemetryLine::Summary(_) | TelemetryLine::Event(_) => continue,
            };
            if let Some(prev) = last_round {
                assert!(row.round > prev, "round {} after {prev} across the chain", row.round);
            }
            last_round = Some(row.round);
        }
        rows_seen += n;
    }
    assert!(
        (rows_seen as u64) < written,
        "nothing ever rotated out: {rows_seen} rows retained of {written} written"
    );
    assert_eq!(
        last_round,
        Some(total - 1),
        "the live file must end with the newest row"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
