//! Per-node SAGA operator-history tables (paper eq. (19)).
//!
//! For linear predictors each component's stored "gradient" is a handful
//! of scalar coefficients (`phi_{n,i}`, width 1 or 4) plus the dense
//! running mean `phibar_n = (1/q) sum_i B_{n,i}[phi_{n,i}]`, maintained
//! incrementally — the `O(q)` storage trick the paper inherits from
//! (Schmidt et al., 2017).

use crate::operators::Problem;

/// SAGA state for one node.
#[derive(Clone, Debug)]
pub struct NodeSaga {
    /// q x coef_width coefficient table, row-major
    pub phi: Vec<f64>,
    /// dense mean of the table's operator outputs (dim = problem.dim())
    pub phibar: Vec<f64>,
    width: usize,
}

impl NodeSaga {
    /// Initialize with `phi_{n,i} = B_{n,i}(z0)` for every component
    /// (Algorithm 1, line 1).
    pub fn init<P: Problem + ?Sized>(p: &P, n: usize, z0: &[f64]) -> NodeSaga {
        let (q, w) = (p.q(), p.coef_width());
        let mut phi = vec![0.0; q * w];
        let mut phibar = vec![0.0; p.dim()];
        let inv_q = 1.0 / q as f64;
        for i in 0..q {
            let c = &mut phi[i * w..(i + 1) * w];
            p.coefs(n, i, z0, c);
            p.scatter(n, i, c, inv_q, &mut phibar);
        }
        NodeSaga { phi, phibar, width: w }
    }

    #[inline]
    pub fn coef(&self, i: usize) -> &[f64] {
        &self.phi[i * self.width..(i + 1) * self.width]
    }

    /// Replace `phi_i` with `new_coefs`, updating `phibar` incrementally.
    /// Returns the coefficient delta (new - old) in `delta_out`.
    pub fn update<P: Problem + ?Sized>(
        &mut self,
        p: &P,
        n: usize,
        i: usize,
        new_coefs: &[f64],
        delta_out: &mut [f64],
    ) {
        let w = self.width;
        let old = &mut self.phi[i * w..(i + 1) * w];
        for k in 0..w {
            delta_out[k] = new_coefs[k] - old[k];
            old[k] = new_coefs[k];
        }
        p.scatter(n, i, delta_out, 1.0 / p.q() as f64, &mut self.phibar);
    }

    /// Recompute `phibar` from scratch (drift check / tests).
    pub fn recompute_phibar<P: Problem + ?Sized>(&self, p: &P, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; p.dim()];
        let inv_q = 1.0 / p.q() as f64;
        for i in 0..p.q() {
            p.scatter(n, i, self.coef(i), inv_q, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{Problem, RidgeProblem};

    #[test]
    fn phibar_consistent_under_updates() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(8);
        let p = RidgeProblem::new(ds.partition(3), 0.1);
        let mut rng = crate::util::rng::Rng::new(4);
        let z0 = vec![0.0; p.dim()];
        let mut saga = NodeSaga::init(&p, 1, &z0);
        let mut delta = vec![0.0; 1];
        for _ in 0..200 {
            let i = rng.below(p.q());
            let z: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
            let mut c = vec![0.0];
            p.coefs(1, i, &z, &mut c);
            saga.update(&p, 1, i, &c, &mut delta);
        }
        let fresh = saga.recompute_phibar(&p, 1);
        let drift: f64 = saga
            .phibar
            .iter()
            .zip(&fresh)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(drift < 1e-12, "incremental phibar drifted by {drift}");
    }

    #[test]
    fn init_matches_definition() {
        let ds = SyntheticSpec::tiny().generate(9);
        let p = RidgeProblem::new(ds.partition(2), 0.0);
        let z0: Vec<f64> = (0..p.dim()).map(|k| (k as f64 * 0.01).sin()).collect();
        let saga = NodeSaga::init(&p, 0, &z0);
        // phibar must equal the full raw mean at z0
        let mut want = vec![0.0; p.dim()];
        p.full_raw_mean(0, &z0, &mut want);
        for (a, b) in saga.phibar.iter().zip(&want) {
            assert!((a - b).abs() < 1e-13);
        }
    }
}
