//! DLM (Ling et al., 2015): Decentralized Linearized ADMM.
//!
//! Node form with edge multipliers folded into a per-node dual `phi`:
//!   `x^{k+1}_n  = x^k_n - (g_n(x^k) + phi^k_n + c sum_{j in N} (x^k_n -
//!                 x^k_j)) / (2 c deg(n) + rho)`
//!   `phi^{k+1}_n = phi^k_n + c sum_{j in N} (x^{k+1}_n - x^{k+1}_j)`
//! where `g_n` is the full regularized local gradient.  The dual update is
//! applied with the freshly exchanged iterates at the start of the next
//! round (one dense exchange per iteration, as in the original paper).
//!
//! Fixed point: consensus `x_n = x*` with `phi_n = -g_n(x*)`, and since
//! `sum_n phi_n` is conserved (= 0 from init) the consensus point solves
//! `sum_n g_n(x*) = 0`.

use super::{AlgoParams, Algorithm};
use crate::comm::Network;
use crate::graph::Topology;
use crate::operators::Problem;
use std::sync::Arc;

pub struct Dlm {
    problem: Arc<dyn Problem>,
    topo: Topology,
    c: f64,
    rho: f64,
    x: Vec<Vec<f64>>,
    x_prev: Vec<Vec<f64>>,
    phi: Vec<Vec<f64>>,
    t: usize,
    evals: u64,
    x_next: Vec<Vec<f64>>,
    g: Vec<f64>,
}

impl Dlm {
    pub fn new(problem: Arc<dyn Problem>, topo: Topology, params: &AlgoParams) -> Dlm {
        let n = problem.nodes();
        let dim = problem.dim();
        let x = vec![params.z0.clone(); n];
        Dlm {
            c: params.dlm_c,
            rho: params.dlm_rho,
            x_prev: x.clone(),
            x_next: x.clone(),
            phi: vec![vec![0.0; dim]; n],
            x,
            t: 0,
            evals: 0,
            g: vec![0.0; dim],
            problem,
            topo,
        }
    }
}

impl Algorithm for Dlm {
    fn step(&mut self, net: &mut Network) {
        let p = self.problem.as_ref();
        let dim = p.dim();
        net.round_dense_exchange(dim);
        // dual update with current exchanged iterates (skipped at t=0,
        // where x is at consensus and the Laplacian term vanishes anyway)
        if self.t > 0 {
            for n in 0..p.nodes() {
                let deg = self.topo.degree(n) as f64;
                for k in 0..dim {
                    let mut lap = deg * self.x[n][k];
                    for &j in self.topo.neighbors(n) {
                        lap -= self.x[j][k];
                    }
                    self.phi[n][k] += self.c * lap;
                }
            }
        }
        for n in 0..p.nodes() {
            p.full_operator(n, &self.x[n], &mut self.g);
            self.evals += p.q() as u64;
            let deg = self.topo.degree(n) as f64;
            let step = 1.0 / (2.0 * self.c * deg + self.rho);
            let xn = &mut self.x_next[n];
            for k in 0..dim {
                let mut lap = deg * self.x[n][k];
                for &j in self.topo.neighbors(n) {
                    lap -= self.x[j][k];
                }
                xn[k] = self.x[n][k]
                    - step * (self.g[k] + self.phi[n][k] + self.c * lap);
            }
        }
        std::mem::swap(&mut self.x_prev, &mut self.x);
        std::mem::swap(&mut self.x, &mut self.x_next);
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.x
    }

    fn passes(&self) -> f64 {
        self.evals as f64 / (self.problem.nodes() * self.problem.q()) as f64
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        "DLM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn dual_sum_conserved_and_converges() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(37);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mut params = AlgoParams::new(0.0, p.dim(), 1);
        params.dlm_c = 0.5;
        params.dlm_rho = 2.0;
        let mut alg = Dlm::new(p.clone(), topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..2000 {
            alg.step(&mut net);
        }
        // sum of duals stays zero
        let mut dual_sum = vec![0.0; p.dim()];
        for n in 0..4 {
            crate::linalg::axpy(1.0, &alg.phi[n], &mut dual_sum);
        }
        assert!(crate::linalg::norm2(&dual_sum) < 1e-9);
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-6, "residual {r}");
    }
}
