//! DLM (Ling et al., 2015): Decentralized Linearized ADMM.
//!
//! Node form with edge multipliers folded into a per-node dual `phi`:
//!   `x^{k+1}_n  = x^k_n - (g_n(x^k) + phi^k_n + c sum_{j in N} (x^k_n -
//!                 x^k_j)) / (2 c deg(n) + rho)`
//!   `phi^{k+1}_n = phi^k_n + c sum_{j in N} (x^{k+1}_n - x^{k+1}_j)`
//! where `g_n` is the full regularized local gradient.  The dual update is
//! applied with the freshly exchanged iterates at the start of the next
//! round (one dense exchange per iteration, as in the original paper).
//!
//! Fixed point: consensus `x_n = x*` with `phi_n = -g_n(x*)`, and since
//! `sum_n phi_n` is conserved (= 0 from init) the consensus point solves
//! `sum_n g_n(x*) = 0`.

use super::node::{broadcast_dense, NeighborBuf, RoundDriver};
use super::{AlgoParams, Algorithm, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::graph::Topology;
use crate::operators::Problem;
use std::sync::Arc;

pub(crate) struct DlmCtx {
    problem: Arc<dyn Problem>,
    topo: Topology,
    c: f64,
    rho: f64,
}

pub(crate) struct DlmNode {
    ctx: Arc<DlmCtx>,
    n: usize,
    x: Vec<f64>,
    nbrs: NeighborBuf,
    phi: Vec<f64>,
    evals: u64,
    x_next: Vec<f64>,
    g: Vec<f64>,
}

impl DlmNode {
    /// Graph-Laplacian row entry `deg(n) x_n[k] - sum_{j in N(n)} x_j[k]`
    /// from the freshly exchanged iterates, same subtraction order as the
    /// monolithic loop (adjacency order).
    #[inline]
    fn laplacian_at(&self, k: usize, deg: f64) -> f64 {
        let mut lap = deg * self.x[k];
        for &j in self.ctx.topo.neighbors(self.n) {
            lap -= self.nbrs.cur(j)[k];
        }
        lap
    }
}

impl NodeState for DlmNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        broadcast_dense(&self.ctx.topo, self.n, &self.x)
    }

    fn on_receive(&mut self, from: usize, msg: Message) {
        match msg {
            Message::Dense(v) => self.nbrs.accept(from, v),
            Message::Sparse(_) => panic!("DLM exchanges dense iterates only"),
        }
    }

    fn local_step(&mut self, t: usize) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.as_ref();
        let dim = p.dim();
        let n = self.n;
        let deg = ctx.topo.degree(n) as f64;
        // dual update with current exchanged iterates (skipped at t=0,
        // where x is at consensus and the Laplacian term vanishes anyway)
        if t > 0 {
            for k in 0..dim {
                let lap = self.laplacian_at(k, deg);
                self.phi[k] += ctx.c * lap;
            }
        }
        p.full_operator(n, &self.x, &mut self.g);
        self.evals += p.q() as u64;
        let step = 1.0 / (2.0 * ctx.c * deg + ctx.rho);
        for k in 0..dim {
            let lap = self.laplacian_at(k, deg);
            self.x_next[k] =
                self.x[k] - step * (self.g[k] + self.phi[k] + ctx.c * lap);
        }
        std::mem::swap(&mut self.x, &mut self.x_next);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

pub(crate) fn dlm_nodes(
    problem: Arc<dyn Problem>,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<DlmNode> {
    let n = problem.nodes();
    let dim = problem.dim();
    let ctx = Arc::new(DlmCtx { problem, topo, c: params.dlm_c, rho: params.dlm_rho });
    (0..n)
        .map(|nd| DlmNode {
            n: nd,
            x: params.z0.clone(),
            nbrs: NeighborBuf::new(&ctx.topo, nd, &params.z0),
            phi: vec![0.0; dim],
            evals: 0,
            x_next: params.z0.clone(),
            g: vec![0.0; dim],
            ctx: ctx.clone(),
        })
        .collect()
}

/// Sequentially driven DLM.
pub struct Dlm {
    drv: RoundDriver<DlmNode>,
}

impl Dlm {
    pub fn new(problem: Arc<dyn Problem>, topo: Topology, params: &AlgoParams) -> Dlm {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let nodes = dlm_nodes(problem, topo, params);
        Dlm { drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }

    /// One node's dual variable (tests / diagnostics).
    pub fn phi(&self, n: usize) -> &[f64] {
        &self.drv.nodes[n].phi
    }
}

impl Algorithm for Dlm {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "DLM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn dual_sum_conserved_and_converges() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(37);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mut params = AlgoParams::new(0.0, p.dim(), 1);
        params.dlm_c = 0.5;
        params.dlm_rho = 2.0;
        let mut alg = Dlm::new(p.clone(), topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..2000 {
            alg.step(&mut net);
        }
        // sum of duals stays zero
        let mut dual_sum = vec![0.0; p.dim()];
        for n in 0..4 {
            crate::linalg::axpy(1.0, alg.phi(n), &mut dual_sum);
        }
        assert!(crate::linalg::norm2(&dual_sum) < 1e-9);
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-6, "residual {r}");
    }
}
