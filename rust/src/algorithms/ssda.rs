//! SSDA (Scaman et al., 2017): Nesterov-accelerated gradient ascent on
//! the dual of the consensus problem.
//!
//! With gossip operator `K = (I - W)/2` (PSD, `ker K = span{1}`), the
//! dual iteration is
//!   `theta_n^t  = grad f_n^*(x_n^t)`      (conjugate-gradient oracle)
//!   `y^{t+1}    = x^t - eta * Theta^t K`  (one neighbor exchange)
//!   `x^{t+1}    = y^{t+1} + momentum (y^{t+1} - y^t)`
//! Primal estimates are the `theta_n` themselves.  Theory constants:
//! `eta = mu_f / lambda_max(K)` and momentum from the dual condition
//! number `kappa_dual = (L_f / mu_f) (lambda_max(K) / gamma(K))`; the
//! paper tunes step sizes, so `params.alpha` scales `eta`.
//!
//! The conjugate oracle `grad f*(v) = argmin_u f(u) - <v, u>` is computed
//! by solving `B_n(u) + lambda u = v` with AGD (closed-form-free but
//! exact to `inner_tol`); for ridge this is an SPD solve identical to CG.
//!
//! Per-node round shape: the oracle runs in the *send* phase (it produces
//! the theta that is broadcast), the y/x update in the local step once
//! neighbor thetas are in.

use super::node::{broadcast_dense, NeighborBuf, RoundDriver};
use super::{AlgoParams, Algorithm, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::graph::{MixingMatrix, Topology};
use crate::linalg::power_iteration;
use crate::operators::Problem;
use crate::solvers::agd_minimize;
use std::sync::Arc;

pub(crate) struct SsdaCtx {
    problem: Arc<dyn Problem>,
    topo: Topology,
    /// true when the operator field is affine (ridge) -> CG oracle
    linear_field: bool,
    /// K = (I - W)/2
    k_op: crate::linalg::DenseMatrix,
    eta: f64,
    momentum: f64,
    inner_tol: f64,
}

impl SsdaCtx {
    /// grad f_n^*(v): solve B_n(u) + lambda u = v.
    ///
    /// Cost accounting follows Table 1's convention for SSDA
    /// (`O(rho q d + q tau)` per iteration): one oracle call is priced as
    /// one pass over the shard, independent of the inner solver's
    /// iteration count — the same convention under which the paper's
    /// Figure 1/2 SSDA curves are plotted.
    fn conjugate_oracle(&self, n: usize, v: &[f64], warm: &[f64], evals: &mut u64) -> Vec<f64> {
        let p = self.problem.clone();
        *evals += p.q() as u64;
        if self.linear_field {
            // ridge: the field is affine, solve by CG (exact in <= rank
            // iterations). matvec(u) = B_n(u) + lambda u - (B_n(0))
            let dim = p.dim();
            let mut b0 = vec![0.0; dim];
            p.full_raw_mean(n, &vec![0.0; dim], &mut b0);
            let lam = p.lambda();
            let op = (dim, |u: &[f64], out: &mut [f64]| {
                p.full_raw_mean(n, u, out);
                for k in 0..u.len() {
                    out[k] += lam * u[k] - b0[k];
                }
            });
            let rhs: Vec<f64> = v.iter().zip(&b0).map(|(vk, bk)| vk - bk).collect();
            let (u, _, _) =
                crate::solvers::cg_solve(&op, &rhs, self.inner_tol, 4 * p.q() + 50);
            return u;
        }
        let grad = |u: &[f64], g: &mut [f64]| {
            p.full_operator(n, u, g);
            for (gk, vk) in g.iter_mut().zip(v) {
                *gk -= vk;
            }
        };
        let (l, mu) = self.problem.l_mu();
        let (u, _) = agd_minimize(grad, warm, l, mu, self.inner_tol, 50_000);
        u
    }
}

pub(crate) struct SsdaNode {
    ctx: Arc<SsdaCtx>,
    n: usize,
    /// dual iterate
    x: Vec<f64>,
    y_prev: Vec<f64>,
    /// primal estimate theta_n (reported iterate)
    theta: Vec<f64>,
    /// neighbor thetas of the current round
    nbrs: NeighborBuf,
    evals: u64,
}

impl NodeState for SsdaNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        // conjugate oracle (local), then dense theta exchange
        let warm = self.theta.clone();
        self.theta = self.ctx.conjugate_oracle(self.n, &self.x, &warm, &mut self.evals);
        broadcast_dense(&self.ctx.topo, self.n, &self.theta)
    }

    fn on_receive(&mut self, from: usize, msg: Message) {
        match msg {
            Message::Dense(v) => self.nbrs.accept(from, v),
            Message::Sparse(_) => panic!("SSDA exchanges dense thetas only"),
        }
    }

    fn local_step(&mut self, _t: usize) {
        let ctx = self.ctx.clone();
        let n = self.n;
        let dim = self.x.len();
        // y^{t+1} = x - eta Theta K ; x^{t+1} = y + m (y - y_prev)
        let mut y_new = self.x.clone();
        // (Theta K)_n = sum_m K[n,m] theta_m — K is graph-sparse
        let kn = ctx.k_op[(n, n)];
        if kn != 0.0 {
            crate::linalg::axpy(-ctx.eta * kn, &self.theta, &mut y_new);
        }
        for &m in ctx.topo.neighbors(n) {
            let km = ctx.k_op[(n, m)];
            if km != 0.0 {
                crate::linalg::axpy(-ctx.eta * km, self.nbrs.cur(m), &mut y_new);
            }
        }
        for k in 0..dim {
            let yv = y_new[k];
            self.x[k] = yv + ctx.momentum * (yv - self.y_prev[k]);
            self.y_prev[k] = yv;
        }
    }

    fn iterate(&self) -> &[f64] {
        &self.theta
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

pub(crate) fn ssda_nodes(
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<SsdaNode> {
    let n = problem.nodes();
    let dim = problem.dim();
    let mut k_op = crate::linalg::DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            k_op[(i, j)] = 0.5 * ((if i == j { 1.0 } else { 0.0 }) - mix.w[(i, j)]);
        }
    }
    let lmax = power_iteration(&k_op, 300).max(1e-12);
    let gamma = mix.gamma; // smallest nonzero eig of K
    let (l_f, mu_f) = problem.l_mu();
    // theory step scaled by the tuned multiplier
    let eta = params.alpha * mu_f / lmax;
    let kappa_dual = (l_f / mu_f) * (lmax / gamma);
    let r = 1.0 / kappa_dual.max(1.0);
    let momentum = params
        .ssda_momentum
        .unwrap_or((1.0 - r.sqrt()) / (1.0 + r.sqrt()));
    // probe linearity of the field (ridge vs logistic/auc): push far
    // along one data row; bounded coefficients mean non-affine
    let linear_field = {
        let z0 = vec![0.0; dim];
        let mut big = vec![0.0; dim];
        problem.partition().shards[0].row_sparse(0).axpy_into(1e6, &mut big);
        let mut c0 = vec![0.0; problem.coef_width()];
        let mut c1 = vec![0.0; problem.coef_width()];
        problem.coefs(0, 0, &z0, &mut c0);
        problem.coefs(0, 0, &big, &mut c1);
        problem.coef_width() == 1 && (c1[0] - c0[0]).abs() > 10.0
    };
    let z0 = params.z0.clone();
    let ctx = Arc::new(SsdaCtx {
        linear_field,
        eta,
        momentum,
        inner_tol: params.inner_tol,
        k_op,
        problem,
        topo,
    });
    (0..n)
        .map(|nd| SsdaNode {
            n: nd,
            x: vec![0.0; dim],
            y_prev: vec![0.0; dim],
            theta: z0.clone(),
            nbrs: NeighborBuf::new(&ctx.topo, nd, &z0),
            evals: 0,
            ctx: ctx.clone(),
        })
        .collect()
}

/// Sequentially driven SSDA.
pub struct Ssda {
    drv: RoundDriver<SsdaNode>,
}

impl Ssda {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Ssda {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let nodes = ssda_nodes(problem, mix, topo, params);
        Ssda { drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }
}

impl Algorithm for Ssda {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "SSDA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn converges_on_ridge() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(41);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.1));
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let mut params = AlgoParams::new(1.0, p.dim(), 1);
        params.inner_tol = 1e-12;
        let mut alg = Ssda::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..400 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-6, "residual {r}");
        // consensus across primal estimates
        let z0 = &alg.iterates()[0];
        for z in alg.iterates() {
            assert!(crate::linalg::dist2_sq(z, z0) < 1e-10);
        }
    }
}
