//! Point-SAGA (Defazio, 2016) — the single-node degenerate case of DSBA
//! (Remark 5.1). Used both as a baseline and as the centralized optimum
//! pre-solver for non-quadratic problems.
//!
//! Update: `psi^t = z^t + alpha (phi_{i_t} - phibar^t)`,
//!         `z^{t+1} = J_{alpha (B_{i_t} + lambda I)}(psi^t)`.

use super::node::RoundDriver;
use super::{AlgoParams, Algorithm, NodeSaga, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::operators::Problem;
use crate::util::rng::Rng;
use std::sync::Arc;

pub(crate) struct PointSagaNode {
    problem: Arc<dyn Problem>,
    alpha: f64,
    z: Vec<f64>,
    saga: NodeSaga,
    rng: Rng,
    evals: u64,
    psi: Vec<f64>,
    z_next: Vec<f64>,
    coefs: Vec<f64>,
    delta: Vec<f64>,
}

impl NodeState for PointSagaNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        Vec::new() // single node: nothing to exchange
    }

    fn on_receive(&mut self, _from: usize, _msg: Message) {
        panic!("Point-SAGA is single-node; no messages expected");
    }

    fn local_step(&mut self, _t: usize) {
        let p = self.problem.clone();
        let i = self.rng.below(p.q());
        // psi = z + alpha (phi_i - phibar)
        self.psi.copy_from_slice(&self.z);
        p.scatter(0, i, self.saga.coef(i), self.alpha, &mut self.psi);
        crate::linalg::axpy(-self.alpha, &self.saga.phibar, &mut self.psi);
        p.backward(0, i, self.alpha, &self.psi, &mut self.z_next, &mut self.coefs);
        self.evals += 1;
        self.saga.update(p.as_ref(), 0, i, &self.coefs, &mut self.delta);
        std::mem::swap(&mut self.z, &mut self.z_next);
    }

    fn iterate(&self) -> &[f64] {
        &self.z
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

pub(crate) fn point_saga_nodes(
    problem: Arc<dyn Problem>,
    params: &AlgoParams,
) -> Vec<PointSagaNode> {
    assert_eq!(
        problem.nodes(),
        1,
        "Point-SAGA is a single-node method; pool the partition first"
    );
    let dim = problem.dim();
    let saga = NodeSaga::init(problem.as_ref(), 0, &params.z0);
    let w = problem.coef_width();
    // fork(0) — identical sample path to node 0 of the decentralized
    // methods under the same seed (Remark 5.1 equivalence tests)
    let rng = Rng::new(params.seed).fork(0);
    vec![PointSagaNode {
        alpha: params.alpha,
        z: params.z0.clone(),
        saga,
        rng,
        evals: 0,
        psi: vec![0.0; dim],
        z_next: vec![0.0; dim],
        coefs: vec![0.0; w],
        delta: vec![0.0; w],
        problem,
    }]
}

/// Sequentially driven Point-SAGA.
pub struct PointSaga {
    problem: Arc<dyn Problem>,
    drv: RoundDriver<PointSagaNode>,
}

impl PointSaga {
    pub fn new(problem: Arc<dyn Problem>, params: &AlgoParams) -> PointSaga {
        let nodes = point_saga_nodes(problem.clone(), params);
        let pass_denom = problem.q() as f64;
        PointSaga { problem, drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }

    /// Run until the global residual drops below `tol` (optimum pre-solve).
    /// Returns the final iterate and the number of iterations used.
    pub fn solve_to_residual(
        &mut self,
        tol: f64,
        check_every: usize,
        max_iters: usize,
    ) -> (Vec<f64>, usize) {
        let mut net = Network::new(
            crate::graph::Topology::from_edges(1, &[]),
            crate::comm::CommCostModel::default(),
        );
        let mut it = 0;
        while it < max_iters {
            for _ in 0..check_every {
                self.step(&mut net);
                it += 1;
            }
            if self.problem.global_residual(&self.iterates()[0]) < tol {
                break;
            }
        }
        (self.iterates()[0].clone(), it)
    }
}

impl Algorithm for PointSaga {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "Point-SAGA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{LogisticProblem, Problem, RidgeProblem};

    #[test]
    fn solves_ridge_to_high_accuracy() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(2);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(ds.partition(1), 0.05));
        let params = AlgoParams::new(0.5, p.dim(), 3);
        let mut ps = PointSaga::new(p.clone(), &params);
        let (z, iters) = ps.solve_to_residual(1e-11, 200, 500_000);
        assert!(iters < 500_000);
        assert!(p.global_residual(&z) < 1e-11);
    }

    #[test]
    fn solves_logistic() {
        let ds = SyntheticSpec::tiny().generate(3);
        let p: Arc<dyn Problem> = Arc::new(LogisticProblem::new(ds.partition(1), 0.05));
        let params = AlgoParams::new(1.0, p.dim(), 4);
        let mut ps = PointSaga::new(p.clone(), &params);
        let (z, _) = ps.solve_to_residual(1e-10, 500, 500_000);
        assert!(p.global_residual(&z) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "single-node")]
    fn rejects_multinode_problem() {
        let ds = SyntheticSpec::tiny().generate(4);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(ds.partition(2), 0.1));
        let params = AlgoParams::new(0.5, p.dim(), 5);
        let _ = PointSaga::new(p, &params);
    }
}
