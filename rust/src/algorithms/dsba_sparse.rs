//! DSBA-s: DSBA with the §5.1 sparse communication scheme, in per-node
//! message-passing form.
//!
//! Nodes never exchange dense iterates.  Each node transmits only its
//! sparse update `delta_n^t = B_{n,i}(z^{t+1}) - phi_{n,i}` (support of a
//! single data row, + the 3-scalar tail for AUC) along the BFS forwarding
//! trees of [`crate::comm::RelayProtocol`], and *reconstructs* delayed
//! copies of every other node's iterate by replaying the delta-closed
//! recursion (28):
//!
//! `(1 + alpha lambda) z_m^{tau+1} = sum_k w~_{mk} (2 z_k^tau -
//!  z_k^{tau-1}) + alpha ((q-1)/q delta_m^{tau-1} - delta_m^tau)
//!  + alpha lambda z_m^tau`
//!
//! A node at distance `xi_m` can reconstruct `z_m` up to time
//! `t + 1 - xi_m` at wall round `t` (the wavefront invariant proved in the
//! paper's §5.1 induction); in particular neighbors are available at time
//! `t`, which is exactly what the `psi_n^t` computation (29) needs.  The
//! reconstruction advances every remote node by one step per round, in
//! decreasing-distance order, using a 3-deep history ring per remote node.
//!
//! Problems with a separable l1 term ([`crate::operators::Problem::l1_weight`])
//! stay delta-closed: the linear sum above reconstructs
//! `X = (1 + alpha lambda) z + alpha l1 u` with `u` the subgradient the
//! remote prox chose, and soft-thresholding `X / (1 + alpha lambda)` by
//! `beta l1` is exactly the resolvent inverting that relation, so the
//! replay recovers the remote iterate (up to the same floating-point
//! reconstruction error as the smooth case) without communicating the
//! (dense) subgradient.
//!
//! Relaying is now *literally* message passing: a node's
//! [`NodeState::outgoing`] forwards the deltas received last round (plus
//! its own fresh delta) to the neighbors for which it is the designated
//! parent on the source's BFS tree — each delta crosses every tree edge
//! exactly once, the `O(N rho d)` DOUBLEs of Table 1.  The only dense
//! traffic is a one-time flood of the initial table means `phibar_m^0`
//! (accounted before round 0 via the driver's setup schedule), needed for
//! the `tau = 0` base case of the replay — the `O(Nd)` per-node storage
//! the paper's §5.1 complexity analysis allows.
//!
//! Equivalence with dense [`super::Dsba`] (identical iterate sequences
//! under identical seeds) is enforced by `rust/tests/sparse_comm.rs`.

use super::node::RoundDriver;
use super::{AlgoParams, Algorithm, NodeSaga, NodeState};
use crate::comm::{Message, Network, Outgoing, RelayDelta, RelayProtocol};
use crate::graph::{MixingMatrix, Topology};
use crate::linalg::SparseVec;
use crate::operators::Problem;
use crate::util::rng::Rng;
use std::sync::Arc;

/// 3-deep time-indexed history of one remote node's reconstructed rows.
#[derive(Clone)]
struct ReplayBuf {
    newest: i64,
    rows: [Vec<f64>; 3],
}

impl ReplayBuf {
    fn new(z0: &[f64]) -> ReplayBuf {
        ReplayBuf { newest: 0, rows: [z0.to_vec(), z0.to_vec(), z0.to_vec()] }
    }

    #[inline]
    fn slot(time: i64) -> usize {
        (time.rem_euclid(3)) as usize
    }

    #[inline]
    fn row(&self, time: i64) -> &[f64] {
        debug_assert!(
            time <= self.newest && time >= self.newest - 2 && time >= 0,
            "replay read outside window: t={time}, newest={}",
            self.newest
        );
        &self.rows[Self::slot(time)]
    }

    fn advance_into(&mut self, time: i64) -> &mut Vec<f64> {
        debug_assert_eq!(time, self.newest + 1, "non-contiguous replay");
        self.newest = time;
        &mut self.rows[Self::slot(time)]
    }
}

/// A received sparse delta (feature block + dense tail).
#[derive(Clone)]
struct ArchivedDelta {
    vec: SparseVec,
    tail: Vec<f64>,
}

impl ArchivedDelta {
    #[inline]
    fn axpy(&self, scale: f64, out: &mut [f64], d_feat: usize) {
        self.vec.axpy_into(scale, out);
        for (k, t) in self.tail.iter().enumerate() {
            out[d_feat + k] += scale * t;
        }
    }
}

/// The archived delta of source `m` at `time` (panics if the wavefront
/// invariant is violated and the slot holds a different round).
fn archived_at<'a>(
    archive_m: &'a [Option<(i64, ArchivedDelta)>; 2],
    m: usize,
    time: i64,
) -> &'a ArchivedDelta {
    let (tt, d) = archive_m[(time.rem_euclid(2)) as usize]
        .as_ref()
        .map(|(t, d)| (*t, d))
        .unwrap_or_else(|| panic!("missing delta_{m}^{time}"));
    assert_eq!(tt, time, "archive slot holds wrong time");
    d
}

pub(crate) struct DsbaSparseCtx {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
    /// precomputed BFS forwarding trees (read-only: children tables)
    relay: RelayProtocol,
}

/// One node's DSBA-s state (what §5.1 calls the node's "memory").
pub(crate) struct DsbaSparseNode {
    ctx: Arc<DsbaSparseCtx>,
    n: usize,
    /// reconstructed rows for every node (own entry holds exact rows)
    replay: Vec<ReplayBuf>,
    /// two-deep delta archive per source: archive[m][t % 2]
    archive: Vec<[Option<(i64, ArchivedDelta)>; 2]>,
    /// initial table means of all nodes (one-time flood)
    phibar0: Vec<Vec<f64>>,
    /// remote nodes in decreasing-distance order
    order: Vec<usize>,
    saga: NodeSaga,
    delta_prev: (usize, Vec<f64>),
    rng: Rng,
    evals: u64,
    /// own iterates (z^t, z^{t-1}) — mirrors of replay[n] kept for the
    /// NodeState::iterate() interface
    z: Vec<f64>,
    z_prev: Vec<f64>,
    /// deltas received this round, to forward next round
    inbox_next: Vec<RelayDelta>,
    /// deltas received last round (forward targets resolved in outgoing)
    pending: Vec<RelayDelta>,
    /// own delta produced last round, injected this round
    fresh: Option<RelayDelta>,
    psi: Vec<f64>,
    coefs_new: Vec<f64>,
}

impl DsbaSparseNode {
    /// Build the communicated sparse delta from a coefficient diff:
    /// feature block = dcoefs[0] * a_{n,i}, tail = dcoefs[1..].
    fn make_delta(&self, i: usize, dcoefs: &[f64]) -> ArchivedDelta {
        let row = self.ctx.problem.partition().shards[self.n].row_sparse(i);
        ArchivedDelta { vec: row.scaled(dcoefs[0]), tail: dcoefs[1..].to_vec() }
    }

    /// Replay node `m` one step forward: reconstruct `z_m^{target}` from
    /// archived deltas and neighbor history.
    fn advance_replay(&mut self, m: usize, target: i64) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.as_ref();
        let (alpha, lam, q) = (ctx.alpha, p.lambda(), p.q() as f64);
        let d_feat = p.feature_dim();
        let dim = p.dim();
        let scale = 1.0 / (1.0 + alpha * lam);
        // proximal problems (Problem::l1_weight): the delta-closed sum
        // reconstructs X = (1 + alpha lam) z + alpha l1 u with u the
        // prox-chosen subgradient, and the soft-threshold is exactly the
        // resolvent that inverts that relation — z = S_{beta l1}(X scale)
        // — so the replay stays exact with no extra communication
        let prox_t = alpha * scale * p.l1_weight();
        // write into the ring slot being retired (time target-3): it is
        // dead, and all reads below touch times target-1/target-2 of m or
        // other nodes' buffers, so no aliasing. Avoids an O(d) alloc per
        // (node, remote) pair per round (see EXPERIMENTS.md §Perf).
        let mut new_row =
            std::mem::take(&mut self.replay[m].rows[ReplayBuf::slot(target)]);
        new_row.fill(0.0);
        debug_assert_eq!(new_row.len(), dim);
        if target == 1 {
            // base case: (1+al) z_m^1 = z^0 - alpha (delta_m^0 + phibar_m^0)
            let (t0, d0) = self.archive[m][0]
                .as_ref()
                .map(|(t, d)| (*t, d))
                .expect("delta_m^0 must have arrived before replay start");
            assert_eq!(t0, 0, "expected delta at time 0");
            new_row.copy_from_slice(self.replay[m].row(0)); // z^0
            d0.axpy(-alpha, &mut new_row, d_feat);
            crate::linalg::axpy(-alpha, &self.phibar0[m], &mut new_row);
        } else {
            let tau = target - 1;
            // mixing over m's neighborhood at times (tau, tau-1)
            {
                let replay = &self.replay;
                let mut mix_term = |k: usize, out: &mut [f64]| {
                    let w = ctx.mix.wt[(m, k)];
                    if w == 0.0 {
                        return;
                    }
                    let zk = replay[k].row(tau);
                    let zkp = replay[k].row(tau - 1);
                    for idx in 0..dim {
                        out[idx] += w * (2.0 * zk[idx] - zkp[idx]);
                    }
                };
                mix_term(m, &mut new_row[..]);
                for &k in ctx.topo.neighbors(m) {
                    mix_term(k, &mut new_row[..]);
                }
            }
            // + alpha ((q-1)/q delta_m^{tau-1} - delta_m^tau) + alpha lam z_m^tau
            let archive_m = &self.archive[m];
            archived_at(archive_m, m, tau).axpy(-alpha, &mut new_row, d_feat);
            if tau >= 1 {
                archived_at(archive_m, m, tau - 1).axpy(
                    alpha * (q - 1.0) / q,
                    &mut new_row,
                    d_feat,
                );
            }
            if lam != 0.0 {
                crate::linalg::axpy(alpha * lam, self.replay[m].row(tau), &mut new_row);
            }
        }
        crate::linalg::scale(&mut new_row, scale);
        if prox_t != 0.0 {
            for v in new_row.iter_mut() {
                *v = crate::solvers::soft_threshold(*v, prox_t);
            }
        }
        *self.replay[m].advance_into(target) = new_row;
    }
}

impl NodeState for DsbaSparseNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        // forward everything received last round, plus the fresh injection
        // (delta produced by last round's local step) — each delta goes to
        // the children for which this node is the designated parent on the
        // source's BFS tree
        let mut msgs = std::mem::take(&mut self.pending);
        if let Some(f) = self.fresh.take() {
            msgs.push(f);
        }
        let mut out = Vec::new();
        for d in msgs {
            let targets = self.ctx.relay.children(self.n, d.src as usize);
            for &l in targets {
                out.push(Outgoing { to: l, msg: Message::Sparse(d.clone()) });
            }
        }
        out
    }

    fn on_receive(&mut self, _from: usize, msg: Message) {
        let d = match msg {
            Message::Sparse(d) => d,
            Message::Dense(_) => panic!("DSBA-s relays sparse deltas only"),
        };
        let src = d.src as usize;
        let time = d.t as i64;
        self.archive[src][(time.rem_euclid(2)) as usize] = Some((
            time,
            ArchivedDelta { vec: d.vec.clone(), tail: d.tail.clone() },
        ));
        self.inbox_next.push(d);
    }

    fn local_step(&mut self, t: usize) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.clone();
        let (alpha, lam, q) = (ctx.alpha, p.lambda(), p.q());
        let dim = p.dim();
        let t_i = t as i64;
        let n = self.n;
        // this round's receipts become next round's forwards
        self.pending = std::mem::take(&mut self.inbox_next);

        // advance remote nodes farthest-first
        for idx in 0..self.order.len() {
            let m = self.order[idx];
            let target = t_i + 1 - ctx.topo.dist[n][m] as i64;
            if target >= 1 {
                debug_assert_eq!(self.replay[m].newest, target - 1);
                self.advance_replay(m, target);
            }
        }

        // psi_n^t from reconstructed neighbor rows
        let i = self.rng.below(q);
        let psi = &mut self.psi;
        if t == 0 {
            // consensus start: sum_m w z^0 = z^0
            psi.copy_from_slice(self.replay[n].row(0));
            p.scatter(n, i, self.saga.coef(i), alpha, psi);
            crate::linalg::axpy(-alpha, &self.saga.phibar, psi);
        } else {
            psi.fill(0.0);
            {
                let replay = &self.replay;
                let mut mix_term = |m: usize, out: &mut [f64]| {
                    let w = ctx.mix.wt[(n, m)];
                    if w == 0.0 {
                        return;
                    }
                    let zm = replay[m].row(t_i);
                    let zmp = replay[m].row(t_i - 1);
                    for k in 0..dim {
                        out[k] += w * (2.0 * zm[k] - zmp[k]);
                    }
                };
                mix_term(n, &mut psi[..]);
                for &m in ctx.topo.neighbors(n) {
                    mix_term(m, &mut psi[..]);
                }
            }
            let (i_prev, ref dprev) = self.delta_prev;
            p.scatter(n, i_prev, dprev, alpha * (q as f64 - 1.0) / q as f64, psi);
            p.scatter(n, i, self.saga.coef(i), alpha, psi);
            if lam != 0.0 {
                crate::linalg::axpy(alpha * lam, self.replay[n].row(t_i), psi);
            }
        }
        // backward step; own row advances to time t+1
        let mut z_new = vec![0.0; dim];
        p.backward(n, i, alpha, psi, &mut z_new, &mut self.coefs_new);
        self.evals += 1;
        let (ip, dp) = &mut self.delta_prev;
        *ip = i;
        self.saga.update(p.as_ref(), n, i, &self.coefs_new, dp);
        // own archive + fresh outgoing delta (delta_n^t)
        let arch = self.make_delta(i, &self.delta_prev.1.clone());
        self.archive[n][(t_i.rem_euclid(2)) as usize] = Some((t_i, arch.clone()));
        self.fresh = Some(RelayDelta {
            src: n as u32,
            t: t as u32,
            vec: arch.vec.clone(),
            tail: arch.tail.clone(),
        });
        self.z_prev.copy_from_slice(self.replay[n].row(t_i));
        *self.replay[n].advance_into(t_i + 1) = z_new.clone();
        self.z = z_new;
    }

    fn iterate(&self) -> &[f64] {
        &self.z
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

/// Round-0 accounting schedule for the one-time dense flood of the
/// initial table means `phibar_m^0` along the BFS trees: every non-source
/// node receives each source's vector exactly once, from its designated
/// parent — the `O(Nd)` setup cost of §5.1.
pub(crate) fn flood_schedule(topo: &Topology, dim: usize) -> Vec<(usize, usize, usize)> {
    let mut setup = Vec::new();
    for src in 0..topo.n {
        for node in 0..topo.n {
            if node == src {
                continue;
            }
            let parent = topo.designated_parent(src, node).unwrap();
            setup.push((parent, node, dim));
        }
    }
    setup
}

pub(crate) fn dsba_sparse_nodes(
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<DsbaSparseNode> {
    let n = problem.nodes();
    let dim = problem.dim();
    assert_eq!(params.z0.len(), dim);
    let saga: Vec<NodeSaga> =
        (0..n).map(|nd| NodeSaga::init(problem.as_ref(), nd, &params.z0)).collect();
    // one-time flood payload: every node learns every phibar_m^0
    let phibar0: Vec<Vec<f64>> = saga.iter().map(|s| s.phibar.clone()).collect();
    let w = problem.coef_width();
    let mut root = Rng::new(params.seed);
    let relay = RelayProtocol::new(&topo);
    let ctx = Arc::new(DsbaSparseCtx { problem, mix, topo, alpha: params.alpha, relay });
    saga.into_iter()
        .enumerate()
        .map(|(nd, saga_nd)| {
            let mut order: Vec<usize> = (0..n).filter(|&m| m != nd).collect();
            order.sort_by_key(|&m| std::cmp::Reverse(ctx.topo.dist[nd][m]));
            DsbaSparseNode {
                n: nd,
                replay: (0..n).map(|_| ReplayBuf::new(&params.z0)).collect(),
                archive: vec![[None, None]; n],
                phibar0: phibar0.clone(),
                order,
                saga: saga_nd,
                delta_prev: (0, vec![0.0; w]),
                rng: root.fork(nd as u64),
                evals: 0,
                z: params.z0.clone(),
                z_prev: params.z0.clone(),
                inbox_next: Vec::new(),
                pending: Vec::new(),
                fresh: None,
                psi: vec![0.0; dim],
                coefs_new: vec![0.0; w],
                ctx: ctx.clone(),
            }
        })
        .collect()
}

/// Sequentially driven DSBA-s.
pub struct DsbaSparse {
    drv: RoundDriver<DsbaSparseNode>,
}

impl DsbaSparse {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> DsbaSparse {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let setup = flood_schedule(&topo, problem.dim());
        let nodes = dsba_sparse_nodes(problem, mix, topo, params);
        DsbaSparse { drv: RoundDriver::new(nodes, setup, pass_denom) }
    }
}

impl Algorithm for DsbaSparse {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "DSBA-s"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    /// The §5.1 headline: DSBA-s produces *identical* iterates to dense
    /// DSBA under the same seed, while transmitting only sparse deltas.
    #[test]
    fn matches_dense_dsba_exactly_ridge() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(51);
        let part = ds.partition_seeded(5, 3);
        let topo = Topology::erdos_renyi(5, 0.5, 7);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.05));
        let params = AlgoParams::new(0.5, p.dim(), 13);
        let mut dense = super::super::Dsba::new(p.clone(), mix.clone(), topo.clone(), &params);
        let mut sparse = DsbaSparse::new(p.clone(), mix, topo.clone(), &params);
        let mut net1 = Network::new(topo.clone(), CommCostModel::default());
        let mut net2 = Network::new(topo, CommCostModel::default());
        for round in 0..120 {
            dense.step(&mut net1);
            sparse.step(&mut net2);
            for n in 0..5 {
                let d = crate::linalg::dist2_sq(&dense.iterates()[n], &sparse.iterates()[n]);
                assert!(d < 1e-18, "round {round} node {n}: drift {d:.3e}");
            }
        }
    }

    #[test]
    fn communication_is_sparse() {
        let ds = SyntheticSpec::rcv1_like()
            .with_samples(200)
            .with_dim(2048)
            .generate(5);
        let part = ds.partition_seeded(5, 3);
        let topo = Topology::erdos_renyi(5, 0.5, 7);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.05));
        let params = AlgoParams::new(0.5, p.dim(), 13);
        let mut dense = super::super::Dsba::new(p.clone(), mix.clone(), topo.clone(), &params);
        let mut sparse = DsbaSparse::new(p.clone(), mix, topo.clone(), &params);
        let mut net1 = Network::new(topo.clone(), CommCostModel::default());
        let mut net2 = Network::new(topo, CommCostModel::default());
        for _ in 0..50 {
            dense.step(&mut net1);
            sparse.step(&mut net2);
        }
        // steady-state: sparse traffic must be far below dense traffic
        // (one-time phibar flood amortizes away)
        assert!(
            net2.max_received() < net1.max_received() / 3.0,
            "sparse {} vs dense {}",
            net2.max_received(),
            net1.max_received()
        );
    }
}
