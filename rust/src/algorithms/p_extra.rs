//! P-EXTRA (Shi et al., 2015b): the proximal / backward counterpart of
//! EXTRA — equivalently, the exact-resolvent fixed-point iteration (18)
//! that DSBA approximates stochastically (§4).
//!
//! Each round solves the full local resolvent
//!   `z^{t+1} + alpha (B_n(z^{t+1}) + lambda z^{t+1})
//!      = sum_m w~(2 z^t - z^{t-1}) + alpha (B_n(z^t) + lambda z^t)`
//! with an accelerated inner solver.  Only valid for gradient-field
//! operators (ridge / logistic); the paper uses it conceptually as the
//! expensive exact method DSBA cheapens.

use super::node::{broadcast_dense, mix_row_local, w_row_local, NeighborBuf, RoundDriver};
use super::{AlgoParams, Algorithm, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use crate::solvers::agd_minimize;
use std::sync::Arc;

pub(crate) struct PExtraCtx {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
    inner_tol: f64,
}

pub(crate) struct PExtraNode {
    ctx: Arc<PExtraCtx>,
    n: usize,
    z: Vec<f64>,
    z_prev: Vec<f64>,
    nbrs: NeighborBuf,
    evals: u64,
    rhs: Vec<f64>,
}

impl PExtraNode {
    /// Solve `u + alpha B_n^lambda(u) = rhs` by minimizing the strongly
    /// convex inner objective with AGD.
    fn solve_resolvent(&mut self, warm: &[f64]) -> Vec<f64> {
        let p = self.ctx.problem.clone();
        let n = self.n;
        let alpha = self.ctx.alpha;
        let lam = p.lambda();
        let rhs = self.rhs.clone();
        let evals = std::cell::Cell::new(0u64);
        let grad = |u: &[f64], g: &mut [f64]| {
            // g = u - rhs + alpha (B_n(u) + lambda u)
            p.full_raw_mean(n, u, g);
            evals.set(evals.get() + p.q() as u64);
            for k in 0..g.len() {
                g[k] = u[k] - rhs[k] + alpha * (g[k] + lam * u[k]);
            }
        };
        let (l, mu) = p.l_mu();
        let (u, _) = agd_minimize(
            grad,
            warm,
            1.0 + alpha * l,
            1.0 + alpha * mu,
            self.ctx.inner_tol,
            20_000,
        );
        self.evals += evals.get();
        u
    }
}

impl NodeState for PExtraNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        broadcast_dense(&self.ctx.topo, self.n, &self.z)
    }

    fn on_receive(&mut self, from: usize, msg: Message) {
        match msg {
            Message::Dense(v) => self.nbrs.accept(from, v),
            Message::Sparse(_) => panic!("P-EXTRA exchanges dense iterates only"),
        }
    }

    fn local_step(&mut self, t: usize) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.as_ref();
        let alpha = ctx.alpha;
        let lam = p.lambda();
        let dim = p.dim();
        let n = self.n;
        // rhs = mix + alpha B_n^lambda(z^t)   (W row at t=0)
        if t == 0 {
            w_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.nbrs, &mut self.rhs);
            // z^1 + alpha B(z^1) = W z^0  (P-EXTRA first step keeps
            // the pure backward form; matches (25) with exact B)
        } else {
            mix_row_local(
                &ctx.mix,
                &ctx.topo,
                n,
                &self.z,
                &self.z_prev,
                &self.nbrs,
                &mut self.rhs,
            );
            let mut bz = vec![0.0; dim];
            p.full_raw_mean(n, &self.z, &mut bz);
            self.evals += p.q() as u64;
            for k in 0..dim {
                self.rhs[k] += alpha * (bz[k] + lam * self.z[k]);
            }
        }
        let warm = self.z.clone();
        let u = self.solve_resolvent(&warm);
        self.z_prev = std::mem::replace(&mut self.z, u);
    }

    fn iterate(&self) -> &[f64] {
        &self.z
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

pub(crate) fn p_extra_nodes(
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<PExtraNode> {
    let n = problem.nodes();
    let dim = problem.dim();
    let ctx = Arc::new(PExtraCtx {
        problem,
        mix,
        topo,
        alpha: params.alpha,
        inner_tol: params.inner_tol,
    });
    (0..n)
        .map(|nd| PExtraNode {
            n: nd,
            z: params.z0.clone(),
            z_prev: params.z0.clone(),
            nbrs: NeighborBuf::new(&ctx.topo, nd, &params.z0),
            evals: 0,
            rhs: vec![0.0; dim],
            ctx: ctx.clone(),
        })
        .collect()
}

/// Sequentially driven P-EXTRA.
pub struct PExtra {
    drv: RoundDriver<PExtraNode>,
}

impl PExtra {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> PExtra {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let nodes = p_extra_nodes(problem, mix, topo, params);
        PExtra { drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }
}

impl Algorithm for PExtra {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "P-EXTRA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn converges_on_ridge_with_large_steps() {
        // the point of proximal steps: alpha far above 1/L still converges
        let ds = SyntheticSpec::tiny().with_regression(true).generate(31);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let mut params = AlgoParams::new(3.0, p.dim(), 1);
        params.inner_tol = 1e-13;
        let mut alg = PExtra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..300 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-7, "residual {r}");
    }
}
