//! P-EXTRA (Shi et al., 2015b): the proximal / backward counterpart of
//! EXTRA — equivalently, the exact-resolvent fixed-point iteration (18)
//! that DSBA approximates stochastically (§4).
//!
//! Each round solves the full local resolvent
//!   `z^{t+1} + alpha (B_n(z^{t+1}) + lambda z^{t+1})
//!      = sum_m w~(2 z^t - z^{t-1}) + alpha (B_n(z^t) + lambda z^t)`
//! with an accelerated inner solver.  Only valid for gradient-field
//! operators (ridge / logistic); the paper uses it conceptually as the
//! expensive exact method DSBA cheapens.

use super::{AlgoParams, Algorithm};
use crate::comm::Network;
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use crate::solvers::agd_minimize;
use std::sync::Arc;

pub struct PExtra {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
    inner_tol: f64,
    z: Vec<Vec<f64>>,
    z_prev: Vec<Vec<f64>>,
    t: usize,
    evals: u64,
    z_next: Vec<Vec<f64>>,
    rhs: Vec<f64>,
}

impl PExtra {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> PExtra {
        let n = problem.nodes();
        let z = vec![params.z0.clone(); n];
        PExtra {
            alpha: params.alpha,
            inner_tol: params.inner_tol,
            z_prev: z.clone(),
            z_next: z.clone(),
            rhs: vec![0.0; problem.dim()],
            z,
            t: 0,
            evals: 0,
            problem,
            mix,
            topo,
        }
    }

    /// Solve `u + alpha B_n^lambda(u) = rhs` by minimizing the strongly
    /// convex inner objective with AGD.
    fn solve_resolvent(&mut self, n: usize, warm: &[f64]) -> Vec<f64> {
        let p = self.problem.clone();
        let alpha = self.alpha;
        let lam = p.lambda();
        let rhs = self.rhs.clone();
        let evals = std::cell::Cell::new(0u64);
        let grad = |u: &[f64], g: &mut [f64]| {
            // g = u - rhs + alpha (B_n(u) + lambda u)
            p.full_raw_mean(n, u, g);
            evals.set(evals.get() + p.q() as u64);
            for k in 0..g.len() {
                g[k] = u[k] - rhs[k] + alpha * (g[k] + lam * u[k]);
            }
        };
        let (l, mu) = p.l_mu();
        let (u, _) = agd_minimize(
            grad,
            warm,
            1.0 + alpha * l,
            1.0 + alpha * mu,
            self.inner_tol,
            20_000,
        );
        self.evals += evals.get();
        u
    }
}

impl Algorithm for PExtra {
    fn step(&mut self, net: &mut Network) {
        let p = self.problem.clone();
        let alpha = self.alpha;
        let lam = p.lambda();
        let dim = p.dim();
        net.round_dense_exchange(dim);
        for n in 0..p.nodes() {
            // rhs = mix + alpha B_n^lambda(z^t)   (W row at t=0)
            if self.t == 0 {
                self.rhs.fill(0.0);
                let add = |m: usize, rhs: &mut [f64]| {
                    let w = self.mix.w[(n, m)];
                    if w != 0.0 {
                        crate::linalg::axpy(w, &self.z[m], rhs);
                    }
                };
                add(n, &mut self.rhs);
                for &m in self.topo.neighbors(n) {
                    add(m, &mut self.rhs);
                }
                // z^1 + alpha B(z^1) = W z^0  (P-EXTRA first step keeps
                // the pure backward form; matches (25) with exact B)
            } else {
                let (z, z_prev) = (&self.z, &self.z_prev);
                let mut rhs = std::mem::take(&mut self.rhs);
                self.mix.mix_row(n, &self.topo, z, z_prev, &mut rhs);
                self.rhs = rhs;
                let mut bz = vec![0.0; dim];
                p.full_raw_mean(n, &self.z[n], &mut bz);
                self.evals += p.q() as u64;
                for k in 0..dim {
                    self.rhs[k] += alpha * (bz[k] + lam * self.z[n][k]);
                }
            }
            let warm = self.z[n].clone();
            self.z_next[n] = self.solve_resolvent(n, &warm);
        }
        std::mem::swap(&mut self.z_prev, &mut self.z);
        std::mem::swap(&mut self.z, &mut self.z_next);
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        self.evals as f64 / (self.problem.nodes() * self.problem.q()) as f64
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        "P-EXTRA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn converges_on_ridge_with_large_steps() {
        // the point of proximal steps: alpha far above 1/L still converges
        let ds = SyntheticSpec::tiny().with_regression(true).generate(31);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let mut params = AlgoParams::new(3.0, p.dim(), 1);
        params.inner_tol = 1e-13;
        let mut alg = PExtra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..300 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-7, "residual {r}");
    }
}
