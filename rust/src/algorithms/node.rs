//! Per-node runtime substrate: neighbor-iterate buffers, local mixing
//! helpers, and the sequential reference driver over [`NodeState`]s.
//!
//! Every helper here reproduces the *exact* floating-point accumulation
//! order of the legacy monolithic implementations (own row first, then
//! neighbors in sorted adjacency order), so the per-node decomposition is
//! bit-for-bit identical to the pre-refactor iterate sequences — which is
//! what lets `rust/tests/sparse_comm.rs` keep pinning DSBA ≡ DSBA-s at
//! 1e-16 and `rust/tests/engine_parity.rs` pin sequential ≡ parallel
//! exactly.

use super::NodeState;
use crate::comm::{Message, Network, Outgoing};
use crate::graph::{MixingMatrix, Topology};
use std::sync::Arc;

/// Per-neighbor storage of the last two received iterates, aligned with
/// the (sorted) adjacency list. Payloads are the broadcast `Arc`s
/// themselves, so delivery is pointer rotation — no per-edge copy. At
/// consensus start both generations hold `z0`, matching the monolithic
/// `z = z_prev = z0` initialization.
pub struct NeighborBuf {
    ids: Vec<usize>,
    z: Vec<Arc<Vec<f64>>>,
    z_prev: Vec<Arc<Vec<f64>>>,
}

impl NeighborBuf {
    pub fn new(topo: &Topology, n: usize, z0: &[f64]) -> NeighborBuf {
        let ids = topo.neighbors(n).to_vec();
        let z0 = Arc::new(z0.to_vec());
        NeighborBuf {
            z: vec![z0.clone(); ids.len()],
            z_prev: vec![z0; ids.len()],
            ids,
        }
    }

    #[inline]
    fn slot(&self, from: usize) -> usize {
        self.ids
            .binary_search(&from)
            .unwrap_or_else(|_| panic!("message from non-neighbor {from}"))
    }

    /// Rotate in a freshly received iterate: current becomes previous.
    pub fn accept(&mut self, from: usize, v: Arc<Vec<f64>>) {
        let j = self.slot(from);
        std::mem::swap(&mut self.z[j], &mut self.z_prev[j]);
        self.z[j] = v;
    }

    /// Latest received iterate of neighbor `from` (`z_m^t` inside round t).
    #[inline]
    pub fn cur(&self, from: usize) -> &[f64] {
        self.z[self.slot(from)].as_slice()
    }

    /// (current, previous) pair of neighbor `from`.
    #[inline]
    pub fn pair(&self, from: usize) -> (&[f64], &[f64]) {
        let j = self.slot(from);
        (self.z[j].as_slice(), self.z_prev[j].as_slice())
    }
}

/// The standard round exchange of every dense-communication method: one
/// shared payload (single allocation + copy of `v`) addressed to each
/// neighbor edge.
pub fn broadcast_dense(topo: &Topology, n: usize, v: &[f64]) -> Vec<Outgoing> {
    let payload = Arc::new(v.to_vec());
    topo.neighbors(n)
        .iter()
        .map(|&to| Outgoing { to, msg: Message::Dense(payload.clone()) })
        .collect()
}

#[inline]
fn acc_mixed(w: f64, zm: &[f64], zmp: &[f64], out: &mut [f64]) {
    if w == 0.0 {
        return;
    }
    for k in 0..out.len() {
        out[k] += w * (2.0 * zm[k] - zmp[k]);
    }
}

/// `out = sum_{m in {n} ∪ N(n)} wt[n][m] (2 z_m^t - z_m^{t-1})` from the
/// node's own rows plus its neighbor buffer — the per-node twin of
/// [`MixingMatrix::mix_row`], same accumulation order.
pub fn mix_row_local(
    mix: &MixingMatrix,
    topo: &Topology,
    n: usize,
    own_z: &[f64],
    own_z_prev: &[f64],
    nbrs: &NeighborBuf,
    out: &mut [f64],
) {
    out.fill(0.0);
    acc_mixed(mix.wt[(n, n)], own_z, own_z_prev, out);
    for &m in topo.neighbors(n) {
        let (zm, zmp) = nbrs.pair(m);
        acc_mixed(mix.wt[(n, m)], zm, zmp, out);
    }
}

/// `out = sum_{m in {n} ∪ N(n)} w[n][m] z_m` — the `W`-row sum every
/// method uses at `t = 0`, same accumulation order as the monolithic
/// `add(n); for m in neighbors { add(m) }` blocks.
pub fn w_row_local(
    mix: &MixingMatrix,
    topo: &Topology,
    n: usize,
    own_z: &[f64],
    nbrs: &NeighborBuf,
    out: &mut [f64],
) {
    out.fill(0.0);
    let w = mix.w[(n, n)];
    if w != 0.0 {
        crate::linalg::axpy(w, own_z, out);
    }
    for &m in topo.neighbors(n) {
        let w = mix.w[(n, m)];
        if w != 0.0 {
            crate::linalg::axpy(w, nbrs.cur(m), out);
        }
    }
}

/// Sequential reference driver: one synchronous round = collect every
/// node's outgoing messages (charging each into the network in node
/// order), deliver, then run every local step in node order. This is the
/// oracle semantics the parallel engine
/// ([`crate::runtime::ParallelEngine`]) must reproduce bit-for-bit.
pub struct RoundDriver<N: NodeState> {
    pub(crate) nodes: Vec<N>,
    /// mirror of per-node iterates for `Algorithm::iterates()`
    z: Vec<Vec<f64>>,
    t: usize,
    /// one-time dense sends charged before round 0 (DSBA-s phibar flood)
    setup: Vec<(usize, usize, usize)>,
    /// `N * q`, the denominator of effective passes
    pass_denom: f64,
}

impl<N: NodeState> RoundDriver<N> {
    pub fn new(nodes: Vec<N>, setup: Vec<(usize, usize, usize)>, pass_denom: f64) -> Self {
        let z = nodes.iter().map(|nd| nd.iterate().to_vec()).collect();
        RoundDriver { nodes, z, t: 0, setup, pass_denom }
    }

    pub fn step(&mut self, net: &mut Network) {
        if self.t == 0 {
            for &(from, to, len) in &self.setup {
                net.send_dense(from, to, len);
            }
        }
        let n = self.nodes.len();
        let mut inbox: Vec<Vec<(usize, Message)>> = (0..n).map(|_| Vec::new()).collect();
        for (src, node) in self.nodes.iter_mut().enumerate() {
            for out in node.outgoing(self.t) {
                out.msg.charge(net, src, out.to);
                inbox[out.to].push((src, out.msg));
            }
        }
        for (nd, node) in self.nodes.iter_mut().enumerate() {
            for (from, msg) in inbox[nd].drain(..) {
                node.on_receive(from, msg);
            }
            node.local_step(self.t);
            let it = node.iterate();
            self.z[nd].copy_from_slice(it);
        }
        self.t += 1;
    }

    pub fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    pub fn iteration(&self) -> usize {
        self.t
    }

    pub fn passes(&self) -> f64 {
        let evals: u64 = self.nodes.iter().map(|n| n.evals()).sum();
        evals as f64 / self.pass_denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbor_buf_rotates_generations() {
        let topo = Topology::ring(4); // node 0 neighbors: 1, 3
        let mut buf = NeighborBuf::new(&topo, 0, &[0.0, 0.0]);
        assert_eq!(buf.pair(1), (&[0.0, 0.0][..], &[0.0, 0.0][..]));
        buf.accept(1, Arc::new(vec![1.0, 1.0]));
        assert_eq!(buf.pair(1), (&[1.0, 1.0][..], &[0.0, 0.0][..]));
        buf.accept(1, Arc::new(vec![2.0, 2.0]));
        assert_eq!(buf.pair(1), (&[2.0, 2.0][..], &[1.0, 1.0][..]));
        // untouched neighbor keeps consensus start
        assert_eq!(buf.pair(3), (&[0.0, 0.0][..], &[0.0, 0.0][..]));
    }

    #[test]
    #[should_panic(expected = "non-neighbor")]
    fn neighbor_buf_rejects_strangers() {
        let topo = Topology::ring(4);
        let mut buf = NeighborBuf::new(&topo, 0, &[0.0]);
        buf.accept(2, Arc::new(vec![1.0]));
    }

    #[test]
    fn mix_row_local_matches_global_mix_row() {
        let topo = Topology::erdos_renyi(6, 0.5, 9);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let d = 5;
        let mut rng = crate::util::rng::Rng::new(2);
        let z: Vec<Vec<f64>> =
            (0..6).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let zp: Vec<Vec<f64>> =
            (0..6).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        for n in 0..6 {
            let mut buf = NeighborBuf::new(&topo, n, &vec![0.0; d]);
            for &m in topo.neighbors(n) {
                buf.accept(m, Arc::new(zp[m].clone()));
                buf.accept(m, Arc::new(z[m].clone()));
            }
            let mut want = vec![0.0; d];
            mix.mix_row(n, &topo, &z, &zp, &mut want);
            let mut got = vec![0.0; d];
            mix_row_local(&mix, &topo, n, &z[n], &zp[n], &buf, &mut got);
            // bit-for-bit: identical accumulation order
            assert_eq!(got, want, "node {n}");
        }
    }
}
