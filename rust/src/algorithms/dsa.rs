//! DSA (Mokhtari & Ribeiro, 2016) — the forward / gradient-evaluation
//! counterpart of DSBA (Remark 5.1): identical mixing and SAGA machinery,
//! but the sampled operator is evaluated at the *current* iterate `z^t`
//! (eq. (32)) instead of through a resolvent at `z^{t+1}`.
//!
//! Closed-form update used here (derived from (24) with forward deltas and
//! the l2 term kept exact):
//!   `z^{t+1} = sum_m w~(2 z^t_m - z^{t-1}_m)
//!              + alpha ((q-1)/q delta_f^{t-1} - delta_f^t)
//!              - alpha lambda (z^t - z^{t-1})`,
//! with `delta_f^t = B_{n,i_t}(z^t) - phi_{n,i_t}` and
//! `z^1 = W z^0 - alpha (phibar^0 + lambda z^0)`.

use super::node::{broadcast_dense, mix_row_local, w_row_local, NeighborBuf, RoundDriver};
use super::{AlgoParams, Algorithm, NodeSaga, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use crate::util::rng::Rng;
use std::sync::Arc;

pub(crate) struct DsaCtx {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
}

pub(crate) struct DsaNode {
    ctx: Arc<DsaCtx>,
    n: usize,
    z: Vec<f64>,
    z_prev: Vec<f64>,
    nbrs: NeighborBuf,
    saga: NodeSaga,
    /// previous forward delta: (component, coef delta)
    delta_prev: (usize, Vec<f64>),
    rng: Rng,
    evals: u64,
    z_next: Vec<f64>,
    coefs: Vec<f64>,
    dcur: Vec<f64>,
    dtable: Vec<f64>,
}

impl NodeState for DsaNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        broadcast_dense(&self.ctx.topo, self.n, &self.z)
    }

    fn on_receive(&mut self, from: usize, msg: Message) {
        match msg {
            Message::Dense(v) => self.nbrs.accept(from, v),
            Message::Sparse(_) => panic!("DSA exchanges dense iterates only"),
        }
    }

    fn local_step(&mut self, t: usize) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.as_ref();
        let (alpha, lam, q) = (ctx.alpha, p.lambda(), p.q());
        let dim = p.dim();
        let n = self.n;
        let i = self.rng.below(q);
        let zn = &mut self.z_next;
        if t == 0 {
            // z^1 = W z^0 - alpha (phibar^0 + lambda z^0)
            w_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.nbrs, zn);
            crate::linalg::axpy(-alpha, &self.saga.phibar, zn);
            if lam != 0.0 {
                crate::linalg::axpy(-alpha * lam, &self.z, zn);
            }
            // forward table refresh at z^0 is a no-op (phi = B(z^0))
            self.evals += 1;
        } else {
            mix_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.z_prev, &self.nbrs, zn);
            // forward delta at z^t
            p.coefs(n, i, &self.z, &mut self.coefs);
            self.evals += 1;
            for (d, (c, ph)) in self
                .dcur
                .iter_mut()
                .zip(self.coefs.iter().zip(self.saga.coef(i)))
            {
                *d = c - ph;
            }
            let (i_prev, ref dprev) = self.delta_prev;
            p.scatter(n, i_prev, dprev, alpha * (q as f64 - 1.0) / q as f64, zn);
            p.scatter(n, i, &self.dcur, -alpha, zn);
            if lam != 0.0 {
                for k in 0..dim {
                    zn[k] -= alpha * lam * (self.z[k] - self.z_prev[k]);
                }
            }
            // table update with the forward coefficients
            let (ip, dp) = &mut self.delta_prev;
            *ip = i;
            dp.copy_from_slice(&self.dcur);
            self.saga.update(p, n, i, &self.coefs, &mut self.dtable);
        }
        std::mem::swap(&mut self.z_prev, &mut self.z);
        std::mem::swap(&mut self.z, &mut self.z_next);
    }

    fn iterate(&self) -> &[f64] {
        &self.z
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

pub(crate) fn dsa_nodes(
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<DsaNode> {
    let n = problem.nodes();
    let w = problem.coef_width();
    let mut root = Rng::new(params.seed);
    let ctx = Arc::new(DsaCtx { problem, mix, topo, alpha: params.alpha });
    (0..n)
        .map(|nd| DsaNode {
            n: nd,
            z: params.z0.clone(),
            z_prev: params.z0.clone(),
            nbrs: NeighborBuf::new(&ctx.topo, nd, &params.z0),
            saga: NodeSaga::init(ctx.problem.as_ref(), nd, &params.z0),
            delta_prev: (0, vec![0.0; w]),
            rng: root.fork(nd as u64),
            evals: 0,
            z_next: params.z0.clone(),
            coefs: vec![0.0; w],
            dcur: vec![0.0; w],
            dtable: vec![0.0; w],
            ctx: ctx.clone(),
        })
        .collect()
}

/// Sequentially driven DSA.
pub struct Dsa {
    drv: RoundDriver<DsaNode>,
}

impl Dsa {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Dsa {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let nodes = dsa_nodes(problem, mix, topo, params);
        Dsa { drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }
}

impl Algorithm for Dsa {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "DSA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn converges_on_tiny_ridge() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(17);
        let part = ds.partition_seeded(4, 3);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.05));
        let params = AlgoParams::new(0.2, p.dim(), 1);
        let mut alg = Dsa::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..150 * p.q() {
            alg.step(&mut net);
        }
        let z0 = &alg.iterates()[0];
        assert!(
            p.global_residual(z0) < 1e-5,
            "residual {}",
            p.global_residual(z0)
        );
    }

    #[test]
    fn dsba_beats_dsa_same_step_budget() {
        // the paper's headline qualitative result on a tiny instance:
        // after the same number of passes, DSBA's residual is lower
        // (backward steps tolerate larger alpha; here same alpha)
        let ds = SyntheticSpec::tiny().with_regression(true).generate(29);
        let part = ds.partition_seeded(4, 7);
        let topo = Topology::erdos_renyi(4, 0.6, 9);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.02));
        // backward steps tolerate step sizes where forward SAGA steps
        // become unstable: compare at alpha well above 1/L
        let params = AlgoParams::new(1.5, p.dim(), 11);
        let mut dsba = super::super::Dsba::new(p.clone(), mix.clone(), topo.clone(), &params);
        let mut dsa = Dsa::new(p.clone(), mix, topo.clone(), &params);
        let mut net1 = Network::new(topo.clone(), CommCostModel::default());
        let mut net2 = Network::new(topo, CommCostModel::default());
        for _ in 0..30 * p.q() {
            dsba.step(&mut net1);
            dsa.step(&mut net2);
        }
        let r_dsba = p.global_residual(&dsba.iterates()[0]);
        let r_dsa = p.global_residual(&dsa.iterates()[0]);
        assert!(
            r_dsba < r_dsa.max(1e-10),
            "DSBA {r_dsba:.3e} should beat DSA {r_dsa:.3e} at alpha=1.5"
        );
    }
}
