//! DSBA — Decentralized Stochastic Backward Aggregation (Algorithm 1),
//! dense-communication implementation.
//!
//! Per round, every node n:
//!   1. gathers neighbor iterates (dense exchange),
//!   2. samples a component `i_n^t`,
//!   3. forms `psi_n^t` — eq. (31) at t=0, eq. (29) for t>=1, with the l2
//!      regularization folded in analytically (see operators module docs):
//!      `psi += alpha * lambda * z_n^t` for t>=1, and the resolvent is
//!      `J_{alpha(B_{n,i} + lambda I)}`,
//!   4. computes `z_n^{t+1}` through the backward step (30),
//!   5. updates the SAGA table with the *post-step* coefficients
//!      (the "backward aggregation" that distinguishes DSBA from DSA).

use super::{AlgoParams, Algorithm, NodeSaga};
use crate::comm::Network;
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct Dsba {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
    /// z^t and z^{t-1}, one row per node
    z: Vec<Vec<f64>>,
    z_prev: Vec<Vec<f64>>,
    saga: Vec<NodeSaga>,
    /// previous round's (component, coefficient delta) per node
    delta_prev: Vec<(usize, Vec<f64>)>,
    rngs: Vec<Rng>,
    t: usize,
    evals: u64,
    /// scratch buffers reused across rounds (hot-path: no allocation)
    psi: Vec<f64>,
    z_next: Vec<Vec<f64>>,
    coefs_new: Vec<f64>,
}

impl Dsba {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Dsba {
        let n = problem.nodes();
        let dim = problem.dim();
        assert_eq!(params.z0.len(), dim, "z0 dimension mismatch");
        let z: Vec<Vec<f64>> = vec![params.z0.clone(); n];
        let saga: Vec<NodeSaga> =
            (0..n).map(|nd| NodeSaga::init(problem.as_ref(), nd, &params.z0)).collect();
        let w = problem.coef_width();
        let mut root = Rng::new(params.seed);
        let rngs = (0..n).map(|nd| root.fork(nd as u64)).collect();
        Dsba {
            alpha: params.alpha,
            z_prev: z.clone(),
            z_next: z.clone(),
            z,
            saga,
            delta_prev: vec![(0, vec![0.0; w]); n],
            rngs,
            t: 0,
            evals: 0,
            psi: vec![0.0; dim],
            coefs_new: vec![0.0; w],
            problem,
            mix,
            topo,
        }
    }

    /// Access to the SAGA tables (Lyapunov probe & tests).
    pub fn saga(&self) -> &[NodeSaga] {
        &self.saga
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

impl Algorithm for Dsba {
    fn step(&mut self, net: &mut Network) {
        let p = self.problem.as_ref();
        let (alpha, lam, q) = (self.alpha, p.lambda(), p.q());
        let dim = p.dim();
        // 1. dense neighbor exchange (Algorithm 1, line 3)
        net.round_dense_exchange(dim);

        for n in 0..p.nodes() {
            let i = self.rngs[n].below(q);
            let psi = &mut self.psi;
            if self.t == 0 {
                // eq. (31): psi = sum_m w_{nm} z_m^0 + alpha (phi_{n,i} - phibar)
                psi.fill(0.0);
                let wrow = &self.mix.w;
                let add = |m: usize, psi: &mut [f64]| {
                    let w = wrow[(n, m)];
                    if w != 0.0 {
                        crate::linalg::axpy(w, &self.z[m], psi);
                    }
                };
                add(n, psi);
                for &m in self.topo.neighbors(n) {
                    add(m, psi);
                }
                p.scatter(n, i, self.saga[n].coef(i), alpha, psi);
                crate::linalg::axpy(-alpha, &self.saga[n].phibar, psi);
            } else {
                // eq. (29) + analytic l2 term:
                // psi = sum w~ (2z - z_prev) + alpha((q-1)/q delta_prev
                //       + phi_{n,i}) + alpha lambda z_n
                self.mix.mix_row(n, &self.topo, &self.z, &self.z_prev, psi);
                let (i_prev, ref dprev) = self.delta_prev[n];
                p.scatter(n, i_prev, dprev, alpha * (q as f64 - 1.0) / q as f64, psi);
                p.scatter(n, i, self.saga[n].coef(i), alpha, psi);
                if lam != 0.0 {
                    crate::linalg::axpy(alpha * lam, &self.z[n], psi);
                }
            }
            // backward step (30) — resolvent of the sampled component
            p.backward(n, i, alpha, psi, &mut self.z_next[n], &mut self.coefs_new);
            self.evals += 1;
            // SAGA table update with post-step coefficients (line 7-8)
            let (ip, dp) = &mut self.delta_prev[n];
            *ip = i;
            self.saga[n].update(p, n, i, &self.coefs_new, dp);
        }
        // synchronous commit
        std::mem::swap(&mut self.z_prev, &mut self.z);
        std::mem::swap(&mut self.z, &mut self.z_next);
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        self.evals as f64 / (self.problem.nodes() * self.problem.q()) as f64
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        "DSBA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    fn setup(nodes: usize, lam: f64) -> (Arc<dyn Problem>, MixingMatrix, Topology) {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(17);
        let part = ds.partition_seeded(nodes, 3);
        let topo = Topology::erdos_renyi(nodes, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (Arc::new(RidgeProblem::new(part, lam)), mix, topo)
    }

    #[test]
    fn converges_on_tiny_ridge() {
        let (p, mix, topo) = setup(4, 0.05);
        let params = AlgoParams::new(0.5, p.dim(), 1);
        let mut alg = Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..60 * p.q() {
            alg.step(&mut net);
        }
        // all nodes near-consensus and near-zero global residual
        let z0 = &alg.iterates()[0];
        for z in alg.iterates() {
            assert!(crate::linalg::dist2_sq(z, z0) < 1e-12);
        }
        assert!(p.global_residual(z0) < 1e-6, "residual {}", p.global_residual(z0));
    }

    #[test]
    fn comm_cost_is_dense_per_round() {
        let (p, mix, topo) = setup(4, 0.05);
        let params = AlgoParams::new(0.5, p.dim(), 1);
        let mut alg = Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo.clone(), CommCostModel::values_only());
        alg.step(&mut net);
        let got = net.max_received();
        let want = (0..topo.n)
            .map(|n| topo.degree(n) as f64 * p.dim() as f64)
            .fold(0.0, f64::max);
        assert_eq!(got, want);
    }

    #[test]
    fn single_node_matches_point_saga() {
        // Remark 5.1: with one node DSBA degenerates to Point-SAGA
        let ds = SyntheticSpec::tiny().with_regression(true).generate(23);
        let part = ds.partition_seeded(1, 3);
        let topo = Topology::from_edges(1, &[]);
        let mix = MixingMatrix::from_w(crate::linalg::DenseMatrix::identity(1));
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.02));
        let params = AlgoParams::new(0.4, p.dim(), 77);
        let mut dsba = Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut ps = super::super::PointSaga::new(p.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..200 {
            dsba.step(&mut net);
            ps.step(&mut net);
            let a = &dsba.iterates()[0];
            let b = &ps.iterates()[0];
            let d = crate::linalg::dist2_sq(a, b);
            assert!(d < 1e-12, "diverged: {d}");
        }
    }
}
