//! DSBA — Decentralized Stochastic Backward Aggregation (Algorithm 1),
//! dense-communication implementation, in per-node form.
//!
//! Per round, every node n:
//!   1. broadcasts `z_n^t` to its neighbors and absorbs theirs (dense
//!      exchange, Algorithm 1 line 3),
//!   2. samples a component `i_n^t`,
//!   3. forms `psi_n^t` — eq. (31) at t=0, eq. (29) for t>=1, with the l2
//!      regularization folded in analytically (see operators module docs):
//!      `psi += alpha * lambda * z_n^t` for t>=1, and the resolvent is
//!      `J_{alpha(B_{n,i} + lambda I)}`,
//!   4. computes `z_n^{t+1}` through the backward step (30),
//!   5. updates the SAGA table with the *post-step* coefficients
//!      (the "backward aggregation" that distinguishes DSBA from DSA).

use super::node::{broadcast_dense, mix_row_local, w_row_local, NeighborBuf, RoundDriver};
use super::{AlgoParams, Algorithm, NodeSaga, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Shared immutable world of one DSBA instance.
pub(crate) struct DsbaCtx {
    pub problem: Arc<dyn Problem>,
    pub mix: MixingMatrix,
    pub topo: Topology,
    pub alpha: f64,
}

/// One node's DSBA state.
pub(crate) struct DsbaNode {
    ctx: Arc<DsbaCtx>,
    n: usize,
    z: Vec<f64>,
    z_prev: Vec<f64>,
    nbrs: NeighborBuf,
    pub(crate) saga: NodeSaga,
    /// previous round's (component, coefficient delta)
    delta_prev: (usize, Vec<f64>),
    rng: Rng,
    evals: u64,
    /// scratch buffers reused across rounds (hot-path: no allocation)
    psi: Vec<f64>,
    z_next: Vec<f64>,
    coefs_new: Vec<f64>,
}

impl NodeState for DsbaNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        broadcast_dense(&self.ctx.topo, self.n, &self.z)
    }

    fn on_receive(&mut self, from: usize, msg: Message) {
        match msg {
            Message::Dense(v) => self.nbrs.accept(from, v),
            Message::Sparse(_) => panic!("DSBA exchanges dense iterates only"),
        }
    }

    fn local_step(&mut self, t: usize) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.as_ref();
        let (alpha, lam, q) = (ctx.alpha, p.lambda(), p.q());
        let n = self.n;
        let i = self.rng.below(q);
        let psi = &mut self.psi;
        if t == 0 {
            // eq. (31): psi = sum_m w_{nm} z_m^0 + alpha (phi_{n,i} - phibar)
            w_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.nbrs, psi);
            p.scatter(n, i, self.saga.coef(i), alpha, psi);
            crate::linalg::axpy(-alpha, &self.saga.phibar, psi);
        } else {
            // eq. (29) + analytic l2 term:
            // psi = sum w~ (2z - z_prev) + alpha((q-1)/q delta_prev
            //       + phi_{n,i}) + alpha lambda z_n
            mix_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.z_prev, &self.nbrs, psi);
            let (i_prev, ref dprev) = self.delta_prev;
            p.scatter(n, i_prev, dprev, alpha * (q as f64 - 1.0) / q as f64, psi);
            p.scatter(n, i, self.saga.coef(i), alpha, psi);
            if lam != 0.0 {
                crate::linalg::axpy(alpha * lam, &self.z, psi);
            }
        }
        // backward step (30) — resolvent of the sampled component
        p.backward(n, i, alpha, psi, &mut self.z_next, &mut self.coefs_new);
        self.evals += 1;
        // SAGA table update with post-step coefficients (line 7-8)
        let (ip, dp) = &mut self.delta_prev;
        *ip = i;
        self.saga.update(p, n, i, &self.coefs_new, dp);
        // synchronous commit
        std::mem::swap(&mut self.z_prev, &mut self.z);
        std::mem::swap(&mut self.z, &mut self.z_next);
    }

    fn iterate(&self) -> &[f64] {
        &self.z
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

/// Construct the per-node states (shared by the sequential driver and the
/// parallel engine; RNG streams forked in node order).
pub(crate) fn dsba_nodes(
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<DsbaNode> {
    let n = problem.nodes();
    let dim = problem.dim();
    assert_eq!(params.z0.len(), dim, "z0 dimension mismatch");
    let w = problem.coef_width();
    let mut root = Rng::new(params.seed);
    let ctx = Arc::new(DsbaCtx { problem, mix, topo, alpha: params.alpha });
    (0..n)
        .map(|nd| DsbaNode {
            n: nd,
            z: params.z0.clone(),
            z_prev: params.z0.clone(),
            nbrs: NeighborBuf::new(&ctx.topo, nd, &params.z0),
            saga: NodeSaga::init(ctx.problem.as_ref(), nd, &params.z0),
            delta_prev: (0, vec![0.0; w]),
            rng: root.fork(nd as u64),
            evals: 0,
            psi: vec![0.0; dim],
            z_next: params.z0.clone(),
            coefs_new: vec![0.0; w],
            ctx: ctx.clone(),
        })
        .collect()
}

/// Sequentially driven DSBA (the reference oracle).
pub struct Dsba {
    drv: RoundDriver<DsbaNode>,
}

impl Dsba {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Dsba {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let nodes = dsba_nodes(problem, mix, topo, params);
        Dsba { drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }

    /// Access to one node's SAGA table (Lyapunov probe & tests).
    pub fn saga(&self, n: usize) -> &NodeSaga {
        &self.drv.nodes[n].saga
    }

    pub fn alpha(&self) -> f64 {
        self.drv.nodes[0].ctx.alpha
    }
}

impl Algorithm for Dsba {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "DSBA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    fn setup(nodes: usize, lam: f64) -> (Arc<dyn Problem>, MixingMatrix, Topology) {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(17);
        let part = ds.partition_seeded(nodes, 3);
        let topo = Topology::erdos_renyi(nodes, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (Arc::new(RidgeProblem::new(part, lam)), mix, topo)
    }

    #[test]
    fn converges_on_tiny_ridge() {
        let (p, mix, topo) = setup(4, 0.05);
        let params = AlgoParams::new(0.5, p.dim(), 1);
        let mut alg = Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..60 * p.q() {
            alg.step(&mut net);
        }
        // all nodes near-consensus and near-zero global residual
        let z0 = &alg.iterates()[0];
        for z in alg.iterates() {
            assert!(crate::linalg::dist2_sq(z, z0) < 1e-12);
        }
        assert!(p.global_residual(z0) < 1e-6, "residual {}", p.global_residual(z0));
    }

    #[test]
    fn comm_cost_is_dense_per_round() {
        let (p, mix, topo) = setup(4, 0.05);
        let params = AlgoParams::new(0.5, p.dim(), 1);
        let mut alg = Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo.clone(), CommCostModel::values_only());
        alg.step(&mut net);
        let got = net.max_received();
        let want = (0..topo.n)
            .map(|n| topo.degree(n) as f64 * p.dim() as f64)
            .fold(0.0, f64::max);
        assert_eq!(got, want);
    }

    #[test]
    fn single_node_matches_point_saga() {
        // Remark 5.1: with one node DSBA degenerates to Point-SAGA
        let ds = SyntheticSpec::tiny().with_regression(true).generate(23);
        let part = ds.partition_seeded(1, 3);
        let topo = Topology::from_edges(1, &[]);
        let mix = MixingMatrix::from_w(crate::linalg::DenseMatrix::identity(1));
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.02));
        let params = AlgoParams::new(0.4, p.dim(), 77);
        let mut dsba = Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut ps = super::super::PointSaga::new(p.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..200 {
            dsba.step(&mut net);
            ps.step(&mut net);
            let a = &dsba.iterates()[0];
            let b = &ps.iterates()[0];
            let d = crate::linalg::dist2_sq(a, b);
            assert!(d < 1e-12, "diverged: {d}");
        }
    }
}
