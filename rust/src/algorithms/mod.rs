//! Decentralized algorithms: DSBA, DSBA-s (sparse communication), and
//! every baseline in the paper's Table 1.
//!
//! | method     | type                             | comm/round        |
//! |------------|----------------------------------|-------------------|
//! | DSBA       | stochastic, backward (resolvent) | dense Δ(G)d       |
//! | DSBA-s     | same iterates, sparse relay      | sparse N rho d    |
//! | DSA        | stochastic, forward (SAGA)       | dense Δ(G)d       |
//! | EXTRA      | deterministic gradient           | dense Δ(G)d       |
//! | P-EXTRA    | deterministic proximal           | dense Δ(G)d       |
//! | DLM        | linearized ADMM                  | dense Δ(G)d       |
//! | SSDA       | accelerated dual                 | dense Δ(G)d       |
//! | DGD        | diminishing-step consensus       | dense Δ(G)d       |
//! | Point-SAGA | single-node stochastic backward  | none              |
//!
//! Every method is implemented as a **per-node state machine**
//! ([`NodeState`]): a node emits typed [`Message`]s to its neighbors,
//! absorbs the round's deliveries, then runs a local update. Two drivers
//! execute that decomposition:
//!
//! * the sequential [`node::RoundDriver`] — deterministic node order, the
//!   reference oracle, behind each method's [`Algorithm`] impl;
//! * the multi-threaded [`crate::runtime::ParallelEngine`] — one worker
//!   thread per node group, mpsc channels on the topology's edges,
//!   barrier-synchronized rounds. Bit-for-bit equal to the sequential
//!   driver under the same seed (per-node RNG streams are forked
//!   identically), pinned by `rust/tests/engine_parity.rs`.
//!
//! All communication is accounted through [`crate::comm::Network`].

pub mod node;

mod saga;
mod dsba;
mod dsba_sparse;
mod dsa;
mod extra;
mod p_extra;
mod dlm;
mod ssda;
mod dgd;
mod point_saga;

pub use dgd::Dgd;
pub use dlm::Dlm;
pub use dsa::Dsa;
pub use dsba::Dsba;
pub use dsba_sparse::DsbaSparse;
pub use extra::Extra;
pub use p_extra::PExtra;
pub use point_saga::PointSaga;
pub use saga::NodeSaga;
pub use ssda::Ssda;

use crate::comm::{Message, Network, Outgoing};
use crate::graph::MixingMatrix;
use crate::metrics::GlobalStats;
use crate::operators::Problem;
use std::sync::Arc;

/// One decentralized optimization method, stepped one synchronous round
/// at a time. The step is the sequential reference execution of the
/// method's per-node decomposition (see [`NodeState`]).
pub trait Algorithm {
    /// Execute one synchronous round on every node; all transmissions are
    /// accounted into `net`.
    fn step(&mut self, net: &mut Network);

    /// Current per-node iterates `z_n^t` (the *primal* estimates for dual
    /// methods).
    fn iterates(&self) -> &[Vec<f64>];

    /// Effective passes over the local datasets so far
    /// (component evaluations / (N q)).
    fn passes(&self) -> f64;

    /// Rounds executed.
    fn iteration(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Split-hosted engines override this: exchange per-node stat rows
    /// (iterate, eval count, and the caller-supplied received-DOUBLE
    /// totals, indexed by node) with the peer engines hosting the rest
    /// of the topology, and return the complete global row set. `None`
    /// — the default — means this driver already executes every node,
    /// so the caller computes metrics locally. Lockstep contract: in a
    /// split run every process must call this at the same rounds (the
    /// coordinator's sampling schedule is derived from shared config,
    /// which guarantees it).
    fn global_stats(
        &mut self,
        received: &[f64],
        received_bytes: &[f64],
    ) -> Option<GlobalStats> {
        let _ = (received, received_bytes);
        None
    }

    /// `(max_staleness, stalls)` observed so far: the largest
    /// rounds-behind of any neighbor iterate a node consumed, and how
    /// many scheduler scans sat blocked on a lagging neighbor. Both are
    /// zero for every synchronous driver — only the parallel engine's
    /// bounded-staleness async clock overrides this.
    fn staleness_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Rows the telemetry writer's wait-free channel has dropped so far.
    /// `None` — the default — means this driver carries no telemetry
    /// writer at all; only the parallel engine (with `--telemetry`)
    /// overrides it, so the coordinator can surface silent row loss in
    /// the run's final summary.
    fn telemetry_dropped(&self) -> Option<u64> {
        None
    }
}

/// One node's slice of a decentralized method: the unit both the
/// sequential driver and the parallel engine schedule.
///
/// Round protocol (synchronous, round `t`):
/// 1. [`NodeState::outgoing`] — emit this round's messages to neighbors
///    (may mutate local state: SSDA runs its conjugate oracle pre-send);
/// 2. [`NodeState::on_receive`] — absorb each delivered message; within a
///    round, handlers must be order-independent across senders (the
///    engine delivers in ascending sender order for determinism anyway);
/// 3. [`NodeState::local_step`] — the local update once the round's
///    messages are all in.
///
/// Determinism contract: given identical construction (seeded per-node
/// RNG streams forked in node order) and per-round message sets, the
/// iterate sequence must not depend on scheduling — nodes may only read
/// their own state plus received payloads.
pub trait NodeState: Send {
    /// Messages to emit at the start of round `t`.
    fn outgoing(&mut self, t: usize) -> Vec<Outgoing>;

    /// Deliver one message from neighbor `from`.
    fn on_receive(&mut self, from: usize, msg: Message);

    /// Local update once the round's messages are all delivered.
    fn local_step(&mut self, t: usize);

    /// Current iterate `z_n^t` (primal estimate for dual methods).
    fn iterate(&self) -> &[f64];

    /// Component evaluations so far on this node.
    fn evals(&self) -> u64;
}

/// Method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmKind {
    Dsba,
    DsbaSparse,
    Dsa,
    Extra,
    PExtra,
    Dlm,
    Ssda,
    Dgd,
    PointSaga,
}

/// The single alias table behind [`AlgorithmKind::parse`],
/// [`AlgorithmKind::name`], [`AlgorithmKind::all`] and the CLI's method
/// listing: `(kind, canonical display name, extra accepted spellings)`.
/// The canonical name itself always parses (case-insensitively), so the
/// `parse(name(k)) == Some(k)` round trip is structural.
const ALGORITHM_TABLE: &[(AlgorithmKind, &str, &[&str])] = &[
    (AlgorithmKind::Dsba, "DSBA", &[]),
    (AlgorithmKind::DsbaSparse, "DSBA-s", &["dsba_sparse", "dsbas"]),
    (AlgorithmKind::Dsa, "DSA", &[]),
    (AlgorithmKind::Extra, "EXTRA", &[]),
    (AlgorithmKind::PExtra, "P-EXTRA", &["pextra"]),
    (AlgorithmKind::Dlm, "DLM", &[]),
    (AlgorithmKind::Ssda, "SSDA", &[]),
    (AlgorithmKind::Dgd, "DGD", &[]),
    (AlgorithmKind::PointSaga, "Point-SAGA", &["pointsaga"]),
];

impl AlgorithmKind {
    pub fn parse(s: &str) -> Option<AlgorithmKind> {
        ALGORITHM_TABLE
            .iter()
            .find(|(_, name, aliases)| {
                name.eq_ignore_ascii_case(s)
                    || aliases.iter().any(|a| a.eq_ignore_ascii_case(s))
            })
            .map(|&(k, _, _)| k)
    }

    pub fn name(&self) -> &'static str {
        ALGORITHM_TABLE
            .iter()
            .find(|(k, _, _)| k == self)
            .map(|&(_, name, _)| name)
            .expect("every AlgorithmKind is in ALGORITHM_TABLE")
    }

    /// Extra accepted spellings beyond the canonical name.
    pub fn aliases(&self) -> &'static [&'static str] {
        ALGORITHM_TABLE
            .iter()
            .find(|(k, _, _)| k == self)
            .map(|&(_, _, aliases)| aliases)
            .expect("every AlgorithmKind is in ALGORITHM_TABLE")
    }

    /// Stochastic methods progress 1/q of a pass per round.
    pub fn is_stochastic(&self) -> bool {
        matches!(
            self,
            AlgorithmKind::Dsba
                | AlgorithmKind::DsbaSparse
                | AlgorithmKind::Dsa
                | AlgorithmKind::PointSaga
        )
    }

    /// Methods whose component evaluations go through the resolvent
    /// (`Problem::backward`): the only ones that handle a declared
    /// separable l1 term ([`crate::operators::Problem::l1_weight`])
    /// exactly — forward and inner-solver baselines optimize the smooth
    /// part only.
    pub fn is_proximal(&self) -> bool {
        matches!(
            self,
            AlgorithmKind::Dsba | AlgorithmKind::DsbaSparse | AlgorithmKind::PointSaga
        )
    }

    /// Every kind, derived from `ALGORITHM_TABLE` so the listing can
    /// never drift from the parse/name source of truth.
    pub fn all() -> &'static [AlgorithmKind] {
        static ALL: std::sync::OnceLock<Vec<AlgorithmKind>> = std::sync::OnceLock::new();
        ALL.get_or_init(|| ALGORITHM_TABLE.iter().map(|&(k, _, _)| k).collect())
    }
}

/// Hyper-parameters shared by the factory. `alpha` is the step size the
/// paper tunes per method; the rest have paper-faithful defaults.
#[derive(Clone, Debug)]
pub struct AlgoParams {
    /// primary step size (alpha for primal methods, eta scale for SSDA)
    pub alpha: f64,
    /// initial consensus iterate (all nodes start here)
    pub z0: Vec<f64>,
    /// RNG seed driving component sampling
    pub seed: u64,
    /// DLM penalty parameter c
    pub dlm_c: f64,
    /// DLM proximal parameter rho
    pub dlm_rho: f64,
    /// SSDA momentum override (None = theory value)
    pub ssda_momentum: Option<f64>,
    /// DGD step decay: alpha_t = alpha / (1 + t)^dgd_decay
    pub dgd_decay: f64,
    /// inner-solver tolerance for P-EXTRA / SSDA oracles
    pub inner_tol: f64,
}

impl AlgoParams {
    pub fn new(alpha: f64, dim: usize, seed: u64) -> AlgoParams {
        AlgoParams {
            alpha,
            z0: vec![0.0; dim],
            seed,
            dlm_c: 1.0,
            dlm_rho: 1.0,
            ssda_momentum: None,
            dgd_decay: 0.5,
            inner_tol: 1e-12,
        }
    }
}

/// Build an algorithm instance (sequential reference driver).
pub fn build(
    kind: AlgorithmKind,
    problem: Arc<dyn Problem>,
    mix: &MixingMatrix,
    topo: &crate::graph::Topology,
    params: &AlgoParams,
) -> Box<dyn Algorithm> {
    match kind {
        AlgorithmKind::Dsba => Box::new(Dsba::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::DsbaSparse => {
            Box::new(DsbaSparse::new(problem, mix.clone(), topo.clone(), params))
        }
        AlgorithmKind::Dsa => Box::new(Dsa::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::Extra => Box::new(Extra::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::PExtra => {
            Box::new(PExtra::new(problem, mix.clone(), topo.clone(), params))
        }
        AlgorithmKind::Dlm => Box::new(Dlm::new(problem, topo.clone(), params)),
        AlgorithmKind::Ssda => Box::new(Ssda::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::Dgd => Box::new(Dgd::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::PointSaga => Box::new(PointSaga::new(problem, params)),
    }
}

/// A method decomposed into engine-schedulable per-node states, plus the
/// round-0 setup accounting (DSBA-s's one-time phibar flood) and the
/// effective-passes denominator.
pub struct NodeProgram {
    pub kind: AlgorithmKind,
    pub nodes: Vec<Box<dyn NodeState>>,
    /// (from, to, dense_len) sends charged once before round 0
    pub setup: Vec<(usize, usize, usize)>,
    /// `N * q`
    pub pass_denom: f64,
}

fn boxup<N: NodeState + 'static>(v: Vec<N>) -> Vec<Box<dyn NodeState>> {
    v.into_iter().map(|x| Box::new(x) as Box<dyn NodeState>).collect()
}

/// Decompose a method into per-node states for an external engine. The
/// states are constructed identically to [`build`]'s (same RNG forking
/// order), so any engine that respects the round protocol reproduces the
/// sequential iterate sequence exactly.
pub fn build_node_program(
    kind: AlgorithmKind,
    problem: Arc<dyn Problem>,
    mix: &MixingMatrix,
    topo: &crate::graph::Topology,
    params: &AlgoParams,
) -> NodeProgram {
    let pass_denom = (problem.nodes() * problem.q()) as f64;
    let (nodes, setup) = match kind {
        AlgorithmKind::Dsba => (
            boxup(dsba::dsba_nodes(problem, mix.clone(), topo.clone(), params)),
            Vec::new(),
        ),
        AlgorithmKind::DsbaSparse => {
            let dim = problem.dim();
            (
                boxup(dsba_sparse::dsba_sparse_nodes(
                    problem,
                    mix.clone(),
                    topo.clone(),
                    params,
                )),
                dsba_sparse::flood_schedule(topo, dim),
            )
        }
        AlgorithmKind::Dsa => (
            boxup(dsa::dsa_nodes(problem, mix.clone(), topo.clone(), params)),
            Vec::new(),
        ),
        AlgorithmKind::Extra => (
            boxup(extra::extra_nodes(problem, mix.clone(), topo.clone(), params)),
            Vec::new(),
        ),
        AlgorithmKind::PExtra => (
            boxup(p_extra::p_extra_nodes(problem, mix.clone(), topo.clone(), params)),
            Vec::new(),
        ),
        AlgorithmKind::Dlm => {
            (boxup(dlm::dlm_nodes(problem, topo.clone(), params)), Vec::new())
        }
        AlgorithmKind::Ssda => (
            boxup(ssda::ssda_nodes(problem, mix.clone(), topo.clone(), params)),
            Vec::new(),
        ),
        AlgorithmKind::Dgd => (
            boxup(dgd::dgd_nodes(problem, mix.clone(), topo.clone(), params)),
            Vec::new(),
        ),
        AlgorithmKind::PointSaga => {
            (boxup(point_saga::point_saga_nodes(problem, params)), Vec::new())
        }
    };
    NodeProgram { kind, nodes, setup, pass_denom }
}
