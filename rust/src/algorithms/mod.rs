//! Decentralized algorithms: DSBA, DSBA-s (sparse communication), and
//! every baseline in the paper's Table 1.
//!
//! | method     | type                             | comm/round        |
//! |------------|----------------------------------|-------------------|
//! | DSBA       | stochastic, backward (resolvent) | dense Δ(G)d       |
//! | DSBA-s     | same iterates, sparse relay      | sparse N rho d    |
//! | DSA        | stochastic, forward (SAGA)       | dense Δ(G)d       |
//! | EXTRA      | deterministic gradient           | dense Δ(G)d       |
//! | P-EXTRA    | deterministic proximal           | dense Δ(G)d       |
//! | DLM        | linearized ADMM                  | dense Δ(G)d       |
//! | SSDA       | accelerated dual                 | dense Δ(G)d       |
//! | DGD        | diminishing-step consensus       | dense Δ(G)d       |
//! | Point-SAGA | single-node stochastic backward  | none              |
//!
//! All methods share the same [`Algorithm`] interface driven by the
//! coordinator one synchronous round at a time, with all communication
//! accounted through [`crate::comm::Network`].

mod saga;
mod dsba;
mod dsba_sparse;
mod dsa;
mod extra;
mod p_extra;
mod dlm;
mod ssda;
mod dgd;
mod point_saga;

pub use dgd::Dgd;
pub use dlm::Dlm;
pub use dsa::Dsa;
pub use dsba::Dsba;
pub use dsba_sparse::DsbaSparse;
pub use extra::Extra;
pub use p_extra::PExtra;
pub use point_saga::PointSaga;
pub use saga::NodeSaga;
pub use ssda::Ssda;

use crate::comm::Network;
use crate::graph::MixingMatrix;
use crate::operators::Problem;
use std::sync::Arc;

/// One decentralized optimization method, stepped one synchronous round
/// at a time.
pub trait Algorithm {
    /// Execute one synchronous round on every node; all transmissions are
    /// accounted into `net`.
    fn step(&mut self, net: &mut Network);

    /// Current per-node iterates `z_n^t` (the *primal* estimates for dual
    /// methods).
    fn iterates(&self) -> &[Vec<f64>];

    /// Effective passes over the local datasets so far
    /// (component evaluations / (N q)).
    fn passes(&self) -> f64;

    /// Rounds executed.
    fn iteration(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmKind {
    Dsba,
    DsbaSparse,
    Dsa,
    Extra,
    PExtra,
    Dlm,
    Ssda,
    Dgd,
    PointSaga,
}

impl AlgorithmKind {
    pub fn parse(s: &str) -> Option<AlgorithmKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "dsba" => AlgorithmKind::Dsba,
            "dsba-s" | "dsba_sparse" | "dsbas" => AlgorithmKind::DsbaSparse,
            "dsa" => AlgorithmKind::Dsa,
            "extra" => AlgorithmKind::Extra,
            "p-extra" | "pextra" => AlgorithmKind::PExtra,
            "dlm" => AlgorithmKind::Dlm,
            "ssda" => AlgorithmKind::Ssda,
            "dgd" => AlgorithmKind::Dgd,
            "point-saga" | "pointsaga" => AlgorithmKind::PointSaga,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::Dsba => "DSBA",
            AlgorithmKind::DsbaSparse => "DSBA-s",
            AlgorithmKind::Dsa => "DSA",
            AlgorithmKind::Extra => "EXTRA",
            AlgorithmKind::PExtra => "P-EXTRA",
            AlgorithmKind::Dlm => "DLM",
            AlgorithmKind::Ssda => "SSDA",
            AlgorithmKind::Dgd => "DGD",
            AlgorithmKind::PointSaga => "Point-SAGA",
        }
    }

    /// Stochastic methods progress 1/q of a pass per round.
    pub fn is_stochastic(&self) -> bool {
        matches!(
            self,
            AlgorithmKind::Dsba
                | AlgorithmKind::DsbaSparse
                | AlgorithmKind::Dsa
                | AlgorithmKind::PointSaga
        )
    }

    pub fn all() -> &'static [AlgorithmKind] {
        &[
            AlgorithmKind::Dsba,
            AlgorithmKind::DsbaSparse,
            AlgorithmKind::Dsa,
            AlgorithmKind::Extra,
            AlgorithmKind::PExtra,
            AlgorithmKind::Dlm,
            AlgorithmKind::Ssda,
            AlgorithmKind::Dgd,
            AlgorithmKind::PointSaga,
        ]
    }
}

/// Hyper-parameters shared by the factory. `alpha` is the step size the
/// paper tunes per method; the rest have paper-faithful defaults.
#[derive(Clone, Debug)]
pub struct AlgoParams {
    /// primary step size (alpha for primal methods, eta scale for SSDA)
    pub alpha: f64,
    /// initial consensus iterate (all nodes start here)
    pub z0: Vec<f64>,
    /// RNG seed driving component sampling
    pub seed: u64,
    /// DLM penalty parameter c
    pub dlm_c: f64,
    /// DLM proximal parameter rho
    pub dlm_rho: f64,
    /// SSDA momentum override (None = theory value)
    pub ssda_momentum: Option<f64>,
    /// DGD step decay: alpha_t = alpha / (1 + t)^dgd_decay
    pub dgd_decay: f64,
    /// inner-solver tolerance for P-EXTRA / SSDA oracles
    pub inner_tol: f64,
}

impl AlgoParams {
    pub fn new(alpha: f64, dim: usize, seed: u64) -> AlgoParams {
        AlgoParams {
            alpha,
            z0: vec![0.0; dim],
            seed,
            dlm_c: 1.0,
            dlm_rho: 1.0,
            ssda_momentum: None,
            dgd_decay: 0.5,
            inner_tol: 1e-12,
        }
    }
}

/// Build an algorithm instance.
pub fn build(
    kind: AlgorithmKind,
    problem: Arc<dyn Problem>,
    mix: &MixingMatrix,
    topo: &crate::graph::Topology,
    params: &AlgoParams,
) -> Box<dyn Algorithm> {
    match kind {
        AlgorithmKind::Dsba => Box::new(Dsba::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::DsbaSparse => {
            Box::new(DsbaSparse::new(problem, mix.clone(), topo.clone(), params))
        }
        AlgorithmKind::Dsa => Box::new(Dsa::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::Extra => Box::new(Extra::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::PExtra => {
            Box::new(PExtra::new(problem, mix.clone(), topo.clone(), params))
        }
        AlgorithmKind::Dlm => Box::new(Dlm::new(problem, topo.clone(), params)),
        AlgorithmKind::Ssda => Box::new(Ssda::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::Dgd => Box::new(Dgd::new(problem, mix.clone(), topo.clone(), params)),
        AlgorithmKind::PointSaga => Box::new(PointSaga::new(problem, params)),
    }
}
