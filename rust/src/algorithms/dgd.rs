//! DGD (Nedic & Ozdaglar, 2009): consensus gradient descent with
//! diminishing steps — the sublinear baseline that motivates everything
//! else in Table 1.
//!
//! `z^{t+1}_n = sum_m w_{nm} z^t_m - alpha_t g_n(z^t_n)`,
//! `alpha_t = alpha0 / (1 + t)^decay`.

use super::{AlgoParams, Algorithm};
use crate::comm::Network;
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use std::sync::Arc;

pub struct Dgd {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha0: f64,
    decay: f64,
    z: Vec<Vec<f64>>,
    z_next: Vec<Vec<f64>>,
    t: usize,
    evals: u64,
    g: Vec<f64>,
}

impl Dgd {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Dgd {
        let n = problem.nodes();
        let z = vec![params.z0.clone(); n];
        Dgd {
            alpha0: params.alpha,
            decay: params.dgd_decay,
            z_next: z.clone(),
            z,
            t: 0,
            evals: 0,
            g: vec![0.0; problem.dim()],
            problem,
            mix,
            topo,
        }
    }
}

impl Algorithm for Dgd {
    fn step(&mut self, net: &mut Network) {
        let p = self.problem.as_ref();
        let dim = p.dim();
        let alpha_t = self.alpha0 / (1.0 + self.t as f64).powf(self.decay);
        net.round_dense_exchange(dim);
        for n in 0..p.nodes() {
            let zn = &mut self.z_next[n];
            zn.fill(0.0);
            let add = |m: usize, zn: &mut [f64]| {
                let w = self.mix.w[(n, m)];
                if w != 0.0 {
                    crate::linalg::axpy(w, &self.z[m], zn);
                }
            };
            add(n, zn);
            for &m in self.topo.neighbors(n) {
                add(m, zn);
            }
            p.full_operator(n, &self.z[n], &mut self.g);
            self.evals += p.q() as u64;
            crate::linalg::axpy(-alpha_t, &self.g, zn);
        }
        std::mem::swap(&mut self.z, &mut self.z_next);
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        self.evals as f64 / (self.problem.nodes() * self.problem.q()) as f64
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        "DGD"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn makes_progress_but_sublinearly() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(43);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let (l, _) = p.l_mu();
        let params = AlgoParams::new(0.5 / l, p.dim(), 1);
        let mut alg = Dgd::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        let r0 = p.global_residual(&alg.iterates()[0]);
        for _ in 0..500 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < r0 * 0.5, "no progress: {r0} -> {r}");
        // but far from the 1e-8 that EXTRA reaches in the same rounds
        assert!(r > 1e-10);
    }
}
