//! DGD (Nedic & Ozdaglar, 2009): consensus gradient descent with
//! diminishing steps — the sublinear baseline that motivates everything
//! else in Table 1.
//!
//! `z^{t+1}_n = sum_m w_{nm} z^t_m - alpha_t g_n(z^t_n)`,
//! `alpha_t = alpha0 / (1 + t)^decay`.

use super::node::{broadcast_dense, w_row_local, NeighborBuf, RoundDriver};
use super::{AlgoParams, Algorithm, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use std::sync::Arc;

pub(crate) struct DgdCtx {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha0: f64,
    decay: f64,
}

pub(crate) struct DgdNode {
    ctx: Arc<DgdCtx>,
    n: usize,
    z: Vec<f64>,
    nbrs: NeighborBuf,
    evals: u64,
    z_next: Vec<f64>,
    g: Vec<f64>,
}

impl NodeState for DgdNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        broadcast_dense(&self.ctx.topo, self.n, &self.z)
    }

    fn on_receive(&mut self, from: usize, msg: Message) {
        match msg {
            Message::Dense(v) => self.nbrs.accept(from, v),
            Message::Sparse(_) => panic!("DGD exchanges dense iterates only"),
        }
    }

    fn local_step(&mut self, t: usize) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.as_ref();
        let n = self.n;
        let alpha_t = ctx.alpha0 / (1.0 + t as f64).powf(ctx.decay);
        let zn = &mut self.z_next;
        w_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.nbrs, zn);
        p.full_operator(n, &self.z, &mut self.g);
        self.evals += p.q() as u64;
        crate::linalg::axpy(-alpha_t, &self.g, zn);
        std::mem::swap(&mut self.z, &mut self.z_next);
    }

    fn iterate(&self) -> &[f64] {
        &self.z
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

pub(crate) fn dgd_nodes(
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<DgdNode> {
    let n = problem.nodes();
    let dim = problem.dim();
    let ctx = Arc::new(DgdCtx {
        problem,
        mix,
        topo,
        alpha0: params.alpha,
        decay: params.dgd_decay,
    });
    (0..n)
        .map(|nd| DgdNode {
            n: nd,
            z: params.z0.clone(),
            nbrs: NeighborBuf::new(&ctx.topo, nd, &params.z0),
            evals: 0,
            z_next: params.z0.clone(),
            g: vec![0.0; dim],
            ctx: ctx.clone(),
        })
        .collect()
}

/// Sequentially driven DGD.
pub struct Dgd {
    drv: RoundDriver<DgdNode>,
}

impl Dgd {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Dgd {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let nodes = dgd_nodes(problem, mix, topo, params);
        Dgd { drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }
}

impl Algorithm for Dgd {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "DGD"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn makes_progress_but_sublinearly() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(43);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let (l, _) = p.l_mu();
        let params = AlgoParams::new(0.5 / l, p.dim(), 1);
        let mut alg = Dgd::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        let r0 = p.global_residual(&alg.iterates()[0]);
        for _ in 0..500 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < r0 * 0.5, "no progress: {r0} -> {r}");
        // but far from the 1e-8 that EXTRA reaches in the same rounds
        assert!(r > 1e-10);
    }
}
