//! EXTRA (Shi et al., 2015a): exact first-order decentralized method.
//!
//! `z^1     = W z^0 - alpha g(z^0)`
//! `z^{t+1} = 2 W~ z^t - W~ z^{t-1} - alpha (g(z^t) - g(z^{t-1}))`
//! with `g` the full regularized local gradient/operator
//! `B_n(z) + lambda z`.  Linear convergence at
//! `O((kappa^2 + kappa_g) log 1/eps)` (Table 1).

use super::node::{broadcast_dense, mix_row_local, w_row_local, NeighborBuf, RoundDriver};
use super::{AlgoParams, Algorithm, NodeState};
use crate::comm::{Message, Network, Outgoing};
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use std::sync::Arc;

pub(crate) struct ExtraCtx {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
}

pub(crate) struct ExtraNode {
    ctx: Arc<ExtraCtx>,
    n: usize,
    z: Vec<f64>,
    z_prev: Vec<f64>,
    nbrs: NeighborBuf,
    /// full regularized operator at z^{t-1}
    g_prev: Vec<f64>,
    evals: u64,
    z_next: Vec<f64>,
    g: Vec<f64>,
}

impl NodeState for ExtraNode {
    fn outgoing(&mut self, _t: usize) -> Vec<Outgoing> {
        broadcast_dense(&self.ctx.topo, self.n, &self.z)
    }

    fn on_receive(&mut self, from: usize, msg: Message) {
        match msg {
            Message::Dense(v) => self.nbrs.accept(from, v),
            Message::Sparse(_) => panic!("EXTRA exchanges dense iterates only"),
        }
    }

    fn local_step(&mut self, t: usize) {
        let ctx = self.ctx.clone();
        let p = ctx.problem.as_ref();
        let alpha = ctx.alpha;
        let dim = p.dim();
        let n = self.n;
        p.full_operator(n, &self.z, &mut self.g);
        self.evals += p.q() as u64;
        let zn = &mut self.z_next;
        if t == 0 {
            // z^1 = W z^0 - alpha g(z^0)
            w_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.nbrs, zn);
            crate::linalg::axpy(-alpha, &self.g, zn);
        } else {
            mix_row_local(&ctx.mix, &ctx.topo, n, &self.z, &self.z_prev, &self.nbrs, zn);
            for k in 0..dim {
                zn[k] -= alpha * (self.g[k] - self.g_prev[k]);
            }
        }
        self.g_prev.copy_from_slice(&self.g);
        std::mem::swap(&mut self.z_prev, &mut self.z);
        std::mem::swap(&mut self.z, &mut self.z_next);
    }

    fn iterate(&self) -> &[f64] {
        &self.z
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

pub(crate) fn extra_nodes(
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    params: &AlgoParams,
) -> Vec<ExtraNode> {
    let n = problem.nodes();
    let dim = problem.dim();
    let ctx = Arc::new(ExtraCtx { problem, mix, topo, alpha: params.alpha });
    (0..n)
        .map(|nd| ExtraNode {
            n: nd,
            z: params.z0.clone(),
            z_prev: params.z0.clone(),
            nbrs: NeighborBuf::new(&ctx.topo, nd, &params.z0),
            g_prev: vec![0.0; dim],
            evals: 0,
            z_next: params.z0.clone(),
            g: vec![0.0; dim],
            ctx: ctx.clone(),
        })
        .collect()
}

/// Sequentially driven EXTRA.
pub struct Extra {
    drv: RoundDriver<ExtraNode>,
}

impl Extra {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Extra {
        let pass_denom = (problem.nodes() * problem.q()) as f64;
        let nodes = extra_nodes(problem, mix, topo, params);
        Extra { drv: RoundDriver::new(nodes, Vec::new(), pass_denom) }
    }
}

impl Algorithm for Extra {
    fn step(&mut self, net: &mut Network) {
        self.drv.step(net);
    }

    fn iterates(&self) -> &[Vec<f64>] {
        self.drv.iterates()
    }

    fn passes(&self) -> f64 {
        self.drv.passes()
    }

    fn iteration(&self) -> usize {
        self.drv.iteration()
    }

    fn name(&self) -> &'static str {
        "EXTRA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::{LogisticProblem, RidgeProblem};

    fn world(nodes: usize) -> (Topology, MixingMatrix) {
        let topo = Topology::erdos_renyi(nodes, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (topo, mix)
    }

    #[test]
    fn converges_on_ridge() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(17);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let (topo, mix) = world(4);
        let (l, _) = p.l_mu();
        let params = AlgoParams::new(0.5 / l, p.dim(), 1);
        let mut alg = Extra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..800 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-8, "residual {r}");
        // consensus
        let z0 = &alg.iterates()[0];
        for z in alg.iterates() {
            assert!(crate::linalg::dist2_sq(z, z0) < 1e-14);
        }
    }

    #[test]
    fn converges_on_logistic() {
        let ds = SyntheticSpec::tiny().generate(19);
        let p: Arc<dyn Problem> =
            Arc::new(LogisticProblem::new(ds.partition_seeded(4, 3), 0.05));
        let (topo, mix) = world(4);
        let (l, _) = p.l_mu();
        let params = AlgoParams::new(0.8 / l, p.dim(), 1);
        let mut alg = Extra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..1500 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-7, "residual {r}");
    }

    #[test]
    fn passes_count_full_dataset_per_round() {
        let ds = SyntheticSpec::tiny().generate(20);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let (topo, mix) = world(4);
        let params = AlgoParams::new(0.1, p.dim(), 1);
        let mut alg = Extra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..5 {
            alg.step(&mut net);
        }
        assert!((alg.passes() - 5.0).abs() < 1e-12);
    }
}
