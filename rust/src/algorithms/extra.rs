//! EXTRA (Shi et al., 2015a): exact first-order decentralized method.
//!
//! `z^1     = W z^0 - alpha g(z^0)`
//! `z^{t+1} = 2 W~ z^t - W~ z^{t-1} - alpha (g(z^t) - g(z^{t-1}))`
//! with `g` the full regularized local gradient/operator
//! `B_n(z) + lambda z`.  Linear convergence at
//! `O((kappa^2 + kappa_g) log 1/eps)` (Table 1).

use super::{AlgoParams, Algorithm};
use crate::comm::Network;
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use std::sync::Arc;

pub struct Extra {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
    z: Vec<Vec<f64>>,
    z_prev: Vec<Vec<f64>>,
    /// full regularized operator at z^{t-1}, per node
    g_prev: Vec<Vec<f64>>,
    t: usize,
    evals: u64,
    z_next: Vec<Vec<f64>>,
    g: Vec<f64>,
}

impl Extra {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: MixingMatrix,
        topo: Topology,
        params: &AlgoParams,
    ) -> Extra {
        let n = problem.nodes();
        let dim = problem.dim();
        let z = vec![params.z0.clone(); n];
        Extra {
            alpha: params.alpha,
            z_prev: z.clone(),
            z_next: z.clone(),
            g_prev: vec![vec![0.0; dim]; n],
            z,
            t: 0,
            evals: 0,
            g: vec![0.0; dim],
            problem,
            mix,
            topo,
        }
    }
}

impl Algorithm for Extra {
    fn step(&mut self, net: &mut Network) {
        let p = self.problem.as_ref();
        let alpha = self.alpha;
        let dim = p.dim();
        net.round_dense_exchange(dim);
        for n in 0..p.nodes() {
            p.full_operator(n, &self.z[n], &mut self.g);
            self.evals += p.q() as u64;
            let zn = &mut self.z_next[n];
            if self.t == 0 {
                // z^1 = W z^0 - alpha g(z^0)
                zn.fill(0.0);
                let add = |m: usize, zn: &mut [f64]| {
                    let w = self.mix.w[(n, m)];
                    if w != 0.0 {
                        crate::linalg::axpy(w, &self.z[m], zn);
                    }
                };
                add(n, zn);
                for &m in self.topo.neighbors(n) {
                    add(m, zn);
                }
                crate::linalg::axpy(-alpha, &self.g, zn);
            } else {
                self.mix.mix_row(n, &self.topo, &self.z, &self.z_prev, zn);
                for k in 0..dim {
                    zn[k] -= alpha * (self.g[k] - self.g_prev[n][k]);
                }
            }
            self.g_prev[n].copy_from_slice(&self.g);
        }
        std::mem::swap(&mut self.z_prev, &mut self.z);
        std::mem::swap(&mut self.z, &mut self.z_next);
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        self.evals as f64 / (self.problem.nodes() * self.problem.q()) as f64
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        "EXTRA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::{LogisticProblem, RidgeProblem};

    fn world(nodes: usize) -> (Topology, MixingMatrix) {
        let topo = Topology::erdos_renyi(nodes, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (topo, mix)
    }

    #[test]
    fn converges_on_ridge() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(17);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let (topo, mix) = world(4);
        let (l, _) = p.l_mu();
        let params = AlgoParams::new(0.5 / l, p.dim(), 1);
        let mut alg = Extra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..800 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-8, "residual {r}");
        // consensus
        let z0 = &alg.iterates()[0];
        for z in alg.iterates() {
            assert!(crate::linalg::dist2_sq(z, z0) < 1e-14);
        }
    }

    #[test]
    fn converges_on_logistic() {
        let ds = SyntheticSpec::tiny().generate(19);
        let p: Arc<dyn Problem> =
            Arc::new(LogisticProblem::new(ds.partition_seeded(4, 3), 0.05));
        let (topo, mix) = world(4);
        let (l, _) = p.l_mu();
        let params = AlgoParams::new(0.8 / l, p.dim(), 1);
        let mut alg = Extra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..1500 {
            alg.step(&mut net);
        }
        let r = p.global_residual(&alg.iterates()[0]);
        assert!(r < 1e-7, "residual {r}");
    }

    #[test]
    fn passes_count_full_dataset_per_round() {
        let ds = SyntheticSpec::tiny().generate(20);
        let p: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(4, 3), 0.05));
        let (topo, mix) = world(4);
        let params = AlgoParams::new(0.1, p.dim(), 1);
        let mut alg = Extra::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..5 {
            alg.step(&mut net);
        }
        assert!((alg.passes() - 5.0).abs() < 1e-12);
    }
}
