//! Minimal property-testing harness (no external deps are vendored, so
//! this plays the role proptest normally would): run a predicate over
//! many seeded random cases and report the first failing seed for
//! reproduction.

use crate::util::rng::Rng;

const SEED_BASE: u64 = 0x5eed_0000_0000_0001;

/// Run `f` over `cases` independent RNG streams; panic with the failing
/// seed and message on the first violation.
pub fn prop_check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = SEED_BASE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property \"{name}\" failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn prop_replay<F>(seed: u64, mut f: F) -> Result<(), String>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    f(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_on_trivial_property() {
        prop_check("u64 below bound", 50, |rng| {
            let n = 1 + rng.below(100);
            let x = rng.below(n);
            if x < n {
                Ok(())
            } else {
                Err(format!("{x} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn fails_with_seed_report() {
        prop_check("always false", 3, |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        let mut first = None;
        prop_check("record", 1, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let seed = SEED_BASE; // case 0 seed
        prop_replay(seed, |rng| {
            assert_eq!(Some(rng.next_u64()), first);
            Ok(())
        })
        .unwrap();
    }
}
