//! Row-major dense matrix — used for stacked iterates `Z in R^{N x d}`,
//! mixing matrices, and the small 4x4 solves in the AUC resolvent.

use super::dot;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|v| v.len()).unwrap_or(0);
        assert!(rows.iter().all(|v| v.len() == c), "ragged rows");
        DenseMatrix { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// `self * other` (naive triple loop with row-major accumulation).
    pub fn matmul(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, other.rows);
        let mut out = DenseMatrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += aik * orow[j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Frobenius norm of `self - other`.
    pub fn frob_dist(&self, other: &DenseMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Weighted squared Frobenius norm `||X||_M^2 = <X, M X>` for a small
    /// symmetric `M` acting on the row index (used by the Lyapunov probe).
    pub fn weighted_frob_sq(&self, m: &DenseMatrix) -> f64 {
        assert_eq!(m.rows, self.rows);
        let mx = m.matmul(self);
        dot(&self.data, &mx.data)
    }

    /// Solve `A x = b` for small dense `A` via partial-pivot Gaussian
    /// elimination (used for the 4x4 AUC resolvent systems and tests).
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        assert_eq!(self.rows, self.cols);
        assert_eq!(b.len(), self.rows);
        let n = self.rows;
        let mut a = self.data.clone();
        let mut x = b.to_vec();
        for col in 0..n {
            // pivot
            let mut piv = col;
            for r in col + 1..n {
                if a[r * n + col].abs() > a[piv * n + col].abs() {
                    piv = r;
                }
            }
            if a[piv * n + col].abs() < 1e-300 {
                return None;
            }
            if piv != col {
                for j in 0..n {
                    a.swap(col * n + j, piv * n + j);
                }
                x.swap(col, piv);
            }
            let d = a[col * n + col];
            for r in col + 1..n {
                let f = a[r * n + col] / d;
                if f == 0.0 {
                    continue;
                }
                for j in col..n {
                    a[r * n + j] -= f * a[col * n + j];
                }
                x[r] -= f * x[col];
            }
        }
        for col in (0..n).rev() {
            let mut s = x[col];
            for j in col + 1..n {
                s -= a[col * n + j] * x[j];
            }
            x[col] = s / a[col * n + col];
        }
        Some(x)
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for DenseMatrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DenseMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_matmul() {
        let a = DenseMatrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = DenseMatrix::identity(2);
        assert_eq!(a.matmul(&b), a);
        let sq = a.matmul(&a);
        assert_eq!(sq.data, vec![7.0, 10.0, 15.0, 22.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = DenseMatrix::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn solve_small_system() {
        let a = DenseMatrix::from_rows(vec![
            vec![4.0, 1.0, 0.0],
            vec![1.0, 3.0, 1.0],
            vec![0.0, 1.0, 2.0],
        ]);
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.matvec(&x_true);
        let x = a.solve(&b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-12);
        }
    }

    #[test]
    fn solve_singular_returns_none() {
        let a = DenseMatrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(a.solve(&[1.0, 2.0]).is_none());
    }

    #[test]
    fn weighted_frob_identity_is_plain() {
        let x = DenseMatrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let m = DenseMatrix::identity(2);
        let got = x.weighted_frob_sq(&m);
        let want: f64 = x.data.iter().map(|v| v * v).sum();
        assert!((got - want).abs() < 1e-12);
    }
}
