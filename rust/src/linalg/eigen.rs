//! Spectral helpers: power iteration (Laplacian scaling `tau >=
//! lambda_max(L)/2`, §7) and a cyclic Jacobi eigensolver for the small
//! symmetric mixing matrices (graph condition number `kappa_g = 1/gamma`).

use super::DenseMatrix;

/// Largest-magnitude eigenvalue of a symmetric matrix via power iteration.
pub fn power_iteration(m: &DenseMatrix, iters: usize) -> f64 {
    assert_eq!(m.rows, m.cols);
    let n = m.rows;
    if n == 0 {
        return 0.0;
    }
    // deterministic start that is unlikely to be orthogonal to the top
    // eigenvector
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.01).collect();
    let mut lambda = 0.0;
    for _ in 0..iters {
        let w = m.matvec(&v);
        let norm = super::norm2(&w);
        if norm < 1e-300 {
            return 0.0;
        }
        lambda = super::dot(&v, &w) / super::dot(&v, &v);
        v = w;
        for x in &mut v {
            *x /= norm;
        }
    }
    lambda
}

/// All eigenvalues of a symmetric matrix via cyclic Jacobi rotations.
/// Suitable for the N x N mixing matrices (N <= a few hundred).
pub fn symmetric_eigenvalues(m: &DenseMatrix, tol: f64) -> Vec<f64> {
    symmetric_eigen(m, tol).0
}

/// Eigenvalues *and* orthonormal eigenvectors (columns of the returned
/// matrix, in ascending eigenvalue order) via cyclic Jacobi.
pub fn symmetric_eigen(m: &DenseMatrix, tol: f64) -> (Vec<f64>, DenseMatrix) {
    assert_eq!(m.rows, m.cols);
    let n = m.rows;
    let mut a = m.clone();
    let mut v = DenseMatrix::identity(n);
    for _sweep in 0..100 {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() < tol {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
                // accumulate rotations: V <- V R
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    order.sort_by(|&x, &y| diag[x].partial_cmp(&diag[y]).unwrap());
    let eig: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vecs = DenseMatrix::zeros(n, n);
    for (col, &src) in order.iter().enumerate() {
        for row in 0..n {
            vecs[(row, col)] = v[(row, src)];
        }
    }
    (eig, vecs)
}

/// Symmetric PSD square root via eigen-decomposition.
pub fn sqrt_psd(m: &DenseMatrix, tol: f64) -> DenseMatrix {
    let (eig, v) = symmetric_eigen(m, tol);
    let n = m.rows;
    let mut out = DenseMatrix::zeros(n, n);
    for k in 0..n {
        let s = eig[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            for j in 0..n {
                out[(i, j)] += s * v[(i, k)] * v[(j, k)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_iteration_diag() {
        let mut m = DenseMatrix::zeros(3, 3);
        m[(0, 0)] = 1.0;
        m[(1, 1)] = 5.0;
        m[(2, 2)] = 2.0;
        let l = power_iteration(&m, 200);
        assert!((l - 5.0).abs() < 1e-9, "{l}");
    }

    #[test]
    fn jacobi_known_spectrum() {
        // path-graph Laplacian on 3 nodes: eigenvalues 0, 1, 3
        let m = DenseMatrix::from_rows(vec![
            vec![1.0, -1.0, 0.0],
            vec![-1.0, 2.0, -1.0],
            vec![0.0, -1.0, 1.0],
        ]);
        let e = symmetric_eigenvalues(&m, 1e-12);
        for (got, want) in e.iter().zip(&[0.0, 1.0, 3.0]) {
            assert!((got - want).abs() < 1e-9, "{e:?}");
        }
    }

    #[test]
    fn sqrt_psd_squares_back() {
        let m = DenseMatrix::from_rows(vec![
            vec![2.0, -1.0, 0.0],
            vec![-1.0, 2.0, -1.0],
            vec![0.0, -1.0, 2.0],
        ]);
        let r = sqrt_psd(&m, 1e-13);
        let sq = r.matmul(&r);
        assert!(sq.max_abs_diff(&m) < 1e-9);
    }

    #[test]
    fn eigenvectors_diagonalize() {
        let m = DenseMatrix::from_rows(vec![
            vec![1.0, -1.0, 0.0],
            vec![-1.0, 2.0, -1.0],
            vec![0.0, -1.0, 1.0],
        ]);
        let (e, v) = symmetric_eigen(&m, 1e-13);
        // M v_k = e_k v_k
        for k in 0..3 {
            let col: Vec<f64> = (0..3).map(|i| v[(i, k)]).collect();
            let mv = m.matvec(&col);
            for i in 0..3 {
                assert!((mv[i] - e[k] * col[i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn jacobi_matches_power_iteration_on_random_sym() {
        let mut rng = crate::util::rng::Rng::new(5);
        let n = 8;
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        let eig = symmetric_eigenvalues(&m, 1e-13);
        let lmax_abs = eig.iter().fold(0.0f64, |acc, &e| acc.max(e.abs()));
        let pi = power_iteration(&m, 500).abs();
        assert!((lmax_abs - pi).abs() < 1e-6 * lmax_abs.max(1.0));
    }
}
