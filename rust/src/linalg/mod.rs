//! Linear-algebra substrate: dense matrices, sparse vectors/matrices
//! (CSR), and a small symmetric eigensolver for mixing-matrix spectra.
//!
//! The DSBA hot path is built on [`SparseVec`] axpy/dot against dense
//! iterates — per-iteration cost must be `O(nnz)`, never `O(d)` — so these
//! primitives are written allocation-free where it matters and benchmarked
//! in `rust/benches/hotpath.rs`.

mod dense;
mod sparse;
mod eigen;

pub use dense::DenseMatrix;
pub use eigen::{power_iteration, sqrt_psd, symmetric_eigen, symmetric_eigenvalues};
pub use sparse::{CsrMatrix, SparseVec};

/// Dot product of two dense slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled for ILP; autovectorizes well.
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let i = 4 * k;
        acc0 += a[i] * b[i];
        acc1 += a[i + 1] * b[i + 1];
        acc2 += a[i + 2] * b[i + 2];
        acc3 += a[i + 3] * b[i + 3];
    }
    for i in 4 * chunks..a.len() {
        acc0 += a[i] * b[i];
    }
    acc0 + acc1 + acc2 + acc3
}

/// `y += alpha * x` over dense slices.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn dist2_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// `out = a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// In-place scale.
#[inline]
pub fn scale(a: &mut [f64], s: f64) {
    for x in a {
        *x *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..101).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..101).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn axpy_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 14.0, 16.0]);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert!((dist2_sq(&[1.0, 1.0], &[0.0, 0.0]) - 2.0).abs() < 1e-15);
    }
}
