//! Sparse vector (sorted index/value pairs) and CSR matrix.
//!
//! `SparseVec` is the currency of the whole system: data rows, operator
//! outputs `B_{n,i}(z) = g * a_i`, and the communicated deltas
//! `delta_n^t` of the sparse protocol (§5.1) are all sparse vectors whose
//! support equals a data row's support. Everything on the DSBA hot path is
//! `O(nnz)`.

/// Sparse vector: parallel sorted `idx`/`val` arrays over a logical
/// dimension `dim`.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl SparseVec {
    pub fn empty(dim: usize) -> Self {
        SparseVec { dim, idx: Vec::new(), val: Vec::new() }
    }

    /// Build from (possibly unsorted) pairs; sorts and merges duplicates.
    pub fn from_pairs(dim: usize, mut pairs: Vec<(u32, f64)>) -> Self {
        pairs.sort_unstable_by_key(|p| p.0);
        let mut idx = Vec::with_capacity(pairs.len());
        let mut val: Vec<f64> = Vec::with_capacity(pairs.len());
        for (i, v) in pairs {
            // unconditional: a release build constructing an out-of-dim
            // vector would only surface later as a wire-codec rejection,
            // far from the real cause
            assert!((i as usize) < dim, "index {i} out of dim {dim}");
            if idx.last() == Some(&i) {
                *val.last_mut().unwrap() += v;
            } else {
                idx.push(i);
                val.push(v);
            }
        }
        SparseVec { dim, idx, val }
    }

    /// Densify into a fresh vector.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.scatter_into(&mut out);
        out
    }

    /// Build from a dense slice, keeping entries with |x| > tol.
    pub fn from_dense(x: &[f64], tol: f64) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &v) in x.iter().enumerate() {
            if v.abs() > tol {
                idx.push(i as u32);
                val.push(v);
            }
        }
        SparseVec { dim: x.len(), idx, val }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Sparsity ratio nnz/dim.
    pub fn density(&self) -> f64 {
        if self.dim == 0 { 0.0 } else { self.nnz() as f64 / self.dim as f64 }
    }

    /// `out[idx] += val` (scatter-add).
    #[inline]
    pub fn scatter_into(&self, out: &mut [f64]) {
        debug_assert!(out.len() >= self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += v;
        }
    }

    /// `out += alpha * self` — THE hot-path primitive.
    #[inline]
    pub fn axpy_into(&self, alpha: f64, out: &mut [f64]) {
        debug_assert!(out.len() >= self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += alpha * v;
        }
    }

    /// Dot with a dense vector — `O(nnz)`.
    #[inline]
    pub fn dot_dense(&self, x: &[f64]) -> f64 {
        debug_assert!(x.len() >= self.dim);
        let mut acc = 0.0;
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            acc += v * x[i as usize];
        }
        acc
    }

    /// Squared Euclidean norm.
    pub fn norm_sq(&self) -> f64 {
        self.val.iter().map(|v| v * v).sum()
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.val {
            *v *= s;
        }
    }

    /// Return a scaled copy.
    pub fn scaled(&self, s: f64) -> SparseVec {
        let mut out = self.clone();
        out.scale(s);
        out
    }

    /// Sparse-sparse sum (union of supports).
    pub fn add(&self, other: &SparseVec) -> SparseVec {
        assert_eq!(self.dim, other.dim);
        let mut idx = Vec::with_capacity(self.nnz() + other.nnz());
        let mut val = Vec::with_capacity(self.nnz() + other.nnz());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.nnz() || j < other.nnz() {
            let a = self.idx.get(i).copied().unwrap_or(u32::MAX);
            let b = other.idx.get(j).copied().unwrap_or(u32::MAX);
            match a.cmp(&b) {
                std::cmp::Ordering::Less => {
                    idx.push(a);
                    val.push(self.val[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    idx.push(b);
                    val.push(other.val[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    idx.push(a);
                    val.push(self.val[i] + other.val[j]);
                    i += 1;
                    j += 1;
                }
            }
        }
        SparseVec { dim: self.dim, idx, val }
    }
}

/// Compressed-sparse-row matrix: the dataset shard `A_n` of each node.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl CsrMatrix {
    pub fn from_rows(cols: usize, rows: &[SparseVec]) -> Self {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in rows {
            assert_eq!(r.dim, cols);
            indices.extend_from_slice(&r.idx);
            values.extend_from_slice(&r.val);
            indptr.push(indices.len());
        }
        CsrMatrix { rows: rows.len(), cols, indptr, indices, values }
    }

    #[inline]
    pub fn row_indices(&self, i: usize) -> &[u32] {
        &self.indices[self.indptr[i]..self.indptr[i + 1]]
    }

    #[inline]
    pub fn row_values(&self, i: usize) -> &[f64] {
        &self.values[self.indptr[i]..self.indptr[i + 1]]
    }

    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// nnz / (rows * cols) — the paper's `rho`.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// Extract row `i` as a `SparseVec` (copies).
    pub fn row_sparse(&self, i: usize) -> SparseVec {
        SparseVec {
            dim: self.cols,
            idx: self.row_indices(i).to_vec(),
            val: self.row_values(i).to_vec(),
        }
    }

    /// `<row_i, x>` against a dense vector — `O(nnz_i)`.
    #[inline]
    pub fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        debug_assert!(x.len() >= self.cols);
        let mut acc = 0.0;
        for (&j, &v) in self.row_indices(i).iter().zip(self.row_values(i)) {
            acc += v * x[j as usize];
        }
        acc
    }

    /// `out[row support] += alpha * row_i`.
    #[inline]
    pub fn row_axpy(&self, i: usize, alpha: f64, out: &mut [f64]) {
        for (&j, &v) in self.row_indices(i).iter().zip(self.row_values(i)) {
            out[j as usize] += alpha * v;
        }
    }

    /// Squared norm of row i.
    #[inline]
    pub fn row_norm_sq(&self, i: usize) -> f64 {
        self.row_values(i).iter().map(|v| v * v).sum()
    }

    /// `A x` dense.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        (0..self.rows).map(|i| self.row_dot(i, x)).collect()
    }

    /// `A^T g` dense.
    pub fn t_matvec(&self, g: &[f64]) -> Vec<f64> {
        assert_eq!(g.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let gi = g[i];
            if gi != 0.0 {
                self.row_axpy(i, gi, &mut out);
            }
        }
        out
    }

    /// Normalize every row to unit Euclidean norm (paper §7 preprocessing).
    pub fn normalize_rows(&mut self) {
        for i in 0..self.rows {
            let n = self.row_norm_sq(i).sqrt();
            if n > 0.0 {
                let (s, e) = (self.indptr[i], self.indptr[i + 1]);
                for v in &mut self.values[s..e] {
                    *v /= n;
                }
            }
        }
    }

    /// Dense copy (tests and small XLA staging only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.rows * self.cols];
        for i in 0..self.rows {
            for (&j, &v) in self.row_indices(i).iter().zip(self.row_values(i)) {
                out[i * self.cols + j as usize] = v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(dim: usize, pairs: &[(u32, f64)]) -> SparseVec {
        SparseVec::from_pairs(dim, pairs.to_vec())
    }

    #[test]
    fn from_pairs_sorts_and_merges() {
        let v = sv(10, &[(5, 1.0), (2, 2.0), (5, 3.0)]);
        assert_eq!(v.idx, vec![2, 5]);
        assert_eq!(v.val, vec![2.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "index 7 out of dim 4")]
    fn from_pairs_rejects_out_of_dim_in_release_too() {
        sv(4, &[(1, 1.0), (7, 2.0)]);
    }

    #[test]
    fn dense_roundtrip() {
        let v = sv(6, &[(0, 1.5), (3, -2.0), (5, 0.25)]);
        let d = v.to_dense();
        assert_eq!(d, vec![1.5, 0.0, 0.0, -2.0, 0.0, 0.25]);
        assert_eq!(SparseVec::from_dense(&d, 0.0), v);
    }

    #[test]
    fn axpy_dot_consistent_with_dense() {
        let v = sv(8, &[(1, 2.0), (4, -1.0), (7, 0.5)]);
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect();
        assert_eq!(v.dot_dense(&x), 2.0 * 1.0 - 4.0 + 0.5 * 7.0);
        let mut y = vec![1.0; 8];
        v.axpy_into(2.0, &mut y);
        let mut want = vec![1.0; 8];
        for (i, val) in [(1, 2.0), (4, -1.0), (7, 0.5)] {
            want[i] += 2.0 * val;
        }
        assert_eq!(y, want);
    }

    #[test]
    fn sparse_add_union() {
        let a = sv(6, &[(0, 1.0), (2, 1.0)]);
        let b = sv(6, &[(2, 2.0), (5, 3.0)]);
        let c = a.add(&b);
        assert_eq!(c.idx, vec![0, 2, 5]);
        assert_eq!(c.val, vec![1.0, 3.0, 3.0]);
    }

    #[test]
    fn csr_matvec_roundtrip() {
        let rows = vec![
            sv(4, &[(0, 1.0), (2, 2.0)]),
            sv(4, &[(1, -1.0)]),
            sv(4, &[(0, 0.5), (3, 4.0)]),
        ];
        let a = CsrMatrix::from_rows(4, &rows);
        assert_eq!(a.nnz(), 5);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(a.matvec(&x), vec![7.0, -2.0, 16.5]);
        let g = vec![1.0, 1.0, 1.0];
        assert_eq!(a.t_matvec(&g), vec![1.5, -1.0, 2.0, 4.0]);
    }

    #[test]
    fn csr_normalize_rows() {
        let rows = vec![sv(3, &[(0, 3.0), (1, 4.0)]), sv(3, &[])];
        let mut a = CsrMatrix::from_rows(3, &rows);
        a.normalize_rows();
        assert!((a.row_norm_sq(0) - 1.0).abs() < 1e-14);
        assert_eq!(a.row_nnz(1), 0); // empty rows untouched
    }

    #[test]
    fn density_matches_definition() {
        let rows = vec![sv(10, &[(0, 1.0)]), sv(10, &[(1, 1.0), (2, 1.0)])];
        let a = CsrMatrix::from_rows(10, &rows);
        assert!((a.density() - 3.0 / 20.0).abs() < 1e-15);
    }
}
