//! Synthetic sparse dataset generators matching the paper's dataset
//! profiles (News20-binary, RCV1, Sector from LIBSVM).
//!
//! The paper's convergence results depend on (kappa, kappa_g, q) and its
//! communication results on (rho, d, N, Delta(G)); we therefore match the
//! real datasets' *statistics* — density, long-tailed per-row nnz, label
//! balance, dimension (scaled to CI size by default) — not their content.
//! Labels are generated from a sparse planted model with noise so both
//! classification losses and ridge targets are learnable (suboptimality
//! actually decreases, as in the figures).

use super::Dataset;
use crate::linalg::{CsrMatrix, SparseVec};
use crate::util::rng::Rng;

/// Specification of a synthetic sparse dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    pub samples: usize,
    pub dim: usize,
    /// target density rho (fraction of nonzeros)
    pub density: f64,
    /// fraction of positive labels
    pub positive_ratio: f64,
    /// label noise: probability of flipping a label
    pub label_noise: f64,
    /// regression mode: y = <a, w*> + eps instead of sign labels
    pub regression: bool,
}

impl SyntheticSpec {
    /// news20.binary profile: very high-dimensional, very sparse
    /// (original: Q=19,996, d=1,355,191, rho≈3.4e-4), scaled to CI size
    /// keeping rho and the near-balanced labels.
    pub fn news20_like() -> SyntheticSpec {
        SyntheticSpec {
            name: "news20-like".into(),
            samples: 2_000,
            dim: 16_384,
            density: 3.4e-4,
            positive_ratio: 0.50,
            label_noise: 0.05,
            regression: false,
        }
    }

    /// rcv1.binary profile (original: Q=20,242, d=47,236, rho≈1.6e-3).
    pub fn rcv1_like() -> SyntheticSpec {
        SyntheticSpec {
            name: "rcv1-like".into(),
            samples: 2_000,
            dim: 8_192,
            density: 1.6e-3,
            positive_ratio: 0.52,
            label_noise: 0.05,
            regression: false,
        }
    }

    /// sector profile (original: Q=6,412, d=55,197, rho≈2.9e-3; multiclass
    /// binarized by the paper's preprocessing).
    pub fn sector_like() -> SyntheticSpec {
        SyntheticSpec {
            name: "sector-like".into(),
            samples: 1_500,
            dim: 8_192,
            density: 2.9e-3,
            positive_ratio: 0.48,
            label_noise: 0.08,
            regression: false,
        }
    }

    /// Tiny dense-ish instance for unit tests.
    pub fn tiny() -> SyntheticSpec {
        SyntheticSpec {
            name: "tiny".into(),
            samples: 120,
            dim: 50,
            density: 0.12,
            positive_ratio: 0.5,
            label_noise: 0.02,
            regression: false,
        }
    }

    pub fn by_name(name: &str) -> Option<SyntheticSpec> {
        Some(match name {
            "news20" | "news20-like" => Self::news20_like(),
            "rcv1" | "rcv1-like" => Self::rcv1_like(),
            "sector" | "sector-like" => Self::sector_like(),
            "tiny" => Self::tiny(),
            _ => return None,
        })
    }

    pub fn with_samples(mut self, q: usize) -> Self {
        self.samples = q;
        self
    }

    pub fn with_dim(mut self, d: usize) -> Self {
        self.dim = d;
        self
    }

    pub fn with_density(mut self, rho: f64) -> Self {
        self.density = rho;
        self
    }

    pub fn with_regression(mut self, on: bool) -> Self {
        self.regression = on;
        self
    }

    /// Generate the dataset. Rows are unit-normalized (paper §7).
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0xda7a);
        let mean_nnz = (self.density * self.dim as f64).max(1.0);

        // planted sparse ground-truth weight vector over a "head" of the
        // vocabulary (text-like features follow a frequency bias: low
        // indices are much more common)
        let head = (self.dim / 8).max(8).min(self.dim);
        let mut w_star = vec![0.0; self.dim];
        for (j, w) in w_star.iter_mut().enumerate().take(head) {
            *w = rng.normal() / ((j + 2) as f64).sqrt();
        }

        let mut rows = Vec::with_capacity(self.samples);
        let mut y = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let nnz = rng.zipf_nnz(mean_nnz, self.dim);
            // frequency-biased feature sampling: P(j) ~ 1/(j+1) over a
            // shuffle-free draw; rejection-sample distinct indices
            let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
            let mut pairs = Vec::with_capacity(nnz);
            let mut guard = 0;
            while pairs.len() < nnz && guard < 50 * nnz + 100 {
                guard += 1;
                // inverse-CDF of a truncated zeta-ish law
                let u = rng.uniform();
                let j = ((self.dim as f64).powf(u) - 1.0) as usize;
                let j = j.min(self.dim - 1);
                if seen.insert(j) {
                    // tf-idf-ish positive magnitudes
                    let v = (0.2 + rng.uniform()).ln_1p().abs() + 0.05;
                    pairs.push((j as u32, v));
                }
            }
            let mut row = SparseVec::from_pairs(self.dim, pairs);
            // unit-normalize (paper preprocessing)
            let norm = row.norm_sq().sqrt();
            if norm > 0.0 {
                row.scale(1.0 / norm);
            }
            let margin = row.dot_dense(&w_star);
            let label = if self.regression {
                margin + 0.1 * rng.normal()
            } else {
                // bias the threshold to hit the requested positive ratio
                let flip = rng.bernoulli(self.label_noise);
                let raw = if margin + 0.25 * rng.normal()
                    > quantile_threshold(self.positive_ratio)
                {
                    1.0
                } else {
                    -1.0
                };
                if flip {
                    -raw
                } else {
                    raw
                }
            };
            rows.push(row);
            y.push(label);
        }
        Dataset {
            name: self.name.clone(),
            a: CsrMatrix::from_rows(self.dim, &rows),
            y,
        }
    }
}

/// Crude margin threshold so that roughly `ratio` of standard-normal-ish
/// margins exceed it.
fn quantile_threshold(ratio: f64) -> f64 {
    // inverse CDF approximation (Beasley–Springer lite): for our purposes
    // a piecewise-linear fit is enough
    let p = 1.0 - ratio.clamp(0.01, 0.99);
    // Acklam-style rational approximation on central region
    let q = p - 0.5;
    if q.abs() <= 0.425 {
        let r = 0.180625 - q * q;
        q * (2.5090809287301226e3
            + r * (3.3430575583588128e4 / (1.0 + r * 10.0)))
            / (1.0e3 + r * 2.0e4)
            * 0.3
    } else {
        let r = (-(p.min(1.0 - p)).ln()).sqrt();
        let sign = if q < 0.0 { -1.0 } else { 1.0 };
        sign * (r - 0.5) * 0.8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_roughly_matches_spec() {
        let spec = SyntheticSpec::rcv1_like().with_samples(500).with_dim(2048);
        let ds = spec.generate(1);
        let rho = ds.density();
        assert!(
            rho > spec.density * 0.4 && rho < spec.density * 2.5,
            "rho {rho} vs target {}",
            spec.density
        );
    }

    #[test]
    fn rows_unit_normalized() {
        let ds = SyntheticSpec::tiny().generate(2);
        for i in 0..ds.samples() {
            let n = ds.a.row_norm_sq(i);
            assert!((n - 1.0).abs() < 1e-12, "row {i} norm^2 {n}");
        }
    }

    #[test]
    fn labels_are_signs_and_roughly_balanced() {
        let ds = SyntheticSpec::news20_like()
            .with_samples(800)
            .with_dim(2048)
            .generate(3);
        assert!(ds.y.iter().all(|&y| y == 1.0 || y == -1.0));
        let pr = ds.positive_ratio();
        assert!(pr > 0.3 && pr < 0.7, "positive ratio {pr}");
    }

    #[test]
    fn regression_targets_continuous() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(4);
        assert!(ds.y.iter().any(|&y| y != 1.0 && y != -1.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticSpec::tiny().generate(9);
        let b = SyntheticSpec::tiny().generate(9);
        assert_eq!(a.a, b.a);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn labels_learnable_by_linear_model() {
        // sanity: a few steps of logistic SGD must beat chance accuracy,
        // otherwise the figure workloads would be vacuous
        let ds = SyntheticSpec::tiny().with_samples(400).generate(11);
        let mut w = vec![0.0; ds.dim()];
        let mut rng = Rng::new(1);
        for _ in 0..4000 {
            let i = rng.below(ds.samples());
            let m = ds.a.row_dot(i, &w);
            let yi = ds.y[i];
            let g = -yi / (1.0 + (yi * m).exp());
            ds.a.row_axpy(i, -0.5 * g, &mut w);
        }
        let acc = (0..ds.samples())
            .filter(|&i| ds.a.row_dot(i, &w) * ds.y[i] > 0.0)
            .count() as f64
            / ds.samples() as f64;
        assert!(acc > 0.75, "accuracy {acc}");
    }
}
