//! Datasets: LIBSVM parsing, synthetic sparse generators matching the
//! paper's dataset profiles, row normalization, and node partitioning.
//!
//! The paper evaluates on News20-binary, RCV1 and Sector (LIBSVM). Those
//! files are not redistributable inside this repo, so `SyntheticSpec`
//! generates sparse datasets matching their *published statistics*
//! (dimension, density rho, per-row nnz long tail, label balance) — the
//! quantities the paper's convergence and communication results actually
//! depend on.  Real LIBSVM files drop in through [`load_libsvm`].

mod libsvm;
mod synthetic;
mod partition;

pub use libsvm::{load_libsvm, parse_libsvm};
pub use partition::Partition;
pub use synthetic::SyntheticSpec;

use crate::linalg::CsrMatrix;

/// A labeled sparse dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// feature rows (samples x dim)
    pub a: CsrMatrix,
    /// labels: {-1, +1} for classification, arbitrary reals for regression
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn samples(&self) -> usize {
        self.a.rows
    }

    pub fn dim(&self) -> usize {
        self.a.cols
    }

    /// Dataset sparsity `rho` (Table 1).
    pub fn density(&self) -> f64 {
        self.a.density()
    }

    /// Fraction of positive labels (AUC's `p`).
    pub fn positive_ratio(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&y| y > 0.0).count() as f64 / self.y.len() as f64
    }

    /// Normalize each row to unit norm (paper §7: `||a_{n,i}|| = 1`).
    pub fn normalize_rows(&mut self) {
        self.a.normalize_rows();
    }

    /// Split into `n` equal-size shards, shuffling with `seed`
    /// (paper §7: "randomly split them into N partitions with equal
    /// sizes" — trailing remainder samples are dropped so every node gets
    /// exactly q = floor(Q/N)).
    pub fn partition(&self, n: usize) -> Partition {
        Partition::equal_random(self, n, 0x5eed)
    }

    /// Same with explicit seed.
    pub fn partition_seeded(&self, n: usize, seed: u64) -> Partition {
        Partition::equal_random(self, n, seed)
    }
}
