//! Equal-size random partitioning of a dataset across the N nodes
//! (paper §7: "randomly split them into N partitions with equal sizes").

use super::Dataset;
use crate::linalg::CsrMatrix;
use crate::util::rng::Rng;

/// A dataset split into per-node shards. Every node holds exactly
/// `q = floor(Q / N)` samples.
#[derive(Clone, Debug)]
pub struct Partition {
    /// per-node feature shards
    pub shards: Vec<CsrMatrix>,
    /// per-node labels
    pub labels: Vec<Vec<f64>>,
    /// samples per node (identical across nodes)
    pub q: usize,
    /// global positive ratio (AUC's p, computed over all kept samples)
    pub positive_ratio: f64,
    /// feature dimension
    pub dim: usize,
}

impl Partition {
    /// Random equal-size split.
    pub fn equal_random(ds: &Dataset, n: usize, seed: u64) -> Partition {
        assert!(n >= 1, "need at least one node");
        assert!(ds.samples() >= n, "fewer samples than nodes");
        let q = ds.samples() / n;
        let mut order: Vec<usize> = (0..ds.samples()).collect();
        Rng::new(seed).shuffle(&mut order);
        let mut shards = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let mut pos = 0usize;
        for node in 0..n {
            let ids = &order[node * q..(node + 1) * q];
            let rows: Vec<_> = ids.iter().map(|&i| ds.a.row_sparse(i)).collect();
            let ys: Vec<f64> = ids.iter().map(|&i| ds.y[i]).collect();
            pos += ys.iter().filter(|&&y| y > 0.0).count();
            shards.push(CsrMatrix::from_rows(ds.dim(), &rows));
            labels.push(ys);
        }
        Partition {
            shards,
            labels,
            q,
            positive_ratio: pos as f64 / (n * q) as f64,
            dim: ds.dim(),
        }
    }

    pub fn nodes(&self) -> usize {
        self.shards.len()
    }

    /// Total kept samples `N * q`.
    pub fn total_samples(&self) -> usize {
        self.nodes() * self.q
    }

    /// Worst-case density across shards (drives the sparse-comm cost).
    pub fn max_shard_density(&self) -> f64 {
        self.shards.iter().map(|s| s.density()).fold(0.0, f64::max)
    }

    /// Pool all shards back into one dataset (used by the centralized
    /// optimum solver).
    pub fn pooled(&self) -> Dataset {
        let mut rows = Vec::with_capacity(self.total_samples());
        let mut y = Vec::with_capacity(self.total_samples());
        for (shard, ys) in self.shards.iter().zip(&self.labels) {
            for i in 0..shard.rows {
                rows.push(shard.row_sparse(i));
            }
            y.extend_from_slice(ys);
        }
        Dataset {
            name: "pooled".into(),
            a: CsrMatrix::from_rows(self.dim, &rows),
            y,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn equal_sizes_and_conservation() {
        let ds = SyntheticSpec::tiny().with_samples(103).generate(5);
        let p = Partition::equal_random(&ds, 10, 7);
        assert_eq!(p.nodes(), 10);
        assert_eq!(p.q, 10);
        assert_eq!(p.total_samples(), 100); // 3 dropped
        for shard in &p.shards {
            assert_eq!(shard.rows, 10);
            assert_eq!(shard.cols, ds.dim());
        }
    }

    #[test]
    fn no_sample_duplicated() {
        let ds = SyntheticSpec::tiny().with_samples(60).generate(6);
        let p = Partition::equal_random(&ds, 6, 8);
        // match rows back to the source by exact content
        let mut used = vec![false; ds.samples()];
        for (shard, ys) in p.shards.iter().zip(&p.labels) {
            for i in 0..shard.rows {
                let row = shard.row_sparse(i);
                let found = (0..ds.samples()).find(|&s| {
                    !used[s] && ds.y[s] == ys[i] && ds.a.row_sparse(s) == row
                });
                let s = found.expect("shard row must come from the dataset");
                used[s] = true;
            }
        }
        assert_eq!(used.iter().filter(|&&u| u).count(), 60);
    }

    #[test]
    fn pooled_roundtrip_counts() {
        let ds = SyntheticSpec::tiny().with_samples(64).generate(7);
        let p = Partition::equal_random(&ds, 8, 9);
        let pooled = p.pooled();
        assert_eq!(pooled.samples(), 64);
        assert_eq!(pooled.dim(), ds.dim());
        assert!((pooled.positive_ratio() - p.positive_ratio).abs() < 1e-12);
    }
}
