//! LIBSVM text format parser (`label idx:val idx:val ...`, 1-based
//! indices). Handles comment lines, blank lines, and both {0,1} and
//! {-1,+1} label conventions (0 is mapped to -1).

use super::Dataset;
use crate::linalg::{CsrMatrix, SparseVec};
use std::io::BufReader;
use std::path::Path;

/// Parse LIBSVM-format text. `dim_hint` fixes the feature dimension (0 =
/// infer from max index).
pub fn parse_libsvm(src: &str, dim_hint: usize) -> Result<Dataset, String> {
    let mut rows_raw: Vec<(f64, Vec<(u32, f64)>)> = Vec::new();
    let mut max_idx = 0u32;
    for (lineno, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .ok_or_else(|| format!("line {}: empty", lineno + 1))?
            .parse()
            .map_err(|e| format!("line {}: bad label ({e})", lineno + 1))?;
        // "nan"/"inf" parse as valid f64 but poison every downstream sum
        if !label.is_finite() {
            return Err(format!("line {}: non-finite label {label}", lineno + 1));
        }
        let label = if label == 0.0 { -1.0 } else { label };
        let mut pairs: Vec<(u32, f64)> = Vec::new();
        for tok in parts {
            if tok.starts_with('#') {
                break; // trailing comment
            }
            let (is, vs) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {}: bad token {tok:?}", lineno + 1))?;
            let i: u32 = is
                .parse()
                .map_err(|e| format!("line {}: bad index ({e})", lineno + 1))?;
            if i == 0 {
                return Err(format!("line {}: LIBSVM indices are 1-based", lineno + 1));
            }
            let v: f64 = vs
                .parse()
                .map_err(|e| format!("line {}: bad value ({e})", lineno + 1))?;
            if !v.is_finite() {
                return Err(format!(
                    "line {}: non-finite value {v} at index {i}",
                    lineno + 1
                ));
            }
            // out-of-order indices are legal (sorted later); a repeated
            // index on one line is a corrupt row, not a feature
            if pairs.iter().any(|&(j, _)| j == i - 1) {
                return Err(format!("line {}: duplicate index {i}", lineno + 1));
            }
            max_idx = max_idx.max(i);
            pairs.push((i - 1, v));
        }
        rows_raw.push((label, pairs));
    }
    if rows_raw.is_empty() {
        return Err(
            "no data rows (empty or all-comment input parses to a degenerate \
             0-sample dataset)"
                .to_string(),
        );
    }
    let dim = if dim_hint > 0 {
        if (max_idx as usize) > dim_hint {
            return Err(format!("index {max_idx} exceeds dim hint {dim_hint}"));
        }
        dim_hint
    } else {
        max_idx as usize
    };
    let mut y = Vec::with_capacity(rows_raw.len());
    let mut rows = Vec::with_capacity(rows_raw.len());
    for (label, pairs) in rows_raw {
        y.push(label);
        rows.push(SparseVec::from_pairs(dim, pairs));
    }
    Ok(Dataset {
        name: "libsvm".into(),
        a: CsrMatrix::from_rows(dim, &rows),
        y,
    })
}

/// Load a LIBSVM file from disk.
pub fn load_libsvm<P: AsRef<Path>>(path: P, dim_hint: usize) -> Result<Dataset, String> {
    let f = std::fs::File::open(path.as_ref())
        .map_err(|e| format!("open {:?}: {e}", path.as_ref()))?;
    let mut src = String::new();
    BufReader::new(f)
        .read_to_string(&mut src)
        .map_err(|e| format!("read: {e}"))?;
    let mut ds = parse_libsvm(&src, dim_hint)?;
    ds.name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    Ok(ds)
}

use std::io::Read as _;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let src = "\
# comment
+1 1:0.5 3:1.5
-1 2:2.0

0 1:1.0 4:-0.25
";
        let ds = parse_libsvm(src, 0).unwrap();
        assert_eq!(ds.samples(), 3);
        assert_eq!(ds.dim(), 4);
        assert_eq!(ds.y, vec![1.0, -1.0, -1.0]); // 0 mapped to -1
        assert_eq!(ds.a.row_dot(0, &[1.0, 0.0, 1.0, 0.0]), 2.0);
        assert_eq!(ds.a.row_nnz(2), 2);
    }

    #[test]
    fn dim_hint_respected_and_checked() {
        let src = "+1 1:1 2:1\n";
        assert_eq!(parse_libsvm(src, 10).unwrap().dim(), 10);
        assert!(parse_libsvm("+1 11:1\n", 10).is_err());
    }

    #[test]
    fn rejects_zero_index_and_garbage() {
        assert!(parse_libsvm("+1 0:1\n", 0).is_err());
        assert!(parse_libsvm("+1 x:1\n", 0).is_err());
        assert!(parse_libsvm("abc 1:1\n", 0).is_err());
    }

    #[test]
    fn rejects_non_finite_labels_and_values_with_line_numbers() {
        for (src, line) in [
            ("+1 1:1\nnan 1:1\n", "line 2"),
            ("inf 1:1\n", "line 1"),
            ("-inf 1:1\n", "line 1"),
            ("+1 1:0.5\n# note\n-1 2:nan\n", "line 3"),
            ("+1 1:inf\n", "line 1"),
            ("+1 1:-inf\n", "line 1"),
        ] {
            let err = parse_libsvm(src, 0).unwrap_err();
            assert!(err.contains("non-finite"), "{src:?} -> {err}");
            assert!(err.contains(line), "{src:?} -> {err}");
        }
    }

    #[test]
    fn rejects_empty_and_all_comment_input() {
        assert!(parse_libsvm("", 0).is_err());
        assert!(parse_libsvm("\n\n", 0).is_err());
        assert!(parse_libsvm("# only\n# comments\n", 0).is_err());
    }

    #[test]
    fn out_of_order_tokens_parse_sorted_duplicates_rejected() {
        // out-of-order indices on one line are fine — rows come out sorted
        let ds = parse_libsvm("+1 3:3.0 1:1.0\n", 0).unwrap();
        assert_eq!(ds.a.row_indices(0), &[0, 2]);
        assert_eq!(ds.a.row_values(0), &[1.0, 3.0]);
        // a repeated index on one line is rejected, with the line number
        let err = parse_libsvm("+1 1:1.0\n-1 2:1.0 2:3.0\n", 0).unwrap_err();
        assert!(err.contains("line 2") && err.contains("duplicate index 2"), "{err}");
    }
}
