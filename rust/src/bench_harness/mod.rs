//! Benchmark harness shared by `rust/benches/*`: runs configured method
//! grids and prints the series the paper's figures/tables report, plus a
//! small timing harness (criterion is not vendored; the benches are
//! `harness = false` binaries built on this module).

use crate::algorithms::AlgorithmKind;
use crate::config::{ExperimentConfig, ProblemKind};
use crate::coordinator::Trace;
use crate::metrics::format_table;
use crate::runtime::{EngineKind, TransportKind};
use crate::util::json::Json;

/// Print a bench section header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Step sizes per (problem, method): the paper tunes per-method; these
/// are the tuned values for the synthetic profiles (see EXPERIMENTS.md).
pub fn tuned_alpha(problem: ProblemKind, method: AlgorithmKind) -> f64 {
    use AlgorithmKind::*;
    match (problem, method) {
        (ProblemKind::Ridge, Dsba | DsbaSparse) => 2.0,
        (ProblemKind::Ridge, Dsa) => 0.3,
        (ProblemKind::Ridge, Extra) => 0.45,
        (ProblemKind::Ridge, PExtra) => 2.0,
        (ProblemKind::Ridge, Dlm) => 0.0, // uses dlm_c / dlm_rho
        (ProblemKind::Ridge, Ssda) => 0.9,
        (ProblemKind::Ridge, Dgd) => 0.4,
        (ProblemKind::Ridge, PointSaga) => 2.0,
        (ProblemKind::Logistic, Dsba | DsbaSparse) => 2.0,
        (ProblemKind::Logistic, Dsa) => 1.0,
        (ProblemKind::Logistic, Extra) => 1.8,
        (ProblemKind::Logistic, PExtra) => 4.0,
        (ProblemKind::Logistic, Dlm) => 0.0,
        (ProblemKind::Logistic, Ssda) => 0.9,
        (ProblemKind::Logistic, Dgd) => 1.5,
        (ProblemKind::Logistic, PointSaga) => 2.0,
        (ProblemKind::Auc, Dsba | DsbaSparse) => 0.5,
        (ProblemKind::Auc, Dsa) => 0.05,
        (ProblemKind::Auc, Extra) => 0.05,
        (ProblemKind::Auc, _) => 0.05,
    }
}

/// One figure run: a (dataset, method-list) grid at fixed passes.
pub struct FigureSpec {
    pub title: &'static str,
    pub problem: ProblemKind,
    pub datasets: Vec<&'static str>,
    pub methods: Vec<AlgorithmKind>,
    pub passes: f64,
    pub samples: usize,
    pub dim: usize,
    pub nodes: usize,
    pub seed: u64,
    /// round driver for every run in the grid (engine parity means the
    /// figures are identical either way; parallel is just faster)
    pub engine: EngineKind,
    /// parallel-engine worker threads (0 = auto)
    pub threads: usize,
    /// parallel-engine edge channels (transport parity means figures are
    /// identical either way; tcp adds the measured socket overhead)
    pub transport: TransportKind,
}

impl FigureSpec {
    /// CI-scale defaults shared by the three figures.
    pub fn defaults(problem: ProblemKind) -> FigureSpec {
        FigureSpec {
            title: "",
            problem,
            datasets: vec!["news20-like", "rcv1-like", "sector-like"],
            methods: vec![
                AlgorithmKind::Dsba,
                AlgorithmKind::Dsa,
                AlgorithmKind::Extra,
                AlgorithmKind::Ssda,
                AlgorithmKind::Dlm,
            ],
            passes: 20.0,
            samples: 600,
            dim: 2048,
            nodes: 10,
            seed: 42,
            engine: EngineKind::Sequential,
            threads: 0,
            transport: TransportKind::Local,
        }
    }

    /// Run the full grid, printing each series and returning
    /// (dataset, method, trace) triples.
    pub fn run(&self) -> Vec<(String, AlgorithmKind, Trace)> {
        let mut out = Vec::new();
        for ds in &self.datasets {
            header(&format!("{} / {}", self.title, ds));
            // share the optimum across methods on the same dataset
            let mut z_star: Option<Vec<f64>> = None;
            for &m in &self.methods {
                let mut cfg = ExperimentConfig {
                    problem: self.problem,
                    dataset: ds.to_string(),
                    samples: self.samples,
                    dim: self.dim,
                    nodes: self.nodes,
                    algorithm: m,
                    alpha: tuned_alpha(self.problem, m),
                    passes: self.passes,
                    seed: self.seed,
                    record_points: 25,
                    engine: self.engine,
                    threads: self.threads,
                    transport: self.transport,
                    ..Default::default()
                };
                if m == AlgorithmKind::Dlm {
                    cfg.alpha = 0.0;
                }
                let mut exp = match cfg.build() {
                    Ok(e) => e,
                    Err(err) => {
                        println!("  {}: skipped ({err})", m.name());
                        continue;
                    }
                };
                exp = exp.with_params(|p| {
                    p.dlm_c = 0.4;
                    p.dlm_rho = 1.5;
                    p.inner_tol = 1e-11;
                });
                if let Some(z) = &z_star {
                    exp = exp.with_z_star(z.clone());
                }
                let trace = exp.run();
                if z_star.is_none() {
                    z_star = Some(trace.z_star.clone());
                }
                println!("--- {} ---", m.name());
                println!("{}", format_table(&trace.rows));
                out.push((ds.to_string(), m, trace));
            }
        }
        out
    }
}

/// Write figure results to `results/<name>.json` for external plotting.
pub fn write_results(name: &str, runs: &[(String, AlgorithmKind, Trace)]) {
    let arr: Vec<Json> = runs
        .iter()
        .map(|(ds, m, t)| {
            Json::from_pairs(vec![
                ("dataset", Json::Str(ds.clone())),
                ("method", Json::Str(m.name().into())),
                (
                    "series",
                    Json::Arr(t.rows.iter().map(|r| r.to_json()).collect()),
                ),
            ])
        })
        .collect();
    let doc = Json::from_pairs(vec![("figure", Json::Str(name.into())), ("runs", Json::Arr(arr))]);
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/{name}.json");
    if std::fs::write(&path, doc.to_string()).is_ok() {
        println!("[wrote {path}]");
    }
}

/// Summarize winners: lowest suboptimality (or highest AUC) per dataset.
pub fn summarize(runs: &[(String, AlgorithmKind, Trace)], auc: bool) {
    header("summary");
    let mut datasets: Vec<&String> = runs.iter().map(|(d, _, _)| d).collect();
    datasets.dedup();
    for ds in datasets {
        let best = runs
            .iter()
            .filter(|(d, _, _)| d == ds)
            .min_by(|a, b| {
                let ka = if auc { -a.2.last_auc() } else { a.2.last_suboptimality() };
                let kb = if auc { -b.2.last_auc() } else { b.2.last_suboptimality() };
                ka.partial_cmp(&kb).unwrap()
            })
            .unwrap();
        if auc {
            println!("{ds}: best final AUC = {} ({:.4})", best.1.name(), best.2.last_auc());
        } else {
            println!(
                "{ds}: best final suboptimality = {} ({:.3e})",
                best.1.name(),
                best.2.last_suboptimality()
            );
        }
    }
}
