//! Benchmark harness shared by `rust/benches/*`: runs configured method
//! grids and prints the series the paper's figures/tables report, plus a
//! small timing harness (criterion is not vendored; the benches are
//! `harness = false` binaries built on this module).

use crate::algorithms::AlgorithmKind;
use crate::config::ExperimentConfig;
use crate::coordinator::Trace;
use crate::metrics::format_table;
use crate::operators::{ProblemRegistry, SaddleStat};
use crate::runtime::EngineSpec;
use crate::util::json::Json;

/// Which final statistic ranks methods in a figure summary — derived
/// from the problem's registry metadata (see [`FigureSpec::score_stat`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreStat {
    /// lowest final suboptimality wins (objective problems)
    Suboptimality,
    /// highest final AUC wins (`SaddleStat::AucRanking` problems)
    Auc,
    /// lowest final saddle residual wins (generic saddle problems)
    SaddleResidual,
}

/// Print a bench section header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Tuned step size per (problem, method), resolved from the problem's
/// registry entry (the paper tunes per method; entries carry the tuned
/// values for the synthetic profiles — see EXPERIMENTS.md).  Unknown
/// problem names fall back to a conservative 0.1.
pub fn tuned_alpha(problem: &str, method: AlgorithmKind) -> f64 {
    ProblemRegistry::builtin()
        .resolve(problem)
        .map(|e| (e.meta.tuned_alpha)(method))
        .unwrap_or(0.1)
}

/// One figure run: a (dataset, method-list) grid at fixed passes.
pub struct FigureSpec {
    pub title: &'static str,
    /// problem name or alias, resolved through the registry
    pub problem: &'static str,
    pub datasets: Vec<&'static str>,
    pub methods: Vec<AlgorithmKind>,
    pub passes: f64,
    pub samples: usize,
    pub dim: usize,
    pub nodes: usize,
    pub seed: u64,
    /// execution engine for every run in the grid (engine and transport
    /// parity mean the figures are identical either way; parallel is
    /// just faster, tcp adds the measured socket overhead)
    pub engine: EngineSpec,
}

impl FigureSpec {
    /// CI-scale defaults shared by the three figures.
    pub fn defaults(problem: &'static str) -> FigureSpec {
        FigureSpec {
            title: "",
            problem,
            datasets: vec!["news20-like", "rcv1-like", "sector-like"],
            methods: vec![
                AlgorithmKind::Dsba,
                AlgorithmKind::Dsa,
                AlgorithmKind::Extra,
                AlgorithmKind::Ssda,
                AlgorithmKind::Dlm,
            ],
            passes: 20.0,
            samples: 600,
            dim: 2048,
            nodes: 10,
            seed: 42,
            engine: EngineSpec::default(),
        }
    }

    /// Summary statistic for the configured problem, resolved from its
    /// registry capability metadata: AUC-scored saddles rank by AUC,
    /// generic saddles by the saddle residual, everything else by
    /// suboptimality.
    pub fn score_stat(&self) -> ScoreStat {
        match ProblemRegistry::builtin()
            .resolve(self.problem)
            .map(|e| e.meta.saddle_stat)
        {
            Some(Some(SaddleStat::AucRanking)) => ScoreStat::Auc,
            Some(Some(SaddleStat::Residual)) => ScoreStat::SaddleResidual,
            _ => ScoreStat::Suboptimality,
        }
    }

    /// Run the full grid, printing each series and returning
    /// (dataset, method, trace) triples.
    pub fn run(&self) -> Vec<(String, AlgorithmKind, Trace)> {
        let mut out = Vec::new();
        for ds in &self.datasets {
            header(&format!("{} / {}", self.title, ds));
            // share the optimum across methods on the same dataset
            let mut z_star: Option<Vec<f64>> = None;
            for &m in &self.methods {
                let cfg = ExperimentConfig {
                    problem: self.problem.to_string(),
                    dataset: ds.to_string(),
                    samples: self.samples,
                    dim: self.dim,
                    nodes: self.nodes,
                    algorithm: m,
                    alpha: tuned_alpha(self.problem, m),
                    passes: self.passes,
                    seed: self.seed,
                    record_points: 25,
                    engine: self.engine.clone(),
                    ..Default::default()
                };
                let mut exp = match cfg.build() {
                    Ok(e) => e,
                    Err(err) => {
                        println!("  {}: skipped ({err})", m.name());
                        continue;
                    }
                };
                exp.params.dlm_c = 0.4;
                exp.params.dlm_rho = 1.5;
                exp.params.inner_tol = 1e-11;
                if let Some(z) = &z_star {
                    exp.z_star = Some(z.clone());
                }
                let trace = exp.run();
                if z_star.is_none() {
                    z_star = Some(trace.z_star.clone());
                }
                println!("--- {} ---", m.name());
                println!("{}", format_table(&trace.rows));
                out.push((ds.to_string(), m, trace));
            }
        }
        out
    }
}

/// Write figure results to `results/<name>.json` for external plotting.
pub fn write_results(name: &str, runs: &[(String, AlgorithmKind, Trace)]) {
    let arr: Vec<Json> = runs
        .iter()
        .map(|(ds, m, t)| {
            Json::from_pairs(vec![
                ("dataset", Json::Str(ds.clone())),
                ("method", Json::Str(m.name().into())),
                (
                    "series",
                    Json::Arr(t.rows.iter().map(|r| r.to_json()).collect()),
                ),
            ])
        })
        .collect();
    let doc = Json::from_pairs(vec![("figure", Json::Str(name.into())), ("runs", Json::Arr(arr))]);
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/{name}.json");
    if std::fs::write(&path, doc.to_string()).is_ok() {
        println!("[wrote {path}]");
    }
}

/// Summarize winners per dataset: lowest suboptimality, highest AUC, or
/// lowest saddle residual, per the figure's [`ScoreStat`].
pub fn summarize(runs: &[(String, AlgorithmKind, Trace)], stat: ScoreStat) {
    header("summary");
    let mut datasets: Vec<&String> = runs.iter().map(|(d, _, _)| d).collect();
    datasets.dedup();
    let key = |t: &Trace| match stat {
        ScoreStat::Auc => -t.last_auc(),
        ScoreStat::SaddleResidual => t.last_saddle_res(),
        ScoreStat::Suboptimality => t.last_suboptimality(),
    };
    for ds in datasets {
        let best = runs
            .iter()
            .filter(|(d, _, _)| d == ds)
            .min_by(|a, b| key(&a.2).partial_cmp(&key(&b.2)).unwrap())
            .unwrap();
        match stat {
            ScoreStat::Auc => println!(
                "{ds}: best final AUC = {} ({:.4})",
                best.1.name(),
                best.2.last_auc()
            ),
            ScoreStat::SaddleResidual => println!(
                "{ds}: best final saddle residual = {} ({:.3e})",
                best.1.name(),
                best.2.last_saddle_res()
            ),
            ScoreStat::Suboptimality => println!(
                "{ds}: best final suboptimality = {} ({:.3e})",
                best.1.name(),
                best.2.last_suboptimality()
            ),
        }
    }
}
