//! Robust (min-max) least squares: the learner fits a linear model while
//! an adversary applies a shared prediction shift `s` under a quadratic
//! budget — a target-shift robustness model, and the first minimax
//! workload registered as a *pure* entry of the generic saddle subsystem
//! (cf. decentralized minimax per Gao, arXiv:2212.02724).
//!
//! With margin `m = a^T w`, the per-component saddle function is
//!
//! ```text
//! L_{n,i}(w, s) = 1/2 (m + s - b_i)^2 - rho/2 s^2      (rho > 1)
//! ```
//!
//! convex in `w`, strongly concave in `s` (curvature `1 - rho < 0`), so
//! each component operator `[dL/dw; -dL/ds]` is monotone:
//! `<B(z)-B(z'), z-z'> = dm^2 + (rho-1) ds^2 >= 0` exactly.  The output
//! is `[c1 * a; c2]` with `c1 = m + s - b` (the robust residual) and
//! `c2 = rho s - c1`, so SAGA tables stay `O(q)` scalars and the §5.1
//! deltas stay sparse (+1 dense tail entry), exactly like AUC.
//!
//! The resolvent is **closed form**: eliminating `w` reduces
//! `z + beta B(z) = psi_hat` to a 2x2 linear system in `(m, s)` with
//! determinant `1 + beta (rho - 1 + c) + beta^2 c rho > 0`.

use super::registry::{ProblemEntry, ProblemMeta, ProblemSpec, ResolventKind};
use super::{Problem, SaddleStat, SaddleStructure};
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use std::sync::Arc;

/// Registry entry (canonical `robust-ls`): regression targets, 1 dense
/// tail dim (the adversarial shift), 2 scalar coefficients, closed-form
/// 2x2 resolvent.  `params`: `rho` — adversary budget curvature
/// (default 2, must be > 1 for per-component concavity).
pub(crate) fn entry() -> ProblemEntry {
    fn tuned(method: AlgorithmKind) -> f64 {
        use AlgorithmKind::*;
        // backward methods tolerate aggressive steps on the saddle
        // operator (resolvent); forward baselines need L-conservative ones
        match method {
            Dsba | DsbaSparse | PointSaga => 0.5,
            Dlm => 0.0, // uses dlm_c / dlm_rho
            _ => 0.05,
        }
    }
    fn ctor(
        spec: &ProblemSpec,
        _ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        let rho = spec.param_f64("rho").unwrap_or(2.0);
        if !rho.is_finite() || rho <= 1.0 {
            return Err(format!(
                "robust-ls: rho must be finite and > 1 (per-component \
                 concavity in the shift), got {rho}"
            ));
        }
        Ok(Arc::new(RobustLsProblem::new(part, spec.lambda, rho)))
    }
    ProblemEntry {
        meta: ProblemMeta {
            name: "robust-ls",
            aliases: &["robust-least-squares", "minmax-ls"],
            summary: "min-max least squares vs an adversarial target shift",
            has_objective: false,
            saddle_stat: Some(SaddleStat::Residual),
            l1: false,
            resolvent: ResolventKind::ClosedForm,
            tail_dims: 1,
            coef_width: 2,
            regression_targets: true,
            params_help: "rho (default 2, > 1)",
            tuned_alpha: tuned,
        },
        ctor,
    }
}

/// Decentralized robust (min-max) least squares.
pub struct RobustLsProblem {
    part: Partition,
    lambda: f64,
    /// adversary budget curvature (> 1)
    pub rho: f64,
    row_norm_sq: Vec<Vec<f64>>,
}

impl RobustLsProblem {
    pub fn new(part: Partition, lambda: f64, rho: f64) -> Self {
        assert!(rho > 1.0, "adversary curvature rho must exceed 1");
        let row_norm_sq = part
            .shards
            .iter()
            .map(|s| (0..s.rows).map(|i| s.row_norm_sq(i)).collect())
            .collect();
        RobustLsProblem { part, lambda, rho, row_norm_sq }
    }

    fn shard(&self, n: usize) -> &crate::linalg::CsrMatrix {
        &self.part.shards[n]
    }

    #[inline]
    fn d(&self) -> usize {
        self.part.dim
    }
}

impl Problem for RobustLsProblem {
    fn dim(&self) -> usize {
        self.d() + 1
    }
    fn feature_dim(&self) -> usize {
        self.d()
    }
    fn nodes(&self) -> usize {
        self.part.nodes()
    }
    fn q(&self) -> usize {
        self.part.q
    }
    fn lambda(&self) -> f64 {
        self.lambda
    }
    fn coef_width(&self) -> usize {
        2
    }
    fn partition(&self) -> &Partition {
        &self.part
    }

    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]) {
        let d = self.d();
        let r = self.shard(n).row_dot(i, z) + z[d] - self.part.labels[n][i];
        out[0] = r;
        out[1] = self.rho * z[d] - r;
    }

    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]) {
        let d = self.d();
        self.shard(n).row_axpy(i, scale * coefs[0], out);
        out[d] += scale * coefs[1];
    }

    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    ) {
        let d = self.d();
        let sf = 1.0 / (1.0 + alpha * self.lambda);
        let beta = alpha * sf;
        let c = self.row_norm_sq[n][i];
        let b = self.part.labels[n][i];
        let rho = self.rho;
        let m_psi = self.shard(n).row_dot(i, psi) * sf;
        let s_psi = sf * psi[d];
        // 2x2 system in (m, s):
        //   (1 + beta c) m + beta c s        = m_psi + beta c b
        //   -beta m + (1 + beta (rho - 1)) s = s_psi - beta b
        let a11 = 1.0 + beta * c;
        let a12 = beta * c;
        let a21 = -beta;
        let a22 = 1.0 + beta * (rho - 1.0);
        let r0 = m_psi + beta * c * b;
        let r1 = s_psi - beta * b;
        let det = a11 * a22 - a12 * a21;
        let m = (a22 * r0 - a12 * r1) / det;
        let s = (a11 * r1 - a21 * r0) / det;
        let c1 = m + s - b;
        for (zo, p) in z_out[..d].iter_mut().zip(psi) {
            *zo = sf * p;
        }
        self.shard(n).row_axpy(i, -beta * c1, &mut z_out[..d]);
        z_out[d] = s;
        coefs_out[0] = c1;
        coefs_out[1] = rho * s - c1;
    }

    /// Saddle problem: no primal objective; scored by the saddle merit
    /// layer (residual + restricted duality gap).
    fn objective(&self, _z: &[f64]) -> Option<f64> {
        None
    }

    fn l_mu(&self) -> (f64, f64) {
        let cmax = self
            .row_norm_sq
            .iter()
            .flatten()
            .fold(0.0f64, |acc, &c| acc.max(c));
        // block Jacobian [[a a^T, a], [-a^T, rho-1]]: norm bounded by
        // c + 2 sqrt(c) + rho - 1
        let l_est = cmax + 2.0 * cmax.sqrt() + self.rho - 1.0;
        (l_est + self.lambda, self.lambda)
    }

    fn rebuild(&self, part: Partition) -> Arc<dyn Problem> {
        Arc::new(RobustLsProblem::new(part, self.lambda, self.rho))
    }

    fn saddle(&self) -> Option<SaddleStructure> {
        Some(SaddleStructure {
            primal_dims: self.d(),
            dual_dims: 1,
            stat: SaddleStat::Residual,
        })
    }

    fn saddle_value(&self, z: &[f64]) -> Option<f64> {
        let d = self.d();
        let s = z[d];
        let n_nodes = self.nodes() as f64;
        let mut total = 0.0;
        for n in 0..self.nodes() {
            let shard = self.shard(n);
            let mut local = 0.0;
            for i in 0..self.q() {
                let r = shard.row_dot(i, z) + s - self.part.labels[n][i];
                local += 0.5 * r * r;
            }
            total += local / self.q() as f64;
        }
        total -= n_nodes * self.rho / 2.0 * s * s;
        let w_sq: f64 = z[..d].iter().map(|v| v * v).sum();
        total += n_nodes * self.lambda / 2.0 * (w_sq - s * s);
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{check_monotone, check_resolvent, check_saddle};
    use crate::util::rng::Rng;

    fn problem() -> RobustLsProblem {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(37);
        RobustLsProblem::new(ds.partition(4), 0.05, 2.0)
    }

    #[test]
    fn resolvent_identity_holds() {
        check_resolvent(&problem(), 0.4, 1, 50).unwrap();
        check_resolvent(&problem(), 4.0, 2, 50).unwrap();
        // near-degenerate adversary curvature must stay exact
        let ds = SyntheticSpec::tiny().with_regression(true).generate(41);
        let tight = RobustLsProblem::new(ds.partition(3), 0.01, 1.01);
        check_resolvent(&tight, 1.0, 3, 50).unwrap();
    }

    #[test]
    fn components_monotone() {
        check_monotone(&problem(), 3, 200).unwrap();
    }

    #[test]
    fn saddle_value_gradient_is_the_operator() {
        check_saddle(&problem(), 5, 10).unwrap();
    }

    #[test]
    fn backward_satisfies_the_defining_equations() {
        // verify the 2x2 solve against the raw resolvent equations
        // m' = a^T w' and s' + beta (rho s' - r') = psi_hat_s directly
        let p = problem();
        let alpha = 1.3;
        let sf = 1.0 / (1.0 + alpha * p.lambda());
        let beta = alpha * sf;
        let d = p.feature_dim();
        let mut rng = Rng::new(9);
        let mut z = vec![0.0; p.dim()];
        let mut cf = vec![0.0; 2];
        for trial in 0..20 {
            let n = rng.below(p.nodes());
            let i = rng.below(p.q());
            let psi: Vec<f64> = (0..p.dim()).map(|_| 2.0 * rng.normal()).collect();
            p.backward(n, i, alpha, &psi, &mut z, &mut cf);
            let row = p.partition().shards[n].row_sparse(i);
            let b = p.partition().labels[n][i];
            let m = row.dot_dense(&z[..d]);
            let s = z[d];
            let r = m + s - b;
            assert!((cf[0] - r).abs() < 1e-9, "trial {trial}: stale c1");
            let lhs = s + beta * (p.rho * s - r);
            let want = sf * psi[d];
            assert!(
                (lhs - want).abs() < 1e-9 * (1.0 + want.abs()),
                "trial {trial}: dual equation violated ({lhs} vs {want})"
            );
        }
    }

    #[test]
    fn adversary_shift_responds_at_the_saddle_point() {
        // at the root, the dual optimality condition links the shift to
        // the mean residual: mean(r) = rho * s  (from sum_n -dL/ds = 0,
        // modulo the lambda tilt) — the adversary is genuinely coupled
        let ds = SyntheticSpec::tiny().with_regression(true).generate(43);
        let p = RobustLsProblem::new(ds.partition(3), 0.02, 2.0);
        let z = crate::coordinator::solve_optimum(&p, 1e-10);
        assert!(p.global_residual(&z) < 1e-9);
        let d = p.feature_dim();
        let s = z[d];
        let mut mean_r = 0.0;
        for n in 0..p.nodes() {
            let shard = &p.partition().shards[n];
            for i in 0..p.q() {
                mean_r += shard.row_dot(i, &z) + s - p.partition().labels[n][i];
            }
        }
        mean_r /= (p.nodes() * p.q()) as f64;
        // stationarity of the tail: sum_n ((rho s - mean_n r) + lambda s) = 0
        let want = (p.rho + p.lambda()) * s;
        assert!(
            (mean_r - want).abs() < 1e-7 * (1.0 + want.abs()),
            "mean residual {mean_r} vs (rho + lambda) s = {want}"
        );
        // the fit is nontrivial: the primal block actually regresses
        assert!(z[..d].iter().any(|v| v.abs() > 1e-3));
    }
}
