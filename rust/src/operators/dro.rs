//! Distributionally robust class-reweighted margin game — a **bilinear
//! saddle** registry entry (the "DRO / bilinear" workload of
//! decentralized minimax, cf. Gao, arXiv:2212.02724).
//!
//! The learner maximizes reweighted signed margins while an adversary
//! tilts the class distribution within a chi-square-style quadratic
//! budget: with `m = a^T w`, class weights `1 + t_c` (one dual scalar
//! per class, `t = [t_pos; t_neg]`),
//!
//! ```text
//! L_{n,i}(w, t) = -(1 + t_{c(i)}) y_i m  -  nu/2 ||t||^2      (nu > 0)
//! ```
//!
//! linear (hence convex) in `w`, strongly concave in `t`, with a purely
//! **bilinear** coupling `-t_c y m`; the framework's analytic l2 term
//! supplies the primal strong convexity.  Monotonicity is exact:
//! `<B(z)-B(z'), z-z'> = nu ||dt||^2` (the bilinear part is skew).
//!
//! Component outputs are `[c1 * a; c2; c3]` with `c1 = -(1 + t_c) y` and
//! the dual pair `c_j = [j == c] y m + nu t_j`, so SAGA tables stay
//! `O(q)` scalars and §5.1 deltas sparse (+2 dense tail entries).  The
//! resolvent is **closed form** (Newton-free): the off-class dual scalar
//! decouples (`t' = psi_hat / (1 + beta nu)`) and `(m, t_c)` solve a 2x2
//! linear system with determinant `1 + beta nu + beta^2 c > 0`.

use super::registry::{ProblemEntry, ProblemMeta, ProblemSpec, ResolventKind};
use super::{Problem, SaddleStat, SaddleStructure};
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use std::sync::Arc;

/// Registry entry (canonical `dro-bilinear`): ±1 labels, 2 dense tail
/// dims (per-class adversarial weights), 3 scalar coefficients,
/// closed-form 2x2 resolvent.  `params`: `nu` — adversary budget
/// curvature (default 1, must be > 0).
pub(crate) fn entry() -> ProblemEntry {
    fn tuned(method: AlgorithmKind) -> f64 {
        use AlgorithmKind::*;
        match method {
            Dsba | DsbaSparse | PointSaga => 0.5,
            Dlm => 0.0, // uses dlm_c / dlm_rho
            _ => 0.05,
        }
    }
    fn ctor(
        spec: &ProblemSpec,
        _ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        let nu = spec.param_f64("nu").unwrap_or(1.0);
        if !nu.is_finite() || nu <= 0.0 {
            return Err(format!(
                "dro-bilinear: nu must be finite and > 0, got {nu}"
            ));
        }
        Ok(Arc::new(DroBilinearProblem::new(part, spec.lambda, nu)))
    }
    ProblemEntry {
        meta: ProblemMeta {
            name: "dro-bilinear",
            aliases: &["dro", "dro-margin", "bilinear-saddle"],
            summary: "distributionally robust class-reweighted margin (bilinear saddle)",
            has_objective: false,
            saddle_stat: Some(SaddleStat::Residual),
            l1: false,
            resolvent: ResolventKind::ClosedForm,
            tail_dims: 2,
            coef_width: 3,
            regression_targets: false,
            params_help: "nu (default 1, > 0)",
            tuned_alpha: tuned,
        },
        ctor,
    }
}

/// Decentralized distributionally robust margin game.
pub struct DroBilinearProblem {
    part: Partition,
    lambda: f64,
    /// adversary budget curvature (> 0)
    pub nu: f64,
    row_norm_sq: Vec<Vec<f64>>,
}

impl DroBilinearProblem {
    pub fn new(part: Partition, lambda: f64, nu: f64) -> Self {
        assert!(nu > 0.0, "adversary curvature nu must be positive");
        let row_norm_sq = part
            .shards
            .iter()
            .map(|s| (0..s.rows).map(|i| s.row_norm_sq(i)).collect())
            .collect();
        DroBilinearProblem { part, lambda, nu, row_norm_sq }
    }

    fn shard(&self, n: usize) -> &crate::linalg::CsrMatrix {
        &self.part.shards[n]
    }

    #[inline]
    fn d(&self) -> usize {
        self.part.dim
    }

    /// Dual-block index of a label's class weight (0 = positives).
    #[inline]
    fn class(y: f64) -> usize {
        if y > 0.0 {
            0
        } else {
            1
        }
    }
}

impl Problem for DroBilinearProblem {
    fn dim(&self) -> usize {
        self.d() + 2
    }
    fn feature_dim(&self) -> usize {
        self.d()
    }
    fn nodes(&self) -> usize {
        self.part.nodes()
    }
    fn q(&self) -> usize {
        self.part.q
    }
    fn lambda(&self) -> f64 {
        self.lambda
    }
    fn coef_width(&self) -> usize {
        3
    }
    fn partition(&self) -> &Partition {
        &self.part
    }

    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]) {
        let d = self.d();
        let y = self.part.labels[n][i];
        let c = Self::class(y);
        let m = self.shard(n).row_dot(i, z);
        out[0] = -(1.0 + z[d + c]) * y;
        out[1] = self.nu * z[d];
        out[2] = self.nu * z[d + 1];
        out[1 + c] += y * m;
    }

    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]) {
        let d = self.d();
        self.shard(n).row_axpy(i, scale * coefs[0], out);
        out[d] += scale * coefs[1];
        out[d + 1] += scale * coefs[2];
    }

    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    ) {
        let d = self.d();
        let sf = 1.0 / (1.0 + alpha * self.lambda);
        let beta = alpha * sf;
        let c = self.row_norm_sq[n][i];
        let y = self.part.labels[n][i];
        let cls = Self::class(y);
        let nu = self.nu;
        let m_psi = self.shard(n).row_dot(i, psi) * sf;
        let tc_psi = sf * psi[d + cls];
        let to_psi = sf * psi[d + 1 - cls];
        // off-class weight decouples; (m, t_c) solve
        //   m - beta c y t_c        = m_psi + beta c y
        //   beta y m + (1 + beta nu) t_c = tc_psi
        let det = 1.0 + beta * nu + beta * beta * c;
        let r0 = m_psi + beta * c * y;
        let m = ((1.0 + beta * nu) * r0 + beta * c * y * tc_psi) / det;
        let tc = (tc_psi - beta * y * r0) / det;
        let to = to_psi / (1.0 + beta * nu);
        let c1 = -(1.0 + tc) * y;
        for (zo, p) in z_out[..d].iter_mut().zip(psi) {
            *zo = sf * p;
        }
        self.shard(n).row_axpy(i, -beta * c1, &mut z_out[..d]);
        z_out[d + cls] = tc;
        z_out[d + 1 - cls] = to;
        coefs_out[0] = c1;
        coefs_out[1] = nu * z_out[d];
        coefs_out[2] = nu * z_out[d + 1];
        coefs_out[1 + cls] += y * m;
    }

    /// Saddle problem: no primal objective; scored by the saddle merit
    /// layer (residual + restricted duality gap).
    fn objective(&self, _z: &[f64]) -> Option<f64> {
        None
    }

    fn l_mu(&self) -> (f64, f64) {
        let cmax = self
            .row_norm_sq
            .iter()
            .flatten()
            .fold(0.0f64, |acc, &c| acc.max(c));
        // block Jacobian [[0, -y a], [y a^T, nu I]]: norm <= nu + 2 sqrt(c)
        let l_est = self.nu + 2.0 * cmax.sqrt();
        (l_est + self.lambda, self.lambda)
    }

    fn rebuild(&self, part: Partition) -> Arc<dyn Problem> {
        Arc::new(DroBilinearProblem::new(part, self.lambda, self.nu))
    }

    fn saddle(&self) -> Option<SaddleStructure> {
        Some(SaddleStructure {
            primal_dims: self.d(),
            dual_dims: 2,
            stat: SaddleStat::Residual,
        })
    }

    fn saddle_value(&self, z: &[f64]) -> Option<f64> {
        let d = self.d();
        let n_nodes = self.nodes() as f64;
        let t_sq = z[d] * z[d] + z[d + 1] * z[d + 1];
        let mut total = 0.0;
        for n in 0..self.nodes() {
            let shard = self.shard(n);
            let mut local = 0.0;
            for i in 0..self.q() {
                let y = self.part.labels[n][i];
                let m = shard.row_dot(i, z);
                local -= (1.0 + z[d + Self::class(y)]) * y * m;
            }
            total += local / self.q() as f64;
        }
        total -= n_nodes * self.nu / 2.0 * t_sq;
        let w_sq: f64 = z[..d].iter().map(|v| v * v).sum();
        total += n_nodes * self.lambda / 2.0 * (w_sq - t_sq);
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{check_monotone, check_resolvent, check_saddle};
    use crate::util::rng::Rng;

    fn problem() -> DroBilinearProblem {
        let ds = SyntheticSpec::tiny().generate(47);
        DroBilinearProblem::new(ds.partition(4), 0.05, 1.0)
    }

    #[test]
    fn resolvent_identity_holds() {
        check_resolvent(&problem(), 0.4, 1, 50).unwrap();
        check_resolvent(&problem(), 4.0, 2, 50).unwrap();
        let ds = SyntheticSpec::tiny().generate(53);
        let soft = DroBilinearProblem::new(ds.partition(3), 0.01, 0.1);
        check_resolvent(&soft, 1.0, 3, 50).unwrap();
    }

    #[test]
    fn components_monotone() {
        check_monotone(&problem(), 3, 200).unwrap();
    }

    #[test]
    fn saddle_value_gradient_is_the_operator() {
        check_saddle(&problem(), 7, 10).unwrap();
    }

    #[test]
    fn off_class_weight_decouples_in_backward() {
        // a positive sample's resolvent must leave the negative-class
        // weight at its decoupled shrinkage psi / (1 + alpha (lambda + nu))
        let p = problem();
        let (n, i) = (0..p.nodes())
            .flat_map(|n| (0..p.q()).map(move |i| (n, i)))
            .find(|&(n, i)| p.partition().labels[n][i] > 0.0)
            .unwrap();
        let mut rng = Rng::new(13);
        let psi: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        let mut z = vec![0.0; p.dim()];
        let mut cf = vec![0.0; 3];
        let alpha = 0.7;
        p.backward(n, i, alpha, &psi, &mut z, &mut cf);
        let sf = 1.0 / (1.0 + alpha * p.lambda());
        let beta = alpha * sf;
        let want = sf * psi[p.dim() - 1] / (1.0 + beta * p.nu);
        assert!((z[p.dim() - 1] - want).abs() < 1e-12);
        // and its dual coefficient is pure shrinkage (no margin coupling)
        assert!((cf[2] - p.nu * z[p.dim() - 1]).abs() < 1e-12);
    }

    #[test]
    fn adversary_tilts_toward_the_harder_class() {
        // at the saddle point the per-class dual stationarity reads
        // sum_n mean_{i in c}(y m) + N (nu + lambda) t_c = 0, i.e. the
        // adversary sets t_c positive exactly when the class's mean
        // signed margin is negative — up-weighting the harder class
        let ds = SyntheticSpec::tiny().generate(59);
        let p = DroBilinearProblem::new(ds.partition(3), 0.05, 1.0);
        let z = crate::coordinator::solve_optimum(&p, 1e-10);
        assert!(p.global_residual(&z) < 1e-9);
        let d = p.feature_dim();
        for cls in [0usize, 1] {
            let mut acc = 0.0;
            for n in 0..p.nodes() {
                let shard = &p.partition().shards[n];
                let mut local = 0.0;
                for i in 0..p.q() {
                    let y = p.partition().labels[n][i];
                    if DroBilinearProblem::class(y) == cls {
                        local += y * shard.row_dot(i, &z);
                    }
                }
                acc += local / p.q() as f64;
            }
            // global tail stationarity: acc + N (nu + lambda) t_c = 0
            let want = -(p.nodes() as f64) * (p.nu + p.lambda()) * z[d + cls];
            assert!(
                (acc - want).abs() < 1e-7 * (1.0 + want.abs()),
                "class {cls}: coupling {acc} vs {want}"
            );
        }
    }
}
