//! Ridge regression operators (paper §7.1).
//!
//! `B_{n,i}(z) = (a_{n,i}^T z - y_{n,i}) a_{n,i}` — one scalar coefficient
//! `g = m - y` per component.  The resolvent admits a closed form: with
//! `c = ||a||^2` and `m` the post-step margin,
//! `m = (a^T psi + alpha c y) / (1 + alpha c)`,
//! `J_{alpha B}(psi) = psi - alpha (m - y) a`,
//! which for `c = 1` reduces to the paper's expression.

use super::registry::{ProblemEntry, ProblemMeta, ProblemSpec, ResolventKind};
use super::Problem;
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use std::sync::Arc;

/// Registry entry (canonical `ridge`): regression targets, 1 scalar
/// coefficient, closed-form resolvent.
pub(crate) fn entry() -> ProblemEntry {
    fn tuned(method: AlgorithmKind) -> f64 {
        use AlgorithmKind::*;
        match method {
            Dsba | DsbaSparse | PExtra | PointSaga => 2.0,
            Dsa => 0.3,
            Extra => 0.45,
            Dlm => 0.0, // uses dlm_c / dlm_rho
            Ssda => 0.9,
            Dgd => 0.4,
        }
    }
    fn ctor(
        spec: &ProblemSpec,
        _ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        Ok(Arc::new(RidgeProblem::new(part, spec.lambda)))
    }
    ProblemEntry {
        meta: ProblemMeta {
            name: "ridge",
            aliases: &["least-squares", "l2"],
            summary: "decentralized ridge regression (paper §7.1)",
            has_objective: true,
            saddle_stat: None,
            l1: false,
            resolvent: ResolventKind::ClosedForm,
            tail_dims: 0,
            coef_width: 1,
            regression_targets: true,
            params_help: "-",
            tuned_alpha: tuned,
        },
        ctor,
    }
}

/// Decentralized ridge regression.
pub struct RidgeProblem {
    part: Partition,
    lambda: f64,
    /// cached row norms ||a_{n,i}||^2
    row_norm_sq: Vec<Vec<f64>>,
}

impl RidgeProblem {
    pub fn new(part: Partition, lambda: f64) -> Self {
        let row_norm_sq = part
            .shards
            .iter()
            .map(|s| (0..s.rows).map(|i| s.row_norm_sq(i)).collect())
            .collect();
        RidgeProblem { part, lambda, row_norm_sq }
    }

    fn shard(&self, n: usize) -> &crate::linalg::CsrMatrix {
        &self.part.shards[n]
    }
}

impl Problem for RidgeProblem {
    fn dim(&self) -> usize {
        self.part.dim
    }
    fn feature_dim(&self) -> usize {
        self.part.dim
    }
    fn nodes(&self) -> usize {
        self.part.nodes()
    }
    fn q(&self) -> usize {
        self.part.q
    }
    fn lambda(&self) -> f64 {
        self.lambda
    }
    fn coef_width(&self) -> usize {
        1
    }
    fn partition(&self) -> &Partition {
        &self.part
    }

    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]) {
        out[0] = self.shard(n).row_dot(i, z) - self.part.labels[n][i];
    }

    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]) {
        self.shard(n).row_axpy(i, scale * coefs[0], out);
    }

    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    ) {
        // regularization via scaling: solve z + beta B(z) = psi / (1+alpha*lambda)
        let s = 1.0 / (1.0 + alpha * self.lambda);
        let beta = alpha * s;
        let c = self.row_norm_sq[n][i];
        let y = self.part.labels[n][i];
        // margin at the new point: m = (a^T psi_hat + beta c y) / (1 + beta c)
        let a_dot_psi = self.shard(n).row_dot(i, psi) * s;
        let m = (a_dot_psi + beta * c * y) / (1.0 + beta * c);
        let g = m - y;
        // z = psi_hat - beta g a
        for (zo, p) in z_out.iter_mut().zip(psi) {
            *zo = s * p;
        }
        self.shard(n).row_axpy(i, -beta * g, z_out);
        coefs_out[0] = g;
    }

    fn objective(&self, z: &[f64]) -> Option<f64> {
        // sum_n [ (1/2q) ||A_n z - y_n||^2 + lambda/2 ||z||^2 ]
        let mut obj = 0.0;
        for n in 0..self.nodes() {
            let shard = self.shard(n);
            let mut local = 0.0;
            for i in 0..self.q() {
                let r = shard.row_dot(i, z) - self.part.labels[n][i];
                local += r * r;
            }
            obj += 0.5 * local / self.q() as f64;
        }
        let znorm: f64 = z.iter().map(|v| v * v).sum();
        obj += 0.5 * self.lambda * self.nodes() as f64 * znorm;
        Some(obj)
    }

    fn l_mu(&self) -> (f64, f64) {
        // raw B_{n,i} has L = ||a||^2 (rank-1 PSD), mu = 0; + lambda I
        let cmax = self
            .row_norm_sq
            .iter()
            .flatten()
            .fold(0.0f64, |acc, &c| acc.max(c));
        (cmax + self.lambda, self.lambda)
    }

    fn rebuild(&self, part: Partition) -> Arc<dyn Problem> {
        Arc::new(RidgeProblem::new(part, self.lambda))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{check_monotone, check_resolvent};

    fn problem() -> RidgeProblem {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(3);
        RidgeProblem::new(ds.partition(4), 0.05)
    }

    #[test]
    fn resolvent_identity_holds() {
        check_resolvent(&problem(), 0.3, 7, 50).unwrap();
        check_resolvent(&problem(), 3.0, 8, 50).unwrap();
    }

    #[test]
    fn components_monotone() {
        check_monotone(&problem(), 9, 100).unwrap();
    }

    #[test]
    fn apply_matches_definition() {
        let p = problem();
        let mut rng = crate::util::rng::Rng::new(4);
        let z: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; p.dim()];
        p.apply(0, 0, &z, 1.0, &mut out);
        // definition: (a^T z - y) a
        let shard = &p.partition().shards[0];
        let g = shard.row_dot(0, &z) - p.partition().labels[0][0];
        let mut want = vec![0.0; p.dim()];
        shard.row_axpy(0, g, &mut want);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn paper_closed_form_matches_for_unit_rows() {
        // paper: z = (alpha y + a^T z_in) / (alpha + 1) margin form for
        // ||a|| = 1, lambda = 0
        let ds = SyntheticSpec::tiny().with_regression(true).generate(5);
        let p = RidgeProblem::new(ds.partition(2), 0.0);
        let alpha = 0.7;
        let mut rng = crate::util::rng::Rng::new(6);
        let psi: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        let mut z = vec![0.0; p.dim()];
        let mut c = vec![0.0];
        p.backward(1, 2, alpha, &psi, &mut z, &mut c);
        let shard = &p.partition().shards[1];
        let y = p.partition().labels[1][2];
        let m_paper = (alpha * y + shard.row_dot(2, &psi)) / (alpha + 1.0);
        let mut want = psi.clone();
        shard.row_axpy(2, -alpha * (m_paper - y), &mut want);
        for (a, b) in z.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn objective_decreases_along_gradient_step() {
        let p = problem();
        let z0 = vec![0.1; p.dim()];
        let mut g = vec![0.0; p.dim()];
        let mut acc = vec![0.0; p.dim()];
        for n in 0..p.nodes() {
            p.full_operator(n, &z0, &mut g);
            for (a, gi) in acc.iter_mut().zip(&g) {
                *a += gi;
            }
        }
        let mut z1 = z0.clone();
        crate::linalg::axpy(-0.05, &acc, &mut z1);
        assert!(p.objective(&z1).unwrap() < p.objective(&z0).unwrap());
    }
}
