//! Smoothed-hinge SVM: the classic max-margin loss with a quadratic
//! smoothing band of width `gamma` (Shalev-Shwartz & Zhang's smoothed
//! hinge), as component monotone operators.
//!
//! With margin `u = y a^T z`:
//!
//! ```text
//! l(u) = 0                   u >= 1
//!      = (1 - u)^2 / (2 g)   1 - g < u < 1
//!      = 1 - u - g/2         u <= 1 - g
//! ```
//!
//! `B_{n,i}(z) = l'(u) y a` — one scalar coefficient, bounded by 1, so
//! SAGA tables and sparse deltas work exactly as for logistic.  Unlike
//! logistic, the resolvent is **closed form**: the post-step margin
//! solves the piecewise-linear equation `u + beta c l'(u) = v`, whose
//! three segments are mutually exclusive and exhaustive in `v`, so the
//! backward step needs no Newton iteration at all.

use super::registry::{ProblemEntry, ProblemMeta, ProblemSpec, ResolventKind};
use super::Problem;
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use std::sync::Arc;

/// Registry entry (canonical `smoothed-hinge`): ±1 labels, 1 scalar
/// coefficient, closed-form 3-segment resolvent.  `params`: `gamma` —
/// smoothing band width (default 0.5).
pub(crate) fn entry() -> ProblemEntry {
    fn tuned(method: AlgorithmKind) -> f64 {
        use AlgorithmKind::*;
        // L = c/gamma is ~4x logistic's c/4 at gamma = 0.5: keep the
        // backward methods aggressive, forward baselines conservative
        match method {
            Dsba | DsbaSparse | PointSaga => 1.0,
            PExtra => 2.0,
            Dsa | Extra | Dgd => 0.3,
            Dlm => 0.0, // uses dlm_c / dlm_rho
            Ssda => 0.9,
        }
    }
    fn ctor(
        spec: &ProblemSpec,
        _ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        let gamma = spec.param_f64("gamma").unwrap_or(0.5);
        if !gamma.is_finite() || gamma <= 0.0 {
            return Err(format!(
                "smoothed-hinge: gamma must be finite and > 0, got {gamma}"
            ));
        }
        Ok(Arc::new(SmoothedHingeProblem::new(part, spec.lambda, gamma)))
    }
    ProblemEntry {
        meta: ProblemMeta {
            name: "smoothed-hinge",
            aliases: &["hinge", "svm", "smooth-hinge"],
            summary: "smoothed-hinge SVM (closed-form piecewise resolvent)",
            has_objective: true,
            saddle_stat: None,
            l1: false,
            resolvent: ResolventKind::ClosedForm,
            tail_dims: 0,
            coef_width: 1,
            regression_targets: false,
            params_help: "gamma (default 0.5)",
            tuned_alpha: tuned,
        },
        ctor,
    }
}

/// Decentralized l2-regularized smoothed-hinge SVM.
pub struct SmoothedHingeProblem {
    part: Partition,
    lambda: f64,
    /// smoothing band width (loss is C^1, l'' <= 1/gamma)
    pub gamma: f64,
    row_norm_sq: Vec<Vec<f64>>,
}

impl SmoothedHingeProblem {
    pub fn new(part: Partition, lambda: f64, gamma: f64) -> Self {
        assert!(gamma > 0.0, "smoothing width must be positive");
        let row_norm_sq = part
            .shards
            .iter()
            .map(|s| (0..s.rows).map(|i| s.row_norm_sq(i)).collect())
            .collect();
        SmoothedHingeProblem { part, lambda, gamma, row_norm_sq }
    }

    fn shard(&self, n: usize) -> &crate::linalg::CsrMatrix {
        &self.part.shards[n]
    }

    /// l'(u): 0 above the margin, -1 below the band, linear inside.
    #[inline]
    fn lprime(&self, u: f64) -> f64 {
        if u >= 1.0 {
            0.0
        } else if u <= 1.0 - self.gamma {
            -1.0
        } else {
            (u - 1.0) / self.gamma
        }
    }

    /// l(u) itself (objective evaluation).
    #[inline]
    fn loss(&self, u: f64) -> f64 {
        if u >= 1.0 {
            0.0
        } else if u <= 1.0 - self.gamma {
            1.0 - u - 0.5 * self.gamma
        } else {
            let d = 1.0 - u;
            d * d / (2.0 * self.gamma)
        }
    }
}

impl Problem for SmoothedHingeProblem {
    fn dim(&self) -> usize {
        self.part.dim
    }
    fn feature_dim(&self) -> usize {
        self.part.dim
    }
    fn nodes(&self) -> usize {
        self.part.nodes()
    }
    fn q(&self) -> usize {
        self.part.q
    }
    fn lambda(&self) -> f64 {
        self.lambda
    }
    fn coef_width(&self) -> usize {
        1
    }
    fn partition(&self) -> &Partition {
        &self.part
    }

    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]) {
        let y = self.part.labels[n][i];
        let u = y * self.shard(n).row_dot(i, z);
        out[0] = y * self.lprime(u);
    }

    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]) {
        self.shard(n).row_axpy(i, scale * coefs[0], out);
    }

    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    ) {
        let s = 1.0 / (1.0 + alpha * self.lambda);
        let beta = alpha * s;
        let c = self.row_norm_sq[n][i];
        let y = self.part.labels[n][i];
        let g = self.gamma;
        // v = y a^T psi_hat; the post-step signed margin u solves the
        // increasing piecewise-linear h(u) = u + beta c l'(u) = v:
        //   h(1) = 1 and h(1-g) = 1 - g - beta c, so the three segments
        //   cover v >= 1, v <= 1 - g - beta c, and the band in between
        let v = y * self.shard(n).row_dot(i, psi) * s;
        let u = if v >= 1.0 {
            v
        } else if v <= 1.0 - g - beta * c {
            v + beta * c
        } else {
            (v + beta * c / g) / (1.0 + beta * c / g)
        };
        let e = y * self.lprime(u);
        for (zo, p) in z_out.iter_mut().zip(psi) {
            *zo = s * p;
        }
        self.shard(n).row_axpy(i, -beta * e, z_out);
        coefs_out[0] = e;
    }

    fn objective(&self, z: &[f64]) -> Option<f64> {
        let mut obj = 0.0;
        for n in 0..self.nodes() {
            let shard = self.shard(n);
            let mut local = 0.0;
            for i in 0..self.q() {
                let u = self.part.labels[n][i] * shard.row_dot(i, z);
                local += self.loss(u);
            }
            obj += local / self.q() as f64;
        }
        let znorm: f64 = z.iter().map(|v| v * v).sum();
        obj += 0.5 * self.lambda * self.nodes() as f64 * znorm;
        Some(obj)
    }

    fn l_mu(&self) -> (f64, f64) {
        let cmax = self
            .row_norm_sq
            .iter()
            .flatten()
            .fold(0.0f64, |acc, &c| acc.max(c));
        (cmax / self.gamma + self.lambda, self.lambda)
    }

    fn rebuild(&self, part: Partition) -> Arc<dyn Problem> {
        Arc::new(SmoothedHingeProblem::new(part, self.lambda, self.gamma))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{check_monotone, check_resolvent};
    use crate::util::rng::Rng;

    fn problem() -> SmoothedHingeProblem {
        let ds = SyntheticSpec::tiny().generate(19);
        SmoothedHingeProblem::new(ds.partition(4), 0.05, 0.5)
    }

    #[test]
    fn resolvent_identity_holds() {
        check_resolvent(&problem(), 0.4, 1, 50).unwrap();
        check_resolvent(&problem(), 4.0, 2, 50).unwrap();
        // narrow band: the piecewise solve must stay exact
        let ds = SyntheticSpec::tiny().generate(23);
        let narrow = SmoothedHingeProblem::new(ds.partition(3), 0.01, 0.05);
        check_resolvent(&narrow, 1.0, 3, 50).unwrap();
    }

    #[test]
    fn components_monotone() {
        check_monotone(&problem(), 3, 100).unwrap();
    }

    #[test]
    fn coef_bounded_by_one() {
        let p = problem();
        let mut rng = Rng::new(5);
        let mut c = vec![0.0];
        for _ in 0..50 {
            let z: Vec<f64> = (0..p.dim()).map(|_| 3.0 * rng.normal()).collect();
            p.coefs(0, rng.below(p.q()), &z, &mut c);
            assert!(c[0].abs() <= 1.0);
        }
    }

    #[test]
    fn loss_and_gradient_are_continuous_at_the_kinks() {
        let p = problem();
        let eps = 1e-9;
        for kink in [1.0, 1.0 - p.gamma] {
            let (lo, hi) = (p.loss(kink - eps), p.loss(kink + eps));
            assert!((lo - hi).abs() < 1e-8, "loss jumps at {kink}: {lo} vs {hi}");
            let (dlo, dhi) = (p.lprime(kink - eps), p.lprime(kink + eps));
            assert!((dlo - dhi).abs() < 1e-7, "l' jumps at {kink}: {dlo} vs {dhi}");
        }
        // exact values at the band edges
        assert_eq!(p.loss(1.0), 0.0);
        assert!((p.loss(1.0 - p.gamma) - 0.5 * p.gamma).abs() < 1e-15);
    }

    #[test]
    fn backward_hits_each_segment() {
        // drive v into all three segments and verify the defining
        // equation u + beta c l'(u) = v directly
        let ds = SyntheticSpec::tiny().generate(29);
        let p = SmoothedHingeProblem::new(ds.partition(2), 0.05, 0.5);
        let alpha = 1.5;
        let s = 1.0 / (1.0 + alpha * p.lambda());
        let beta = alpha * s;
        let mut z = vec![0.0; p.dim()];
        let mut cf = vec![0.0];
        for scale in [-40.0, -1.0, -0.2, 0.0, 0.2, 1.0, 40.0] {
            let (n, i) = (0, 3);
            let row = p.partition().shards[n].row_sparse(i);
            let y = p.partition().labels[n][i];
            // psi proportional to the data row steers the margin
            let mut psi = vec![0.0; p.dim()];
            row.axpy_into(scale * y, &mut psi);
            p.backward(n, i, alpha, &psi, &mut z, &mut cf);
            let c = row.norm_sq();
            let u = y * row.dot_dense(&z);
            let v = y * row.dot_dense(&psi) * s;
            let h = u + beta * c * p.lprime(u);
            assert!(
                (h - v).abs() < 1e-9 * (1.0 + v.abs()),
                "scale {scale}: h(u) = {h} != v = {v}"
            );
        }
    }

    #[test]
    fn solvable_to_high_accuracy_by_the_generic_presolve() {
        let ds = SyntheticSpec::tiny().generate(31);
        let p = SmoothedHingeProblem::new(ds.partition(3), 0.05, 0.5);
        let z = crate::coordinator::solve_optimum(&p, 1e-9);
        assert!(p.global_residual(&z) < 1e-8, "residual {}", p.global_residual(&z));
        // the optimum classifies better than the zero vector
        let obj_star = p.objective(&z).unwrap();
        let obj_zero = p.objective(&vec![0.0; p.dim()]).unwrap();
        assert!(obj_star < obj_zero, "{obj_star} !< {obj_zero}");
    }
}
