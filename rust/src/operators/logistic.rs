//! Logistic regression operators (paper §7.2 and appendix §9.6).
//!
//! `B_{n,i}(z) = -y / (1 + exp(y a^T z)) a` — coefficient
//! `e(m) = -y sigmoid(-y m)`.  The resolvent has no closed form; the
//! post-step margin solves the 1-D equation `m + beta c e(m) = a^T
//! psi_hat`, which we solve with safeguarded Newton (the paper's (73)
//! generalized to `||a||^2 = c`; 20 iterations suffice, as the paper
//! notes).

use super::registry::{ProblemEntry, ProblemMeta, ProblemSpec, ResolventKind};
use super::Problem;
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use std::sync::Arc;

/// Registry entry (canonical `logistic`): ±1 labels, 1 scalar
/// coefficient, safeguarded-Newton resolvent.
pub(crate) fn entry() -> ProblemEntry {
    fn tuned(method: AlgorithmKind) -> f64 {
        use AlgorithmKind::*;
        match method {
            Dsba | DsbaSparse | PointSaga => 2.0,
            Dsa => 1.0,
            Extra => 1.8,
            PExtra => 4.0,
            Dlm => 0.0, // uses dlm_c / dlm_rho
            Ssda => 0.9,
            Dgd => 1.5,
        }
    }
    fn ctor(
        spec: &ProblemSpec,
        _ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        Ok(Arc::new(LogisticProblem::new(part, spec.lambda)))
    }
    ProblemEntry {
        meta: ProblemMeta {
            name: "logistic",
            aliases: &["logreg", "log"],
            summary: "decentralized l2-regularized logistic regression (paper §7.2)",
            has_objective: true,
            saddle_stat: None,
            l1: false,
            resolvent: ResolventKind::Newton,
            tail_dims: 0,
            coef_width: 1,
            regression_targets: false,
            params_help: "-",
            tuned_alpha: tuned,
        },
        ctor,
    }
}

/// Decentralized l2-regularized logistic regression.
pub struct LogisticProblem {
    part: Partition,
    lambda: f64,
    pub newton_iters: usize,
    row_norm_sq: Vec<Vec<f64>>,
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

impl LogisticProblem {
    pub fn new(part: Partition, lambda: f64) -> Self {
        let row_norm_sq = part
            .shards
            .iter()
            .map(|s| (0..s.rows).map(|i| s.row_norm_sq(i)).collect())
            .collect();
        LogisticProblem { part, lambda, newton_iters: 20, row_norm_sq }
    }

    fn shard(&self, n: usize) -> &crate::linalg::CsrMatrix {
        &self.part.shards[n]
    }

    /// gradient coefficient e(m) = -y sigmoid(-y m)
    #[inline]
    fn coef_at(&self, y: f64, m: f64) -> f64 {
        -y * sigmoid(-y * m)
    }
}

impl Problem for LogisticProblem {
    fn dim(&self) -> usize {
        self.part.dim
    }
    fn feature_dim(&self) -> usize {
        self.part.dim
    }
    fn nodes(&self) -> usize {
        self.part.nodes()
    }
    fn q(&self) -> usize {
        self.part.q
    }
    fn lambda(&self) -> f64 {
        self.lambda
    }
    fn coef_width(&self) -> usize {
        1
    }
    fn partition(&self) -> &Partition {
        &self.part
    }

    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]) {
        let m = self.shard(n).row_dot(i, z);
        out[0] = self.coef_at(self.part.labels[n][i], m);
    }

    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]) {
        self.shard(n).row_axpy(i, scale * coefs[0], out);
    }

    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    ) {
        let s = 1.0 / (1.0 + alpha * self.lambda);
        let beta = alpha * s;
        let c = self.row_norm_sq[n][i];
        let y = self.part.labels[n][i];
        let b = self.shard(n).row_dot(i, psi) * s; // a^T psi_hat

        // solve h(m) = m + beta c e(m) - b = 0 by safeguarded Newton.
        // h' = 1 + beta c e'(m) >= 1 since e' = sigmoid'(-ym) >= 0.
        let mut m = b; // good initial guess: ignore the operator term
        for _ in 0..self.newton_iters {
            let e = self.coef_at(y, m);
            let sig = -y * e; // sigmoid(-y m)
            let eprime = sig * (1.0 - sig); // = sigma'(-ym), y^2 = 1
            let h = m + beta * c * e - b;
            if h.abs() < 1e-15 {
                break;
            }
            m -= h / (1.0 + beta * c * eprime);
        }
        let e = self.coef_at(y, m);
        for (zo, p) in z_out.iter_mut().zip(psi) {
            *zo = s * p;
        }
        self.shard(n).row_axpy(i, -beta * e, z_out);
        coefs_out[0] = e;
    }

    fn objective(&self, z: &[f64]) -> Option<f64> {
        let mut obj = 0.0;
        for n in 0..self.nodes() {
            let shard = self.shard(n);
            let mut local = 0.0;
            for i in 0..self.q() {
                let ym = self.part.labels[n][i] * shard.row_dot(i, z);
                // log(1 + exp(-ym)), stable
                local += if ym > 0.0 {
                    (-ym).exp().ln_1p()
                } else {
                    -ym + ym.exp().ln_1p()
                };
            }
            obj += local / self.q() as f64;
        }
        let znorm: f64 = z.iter().map(|v| v * v).sum();
        obj += 0.5 * self.lambda * self.nodes() as f64 * znorm;
        Some(obj)
    }

    fn l_mu(&self) -> (f64, f64) {
        let cmax = self
            .row_norm_sq
            .iter()
            .flatten()
            .fold(0.0f64, |acc, &c| acc.max(c));
        (0.25 * cmax + self.lambda, self.lambda)
    }

    fn rebuild(&self, part: Partition) -> Arc<dyn Problem> {
        let mut p = LogisticProblem::new(part, self.lambda);
        p.newton_iters = self.newton_iters;
        Arc::new(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{check_monotone, check_resolvent};

    fn problem() -> LogisticProblem {
        let ds = SyntheticSpec::tiny().generate(13);
        LogisticProblem::new(ds.partition(4), 0.05)
    }

    #[test]
    fn resolvent_identity_holds() {
        check_resolvent(&problem(), 0.5, 1, 50).unwrap();
        check_resolvent(&problem(), 5.0, 2, 50).unwrap();
    }

    #[test]
    fn components_monotone() {
        check_monotone(&problem(), 3, 100).unwrap();
    }

    #[test]
    fn coef_bounded_by_one() {
        let p = problem();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut c = vec![0.0];
        for _ in 0..50 {
            let z: Vec<f64> = (0..p.dim()).map(|_| 3.0 * rng.normal()).collect();
            p.coefs(0, rng.below(p.q()), &z, &mut c);
            assert!(c[0].abs() <= 1.0);
        }
    }

    #[test]
    fn newton_converges_on_extreme_margins() {
        let p = problem();
        let alpha = 2.0;
        let mut z = vec![0.0; p.dim()];
        let mut c = vec![0.0];
        // huge psi => huge margins; identity must still hold
        let psi: Vec<f64> = (0..p.dim()).map(|k| ((k % 7) as f64 - 3.0) * 50.0).collect();
        p.backward(1, 0, alpha, &psi, &mut z, &mut c);
        let mut recon: Vec<f64> = z.iter().map(|v| v * (1.0 + alpha * p.lambda())).collect();
        p.apply(1, 0, &z, alpha, &mut recon);
        let err: f64 = recon
            .iter()
            .zip(&psi)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-7, "err {err}");
    }

    #[test]
    fn objective_matches_naive_small() {
        let p = problem();
        let z = vec![0.01; p.dim()];
        let mut naive = 0.0;
        for n in 0..p.nodes() {
            for i in 0..p.q() {
                let m = p.partition().shards[n].row_dot(i, &z);
                naive += (1.0 + (-p.partition().labels[n][i] * m).exp()).ln()
                    / p.q() as f64;
            }
        }
        naive += 0.5 * p.lambda() * p.nodes() as f64
            * z.iter().map(|v| v * v).sum::<f64>();
        assert!((p.objective(&z).unwrap() - naive).abs() < 1e-10);
    }
}
