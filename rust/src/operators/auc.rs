//! l2-relaxed AUC maximization as a saddle-point monotone operator
//! (paper §3.2, §7.3, appendix §9.7).
//!
//! The augmented variable is `z = [w; a; b; theta] in R^{d+3}`.  Component
//! operators are eqs. (75) (positive samples) and (76) (negative
//! samples); each output is `[c1 * a_{n,i}; c2; c3; c4]` with four
//! margin-dependent scalars, so SAGA tables stay `O(q)` scalars and the
//! communicated deltas stay sparse (+3 dense tail entries).
//!
//! The resolvent reduces to a 4x4 linear solve in `(m, a, b, theta)`
//! (appendix eqs. (77)-(82), generalized to `||a_{n,i}||^2 = c`).

use super::registry::{ProblemEntry, ProblemMeta, ProblemSpec, ResolventKind};
use super::{Problem, SaddleStat, SaddleStructure};
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use crate::linalg::DenseMatrix;
use std::sync::Arc;

/// Registry entry (canonical `auc`): saddle problem (no objective —
/// scored by the AUC ranking statistic through the generic saddle
/// subsystem), 3 dense tail dims, 4 scalar coefficients per component.
pub(crate) fn entry() -> ProblemEntry {
    fn tuned(method: AlgorithmKind) -> f64 {
        use AlgorithmKind::*;
        match method {
            Dsba | DsbaSparse => 0.5,
            Dlm => 0.0, // uses dlm_c / dlm_rho
            _ => 0.05,
        }
    }
    fn ctor(
        spec: &ProblemSpec,
        _ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        Ok(Arc::new(AucProblem::new(part, spec.lambda)))
    }
    ProblemEntry {
        meta: ProblemMeta {
            name: "auc",
            aliases: &["auc-max"],
            summary: "l2-relaxed AUC maximization saddle operator (paper §7.3)",
            has_objective: false,
            saddle_stat: Some(SaddleStat::AucRanking),
            l1: false,
            resolvent: ResolventKind::ClosedForm,
            tail_dims: 3,
            coef_width: 4,
            regression_targets: false,
            params_help: "-",
            tuned_alpha: tuned,
        },
        ctor,
    }
}

/// Decentralized l2-relaxed AUC maximization.
pub struct AucProblem {
    part: Partition,
    lambda: f64,
    /// global positive ratio `p`
    pub p: f64,
    row_norm_sq: Vec<Vec<f64>>,
    /// numerically estimated smoothness of the raw components
    l_estimate: f64,
}

impl AucProblem {
    pub fn new(part: Partition, lambda: f64) -> Self {
        let p = part.positive_ratio;
        let row_norm_sq: Vec<Vec<f64>> = part
            .shards
            .iter()
            .map(|s| (0..s.rows).map(|i| s.row_norm_sq(i)).collect())
            .collect();
        let cmax = row_norm_sq
            .iter()
            .flatten()
            .fold(0.0f64, |acc, &c| acc.max(c));
        // analytic bound on the block Jacobian of (75)/(76): entries are
        // products of {2p, 2(1-p)} with {c, sqrt(c), 1}; the spectral norm
        // is bounded by 2 max(p, 1-p) (c + 2 sqrt(c) + 1) = 2 max(p,1-p)
        // (sqrt(c)+1)^2.
        let k = 2.0 * p.max(1.0 - p);
        let l_estimate = k * (cmax.sqrt() + 1.0) * (cmax.sqrt() + 1.0);
        AucProblem { part, lambda, p, row_norm_sq, l_estimate }
    }

    fn shard(&self, n: usize) -> &crate::linalg::CsrMatrix {
        &self.part.shards[n]
    }

    #[inline]
    fn d(&self) -> usize {
        self.part.dim
    }

    /// Raw coefficients (c1..c4) at margin `m` and tail `(a, b, theta)`.
    #[inline]
    fn coefs_at(&self, y: f64, m: f64, a: f64, b: f64, theta: f64) -> [f64; 4] {
        let p = self.p;
        if y > 0.0 {
            let k = 2.0 * (1.0 - p);
            [
                k * ((m - a) - (1.0 + theta)),
                -k * (m - a),
                0.0,
                2.0 * p * (1.0 - p) * theta + k * m,
            ]
        } else {
            let h = 2.0 * p;
            [
                h * ((m - b) + (1.0 + theta)),
                0.0,
                -h * (m - b),
                2.0 * p * (1.0 - p) * theta - h * m,
            ]
        }
    }
}

impl Problem for AucProblem {
    fn dim(&self) -> usize {
        self.d() + 3
    }
    fn feature_dim(&self) -> usize {
        self.d()
    }
    fn nodes(&self) -> usize {
        self.part.nodes()
    }
    fn q(&self) -> usize {
        self.part.q
    }
    fn lambda(&self) -> f64 {
        self.lambda
    }
    fn coef_width(&self) -> usize {
        4
    }
    fn partition(&self) -> &Partition {
        &self.part
    }

    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]) {
        let d = self.d();
        let m = self.shard(n).row_dot(i, z);
        let c = self.coefs_at(self.part.labels[n][i], m, z[d], z[d + 1], z[d + 2]);
        out.copy_from_slice(&c);
    }

    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]) {
        let d = self.d();
        self.shard(n).row_axpy(i, scale * coefs[0], out);
        out[d] += scale * coefs[1];
        out[d + 1] += scale * coefs[2];
        out[d + 2] += scale * coefs[3];
    }

    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    ) {
        let d = self.d();
        let s = 1.0 / (1.0 + alpha * self.lambda);
        let beta = alpha * s;
        let c = self.row_norm_sq[n][i];
        let y = self.part.labels[n][i];
        let p = self.p;
        let t2 = 2.0 * p * (1.0 - p);
        // psi_hat components
        let bw = self.shard(n).row_dot(i, psi) * s; // x^T psi_hat_w
        let (pa, pb, pt) = (s * psi[d], s * psi[d + 1], s * psi[d + 2]);

        // solve the 4x4 system in v = [m, a, b, theta]
        let (mat, rhs) = if y > 0.0 {
            let k = 2.0 * (1.0 - p);
            (
                DenseMatrix::from_rows(vec![
                    vec![1.0 + beta * c * k, -beta * c * k, 0.0, -beta * c * k],
                    vec![-beta * k, 1.0 + beta * k, 0.0, 0.0],
                    vec![0.0, 0.0, 1.0, 0.0],
                    vec![beta * k, 0.0, 0.0, 1.0 + beta * t2],
                ]),
                vec![bw + beta * c * k, pa, pb, pt],
            )
        } else {
            let h = 2.0 * p;
            (
                DenseMatrix::from_rows(vec![
                    vec![1.0 + beta * c * h, 0.0, -beta * c * h, beta * c * h],
                    vec![0.0, 1.0, 0.0, 0.0],
                    vec![-beta * h, 0.0, 1.0 + beta * h, 0.0],
                    vec![-beta * h, 0.0, 0.0, 1.0 + beta * t2],
                ]),
                vec![bw - beta * c * h, pa, pb, pt],
            )
        };
        let v = mat
            .solve(&rhs)
            .expect("AUC resolvent system is nonsingular for alpha > 0");
        let (m, a_new, b_new, th_new) = (v[0], v[1], v[2], v[3]);
        let cf = self.coefs_at(y, m, a_new, b_new, th_new);

        // w' = psi_hat_w - beta c1 x ; tail set to solved values
        for k in 0..d {
            z_out[k] = s * psi[k];
        }
        self.shard(n).row_axpy(i, -beta * cf[0], &mut z_out[..d]);
        z_out[d] = a_new;
        z_out[d + 1] = b_new;
        z_out[d + 2] = th_new;
        coefs_out.copy_from_slice(&cf);
    }

    /// Saddle problems have no primal objective to report; the AUC
    /// statistic is computed by `metrics::auc_score`.
    fn objective(&self, _z: &[f64]) -> Option<f64> {
        None
    }

    fn l_mu(&self) -> (f64, f64) {
        (self.l_estimate + self.lambda, self.lambda)
    }

    fn rebuild(&self, part: Partition) -> Arc<dyn Problem> {
        Arc::new(AucProblem::new(part, self.lambda))
    }

    /// AUC is a client of the generic saddle subsystem: min over
    /// `(w, a, b)` (the leading `d + 2` coordinates), max over `theta`
    /// (the last), scored by the ranking statistic. The legacy
    /// `auc_metric()` shim derives from this declaration.
    fn saddle(&self) -> Option<SaddleStructure> {
        Some(SaddleStructure {
            primal_dims: self.d() + 2,
            dual_dims: 1,
            stat: SaddleStat::AucRanking,
        })
    }

    /// The l2-relaxed AUC saddle function (Ying et al.'s F, per-sample
    /// form behind eqs. (75)/(76)):
    /// `(1-p)(m-a)^2 - 2(1-p)(1+theta) m - p(1-p) theta^2` for positives,
    /// `p(m-b)^2 + 2p(1+theta) m - p(1-p) theta^2` for negatives,
    /// averaged per node and summed, plus the analytic
    /// `N lambda/2 (||w,a,b||^2 - theta^2)` split.
    fn saddle_value(&self, z: &[f64]) -> Option<f64> {
        let d = self.d();
        let p = self.p;
        let (a, b, theta) = (z[d], z[d + 1], z[d + 2]);
        let mut total = 0.0;
        for n in 0..self.nodes() {
            let shard = self.shard(n);
            let mut local = 0.0;
            for i in 0..self.q() {
                let m = shard.row_dot(i, z);
                let class_term = if self.part.labels[n][i] > 0.0 {
                    let dm = m - a;
                    (1.0 - p) * dm * dm - 2.0 * (1.0 - p) * (1.0 + theta) * m
                } else {
                    let dm = m - b;
                    p * dm * dm + 2.0 * p * (1.0 + theta) * m
                };
                local += class_term - p * (1.0 - p) * theta * theta;
            }
            total += local / self.q() as f64;
        }
        let primal_sq: f64 = z[..d + 2].iter().map(|v| v * v).sum();
        total += self.nodes() as f64 * self.lambda / 2.0 * (primal_sq - theta * theta);
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{check_monotone, check_resolvent};

    fn problem() -> AucProblem {
        let ds = SyntheticSpec::tiny().generate(21);
        AucProblem::new(ds.partition(4), 0.05)
    }

    #[test]
    fn resolvent_identity_holds() {
        check_resolvent(&problem(), 0.4, 1, 50).unwrap();
        check_resolvent(&problem(), 4.0, 2, 50).unwrap();
    }

    #[test]
    fn components_monotone() {
        // per-sample saddle operator of a convex-concave function
        check_monotone(&problem(), 3, 200).unwrap();
    }

    #[test]
    fn saddle_declaration_consistent_with_operator() {
        // AUC as a *client* of the generic saddle subsystem: the declared
        // split covers the variable, the shim derives the ranking stat,
        // and the saddle function's gradient field is the operator
        let p = problem();
        let s = p.saddle().expect("AUC declares a saddle split");
        assert_eq!(s.primal_dims, p.feature_dim() + 2);
        assert_eq!(s.dual_dims, 1);
        assert!(p.auc_metric());
        crate::operators::check_saddle(&p, 11, 10).unwrap();
    }

    #[test]
    fn positive_sample_leaves_b_fixed() {
        let p = problem();
        // find a positive sample
        let (n, i) = (0..p.nodes())
            .flat_map(|n| (0..p.q()).map(move |i| (n, i)))
            .find(|&(n, i)| p.partition().labels[n][i] > 0.0)
            .unwrap();
        let mut rng = crate::util::rng::Rng::new(9);
        let psi: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        let mut z = vec![0.0; p.dim()];
        let mut c = vec![0.0; 4];
        let lam = p.lambda();
        let alpha = 0.8;
        p.backward(n, i, alpha, &psi, &mut z, &mut c);
        // b' = psi_b / (1 + alpha lambda) (b untouched by positive op)
        let want_b = psi[p.dim() - 2] / (1.0 + alpha * lam);
        assert!((z[p.dim() - 2] - want_b).abs() < 1e-12);
        assert_eq!(c[2], 0.0);
    }

    #[test]
    fn coefs_match_kernel_reference_formulas() {
        // mirror of python/compile/kernels/ref.py::auc_coefs_ref
        let p = problem();
        let d = p.feature_dim();
        let mut rng = crate::util::rng::Rng::new(4);
        let z: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        let mut c = vec![0.0; 4];
        for n in 0..p.nodes() {
            for i in 0..p.q() {
                p.coefs(n, i, &z, &mut c);
                let y = p.partition().labels[n][i];
                let m = p.partition().shards[n].row_dot(i, &z);
                let (a, b, th) = (z[d], z[d + 1], z[d + 2]);
                let pr = p.p;
                let want = if y > 0.0 {
                    [
                        2.0 * (1.0 - pr) * ((m - a) - (1.0 + th)),
                        -2.0 * (1.0 - pr) * (m - a),
                        0.0,
                        2.0 * pr * (1.0 - pr) * th + 2.0 * (1.0 - pr) * m,
                    ]
                } else {
                    [
                        2.0 * pr * ((m - b) + (1.0 + th)),
                        0.0,
                        -2.0 * pr * (m - b),
                        2.0 * pr * (1.0 - pr) * th - 2.0 * pr * m,
                    ]
                };
                for (got, w) in c.iter().zip(&want) {
                    assert!((got - w).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn root_of_global_operator_ranks_positives_higher() {
        // drive the (regularized) operator near its root with single-node
        // backward steps and check AUC > 0.5 — the operator formulation
        // must actually maximize AUC
        let ds = SyntheticSpec::tiny().with_samples(200).generate(33);
        let p = AucProblem::new(ds.partition(1), 0.01);
        let mut z = vec![0.0; p.dim()];
        let mut coefs = vec![0.0; 4];
        let mut rng = crate::util::rng::Rng::new(2);
        let mut phi = vec![vec![0.0f64; 4]; p.q()];
        let mut phibar = vec![0.0; p.dim()];
        for i in 0..p.q() {
            let mut c = vec![0.0; 4];
            p.coefs(0, i, &z, &mut c);
            phi[i].copy_from_slice(&c);
            p.scatter(0, i, &c, 1.0 / p.q() as f64, &mut phibar);
        }
        let alpha = 0.5;
        // point-SAGA iterations
        for _ in 0..40 * p.q() {
            let i = rng.below(p.q());
            let mut psi = z.clone();
            p.scatter(0, i, &phi[i], alpha, &mut psi);
            for (ps, pb) in psi.iter_mut().zip(&phibar) {
                *ps -= alpha * pb;
            }
            p.backward(0, i, alpha, &psi, &mut z.clone(), &mut coefs);
            let mut znew = vec![0.0; p.dim()];
            p.backward(0, i, alpha, &psi, &mut znew, &mut coefs);
            z = znew;
            // table update
            let delta: Vec<f64> =
                coefs.iter().zip(&phi[i]).map(|(a, b)| a - b).collect();
            p.scatter(0, i, &delta, 1.0 / p.q() as f64, &mut phibar);
            phi[i].copy_from_slice(&coefs);
        }
        let auc = crate::metrics::auc_score(p.partition(), &z);
        assert!(auc > 0.8, "AUC {auc}");
    }
}
