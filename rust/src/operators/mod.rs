//! Monotone operators and their resolvents (paper §3–§4).
//!
//! Every learning problem is expressed as a sum of component monotone
//! operators `B_{n,i}` held at node `n`.  For linear predictors the
//! component output is fully described by a few *scalar coefficients*
//! applied to the data row (plus a small dense tail for the AUC saddle
//! operator) — the structure behind both the `O(q)`-scalar SAGA table
//! (Schmidt et al., 2017) and the sparse deltas of the §5.1 communication
//! protocol.
//!
//! The l2 regularization of §7 is *not* baked into the raw components
//! (that would densify the deltas); it is applied through the resolvent
//! identity `J_{alpha B^lambda}(psi) = J_{beta B}(psi / (1+alpha lambda))`
//! with `beta = alpha/(1 + alpha lambda)`, and added analytically wherever
//! a forward evaluation of `B^lambda` is needed.

mod ridge;
mod logistic;
mod auc;
mod elastic_net;
mod hinge;
mod robust_ls;
mod dro;
pub mod registry;

pub use auc::AucProblem;
pub use dro::DroBilinearProblem;
pub use elastic_net::ElasticNetProblem;
pub use hinge::SmoothedHingeProblem;
pub use logistic::LogisticProblem;
pub use registry::{
    ProblemEntry, ProblemMeta, ProblemRegistry, ProblemSpec, ResolventKind,
};
pub use ridge::RidgeProblem;
pub use robust_ls::RobustLsProblem;

use crate::data::Partition;
use std::sync::Arc;

/// How the metrics layer scores iterates of a saddle problem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SaddleStat {
    /// Generic merit: the saddle (first-order optimality) residual
    /// [`Problem::global_residual`], reported by the metrics layer as
    /// `saddle_res` — 0 exactly at the saddle point.
    Residual,
    /// The AUC ranking statistic (§7.3's workload-specific score; the
    /// saddle residual is still reported alongside it).
    AucRanking,
}

/// Declared primal/dual coordinate split of a saddle (minimax) problem.
///
/// The augmented variable is laid out `z = [x; y]` with the **leading**
/// `primal_dims` coordinates holding the min block and the **trailing**
/// `dual_dims` coordinates the max block, so the component operators are
/// `B_{n,i} = [grad_x L_{n,i}; -grad_y L_{n,i}]` and the framework's
/// analytic l2 term `lambda z` regularizes the saddle function as
/// `+ lambda/2 ||x||^2 - lambda/2 ||y||^2` (what makes the operator
/// strongly monotone). AUC declares `primal = d + 2` (w, a, b) and
/// `dual = 1` (theta).
///
/// Note the §5.1 sparse relay additionally requires the coefficient
/// layout shared by every workload here: `coefs[0]` scales the data row
/// into the feature block and `coefs[1..]` map one-to-one onto the
/// dense tail, so declaring a saddle split never changes the wire
/// format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SaddleStructure {
    /// leading coordinates of `z` holding the primal (min) block
    pub primal_dims: usize,
    /// trailing coordinates holding the dual (max) block
    pub dual_dims: usize,
    /// statistic the metrics layer scores iterates with
    pub stat: SaddleStat,
}

impl SaddleStructure {
    /// Split a full iterate into its (primal, dual) blocks.
    pub fn split<'a>(&self, z: &'a [f64]) -> (&'a [f64], &'a [f64]) {
        z.split_at(self.primal_dims)
    }
}

/// A decentralized monotone-operator root-finding problem (13).
pub trait Problem: Send + Sync {
    /// Total variable dimension `D` (= d for minimization, d+3 for AUC).
    fn dim(&self) -> usize;
    /// Feature dimension `d` (sparse block of the variable).
    fn feature_dim(&self) -> usize;
    /// Dense tail dimensions (0, or 3 for AUC's `[a, b, theta]`).
    fn tail_dims(&self) -> usize {
        self.dim() - self.feature_dim()
    }
    /// Number of nodes `N`.
    fn nodes(&self) -> usize;
    /// Components per node `q`.
    fn q(&self) -> usize;
    /// l2 regularization weight `lambda` (the operator solved for the
    /// root is `sum_n (B_n + lambda I)`).
    fn lambda(&self) -> f64;
    /// Scalar coefficients per component (1 for ridge/logistic, 4 for AUC).
    fn coef_width(&self) -> usize;

    /// Access to the underlying partition (shards/labels).
    fn partition(&self) -> &Partition;

    /// Raw (unregularized) coefficients of `B_{n,i}` at `z`.
    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]);

    /// `out += scale * B_{n,i}[coefs]` — scatter a coefficient-encoded
    /// operator output. `O(nnz + tail)`.
    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]);

    /// Backward step `z = J_{alpha (B_{n,i} + lambda I)}(psi)`.
    /// Writes the new iterate into `z_out` (len `dim()`) and the raw
    /// coefficients of `B_{n,i}(z)` *at the new point* into `coefs_out`.
    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    );

    /// Global objective for metrics (None for saddle problems, which
    /// are scored through the saddle merit layer instead: the residual,
    /// the restricted gap via [`Problem::saddle_value`], and — for AUC —
    /// the ranking statistic).
    fn objective(&self, z: &[f64]) -> Option<f64>;

    /// (L, mu) of the regularized components `B_{n,i} + lambda I`
    /// (smooth part for problems with an l1 term).
    fn l_mu(&self) -> (f64, f64);

    /// Rebuild this problem on a different partition with identical
    /// hyper-parameters — the coordinator's pooled-twin optimum
    /// pre-solve uses this instead of guessing the concrete type.
    fn rebuild(&self, part: Partition) -> Arc<dyn Problem>;

    /// Weight of a separable l1 term `l1 ||z||_1` folded into each
    /// component operator.  It is handled *proximally*: `backward`
    /// resolves it through its soft-threshold resolvent, while the
    /// coefficient-encoded forward path (`coefs`/`scatter`/`apply`)
    /// covers the smooth part only — mirroring how `lambda` is applied
    /// analytically rather than baked into the raw components.  The
    /// effective global operator gains `N * l1 * d||z||_1`, which
    /// [`check_resolvent`] and [`Problem::global_residual`] account for.
    fn l1_weight(&self) -> f64 {
        0.0
    }

    /// Declared primal/dual split of a saddle (minimax) problem; `None`
    /// for pure minimization. The generic capability behind the saddle
    /// merit layer: the coordinator reports the saddle residual (and the
    /// restricted duality gap when [`Problem::saddle_value`] is
    /// available) for every problem that declares a split, and scores
    /// with the AUC statistic only when the declared
    /// [`SaddleStructure::stat`] asks for it.
    fn saddle(&self) -> Option<SaddleStructure> {
        None
    }

    /// Global saddle function value
    /// `L(z) = sum_n (1/q) sum_i L_{n,i}(z) + N lambda/2 (||x||^2 - ||y||^2)`
    /// (regularization included analytically, mirroring
    /// [`Problem::objective`]'s convention), so the global operator is
    /// exactly `[grad_x L; -grad_y L]` — pinned numerically by
    /// [`check_saddle`]. `None` when not cheaply evaluable; used by the
    /// metrics layer for the restricted duality gap
    /// `L(x, y*) - L(x*, y)`.
    fn saddle_value(&self, z: &[f64]) -> Option<f64> {
        let _ = z;
        None
    }

    /// Thin shim kept for saddle-subsystem clients: scored by the AUC
    /// ranking statistic iff the declared [`SaddleStructure::stat`] says
    /// so. Derived — problems declare [`Problem::saddle`] instead of
    /// overriding this.
    fn auc_metric(&self) -> bool {
        self.saddle().is_some_and(|s| s.stat == SaddleStat::AucRanking)
    }

    // ---- provided ----

    /// `out += scale * B_{n,i}(z)` (raw forward evaluation).
    fn apply(&self, n: usize, i: usize, z: &[f64], scale: f64, out: &mut [f64]) {
        let mut c = vec![0.0; self.coef_width()];
        self.coefs(n, i, z, &mut c);
        self.scatter(n, i, &c, scale, out);
    }

    /// Full raw local operator mean `(1/q) sum_i B_{n,i}(z)` into `out`
    /// (overwrites). The deterministic baselines' inner loop.
    fn full_raw_mean(&self, n: usize, z: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        let scale = 1.0 / self.q() as f64;
        for i in 0..self.q() {
            self.apply(n, i, z, scale, out);
        }
    }

    /// Regularized full local operator `B_n(z) + lambda z` (overwrites).
    fn full_operator(&self, n: usize, z: &[f64], out: &mut [f64]) {
        self.full_raw_mean(n, z, out);
        let lam = self.lambda();
        for (o, zi) in out.iter_mut().zip(z) {
            *o += lam * zi;
        }
    }

    /// Optimality residual of (13): `|| sum_n (B_n(z) + lambda z) ||`
    /// for smooth problems, and the KKT inclusion residual
    /// `dist(-sum_n(B_n + lambda z), N l1 d||z||_1)` when an l1 term is
    /// present.  0 exactly at the solution either way.  Used by optimum
    /// pre-solves and convergence checks.
    fn global_residual(&self, z: &[f64]) -> f64 {
        let mut acc = vec![0.0; self.dim()];
        let mut tmp = vec![0.0; self.dim()];
        for n in 0..self.nodes() {
            self.full_operator(n, z, &mut tmp);
            for (a, t) in acc.iter_mut().zip(&tmp) {
                *a += t;
            }
        }
        l1_kkt_residual(z, &acc, self.nodes() as f64 * self.l1_weight())
    }

    /// nnz of the sparse part of component (n,i)'s output — the §5.1
    /// delta communication payload (values; tail adds `tail_dims()`).
    fn delta_nnz(&self, n: usize, i: usize) -> usize {
        self.partition().shards[n].row_nnz(i) + self.tail_dims()
    }

    /// Condition number `kappa = L / mu` of the regularized components.
    fn kappa(&self) -> f64 {
        let (l, mu) = self.l_mu();
        l / mu
    }
}

/// KKT residual of the inclusion `0 in g + t d||z||_1`: the Euclidean
/// distance from `-g` to the (scaled) l1 subdifferential at `z`.
/// Reduces to `||g||` at `t = 0`.
pub fn l1_kkt_residual(z: &[f64], g: &[f64], t: f64) -> f64 {
    if t == 0.0 {
        return crate::linalg::norm2(g);
    }
    let mut acc = 0.0;
    for (&zk, &gk) in z.iter().zip(g) {
        let s = if zk != 0.0 {
            gk + t * zk.signum()
        } else {
            (gk.abs() - t).max(0.0)
        };
        acc += s * s;
    }
    acc.sqrt()
}

/// Numerically verify monotonicity of components at random pairs —
/// shared test/diagnostic helper.  Covers the coefficient-encoded
/// (smooth) part of the operators; a declared l1 term is itself
/// monotone and checked separately through [`check_resolvent`]'s
/// subdifferential inclusion.
pub fn check_monotone<P: Problem + ?Sized>(
    p: &P,
    seed: u64,
    trials: usize,
) -> Result<(), String> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let dim = p.dim();
    for t in 0..trials {
        let n = rng.below(p.nodes());
        let i = rng.below(p.q());
        let x: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut bx = vec![0.0; dim];
        let mut by = vec![0.0; dim];
        p.apply(n, i, &x, 1.0, &mut bx);
        p.apply(n, i, &y, 1.0, &mut by);
        let lam = p.lambda();
        let mut inner = 0.0;
        let mut dist = 0.0;
        for k in 0..dim {
            let dz = x[k] - y[k];
            let db = (bx[k] + lam * x[k]) - (by[k] + lam * y[k]);
            inner += db * dz;
            dist += dz * dz;
        }
        if inner < -1e-10 * dist.max(1.0) {
            return Err(format!(
                "trial {t}: component ({n},{i}) not monotone: <Bx-By,x-y> = {inner}"
            ));
        }
    }
    Ok(())
}

/// Numerically verify the resolvent identity `z + alpha (B + lambda I)(z)
/// = psi` at random points — the core correctness check for every
/// backward implementation.
///
/// For problems with a declared [`Problem::l1_weight`], the identity
/// becomes the inclusion `psi - (1 + alpha lambda) z - alpha B(z) in
/// alpha l1 d||z||_1`, which is verified coordinatewise: thresholded
/// coordinates must leave a residual inside `[-alpha l1, alpha l1]` and
/// surviving coordinates must leave exactly `alpha l1 sign(z_k)`.
pub fn check_resolvent<P: Problem + ?Sized>(
    p: &P,
    alpha: f64,
    seed: u64,
    trials: usize,
) -> Result<(), String> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let dim = p.dim();
    let l1 = p.l1_weight();
    let mut z = vec![0.0; dim];
    let mut coefs = vec![0.0; p.coef_width()];
    for t in 0..trials {
        let n = rng.below(p.nodes());
        let i = rng.below(p.q());
        let psi: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        p.backward(n, i, alpha, &psi, &mut z, &mut coefs);
        // reconstruct psi_hat = z + alpha B(z) + alpha lambda z
        let mut recon = z.clone();
        for r in recon.iter_mut().zip(&z).map(|(r, _)| r) {
            *r *= 1.0 + alpha * p.lambda();
        }
        p.apply(n, i, &z, alpha, &mut recon);
        if l1 == 0.0 {
            let err: f64 = recon
                .iter()
                .zip(&psi)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            if err > 1e-8 {
                return Err(format!(
                    "trial {t}: resolvent identity violated on ({n},{i}): err {err}"
                ));
            }
        } else {
            for k in 0..dim {
                let r = psi[k] - recon[k]; // must equal alpha*l1*u_k
                let bad = if z[k] != 0.0 {
                    (r - alpha * l1 * z[k].signum()).abs() > 1e-8
                } else {
                    r.abs() > alpha * l1 + 1e-8
                };
                if bad {
                    return Err(format!(
                        "trial {t}: prox inclusion violated on ({n},{i}) coord {k}: \
                         z={} residual={r} bound={}",
                        z[k],
                        alpha * l1
                    ));
                }
            }
        }
        // check coefs_out really are the coefs at the new point
        let mut fresh = vec![0.0; p.coef_width()];
        p.coefs(n, i, &z, &mut fresh);
        for (a, b) in coefs.iter().zip(&fresh) {
            if (a - b).abs() > 1e-8 {
                return Err(format!(
                    "trial {t}: stale coefs from backward ({a} vs {b})"
                ));
            }
        }
    }
    Ok(())
}

/// Numerically verify a declared [`Problem::saddle`] capability:
///
/// * the split is well-formed (`primal_dims + dual_dims == dim`, a
///   nonempty dual block);
/// * when [`Problem::saddle_value`] is available, the global operator
///   `sum_n (B_n + lambda I)` really is the primal-dual gradient field of
///   it — `+dL/dz_k` on primal coordinates, `-dL/dz_k` on dual ones —
///   checked by central differences at random points (exact up to
///   rounding for the quadratic couplings every built-in saddle workload
///   uses).
///
/// Trivially `Ok` for problems without a saddle declaration, so the
/// registry-wide property suite can enroll every entry unconditionally.
pub fn check_saddle<P: Problem + ?Sized>(
    p: &P,
    seed: u64,
    trials: usize,
) -> Result<(), String> {
    let Some(ss) = p.saddle() else {
        return Ok(());
    };
    let dim = p.dim();
    if ss.primal_dims + ss.dual_dims != dim {
        return Err(format!(
            "saddle split {} + {} != dim {}",
            ss.primal_dims, ss.dual_dims, dim
        ));
    }
    if ss.dual_dims == 0 {
        return Err("saddle declaration with an empty dual block".to_string());
    }
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut g = vec![0.0; dim];
    let mut tmp = vec![0.0; dim];
    for t in 0..trials {
        let z: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        if p.saddle_value(&z).is_none() {
            return Ok(()); // split validated; no value to cross-check
        }
        // G(z) = sum_n (B_n(z) + lambda z)
        g.fill(0.0);
        for n in 0..p.nodes() {
            p.full_operator(n, &z, &mut tmp);
            for (a, b) in g.iter_mut().zip(&tmp) {
                *a += b;
            }
        }
        // a few random coordinates per trial keep the check O(dim)-free
        for _ in 0..6 {
            let k = rng.below(dim);
            let h = 1e-4;
            let mut zp = z.clone();
            zp[k] += h;
            let mut zm = z.clone();
            zm[k] -= h;
            let (lp, lm) = match (p.saddle_value(&zp), p.saddle_value(&zm)) {
                (Some(a), Some(b)) => (a, b),
                _ => return Err("saddle_value not defined near a random point".into()),
            };
            let fd = (lp - lm) / (2.0 * h);
            let sign = if k < ss.primal_dims { 1.0 } else { -1.0 };
            let err = (sign * fd - g[k]).abs();
            if err > 1e-5 * (1.0 + g[k].abs()) {
                return Err(format!(
                    "trial {t}: saddle_value gradient mismatch at coord {k} \
                     ({} block): fd {fd} vs operator {}",
                    if k < ss.primal_dims { "primal" } else { "dual" },
                    g[k]
                ));
            }
        }
    }
    Ok(())
}
