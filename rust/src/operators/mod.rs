//! Monotone operators and their resolvents (paper §3–§4).
//!
//! Every learning problem is expressed as a sum of component monotone
//! operators `B_{n,i}` held at node `n`.  For linear predictors the
//! component output is fully described by a few *scalar coefficients*
//! applied to the data row (plus a small dense tail for the AUC saddle
//! operator) — the structure behind both the `O(q)`-scalar SAGA table
//! (Schmidt et al., 2017) and the sparse deltas of the §5.1 communication
//! protocol.
//!
//! The l2 regularization of §7 is *not* baked into the raw components
//! (that would densify the deltas); it is applied through the resolvent
//! identity `J_{alpha B^lambda}(psi) = J_{beta B}(psi / (1+alpha lambda))`
//! with `beta = alpha/(1 + alpha lambda)`, and added analytically wherever
//! a forward evaluation of `B^lambda` is needed.

mod ridge;
mod logistic;
mod auc;

pub use auc::AucProblem;
pub use logistic::LogisticProblem;
pub use ridge::RidgeProblem;

use crate::data::Partition;

/// A decentralized monotone-operator root-finding problem (13).
pub trait Problem: Send + Sync {
    /// Total variable dimension `D` (= d for minimization, d+3 for AUC).
    fn dim(&self) -> usize;
    /// Feature dimension `d` (sparse block of the variable).
    fn feature_dim(&self) -> usize;
    /// Dense tail dimensions (0, or 3 for AUC's `[a, b, theta]`).
    fn tail_dims(&self) -> usize {
        self.dim() - self.feature_dim()
    }
    /// Number of nodes `N`.
    fn nodes(&self) -> usize;
    /// Components per node `q`.
    fn q(&self) -> usize;
    /// l2 regularization weight `lambda` (the operator solved for the
    /// root is `sum_n (B_n + lambda I)`).
    fn lambda(&self) -> f64;
    /// Scalar coefficients per component (1 for ridge/logistic, 4 for AUC).
    fn coef_width(&self) -> usize;

    /// Access to the underlying partition (shards/labels).
    fn partition(&self) -> &Partition;

    /// Raw (unregularized) coefficients of `B_{n,i}` at `z`.
    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]);

    /// `out += scale * B_{n,i}[coefs]` — scatter a coefficient-encoded
    /// operator output. `O(nnz + tail)`.
    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]);

    /// Backward step `z = J_{alpha (B_{n,i} + lambda I)}(psi)`.
    /// Writes the new iterate into `z_out` (len `dim()`) and the raw
    /// coefficients of `B_{n,i}(z)` *at the new point* into `coefs_out`.
    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    );

    /// Global objective for metrics (None for saddle problems; AUC
    /// reports the AUC statistic through `Metrics` instead).
    fn objective(&self, z: &[f64]) -> Option<f64>;

    /// (L, mu) of the regularized components `B_{n,i} + lambda I`.
    fn l_mu(&self) -> (f64, f64);

    // ---- provided ----

    /// `out += scale * B_{n,i}(z)` (raw forward evaluation).
    fn apply(&self, n: usize, i: usize, z: &[f64], scale: f64, out: &mut [f64]) {
        let mut c = vec![0.0; self.coef_width()];
        self.coefs(n, i, z, &mut c);
        self.scatter(n, i, &c, scale, out);
    }

    /// Full raw local operator mean `(1/q) sum_i B_{n,i}(z)` into `out`
    /// (overwrites). The deterministic baselines' inner loop.
    fn full_raw_mean(&self, n: usize, z: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        let scale = 1.0 / self.q() as f64;
        for i in 0..self.q() {
            self.apply(n, i, z, scale, out);
        }
    }

    /// Regularized full local operator `B_n(z) + lambda z` (overwrites).
    fn full_operator(&self, n: usize, z: &[f64], out: &mut [f64]) {
        self.full_raw_mean(n, z, out);
        let lam = self.lambda();
        for (o, zi) in out.iter_mut().zip(z) {
            *o += lam * zi;
        }
    }

    /// Residual `|| sum_n (B_n(z) + lambda z) ||` — 0 at the solution of
    /// (13). Used by optimum pre-solves and convergence checks.
    fn global_residual(&self, z: &[f64]) -> f64 {
        let mut acc = vec![0.0; self.dim()];
        let mut tmp = vec![0.0; self.dim()];
        for n in 0..self.nodes() {
            self.full_operator(n, z, &mut tmp);
            for (a, t) in acc.iter_mut().zip(&tmp) {
                *a += t;
            }
        }
        crate::linalg::norm2(&acc)
    }

    /// nnz of the sparse part of component (n,i)'s output — the §5.1
    /// delta communication payload (values; tail adds `tail_dims()`).
    fn delta_nnz(&self, n: usize, i: usize) -> usize {
        self.partition().shards[n].row_nnz(i) + self.tail_dims()
    }

    /// Condition number `kappa = L / mu` of the regularized components.
    fn kappa(&self) -> f64 {
        let (l, mu) = self.l_mu();
        l / mu
    }
}

/// Numerically verify monotonicity of components at random pairs —
/// shared test/diagnostic helper.
pub fn check_monotone<P: Problem + ?Sized>(
    p: &P,
    seed: u64,
    trials: usize,
) -> Result<(), String> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let dim = p.dim();
    for t in 0..trials {
        let n = rng.below(p.nodes());
        let i = rng.below(p.q());
        let x: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut bx = vec![0.0; dim];
        let mut by = vec![0.0; dim];
        p.apply(n, i, &x, 1.0, &mut bx);
        p.apply(n, i, &y, 1.0, &mut by);
        let lam = p.lambda();
        let mut inner = 0.0;
        let mut dist = 0.0;
        for k in 0..dim {
            let dz = x[k] - y[k];
            let db = (bx[k] + lam * x[k]) - (by[k] + lam * y[k]);
            inner += db * dz;
            dist += dz * dz;
        }
        if inner < -1e-10 * dist.max(1.0) {
            return Err(format!(
                "trial {t}: component ({n},{i}) not monotone: <Bx-By,x-y> = {inner}"
            ));
        }
    }
    Ok(())
}

/// Numerically verify the resolvent identity `z + alpha (B + lambda I)(z)
/// = psi` at random points — the core correctness check for every
/// backward implementation.
pub fn check_resolvent<P: Problem + ?Sized>(
    p: &P,
    alpha: f64,
    seed: u64,
    trials: usize,
) -> Result<(), String> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let dim = p.dim();
    let mut z = vec![0.0; dim];
    let mut coefs = vec![0.0; p.coef_width()];
    for t in 0..trials {
        let n = rng.below(p.nodes());
        let i = rng.below(p.q());
        let psi: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        p.backward(n, i, alpha, &psi, &mut z, &mut coefs);
        // reconstruct psi_hat = z + alpha B(z) + alpha lambda z
        let mut recon = z.clone();
        for r in recon.iter_mut().zip(&z).map(|(r, _)| r) {
            *r *= 1.0 + alpha * p.lambda();
        }
        p.apply(n, i, &z, alpha, &mut recon);
        let err: f64 = recon
            .iter()
            .zip(&psi)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        if err > 1e-8 {
            return Err(format!(
                "trial {t}: resolvent identity violated on ({n},{i}): err {err}"
            ));
        }
        // check coefs_out really are the coefs at the new point
        let mut fresh = vec![0.0; p.coef_width()];
        p.coefs(n, i, &z, &mut fresh);
        for (a, b) in coefs.iter().zip(&fresh) {
            if (a - b).abs() > 1e-8 {
                return Err(format!(
                    "trial {t}: stale coefs from backward ({a} vs {b})"
                ));
            }
        }
    }
    Ok(())
}
