//! Open problem registry: the extension point that makes `dsba` a
//! monotone-operator *framework* rather than a three-problem benchmark.
//!
//! A workload is registered as a [`ProblemEntry`]: a canonical name plus
//! aliases, capability metadata ([`ProblemMeta`]), per-method tuned step
//! sizes for the figure harness, and a constructor from a
//! [`ProblemSpec`] (the config layer's resolved hyper-parameters) and a
//! data [`Partition`].  `config`, the CLI (`run`/`info`/`figure`) and
//! `bench_harness` resolve problems exclusively through
//! [`ProblemRegistry::builtin`], so adding a workload means writing one
//! `operators/<name>.rs` module with a `Problem` impl and an `entry()`
//! function, and listing that entry here — no `match` in any core file.

use super::{Problem, SaddleStat};
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use crate::util::json::Json;
use std::sync::{Arc, OnceLock};

/// How a registered problem implements its backward step — one of the
/// capability columns `dsba info` prints straight from the registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolventKind {
    /// Exact closed form (scalar formula or a small linear solve).
    ClosedForm,
    /// Scalar Newton iteration to machine precision.
    Newton,
    /// Closed-form smooth part plus a proximal (soft-threshold) l1 stage.
    Proximal,
}

impl ResolventKind {
    pub fn name(&self) -> &'static str {
        match self {
            ResolventKind::ClosedForm => "closed-form",
            ResolventKind::Newton => "newton",
            ResolventKind::Proximal => "prox",
        }
    }
}

/// Resolved problem hyper-parameters handed to a registry constructor.
///
/// `lambda` is the *effective* l2 weight (the config layer resolves the
/// paper's `1/(10 Q)` default before construction); `params` carries
/// problem-specific knobs as free-form JSON (e.g. `{"l1": 0.01}` for
/// elastic net).  Constructors read the keys they know and ignore the
/// rest, so one generic params object can drive every problem.
#[derive(Clone, Debug)]
pub struct ProblemSpec {
    /// canonical problem name (as registered)
    pub name: String,
    /// effective l2 regularization weight
    pub lambda: f64,
    /// problem-specific knobs (JSON object; `Json::Null` = all defaults)
    pub params: Json,
}

impl ProblemSpec {
    pub fn new(name: &str, lambda: f64) -> ProblemSpec {
        ProblemSpec { name: name.to_string(), lambda, params: Json::Null }
    }

    pub fn with_params(mut self, params: Json) -> ProblemSpec {
        self.params = params;
        self
    }

    /// Read a numeric knob from `params` (None = key absent / not a
    /// number — caller applies its default).
    pub fn param_f64(&self, key: &str) -> Option<f64> {
        self.params.get(key).and_then(Json::as_f64)
    }
}

/// Capability metadata of a registered problem — everything the generic
/// layers (metrics, dataset generation, CLI listings, property suites)
/// need to know without downcasting the `Problem` object.
#[derive(Clone, Copy, Debug)]
pub struct ProblemMeta {
    /// canonical name (`dsba run --problem <name>`)
    pub name: &'static str,
    /// accepted alternative spellings (case-insensitive, like `name`)
    pub aliases: &'static [&'static str],
    /// one-line description for `dsba info`
    pub summary: &'static str,
    /// `Problem::objective` returns `Some` (false = saddle problem
    /// scored through the saddle merit layer instead)
    pub has_objective: bool,
    /// saddle (minimax) problems declare how they are scored; `None` =
    /// pure minimization. Must agree with the built problem's
    /// `Problem::saddle()` declaration (pinned by the registry tests).
    pub saddle_stat: Option<SaddleStat>,
    /// the problem supports a separable l1 term (`Problem::l1_weight`)
    pub l1: bool,
    /// how the backward step is implemented
    pub resolvent: ResolventKind,
    /// dense tail dimensions appended to the feature block
    pub tail_dims: usize,
    /// scalar coefficients per component operator
    pub coef_width: usize,
    /// synthetic datasets should generate regression targets (vs ±1
    /// classification labels)
    pub regression_targets: bool,
    /// human-readable list of `params` keys the constructor reads
    pub params_help: &'static str,
    /// per-method tuned step size for the figure/bench harness (the
    /// paper tunes alpha per (problem, method))
    pub tuned_alpha: fn(AlgorithmKind) -> f64,
}

/// Constructor signature every registered problem provides.
pub type ProblemCtor =
    fn(&ProblemSpec, &Dataset, Partition) -> Result<Arc<dyn Problem>, String>;

/// One registered workload: metadata + constructor.
#[derive(Clone)]
pub struct ProblemEntry {
    pub meta: ProblemMeta,
    pub ctor: ProblemCtor,
}

impl ProblemEntry {
    /// Build the problem from resolved hyper-parameters and a partition.
    pub fn build(
        &self,
        spec: &ProblemSpec,
        ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        (self.ctor)(spec, ds, part)
    }

    fn matches(&self, lower: &str) -> bool {
        self.meta.name.eq_ignore_ascii_case(lower)
            || self.meta.aliases.iter().any(|a| a.eq_ignore_ascii_case(lower))
    }
}

/// Name/alias-indexed set of problem entries.
pub struct ProblemRegistry {
    entries: Vec<ProblemEntry>,
}

impl ProblemRegistry {
    /// Build a registry, rejecting duplicate names or aliases (two
    /// entries answering to one spelling would make resolution
    /// order-dependent).
    pub fn new(entries: Vec<ProblemEntry>) -> Result<ProblemRegistry, String> {
        let mut seen: Vec<String> = Vec::new();
        for e in &entries {
            for s in std::iter::once(e.meta.name).chain(e.meta.aliases.iter().copied()) {
                let lower = s.to_ascii_lowercase();
                if seen.contains(&lower) {
                    return Err(format!("duplicate problem name/alias {s:?}"));
                }
                seen.push(lower);
            }
        }
        Ok(ProblemRegistry { entries })
    }

    /// The process-wide registry of built-in workloads. Adding a problem
    /// to the system means adding exactly one `entry()` line here (plus
    /// its `operators/<name>.rs` module).
    pub fn builtin() -> &'static ProblemRegistry {
        static BUILTIN: OnceLock<ProblemRegistry> = OnceLock::new();
        BUILTIN.get_or_init(|| {
            ProblemRegistry::new(vec![
                super::ridge::entry(),
                super::logistic::entry(),
                super::auc::entry(),
                super::elastic_net::entry(),
                super::hinge::entry(),
                super::robust_ls::entry(),
                super::dro::entry(),
            ])
            .expect("builtin problem registry is well-formed")
        })
    }

    /// Resolve a name or alias (case-insensitive).
    pub fn resolve(&self, name: &str) -> Option<&ProblemEntry> {
        let lower = name.to_ascii_lowercase();
        self.entries.iter().find(|e| e.matches(&lower))
    }

    /// Canonical name for any accepted spelling.
    pub fn canonical(&self, name: &str) -> Option<&'static str> {
        self.resolve(name).map(|e| e.meta.name)
    }

    pub fn entries(&self) -> &[ProblemEntry] {
        &self.entries
    }

    /// Canonical names, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.meta.name).collect()
    }

    /// Aligned capability table for `dsba info` — generated from the
    /// entries' live metadata (saddle / l1 / resolvent kind included) so
    /// the CLI text cannot drift from the code.
    pub fn describe(&self) -> String {
        // aliases column sized to the longest registered alias list so
        // the capability rows stay aligned as entries grow
        let alias_w = self
            .entries
            .iter()
            .map(|e| e.meta.aliases.join(", ").len())
            .max()
            .unwrap_or(0)
            .max("aliases".len());
        let mut out = format!(
            "problem       {:<alias_w$}  metric      saddle  l1  \
             resolvent    tail  coefs  params\n",
            "aliases",
        );
        for e in &self.entries {
            let m = &e.meta;
            let metric = match m.saddle_stat {
                None => "objective",
                Some(SaddleStat::AucRanking) => "auc-stat",
                Some(SaddleStat::Residual) => "saddle-res",
            };
            out.push_str(&format!(
                "{:<12}  {:<alias_w$}  {:<10}  {:<6}  {:<2}  {:<11}  {:>4}  {:>5}  {}\n",
                m.name,
                m.aliases.join(", "),
                metric,
                if m.saddle_stat.is_some() { "y" } else { "-" },
                if m.l1 { "y" } else { "-" },
                m.resolvent.name(),
                m.tail_dims,
                m.coef_width,
                m.params_help,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn builtin_resolves_names_and_aliases_case_insensitively() {
        let reg = ProblemRegistry::builtin();
        for e in reg.entries() {
            let canon = reg.resolve(e.meta.name).unwrap();
            assert_eq!(canon.meta.name, e.meta.name);
            let upper = e.meta.name.to_ascii_uppercase();
            assert_eq!(reg.canonical(&upper), Some(e.meta.name));
            for alias in e.meta.aliases {
                assert_eq!(
                    reg.canonical(alias),
                    Some(e.meta.name),
                    "alias {alias} must resolve to {}",
                    e.meta.name
                );
            }
        }
        assert!(reg.resolve("no-such-problem").is_none());
    }

    #[test]
    fn duplicate_aliases_rejected() {
        let reg = ProblemRegistry::builtin();
        let mut entries: Vec<ProblemEntry> = reg.entries().to_vec();
        entries.push(entries[0].clone());
        assert!(ProblemRegistry::new(entries).is_err());
    }

    #[test]
    fn entries_build_and_match_their_metadata() {
        let reg = ProblemRegistry::builtin();
        for e in reg.entries() {
            let ds = SyntheticSpec::tiny()
                .with_regression(e.meta.regression_targets)
                .generate(11);
            let part = ds.partition_seeded(2, 5);
            let spec = ProblemSpec::new(e.meta.name, 0.05);
            let p = e.build(&spec, &ds, part).expect("builtin entry builds");
            assert_eq!(p.tail_dims(), e.meta.tail_dims, "{}", e.meta.name);
            assert_eq!(p.coef_width(), e.meta.coef_width, "{}", e.meta.name);
            let z = vec![0.0; p.dim()];
            assert_eq!(
                p.objective(&z).is_some(),
                e.meta.has_objective,
                "{}: has_objective metadata disagrees with objective()",
                e.meta.name
            );
            // capability metadata must agree with the built problem
            assert_eq!(
                p.saddle().map(|s| s.stat),
                e.meta.saddle_stat,
                "{}: saddle_stat metadata disagrees with saddle()",
                e.meta.name
            );
            if let Some(s) = p.saddle() {
                assert_eq!(
                    s.primal_dims + s.dual_dims,
                    p.dim(),
                    "{}: saddle split does not cover the variable",
                    e.meta.name
                );
                assert_eq!(
                    p.auc_metric(),
                    s.stat == crate::operators::SaddleStat::AucRanking,
                    "{}: auc_metric shim disagrees with the declared stat",
                    e.meta.name
                );
            }
            if !e.meta.l1 {
                assert_eq!(
                    p.l1_weight(),
                    0.0,
                    "{}: l1 capability not declared but l1_weight > 0",
                    e.meta.name
                );
            }
            assert_eq!(p.lambda(), 0.05);
            // rebuild keeps every hyper-parameter (the coordinator's
            // pooled-twin pre-solve depends on this)
            let twin = p.rebuild(Partition::equal_random(&p.partition().pooled(), 1, 0));
            assert_eq!(twin.lambda(), p.lambda());
            assert_eq!(twin.l1_weight(), p.l1_weight());
            assert_eq!(twin.coef_width(), p.coef_width());
            assert_eq!(twin.tail_dims(), p.tail_dims());
        }
    }

    #[test]
    fn tuned_alpha_positive_for_stochastic_methods() {
        for e in ProblemRegistry::builtin().entries() {
            for &k in AlgorithmKind::all() {
                let a = (e.meta.tuned_alpha)(k);
                assert!(a.is_finite() && a >= 0.0, "{} / {}", e.meta.name, k.name());
                if k.is_stochastic() {
                    assert!(a > 0.0, "{} / {}", e.meta.name, k.name());
                }
            }
        }
    }
}
