//! Elastic-net ridge regression: squared loss + l2 + **l1**, the first
//! workload with a genuinely *proximal* backward step.
//!
//! Component operators are the ridge residual operators
//! `B_{n,i}(z) = (a^T z - y) a` plus a separable `l1 ||z||_1` term that
//! is — like the l2 term (see the module docs of [`crate::operators`]) —
//! not baked into the raw coefficients: the forward path stays the
//! 1-scalar ridge encoding (SAGA tables and sparse deltas unchanged),
//! while [`Problem::backward`] resolves the l1 term through its
//! soft-threshold resolvent and reports it via [`Problem::l1_weight`].
//! Proximal methods (DSBA, DSBA-s via its prox-aware replay, Point-SAGA)
//! therefore solve the true elastic-net problem; forward and
//! inner-solver baselines see only the smooth part — the CLI points this
//! out, and it is precisely the backward-vs-forward contrast the paper
//! is about.
//!
//! The resolvent `J_{beta(B + l1 d|.|)}(psi_hat)` reduces to a scalar
//! root-find: with `z(g) = S_{beta l1}(psi_hat - beta g a)` the margin
//! coefficient solves `g = a^T z(g) - y`, and
//! `h(g) = g - a^T z(g) + y` is continuous piecewise-linear with slope
//! in `[1, 1 + beta ||a||^2]`, so the root segment is located by
//! monotone bisection over the `2 nnz` activity breakpoints and solved
//! exactly in closed form — `O(nnz log nnz)`, no iteration tolerance.

use super::registry::{ProblemEntry, ProblemMeta, ProblemSpec, ResolventKind};
use super::Problem;
use crate::algorithms::AlgorithmKind;
use crate::data::{Dataset, Partition};
use crate::solvers::soft_threshold;
use std::sync::Arc;

/// Registry entry (canonical `elastic-net`): ridge + l1, proximal
/// backward.  `params`: `l1` — the l1 weight (default = lambda).
pub(crate) fn entry() -> ProblemEntry {
    fn tuned(method: AlgorithmKind) -> f64 {
        use AlgorithmKind::*;
        // backward methods inherit the ridge tuning (the prox adds no
        // curvature); forward baselines only see the smooth part
        match method {
            Dsba | DsbaSparse | PExtra | PointSaga => 2.0,
            Dsa => 0.3,
            Extra => 0.45,
            Dlm => 0.0, // uses dlm_c / dlm_rho
            Ssda => 0.9,
            Dgd => 0.4,
        }
    }
    fn ctor(
        spec: &ProblemSpec,
        _ds: &Dataset,
        part: Partition,
    ) -> Result<Arc<dyn Problem>, String> {
        let l1 = spec.param_f64("l1").unwrap_or(spec.lambda);
        if !l1.is_finite() || l1 < 0.0 {
            return Err(format!("elastic-net: l1 must be finite and >= 0, got {l1}"));
        }
        Ok(Arc::new(ElasticNetProblem::new(part, spec.lambda, l1)))
    }
    ProblemEntry {
        meta: ProblemMeta {
            name: "elastic-net",
            aliases: &["elasticnet", "enet", "l1-ridge"],
            summary: "ridge + l1 (soft-threshold resolvent, proximal backward)",
            has_objective: true,
            saddle_stat: None,
            l1: true,
            resolvent: ResolventKind::Proximal,
            tail_dims: 0,
            coef_width: 1,
            regression_targets: true,
            params_help: "l1 (default = lambda)",
            tuned_alpha: tuned,
        },
        ctor,
    }
}

/// Decentralized elastic-net regression.
pub struct ElasticNetProblem {
    part: Partition,
    lambda: f64,
    l1: f64,
    /// cached row norms ||a_{n,i}||^2
    row_norm_sq: Vec<Vec<f64>>,
}

impl ElasticNetProblem {
    pub fn new(part: Partition, lambda: f64, l1: f64) -> Self {
        assert!(l1 >= 0.0, "l1 weight must be nonnegative");
        let row_norm_sq = part
            .shards
            .iter()
            .map(|s| (0..s.rows).map(|i| s.row_norm_sq(i)).collect())
            .collect();
        ElasticNetProblem { part, lambda, l1, row_norm_sq }
    }

    fn shard(&self, n: usize) -> &crate::linalg::CsrMatrix {
        &self.part.shards[n]
    }
}

impl Problem for ElasticNetProblem {
    fn dim(&self) -> usize {
        self.part.dim
    }
    fn feature_dim(&self) -> usize {
        self.part.dim
    }
    fn nodes(&self) -> usize {
        self.part.nodes()
    }
    fn q(&self) -> usize {
        self.part.q
    }
    fn lambda(&self) -> f64 {
        self.lambda
    }
    fn coef_width(&self) -> usize {
        1
    }
    fn partition(&self) -> &Partition {
        &self.part
    }
    fn l1_weight(&self) -> f64 {
        self.l1
    }

    fn coefs(&self, n: usize, i: usize, z: &[f64], out: &mut [f64]) {
        out[0] = self.shard(n).row_dot(i, z) - self.part.labels[n][i];
    }

    fn scatter(&self, n: usize, i: usize, coefs: &[f64], scale: f64, out: &mut [f64]) {
        self.shard(n).row_axpy(i, scale * coefs[0], out);
    }

    fn backward(
        &self,
        n: usize,
        i: usize,
        alpha: f64,
        psi: &[f64],
        z_out: &mut [f64],
        coefs_out: &mut [f64],
    ) {
        // scaled identity (covers l2 AND l1):
        // J_{alpha(B + l1 d|.| + lambda I)}(psi)
        //   = J_{beta(B + l1 d|.|)}(psi / (1 + alpha lambda))
        let s = 1.0 / (1.0 + alpha * self.lambda);
        let beta = alpha * s;
        let t = beta * self.l1;
        let y = self.part.labels[n][i];
        let shard = self.shard(n);

        if t == 0.0 {
            // inactive threshold (l1 == 0 or alpha == 0): the ridge
            // closed form, which also keeps the breakpoint math below
            // free of 0/0 corner cases
            let c = self.row_norm_sq[n][i];
            let a_dot_psi = shard.row_dot(i, psi) * s;
            let m = (a_dot_psi + beta * c * y) / (1.0 + beta * c);
            let g = m - y;
            for (zo, p) in z_out.iter_mut().zip(psi) {
                *zo = s * p;
            }
            shard.row_axpy(i, -beta * g, z_out);
            coefs_out[0] = g;
            return;
        }

        let idx = shard.row_indices(i);
        let val = shard.row_values(i);

        // off-support coordinates separate completely: z_k = S_t(s psi_k)
        for (zo, &p) in z_out.iter_mut().zip(psi) {
            *zo = soft_threshold(s * p, t);
        }

        // support: z_k depends on the margin coefficient g = a^T z - y
        // through z(g) = S_t(s psi - beta g a); h below is strictly
        // increasing piecewise-linear, kinked only where a coordinate
        // crosses the threshold
        let m_of = |g: f64| -> f64 {
            let mut m = 0.0;
            for (&k, &a) in idx.iter().zip(val) {
                m += a * soft_threshold(s * psi[k as usize] - beta * g * a, t);
            }
            m
        };
        let h = |g: f64| g - m_of(g) + y;

        let mut bps: Vec<f64> = Vec::with_capacity(2 * idx.len());
        for (&k, &a) in idx.iter().zip(val) {
            if a != 0.0 {
                let b = s * psi[k as usize];
                bps.push((b - t) / (beta * a));
                bps.push((b + t) / (beta * a));
            }
        }
        bps.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let j = bps.partition_point(|&b| h(b) < 0.0);
        // a probe point strictly inside the root's linear segment fixes
        // the active set and the signs
        let probe = if bps.is_empty() {
            0.0
        } else if j == 0 {
            bps[0] - 1.0
        } else if j == bps.len() {
            bps[bps.len() - 1] + 1.0
        } else {
            0.5 * (bps[j - 1] + bps[j])
        };
        let mut s0 = 0.0; // sum_A a_k (b_k - sigma_k t)
        let mut c_a = 0.0; // sum_A a_k^2
        for (&k, &a) in idx.iter().zip(val) {
            let b = s * psi[k as usize];
            let r = b - beta * probe * a;
            if r.abs() > t {
                s0 += a * (b - t * r.signum());
                c_a += a * a;
            }
        }
        // on the segment: h(g) = g (1 + beta C_A) - S0 + y = 0
        let g = (s0 - y) / (1.0 + beta * c_a);

        for (&k, &a) in idx.iter().zip(val) {
            z_out[k as usize] = soft_threshold(s * psi[k as usize] - beta * g * a, t);
        }
        coefs_out[0] = g;
    }

    fn objective(&self, z: &[f64]) -> Option<f64> {
        // sum_n [ (1/2q) ||A_n z - y_n||^2
        //         + lambda/2 ||z||^2 + l1 ||z||_1 ]
        let mut obj = 0.0;
        for n in 0..self.nodes() {
            let shard = self.shard(n);
            let mut local = 0.0;
            for i in 0..self.q() {
                let r = shard.row_dot(i, z) - self.part.labels[n][i];
                local += r * r;
            }
            obj += 0.5 * local / self.q() as f64;
        }
        let znorm: f64 = z.iter().map(|v| v * v).sum();
        let z1: f64 = z.iter().map(|v| v.abs()).sum();
        obj += self.nodes() as f64 * (0.5 * self.lambda * znorm + self.l1 * z1);
        Some(obj)
    }

    fn l_mu(&self) -> (f64, f64) {
        // smooth part only (the l1 term carries no curvature)
        let cmax = self
            .row_norm_sq
            .iter()
            .flatten()
            .fold(0.0f64, |acc, &c| acc.max(c));
        (cmax + self.lambda, self.lambda)
    }

    fn rebuild(&self, part: Partition) -> Arc<dyn Problem> {
        Arc::new(ElasticNetProblem::new(part, self.lambda, self.l1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{check_monotone, check_resolvent, RidgeProblem};
    use crate::util::rng::Rng;

    fn problem(l1: f64) -> ElasticNetProblem {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(37);
        ElasticNetProblem::new(ds.partition(4), 0.05, l1)
    }

    #[test]
    fn prox_inclusion_holds() {
        // large t = alpha*l1 so many coordinates actually threshold
        check_resolvent(&problem(0.05), 0.3, 7, 50).unwrap();
        check_resolvent(&problem(0.05), 3.0, 8, 50).unwrap();
        check_resolvent(&problem(0.5), 1.0, 9, 50).unwrap();
    }

    #[test]
    fn components_monotone() {
        check_monotone(&problem(0.05), 9, 100).unwrap();
    }

    #[test]
    fn reduces_to_ridge_at_l1_zero() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(41);
        let en = ElasticNetProblem::new(ds.partition(3), 0.07, 0.0);
        let ridge = RidgeProblem::new(ds.partition(3), 0.07);
        let mut rng = Rng::new(5);
        let alpha = 0.8;
        let mut z_en = vec![0.0; en.dim()];
        let mut z_r = vec![0.0; ridge.dim()];
        let mut c_en = vec![0.0];
        let mut c_r = vec![0.0];
        for _ in 0..20 {
            let n = rng.below(en.nodes());
            let i = rng.below(en.q());
            let psi: Vec<f64> = (0..en.dim()).map(|_| rng.normal()).collect();
            en.backward(n, i, alpha, &psi, &mut z_en, &mut c_en);
            ridge.backward(n, i, alpha, &psi, &mut z_r, &mut c_r);
            for (a, b) in z_en.iter().zip(&z_r) {
                assert!((a - b).abs() < 1e-10, "{a} vs {b}");
            }
            assert!((c_en[0] - c_r[0]).abs() < 1e-10);
        }
    }

    #[test]
    fn backward_thresholds_to_exact_zeros() {
        let p = problem(0.5);
        let mut rng = Rng::new(6);
        let psi: Vec<f64> = (0..p.dim()).map(|_| 0.3 * rng.normal()).collect();
        let mut z = vec![0.0; p.dim()];
        let mut c = vec![0.0];
        p.backward(0, 0, 2.0, &psi, &mut z, &mut c);
        let zeros = z.iter().filter(|&&v| v == 0.0).count();
        assert!(
            zeros > p.dim() / 2,
            "strong l1 must produce exact zeros ({zeros}/{})",
            p.dim()
        );
        // and the reported coefficient is the margin at the new point
        let g = p.partition().shards[0].row_dot(0, &z) - p.partition().labels[0][0];
        assert!((c[0] - g).abs() < 1e-10, "{} vs {g}", c[0]);
    }

    #[test]
    fn scalar_solve_consistent_at_every_alpha() {
        let p = problem(0.1);
        let mut rng = Rng::new(11);
        let mut z = vec![0.0; p.dim()];
        let mut c = vec![0.0];
        for &alpha in &[0.05, 0.5, 1.0, 4.0] {
            for _ in 0..10 {
                let n = rng.below(p.nodes());
                let i = rng.below(p.q());
                let psi: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
                p.backward(n, i, alpha, &psi, &mut z, &mut c);
                let g = p.partition().shards[n].row_dot(i, &z)
                    - p.partition().labels[n][i];
                assert!(
                    (c[0] - g).abs() < 1e-9,
                    "alpha {alpha}: coef {} vs margin {g}",
                    c[0]
                );
            }
        }
    }

    #[test]
    fn objective_includes_l1_term() {
        let p = problem(0.2);
        let ridge_twin = problem(0.0);
        let mut rng = Rng::new(8);
        let z: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        let z1: f64 = z.iter().map(|v| v.abs()).sum();
        let want = ridge_twin.objective(&z).unwrap() + p.nodes() as f64 * 0.2 * z1;
        assert!((p.objective(&z).unwrap() - want).abs() < 1e-10);
    }

    #[test]
    fn optimum_presolve_finds_sparse_kkt_point() {
        // the generic pooled-twin pre-solve (Point-SAGA + prox-gradient
        // polish) must drive the l1-aware KKT residual to ~0, and a
        // meaningful l1 weight must produce genuinely sparse optima
        let ds = SyntheticSpec::tiny().with_regression(true).generate(53);
        let p = ElasticNetProblem::new(ds.partition(3), 0.05, 0.3);
        let z = crate::coordinator::solve_optimum(&p, 1e-9);
        assert!(p.global_residual(&z) < 1e-8, "residual {}", p.global_residual(&z));
        let zeros = z.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 0, "l1 optimum should have exact zeros");
        // the same pre-solve with l1 = 0 must match plain ridge
        let pr = RidgeProblem::new(ds.partition(3), 0.05);
        let zr = crate::coordinator::solve_optimum(&pr, 1e-10);
        let pe0 = ElasticNetProblem::new(ds.partition(3), 0.05, 0.0);
        let ze0 = crate::coordinator::solve_optimum(&pe0, 1e-10);
        let err: f64 = zr
            .iter()
            .zip(&ze0)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-7, "l1=0 optimum drifted from ridge by {err}");
    }
}
