//! Inner solvers used by the deterministic baselines and the optimum
//! pre-solve: conjugate gradients for SPD systems (ridge resolvents,
//! SSDA's conjugate-gradient oracle) and an accelerated proximal solver
//! for the full-function resolvents P-EXTRA needs on non-quadratic
//! losses.

mod cg;
mod prox;

pub use cg::{cg_solve, LinearOperator};
pub use prox::{agd_minimize, soft_threshold};
