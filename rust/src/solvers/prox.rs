//! Nesterov-accelerated gradient descent on a smooth strongly convex
//! objective — the inner engine for P-EXTRA's full-function resolvents on
//! non-quadratic losses and for the logistic optimum pre-solve — plus the
//! scalar soft-threshold operator (the l1 resolvent used by proximal
//! backward steps and the elastic-net optimum polish).

/// Soft-threshold `S_t(v) = sign(v) max(|v| - t, 0)` — the resolvent of
/// `t d|.|`, applied coordinatewise by every l1-aware backward step.
#[inline]
pub fn soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

/// Minimize a mu-strongly-convex, L-smooth `f` given its gradient oracle,
/// from `x0`, to gradient norm <= tol. Returns (x, iterations).
pub fn agd_minimize<G: FnMut(&[f64], &mut [f64])>(
    mut grad: G,
    x0: &[f64],
    l_smooth: f64,
    mu: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, usize) {
    let n = x0.len();
    let mut x = x0.to_vec();
    let mut y = x0.to_vec();
    let mut g = vec![0.0; n];
    let kappa = l_smooth / mu.max(1e-300);
    let momentum = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
    let step = 1.0 / l_smooth;
    for it in 0..max_iters {
        grad(&y, &mut g);
        let gnorm = crate::linalg::norm2(&g);
        if gnorm <= tol {
            return (y, it);
        }
        // x_{k+1} = y_k - step * g ; y_{k+1} = x_{k+1} + m (x_{k+1} - x_k)
        let mut x_new = y.clone();
        crate::linalg::axpy(-step, &g, &mut x_new);
        for i in 0..n {
            y[i] = x_new[i] + momentum * (x_new[i] - x[i]);
        }
        x = x_new;
    }
    (x, max_iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = 0.5 x^T D x - b x with D = diag(1..=4)
        let d = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 1.0, 1.0, 1.0];
        let (x, iters) = agd_minimize(
            |x, g| {
                for i in 0..4 {
                    g[i] = d[i] * x[i] - b[i];
                }
            },
            &[0.0; 4],
            4.0,
            1.0,
            1e-12,
            10_000,
        );
        assert!(iters < 10_000);
        for i in 0..4 {
            assert!((x[i] - b[i] / d[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn ill_conditioned_still_converges() {
        let d = [1e-3, 1.0];
        let (x, _) = agd_minimize(
            |x, g| {
                g[0] = d[0] * x[0] - 1.0;
                g[1] = d[1] * x[1];
            },
            &[0.0, 5.0],
            1.0,
            1e-3,
            1e-10,
            200_000,
        );
        assert!((x[0] - 1000.0).abs() < 1e-4, "{}", x[0]);
        assert!(x[1].abs() < 1e-7);
    }

    #[test]
    fn soft_threshold_shrinks_toward_zero() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(2.0, 0.0), 2.0);
        // exact zero at the kink, with sign(0) never leaking through
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
        assert!(soft_threshold(-1.0, 1.0) == 0.0);
    }
}
