//! Conjugate gradients on an implicit symmetric positive-definite
//! operator.

/// An implicit SPD linear map `y = A x`.
pub trait LinearOperator {
    fn dim(&self) -> usize;
    fn apply(&self, x: &[f64], out: &mut [f64]);
}

impl<F: Fn(&[f64], &mut [f64])> LinearOperator for (usize, F) {
    fn dim(&self) -> usize {
        self.0
    }
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        (self.1)(x, out)
    }
}

/// Solve `A x = b` by CG. Returns (x, iterations, final residual norm).
pub fn cg_solve<A: LinearOperator>(
    a: &A,
    b: &[f64],
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, usize, f64) {
    let n = a.dim();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rs = crate::linalg::dot(&r, &r);
    let b_norm = rs.sqrt().max(1e-300);
    if rs.sqrt() <= tol * b_norm {
        return (x, 0, rs.sqrt());
    }
    for it in 0..max_iters {
        a.apply(&p, &mut ap);
        let denom = crate::linalg::dot(&p, &ap);
        if denom.abs() < 1e-300 {
            return (x, it, rs.sqrt());
        }
        let alpha = rs / denom;
        crate::linalg::axpy(alpha, &p, &mut x);
        crate::linalg::axpy(-alpha, &ap, &mut r);
        let rs_new = crate::linalg::dot(&r, &r);
        if rs_new.sqrt() <= tol * b_norm {
            return (x, it + 1, rs_new.sqrt());
        }
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
    }
    let res = rs.sqrt();
    (x, max_iters, res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    struct DenseOp(DenseMatrix);
    impl LinearOperator for DenseOp {
        fn dim(&self) -> usize {
            self.0.rows
        }
        fn apply(&self, x: &[f64], out: &mut [f64]) {
            out.copy_from_slice(&self.0.matvec(x));
        }
    }

    #[test]
    fn solves_spd_system() {
        // A = M^T M + I is SPD
        let mut rng = crate::util::rng::Rng::new(1);
        let n = 12;
        let mut m = DenseMatrix::zeros(n, n);
        for v in &mut m.data {
            *v = rng.normal();
        }
        let mut a = m.transpose().matmul(&m);
        for i in 0..n {
            a[(i, i)] += 1.0;
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 - 3.0) * 0.5).collect();
        let b = a.matvec(&x_true);
        let (x, iters, res) = cg_solve(&DenseOp(a), &b, 1e-12, 200);
        assert!(iters <= 200);
        assert!(res < 1e-8);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let a = DenseOp(DenseMatrix::identity(4));
        let (x, iters, _) = cg_solve(&a, &[0.0; 4], 1e-10, 10);
        assert_eq!(iters, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }
}
