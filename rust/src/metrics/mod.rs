//! Metrics: the exact quantities the paper's figures plot.
//!
//! * *effective passes* over the dataset (x-axis of every figure's left
//!   panel): `t / q` for stochastic methods, `t` for deterministic ones.
//! * `C_max^t = max_n C_n^t` — DOUBLEs received by the hottest node
//!   (x-axis of the right panels, §7).
//! * suboptimality `sum_n ||z_n - z*||^2 / N` (objective-style problems)
//!   and the AUC statistic (§7.3).

use crate::data::Partition;
use crate::util::json::Json;

/// One sampled point of an experiment trace.
#[derive(Clone, Debug)]
pub struct MetricsRow {
    /// iteration index t
    pub iter: usize,
    /// effective passes over the local datasets
    pub passes: f64,
    /// max over nodes of DOUBLEs received so far (paper's C_max^t)
    pub comm_doubles: f64,
    /// mean over nodes of ||z_n - z*||^2 (consensus suboptimality)
    pub suboptimality: f64,
    /// global objective value (NaN for saddle problems)
    pub objective: f64,
    /// AUC statistic at the averaged iterate (NaN unless AUC problem)
    pub auc: f64,
    /// wall-clock seconds since experiment start
    pub wall_secs: f64,
}

impl MetricsRow {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("iter", Json::Num(self.iter as f64)),
            ("passes", Json::Num(self.passes)),
            ("comm_doubles", Json::Num(self.comm_doubles)),
            ("suboptimality", Json::Num(self.suboptimality)),
            ("objective", Json::Num(self.objective)),
            ("auc", Json::Num(self.auc)),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }
}

/// Exact AUC of the linear scores `A w` over all samples in the
/// partition: the probability a random positive outranks a random
/// negative, ties counted 1/2 (Hanley & McNeil / Mann–Whitney).
///
/// `z` may be the augmented AUC variable (only the first `dim` entries
/// are read).
pub fn auc_score(part: &Partition, z: &[f64]) -> f64 {
    let mut scored: Vec<(f64, bool)> = Vec::with_capacity(part.total_samples());
    for (shard, labels) in part.shards.iter().zip(&part.labels) {
        for i in 0..shard.rows {
            scored.push((shard.row_dot(i, &z[..part.dim]), labels[i] > 0.0));
        }
    }
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let n_pos = scored.iter().filter(|s| s.1).count();
    let n_neg = scored.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // rank-sum with average ranks for ties
    let mut rank_sum_pos = 0.0;
    let mut i = 0;
    while i < scored.len() {
        let mut j = i;
        while j < scored.len() && scored[j].0 == scored[i].0 {
            j += 1;
        }
        let avg_rank = (i + j - 1) as f64 / 2.0 + 1.0; // 1-based
        for s in &scored[i..j] {
            if s.1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j;
    }
    (rank_sum_pos - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0)
        / (n_pos as f64 * n_neg as f64)
}

/// Mean squared distance of stacked iterates from `z*`:
/// `(1/N) sum_n ||z_n - z*||^2`.
pub fn suboptimality(zs: &[Vec<f64>], z_star: &[f64]) -> f64 {
    if zs.is_empty() {
        return 0.0;
    }
    zs.iter()
        .map(|z| crate::linalg::dist2_sq(z, z_star))
        .sum::<f64>()
        / zs.len() as f64
}

/// Write a trace as a JSON file `{series: [rows...], meta: {...}}`.
pub fn write_trace_json(
    path: &str,
    meta: Vec<(&str, Json)>,
    rows: &[MetricsRow],
) -> std::io::Result<()> {
    let doc = Json::from_pairs(vec![
        ("meta", Json::from_pairs(meta)),
        ("series", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ]);
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, doc.to_string())
}

/// Render rows as an aligned text table (the bench harness's stdout
/// format, one row per sampled point).
pub fn format_table(rows: &[MetricsRow]) -> String {
    let mut out = String::from(
        "  iter      passes   comm_doubles   suboptimality      objective        auc\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:>6}  {:>10.2}  {:>13.3e}  {:>14.6e}  {:>13.6e}  {:>9.4}\n",
            r.iter, r.passes, r.comm_doubles, r.suboptimality, r.objective, r.auc
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn auc_of_perfect_separator_is_one() {
        let ds = SyntheticSpec::tiny().with_samples(60).generate(1);
        let part = ds.partition(3);
        // build w that scores positives high by construction: w = sum y_i a_i
        let mut w = vec![0.0; part.dim + 3];
        for (shard, ys) in part.shards.iter().zip(&part.labels) {
            for i in 0..shard.rows {
                shard.row_axpy(i, ys[i] * 100.0, &mut w[..part.dim]);
            }
        }
        // not necessarily perfect, but must beat chance decisively
        let auc = auc_score(&part, &w);
        assert!(auc > 0.7, "auc {auc}");
        // and the reversed scorer must be symmetric around 1/2
        let neg: Vec<f64> = w.iter().map(|v| -v).collect();
        let auc_neg = auc_score(&part, &neg);
        assert!((auc + auc_neg - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auc_of_zero_scores_is_half() {
        let ds = SyntheticSpec::tiny().with_samples(40).generate(2);
        let part = ds.partition(2);
        let z = vec![0.0; part.dim + 3];
        assert!((auc_score(&part, &z) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn suboptimality_zero_at_star() {
        let star = vec![1.0, 2.0, 3.0];
        let zs = vec![star.clone(), star.clone()];
        assert_eq!(suboptimality(&zs, &star), 0.0);
        let zs2 = vec![vec![2.0, 2.0, 3.0], star.clone()];
        assert!((suboptimality(&zs2, &star) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn table_formats_all_rows() {
        let rows = vec![MetricsRow {
            iter: 10,
            passes: 1.0,
            comm_doubles: 1e4,
            suboptimality: 1e-5,
            objective: 0.5,
            auc: f64::NAN,
            wall_secs: 0.1,
        }];
        let t = format_table(&rows);
        assert!(t.contains("passes"));
        assert_eq!(t.lines().count(), 2);
    }
}
