//! Metrics: the exact quantities the paper's figures plot.
//!
//! * *effective passes* over the dataset (x-axis of every figure's left
//!   panel): `t / q` for stochastic methods, `t` for deterministic ones.
//! * `C_max^t = max_n C_n^t` — DOUBLEs received by the hottest node
//!   (x-axis of the right panels, §7).
//! * suboptimality `sum_n ||z_n - z*||^2 / N` (objective-style problems)
//!   and the AUC statistic (§7.3).
//! * the saddle merit series for minimax problems
//!   ([`crate::operators::SaddleStructure`]): the saddle residual
//!   `||sum_n (B_n + lambda I)(z_avg)||` and — when the problem exposes
//!   [`crate::operators::Problem::saddle_value`] — the restricted
//!   duality gap `L(x, y*) - L(x*, y)`, both 0 exactly at the saddle
//!   point and geometrically decreasing under DSBA.
//!
//! The module also defines [`NodeStatRow`]: the per-node metric row
//! split-hosted engines exchange over the transport's STATS control
//! frames so a cross-process run reports *global* series (its codec is
//! property-tested like the message wire codec).

use crate::data::Partition;
use crate::util::json::Json;

/// One sampled point of an experiment trace.
#[derive(Clone, Debug)]
pub struct MetricsRow {
    /// iteration index t
    pub iter: usize,
    /// effective passes over the local datasets
    pub passes: f64,
    /// max over nodes of DOUBLEs received so far (paper's C_max^t)
    pub comm_doubles: f64,
    /// max over nodes of declared wire bytes received so far; differs
    /// from `8 * comm_doubles` exactly when `--compress` shrinks frames
    pub comm_bytes: f64,
    /// mean over nodes of ||z_n - z*||^2 (consensus suboptimality)
    pub suboptimality: f64,
    /// global objective value (NaN for saddle problems)
    pub objective: f64,
    /// AUC statistic at the averaged iterate (NaN unless the problem
    /// declares `SaddleStat::AucRanking`)
    pub auc: f64,
    /// saddle residual at the averaged iterate (NaN unless the problem
    /// declares a saddle split)
    pub saddle_res: f64,
    /// restricted duality gap `L(x, y*) - L(x*, y)` at the averaged
    /// iterate (NaN unless the problem exposes `saddle_value`)
    pub saddle_gap: f64,
    /// wall-clock seconds since experiment start
    pub wall_secs: f64,
    /// max rounds-behind of any neighbor iterate consumed so far (0 for
    /// every synchronous driver; bounded by tau under `async:TAU`)
    pub max_staleness: u64,
    /// scheduler scans that sat blocked on a lagging neighbor so far
    /// (async engine only — the straggler cost the mode is built to cut)
    pub stalls: u64,
}

impl MetricsRow {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("iter", Json::Num(self.iter as f64)),
            ("passes", Json::Num(self.passes)),
            ("comm_doubles", Json::Num(self.comm_doubles)),
            ("comm_bytes", Json::Num(self.comm_bytes)),
            ("suboptimality", Json::Num(self.suboptimality)),
            ("objective", Json::Num(self.objective)),
            ("auc", Json::Num(self.auc)),
            ("saddle_res", Json::Num(self.saddle_res)),
            ("saddle_gap", Json::Num(self.saddle_gap)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("max_staleness", Json::Num(self.max_staleness as f64)),
            ("stalls", Json::Num(self.stalls as f64)),
        ])
    }
}

/// One node's contribution to a split run's global metrics: the owning
/// engine process fills these for its hosted nodes and peers exchange
/// them over the transport's end-of-round STATS control frames.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeStatRow {
    /// topology node index
    pub node: u32,
    /// component evaluations so far on this node (drives global passes)
    pub evals: u64,
    /// DOUBLEs received so far (exact: each process charges its hosted
    /// nodes' inflow through receive-side cost events)
    pub received: f64,
    /// declared wire bytes received so far (tracks the compressed frame
    /// sizes, not the abstract DOUBLE cost model)
    pub received_bytes: f64,
    /// the node's current iterate
    pub z: Vec<f64>,
}

/// Complete global row set of a split run plus the *global*
/// effective-pass denominator (`N q`, unscaled by the hosted share).
#[derive(Clone, Debug, PartialEq)]
pub struct GlobalStats {
    /// one row per topology node, sorted by node index
    pub rows: Vec<NodeStatRow>,
    /// `N * q` — global passes = sum of row evals / this
    pub pass_denom: f64,
}

/// Serialize stat rows for a STATS control frame (little-endian, f64 via
/// `to_bits` so the roundtrip is bit-exact — property-pinned).
pub fn encode_stat_rows(rows: &[NodeStatRow]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(rows.len() as u64).to_le_bytes());
    for r in rows {
        out.extend_from_slice(&r.node.to_le_bytes());
        out.extend_from_slice(&r.evals.to_le_bytes());
        out.extend_from_slice(&r.received.to_bits().to_le_bytes());
        out.extend_from_slice(&r.received_bytes.to_bits().to_le_bytes());
        out.extend_from_slice(&(r.z.len() as u64).to_le_bytes());
        for &v in &r.z {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    out
}

/// Decode a STATS payload. Total on arbitrary bytes — it reuses the
/// bounded wire reader behind `Message::decode`, so every length field
/// is validated against the remaining buffer before any allocation and
/// trailing bytes are rejected.
pub fn decode_stat_rows(buf: &[u8]) -> Result<Vec<NodeStatRow>, String> {
    let mut r = crate::comm::Reader::new(buf);
    // one row is at least node(4) + evals(8) + received(8) +
    // received_bytes(8) + z len(8)
    let n_rows = r.count("stat row count", 36)?;
    let mut rows = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let node = r.u32()?;
        let evals = r.u64()?;
        let received = r.f64()?;
        let received_bytes = r.f64()?;
        let z_len = r.count("iterate length", 8)?;
        let mut z = Vec::with_capacity(z_len);
        for _ in 0..z_len {
            z.push(r.f64()?);
        }
        rows.push(NodeStatRow { node, evals, received, received_bytes, z });
    }
    if r.remaining() != 0 {
        return Err(format!("{} trailing bytes after stat rows", r.remaining()));
    }
    Ok(rows)
}

/// Exact AUC of the linear scores `A w` over all samples in the
/// partition: the probability a random positive outranks a random
/// negative, ties counted 1/2 (Hanley & McNeil / Mann–Whitney).
///
/// `z` may be the augmented AUC variable (only the first `dim` entries
/// are read).
pub fn auc_score(part: &Partition, z: &[f64]) -> f64 {
    let mut scored: Vec<(f64, bool)> = Vec::with_capacity(part.total_samples());
    for (shard, labels) in part.shards.iter().zip(&part.labels) {
        for i in 0..shard.rows {
            scored.push((shard.row_dot(i, &z[..part.dim]), labels[i] > 0.0));
        }
    }
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let n_pos = scored.iter().filter(|s| s.1).count();
    let n_neg = scored.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // rank-sum with average ranks for ties
    let mut rank_sum_pos = 0.0;
    let mut i = 0;
    while i < scored.len() {
        let mut j = i;
        while j < scored.len() && scored[j].0 == scored[i].0 {
            j += 1;
        }
        let avg_rank = (i + j - 1) as f64 / 2.0 + 1.0; // 1-based
        for s in &scored[i..j] {
            if s.1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j;
    }
    (rank_sum_pos - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0)
        / (n_pos as f64 * n_neg as f64)
}

/// Mean squared distance of stacked iterates from `z*`:
/// `(1/N) sum_n ||z_n - z*||^2`.
pub fn suboptimality(zs: &[Vec<f64>], z_star: &[f64]) -> f64 {
    if zs.is_empty() {
        return 0.0;
    }
    zs.iter()
        .map(|z| crate::linalg::dist2_sq(z, z_star))
        .sum::<f64>()
        / zs.len() as f64
}

/// Write a trace as a JSON file `{series: [rows...], meta: {...}}`.
pub fn write_trace_json(
    path: &str,
    meta: Vec<(&str, Json)>,
    rows: &[MetricsRow],
) -> std::io::Result<()> {
    let doc = Json::from_pairs(vec![
        ("meta", Json::from_pairs(meta)),
        ("series", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ]);
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, doc.to_string())
}

/// Render rows as an aligned text table (the bench harness's stdout
/// format, one row per sampled point).
pub fn format_table(rows: &[MetricsRow]) -> String {
    let mut out = String::from(
        "  iter      passes   comm_doubles   suboptimality      objective     \
         saddle_res        auc\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:>6}  {:>10.2}  {:>13.3e}  {:>14.6e}  {:>13.6e}  {:>13.6e}  {:>9.4}\n",
            r.iter,
            r.passes,
            r.comm_doubles,
            r.suboptimality,
            r.objective,
            r.saddle_res,
            r.auc
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn auc_of_perfect_separator_is_one() {
        let ds = SyntheticSpec::tiny().with_samples(60).generate(1);
        let part = ds.partition(3);
        // build w that scores positives high by construction: w = sum y_i a_i
        let mut w = vec![0.0; part.dim + 3];
        for (shard, ys) in part.shards.iter().zip(&part.labels) {
            for i in 0..shard.rows {
                shard.row_axpy(i, ys[i] * 100.0, &mut w[..part.dim]);
            }
        }
        // not necessarily perfect, but must beat chance decisively
        let auc = auc_score(&part, &w);
        assert!(auc > 0.7, "auc {auc}");
        // and the reversed scorer must be symmetric around 1/2
        let neg: Vec<f64> = w.iter().map(|v| -v).collect();
        let auc_neg = auc_score(&part, &neg);
        assert!((auc + auc_neg - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auc_of_zero_scores_is_half() {
        let ds = SyntheticSpec::tiny().with_samples(40).generate(2);
        let part = ds.partition(2);
        let z = vec![0.0; part.dim + 3];
        assert!((auc_score(&part, &z) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn suboptimality_zero_at_star() {
        let star = vec![1.0, 2.0, 3.0];
        let zs = vec![star.clone(), star.clone()];
        assert_eq!(suboptimality(&zs, &star), 0.0);
        let zs2 = vec![vec![2.0, 2.0, 3.0], star.clone()];
        assert!((suboptimality(&zs2, &star) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn table_formats_all_rows() {
        let rows = vec![MetricsRow {
            iter: 10,
            passes: 1.0,
            comm_doubles: 1e4,
            comm_bytes: 8e4,
            suboptimality: 1e-5,
            objective: 0.5,
            auc: f64::NAN,
            saddle_res: 1e-3,
            saddle_gap: f64::NAN,
            wall_secs: 0.1,
            max_staleness: 0,
            stalls: 0,
        }];
        let t = format_table(&rows);
        assert!(t.contains("passes"));
        assert!(t.contains("saddle_res"));
        assert_eq!(t.lines().count(), 2);
    }

    #[test]
    fn stat_rows_roundtrip_bit_exact() {
        let rows = vec![
            NodeStatRow {
                node: 0,
                evals: 41,
                received: 1234.5,
                received_bytes: 9876.0,
                z: vec![0.0, -0.0, 1.5, f64::MIN_POSITIVE],
            },
            NodeStatRow {
                node: 3,
                evals: 0,
                received: 0.0,
                received_bytes: 0.0,
                z: vec![],
            },
        ];
        let enc = encode_stat_rows(&rows);
        let back = decode_stat_rows(&enc).unwrap();
        assert_eq!(back, rows);
        // bit-exactness beyond PartialEq (signed zeros)
        assert_eq!(encode_stat_rows(&back), enc);
        // empty set roundtrips too
        assert_eq!(decode_stat_rows(&encode_stat_rows(&[])).unwrap(), vec![]);
    }

    #[test]
    fn stat_row_decode_rejects_corrupt_payloads() {
        let rows = vec![NodeStatRow {
            node: 7,
            evals: 9,
            received: 2.5,
            received_bytes: 52.0,
            z: vec![1.0, 2.0],
        }];
        let enc = encode_stat_rows(&rows);
        for k in 0..enc.len() {
            assert!(decode_stat_rows(&enc[..k]).is_err(), "prefix {k} decoded Ok");
        }
        // huge row count must error before allocating
        let mut b = enc.clone();
        b[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_stat_rows(&b).is_err());
        // trailing garbage rejected
        let mut b = enc.clone();
        b.push(0);
        assert!(decode_stat_rows(&b).is_err());
    }
}
