//! # dsba — Decentralized Stochastic Backward Aggregation
//!
//! A full-system reproduction of *"Towards More Efficient Stochastic
//! Decentralized Learning: Faster Convergence and Sparse Communication"*
//! (Shen, Mokhtari, Zhou, Zhao, Qian — ICML 2018).
//!
//! The crate is the Layer-3 (coordination) half of a three-layer stack:
//!
//! * **L3 (this crate)** — the decentralized runtime: graph topologies and
//!   mixing matrices, an in-process message-passing network simulator with
//!   per-node DOUBLE accounting, the DSBA / DSBA-s algorithms and every
//!   baseline from the paper's Table 1 (each decomposed into per-node
//!   [`algorithms::NodeState`] machines, driven either by the sequential
//!   reference driver or bit-for-bit-identically by the multi-threaded
//!   [`runtime::ParallelEngine`]), problem operators with closed-form
//!   or Newton resolvents, metrics, a config system, and a CLI launcher.
//! * **L2/L1 (python/, build-time only)** — JAX compute graphs calling
//!   Pallas kernels, AOT-lowered to HLO text under `artifacts/` and
//!   executed from [`runtime`] through the XLA PJRT CPU client.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dsba::prelude::*;
//!
//! let ds = SyntheticSpec::rcv1_like().with_samples(2_000).with_dim(512)
//!     .generate(7);
//! let topo = Topology::erdos_renyi(10, 0.4, 42);
//! let problem = RidgeProblem::new(ds.partition(10), 1e-3);
//! let mut exp = Experiment::builder(problem, topo, AlgorithmKind::Dsba)
//!     .step_size(0.5)
//!     .passes(20.0)
//!     .build();
//! let trace = exp.run();
//! println!("final suboptimality: {:.3e}", trace.last_suboptimality());
//! ```
//!
//! Problems are pluggable: anything expressible as component monotone
//! operators registers itself in [`operators::ProblemRegistry`] (name,
//! aliases, capability metadata, constructor) and is then reachable from
//! JSON configs, every CLI subcommand, and the bench harness with no
//! change to the algorithms, runtime, or communication layers — see the
//! registry module docs for the recipe.

pub mod util;
pub mod linalg;
pub mod graph;
pub mod data;
pub mod operators;
pub mod algorithms;
pub mod comm;
pub mod coordinator;
pub mod metrics;
pub mod config;
pub mod runtime;
pub mod solvers;
pub mod telemetry;
pub mod bench_harness;
pub mod cli;
pub mod testing;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::{Algorithm, AlgorithmKind};
    pub use crate::comm::{CommCostModel, Network};
    pub use crate::config::ExperimentConfig;
    pub use crate::coordinator::{Experiment, ExperimentBuilder, Trace};
    pub use crate::data::{Dataset, Partition, SyntheticSpec};
    pub use crate::graph::{MixingMatrix, Topology};
    pub use crate::linalg::{CsrMatrix, DenseMatrix, SparseVec};
    pub use crate::metrics::MetricsRow;
    pub use crate::operators::{
        AucProblem, DroBilinearProblem, LogisticProblem, Problem, ProblemRegistry,
        ProblemSpec, RidgeProblem, RobustLsProblem, SaddleStat, SaddleStructure,
    };
    pub use crate::runtime::{
        EngineKind, EngineSpec, FaultSpec, ModeSpec, ParallelEngine, ProgressProbe,
        TcpSpec, TcpTransport, TransportKind,
    };
    pub use crate::telemetry::TelemetrySpec;
    pub use crate::util::rng::Rng;
}
