//! Command-line launcher (`dsba <subcommand>`), hand-rolled since clap is
//! not in the vendor set.
//!
//! Subcommands:
//!
//! ```text
//! run             --config <file.json> | inline flags   run one experiment
//! figure          <1|2|3>                                regenerate a figure
//! info            --dataset <name> --nodes <n> ...       problem/method/dataset info
//! artifacts                                              check XLA artifacts
//! telemetry-check <run.jsonl>                            validate a telemetry stream
//! help
//! ```
//!
//! The problem and method listings in `help` and `info` are generated
//! from [`ProblemRegistry`] and [`AlgorithmKind::all`], so the text
//! cannot drift from what the binary actually accepts.

use crate::algorithms::AlgorithmKind;
use crate::bench_harness::FigureSpec;
use crate::config::ExperimentConfig;
use crate::graph::TopologyKind;
use crate::metrics::format_table;
use crate::operators::{Problem, ProblemRegistry};
use crate::runtime::{EngineKind, ModeSpec, TransportKind};
use crate::util::json;

pub fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = dispatch(&args);
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("figure") => cmd_figure(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        Some("problems") => {
            // machine-readable canonical names, one per line — the
            // registry-driven loop behind `make smoke`
            for name in ProblemRegistry::builtin().names() {
                println!("{name}");
            }
            0
        }
        Some("artifacts") => cmd_artifacts(),
        Some("telemetry-check") => cmd_telemetry_check(&args[1..]),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    }
}

/// Registry-derived list of accepted problem names.
fn problem_list() -> String {
    ProblemRegistry::builtin().names().join("|")
}

/// Table-derived list of accepted method names.
fn method_list() -> String {
    AlgorithmKind::all()
        .iter()
        .map(|k| k.name())
        .collect::<Vec<_>>()
        .join("|")
}

fn print_help() {
    println!(
        "dsba — decentralized stochastic backward aggregation (ICML 2018 reproduction)

USAGE:
  dsba run [--config FILE] [--problem {problems}]
           [--params JSON] [--dataset NAME]
           [--algorithm {methods}]
           [--alpha X] [--passes X] [--nodes N]
           [--topology KIND] [--samples N] [--dim N] [--seed N]
           [--engine sequential|parallel] [--threads N]
           [--mode sync|async:TAU]
           (round clock; parallel engine only. sync runs barrier
            rounds; async:TAU lets nodes run ahead with bounded
            staleness TAU — async:0 is bit-for-bit identical to sync)
           [--transport local|tcp] [--listen ADDR] [--peers N=ADDR,..]
           [--hosted SPEC]
           [--compress none|identity|topk:K|randk:K|qsgd:L]
           (wire compression with CHOCO error feedback at the transport
            boundary; parallel engine only. comm_bytes in the output
            tracks the declared bytes-on-wire next to the DOUBLE model)
           (tcp transport: every edge crosses a loopback/host socket;
            default hosts all nodes on loopback. --hosted \"0-4\" +
            --peers \"5=host:port,...\" splits one run across engine
            processes, each reporting metrics for its own nodes)
           [--fault drop:P,dup:P,delay:MS[@NODE],kill:NODE@ROUND]
           (deterministic fault injection; parallel engine only.
            drop/dup perturb MSG frames on the wire and need
            --transport tcp, whose link layer recovers them — runs
            stay bit-identical to fault-free. delay stalls a node
            per round; kill fails the run fast with a named error)
           [--telemetry FILE.jsonl] [--telemetry-max-bytes N]
           [--telemetry-keep N]
           (per-round per-node JSONL telemetry: residual, DOUBLEs,
            bytes-on-wire, staleness, stalls, link fault counters.
            Rotates at max-bytes, keeping N rotated files)
  dsba figure <1|2|3>     regenerate Figure 1 (ridge) / 2 (logistic) / 3 (AUC)
  dsba info [--dataset NAME] [--nodes N]   registry capability table, methods,
                          dataset stats (saddle / l1 / resolvent per problem)
  dsba problems           canonical problem names, one per line (for scripting)
  dsba artifacts          verify the XLA artifact directory
  dsba telemetry-check <run.jsonl>   validate every row of a telemetry stream
                          against the versioned schema (exit 0 = well-formed)
  dsba help",
        problems = problem_list(),
        methods = method_list(),
    );
}

/// Tiny flag parser: --key value pairs.
fn flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut map = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn cmd_run(args: &[String]) -> i32 {
    let f = flags(args);
    let mut cfg = if let Some(path) = f.get("config") {
        match std::fs::read_to_string(path)
            .map_err(|e| e.to_string())
            .and_then(|s| ExperimentConfig::from_json(&s))
        {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    } else {
        ExperimentConfig::default()
    };
    if let Some(v) = f.get("problem") {
        match ProblemRegistry::builtin().canonical(v) {
            Some(name) => cfg.problem = name.to_string(),
            None => {
                eprintln!("bad --problem {v} (available: {})", problem_list());
                return 2;
            }
        }
    }
    if let Some(v) = f.get("params") {
        match json::parse(v) {
            Ok(p) => cfg.problem_params = p,
            Err(e) => {
                eprintln!("bad --params {v}: {e}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("dataset") {
        cfg.dataset = v.clone();
    }
    if let Some(v) = f.get("algorithm") {
        match AlgorithmKind::parse(v) {
            Some(a) => cfg.algorithm = a,
            None => {
                eprintln!("bad --algorithm {v} (available: {})", method_list());
                return 2;
            }
        }
    }
    if let Some(v) = f.get("topology") {
        match TopologyKind::parse(v) {
            Some(t) => cfg.topology = t,
            None => {
                eprintln!("bad --topology {v}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("engine") {
        match EngineKind::parse(v) {
            Some(e) => cfg.engine.kind = e,
            None => {
                eprintln!("bad --engine {v} (sequential|parallel)");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("transport") {
        match TransportKind::parse(v) {
            Some(t) => cfg.engine.transport = t,
            None => {
                eprintln!("bad --transport {v} (local|tcp)");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("listen") {
        cfg.engine.tcp.listen = v.clone();
    }
    if let Some(v) = f.get("peers") {
        cfg.engine.tcp.peers = v.clone();
    }
    if let Some(v) = f.get("hosted") {
        cfg.engine.tcp.hosted = v.clone();
    }
    if let Some(v) = f.get("compress") {
        match crate::comm::CompressionSpec::parse(v) {
            Ok(s) => cfg.engine.compress = s,
            Err(e) => {
                eprintln!("bad --compress: {e}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("mode") {
        match ModeSpec::parse(v) {
            Some(m) => cfg.engine.mode = m,
            None => {
                eprintln!("bad --mode {v} (sync|async:TAU)");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("fault") {
        match crate::runtime::FaultSpec::parse(v) {
            Ok(s) => cfg.engine.fault = s,
            Err(e) => {
                eprintln!("bad --fault: {e}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("telemetry") {
        cfg.engine.telemetry = crate::telemetry::TelemetrySpec::to_path(v);
    }
    macro_rules! num {
        ($key:expr, $field:expr, $ty:ty) => {
            if let Some(v) = f.get($key) {
                match v.parse::<$ty>() {
                    Ok(x) => $field = x,
                    Err(_) => {
                        eprintln!("bad --{} {v}", $key);
                        return 2;
                    }
                }
            }
        };
    }
    num!("alpha", cfg.alpha, f64);
    num!("passes", cfg.passes, f64);
    num!("nodes", cfg.nodes, usize);
    num!("samples", cfg.samples, usize);
    num!("dim", cfg.dim, usize);
    num!("seed", cfg.seed, u64);
    num!("lambda", cfg.lambda, f64);
    num!("threads", cfg.engine.threads, usize);
    num!("telemetry-max-bytes", cfg.engine.telemetry.max_bytes, u64);
    num!("telemetry-keep", cfg.engine.telemetry.keep, usize);

    println!("config: {}", cfg.to_json());
    let mut exp = match cfg.build() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("build error: {e}");
            return 1;
        }
    };
    println!(
        "graph: kappa_g = {:.2}, diameter = {}, max degree = {}",
        exp.mix.kappa_g,
        exp.topo.diameter,
        exp.topo.max_degree()
    );
    if cfg.engine.kind == EngineKind::Parallel {
        let t = if cfg.engine.threads == 0 {
            crate::runtime::engine::auto_threads(cfg.nodes)
        } else {
            cfg.engine.threads
        };
        println!(
            "engine: parallel, {t} worker thread(s), {} transport, {} clock",
            cfg.engine.transport.name(),
            cfg.engine.mode.name()
        );
    } else {
        if cfg.engine.transport == TransportKind::Tcp {
            eprintln!("note: --transport tcp only applies to --engine parallel; ignored");
        }
        if cfg.engine.mode.is_async() {
            eprintln!(
                "note: --mode {} only applies to --engine parallel; the \
                 sequential oracle is synchronous by definition",
                cfg.engine.mode.name()
            );
        }
    }
    if cfg.engine.transport == TransportKind::Local && !cfg.engine.tcp.is_empty() {
        eprintln!(
            "note: --hosted/--peers/--listen only apply to --transport tcp; \
             ignored (this process will simulate ALL nodes in-process)"
        );
    }
    if exp.problem.l1_weight() > 0.0 && !cfg.algorithm.is_proximal() {
        eprintln!(
            "note: {} is not a proximal (backward) method — the problem's l1 \
             term is resolved only by DSBA/DSBA-s/Point-SAGA; this run \
             optimizes the smooth part and is scored against the l1-aware \
             optimum",
            cfg.algorithm.name()
        );
    }
    let trace = match exp.try_run() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("run error: {e}");
            return 1;
        }
    };
    println!("{}", format_table(&trace.rows));
    println!(
        "final: suboptimality {:.3e}, comm {:.3e} doubles, {:.3e} wire bytes",
        trace.last_suboptimality(),
        trace.final_comm(),
        trace.final_comm_bytes()
    );
    0
}

fn cmd_figure(args: &[String]) -> i32 {
    let which = args.first().map(String::as_str).unwrap_or("1");
    let (title, problem, methods) = match which {
        "1" => ("Figure 1: Ridge Regression", "ridge", None),
        "2" => ("Figure 2: Logistic Regression", "logistic", None),
        "3" => (
            "Figure 3: AUC maximization",
            "auc",
            Some(vec![AlgorithmKind::Dsba, AlgorithmKind::Dsa, AlgorithmKind::Extra]),
        ),
        _ => {
            eprintln!("figure must be 1, 2 or 3");
            return 2;
        }
    };
    let mut spec = FigureSpec::defaults(problem);
    spec.title = title;
    if let Some(m) = methods {
        spec.methods = m;
    }
    let runs = spec.run();
    crate::bench_harness::summarize(&runs, spec.score_stat());
    0
}

fn cmd_info(args: &[String]) -> i32 {
    let f = flags(args);

    // problem registry and method table first: `info` is the live
    // answer to "what can this binary run?"
    println!("registered problems:");
    print!("{}", ProblemRegistry::builtin().describe());
    println!("\nmethods:");
    for k in AlgorithmKind::all() {
        let aliases = k.aliases();
        println!(
            "  {:<11} {}{}",
            k.name(),
            if k.is_stochastic() { "stochastic" } else { "deterministic" },
            if aliases.is_empty() {
                String::new()
            } else {
                format!("  (aliases: {})", aliases.join(", "))
            }
        );
    }
    println!();

    let mut cfg = ExperimentConfig::default();
    if let Some(v) = f.get("dataset") {
        cfg.dataset = v.clone();
    }
    if let Some(v) = f.get("nodes").and_then(|v| v.parse().ok()) {
        cfg.nodes = v;
    }
    match cfg.build_dataset() {
        Ok(ds) => {
            let part = ds.partition(cfg.nodes);
            println!(
                "dataset {}: Q = {}, d = {}, rho = {:.3e}, positive ratio = {:.3}",
                ds.name,
                ds.samples(),
                ds.dim(),
                ds.density(),
                ds.positive_ratio()
            );
            println!(
                "partition: N = {}, q = {}, max shard rho = {:.3e}",
                part.nodes(),
                part.q,
                part.max_shard_density()
            );
            let topo = crate::graph::Topology::generate(
                cfg.topology,
                cfg.nodes,
                cfg.edge_prob,
                cfg.seed ^ 0x109,
            );
            let mix = crate::graph::MixingMatrix::laplacian(&topo, 1.0);
            println!(
                "graph {}: diameter = {}, max degree = {}, gamma = {:.4}, kappa_g = {:.2}",
                cfg.topology.name(),
                topo.diameter,
                topo.max_degree(),
                mix.gamma,
                mix.kappa_g
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

/// `dsba telemetry-check <run.jsonl>` — validate every line of a
/// telemetry stream against the versioned row schema.  Exit 0 means the
/// file is well-formed JSONL and every row carries every schema field
/// with the right type; the row count is printed so scripts can assert
/// completeness (`rounds * nodes` rows for a fault-free run).
fn cmd_telemetry_check(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: dsba telemetry-check <run.jsonl>");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("telemetry-check: cannot read {path}: {e}");
            return 1;
        }
    };
    match crate::telemetry::validate_jsonl(&text) {
        Ok(rows) => {
            println!(
                "telemetry OK: {rows} row(s), schema v{}",
                crate::telemetry::TELEMETRY_SCHEMA_VERSION
            );
            0
        }
        Err(e) => {
            eprintln!("telemetry-check: {path}: {e}");
            1
        }
    }
}

fn cmd_artifacts() -> i32 {
    match crate::runtime::XlaRuntime::load_default() {
        Ok(rt) => {
            let m = rt.manifest();
            println!(
                "artifacts OK: {} entries, functions: {:?}",
                m.entries.len(),
                m.fn_names()
            );
            if !rt.has_backend() {
                println!(
                    "note: manifest validated, but the PJRT execution backend is \
                     not compiled in (build with --features pjrt to execute)"
                );
            }
            0
        }
        Err(e) => {
            eprintln!("artifacts check failed: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parser_handles_pairs_and_bools() {
        let args: Vec<String> = ["--alpha", "0.5", "--verbose", "--nodes", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = flags(&args);
        assert_eq!(f.get("alpha").unwrap(), "0.5");
        assert_eq!(f.get("verbose").unwrap(), "true");
        assert_eq!(f.get("nodes").unwrap(), "4");
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert_eq!(dispatch(&["bogus".to_string()]), 2);
    }

    #[test]
    fn telemetry_check_validates_files() {
        // no path → usage error
        assert_eq!(dispatch(&["telemetry-check".to_string()]), 2);
        // missing file → runtime error
        assert_eq!(
            dispatch(&[
                "telemetry-check".to_string(),
                "/nonexistent/definitely-not-here.jsonl".to_string()
            ]),
            1
        );
        // well-formed and corrupt streams round through validate_jsonl
        let dir = std::env::temp_dir().join(format!("dsba_cli_tc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let row = crate::telemetry::TelemetryRow {
            round: 0,
            node: 1,
            residual: 0.5,
            ..crate::telemetry::TelemetryRow::default()
        };
        let good = dir.join("good.jsonl");
        std::fs::write(&good, format!("{}\n", row.to_json_line())).unwrap();
        assert_eq!(
            dispatch(&["telemetry-check".to_string(), good.display().to_string()]),
            0
        );
        let bad = dir.join("bad.jsonl");
        std::fs::write(&bad, "{\"round\":0}\n").unwrap();
        assert_eq!(
            dispatch(&["telemetry-check".to_string(), bad.display().to_string()]),
            1
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_flags_accept_fault_and_telemetry() {
        let args: Vec<String> = ["--fault", "drop:0.05,dup:0.1", "--telemetry", "t.jsonl"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = flags(&args);
        assert_eq!(f.get("fault").unwrap(), "drop:0.05,dup:0.1");
        assert_eq!(f.get("telemetry").unwrap(), "t.jsonl");
        assert!(crate::runtime::FaultSpec::parse(f.get("fault").unwrap()).is_ok());
    }

    #[test]
    fn help_succeeds() {
        assert_eq!(dispatch(&["help".to_string()]), 0);
    }

    #[test]
    fn info_enumerates_registries() {
        // `info` must succeed with no flags, enumerating problems and
        // methods straight from the registries
        assert_eq!(dispatch(&["info".to_string()]), 0);
    }

    #[test]
    fn problems_lists_canonical_names() {
        assert_eq!(dispatch(&["problems".to_string()]), 0);
    }

    #[test]
    fn info_capability_table_covers_every_entry() {
        // the `dsba info` capability table is generated from live
        // registry metadata: every entry's resolvent kind shows up, and
        // saddle entries are marked
        let table = ProblemRegistry::builtin().describe();
        for e in ProblemRegistry::builtin().entries() {
            assert!(table.contains(e.meta.name), "{} missing", e.meta.name);
            assert!(
                table.contains(e.meta.resolvent.name()),
                "{} resolvent kind missing",
                e.meta.name
            );
        }
        for col in ["saddle", "l1", "resolvent"] {
            assert!(table.contains(col), "capability column {col} missing");
        }
    }

    #[test]
    fn listings_cover_every_registration() {
        let problems = problem_list();
        for name in ProblemRegistry::builtin().names() {
            assert!(problems.contains(name), "{name} missing from help text");
        }
        let methods = method_list();
        for k in AlgorithmKind::all() {
            assert!(methods.contains(k.name()), "{} missing from help text", k.name());
        }
    }
}
