//! Command-line launcher (`dsba <subcommand>`), hand-rolled since clap is
//! not in the vendor set.
//!
//! Subcommands:
//!
//! ```text
//! run             --config <file.json> | inline flags   run one experiment
//! figure          <1|2|3>                                regenerate a figure
//! info            --dataset <name> --nodes <n> ...       problem/method/dataset info
//! artifacts                                              check XLA artifacts
//! telemetry-check <run.jsonl>                            validate + summarize a stream
//! report          <run.jsonl> [--json]                   analyze a telemetry stream
//! trace export    <run.jsonl> [--format chrome]          export a Chrome/Perfetto trace
//! watch           <run.jsonl> [--once]                   tail a growing stream live
//! bench-compare   <old.json> <new.json> [--tol PCT]      diff two bench snapshots
//! help
//! ```
//!
//! The problem and method listings in `help` and `info` are generated
//! from [`ProblemRegistry`] and [`AlgorithmKind::all`], so the text
//! cannot drift from what the binary actually accepts.

use crate::algorithms::AlgorithmKind;
use crate::bench_harness::FigureSpec;
use crate::config::ExperimentConfig;
use crate::graph::TopologyKind;
use crate::metrics::format_table;
use crate::operators::{Problem, ProblemRegistry};
use crate::runtime::{EngineKind, ModeSpec, TransportKind};
use crate::util::json;

pub fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = dispatch(&args);
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("figure") => cmd_figure(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        Some("problems") => {
            // machine-readable canonical names, one per line — the
            // registry-driven loop behind `make smoke`
            for name in ProblemRegistry::builtin().names() {
                println!("{name}");
            }
            0
        }
        Some("artifacts") => cmd_artifacts(),
        Some("telemetry-check") => cmd_telemetry_check(&args[1..]),
        Some("report") => cmd_report(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("watch") => cmd_watch(&args[1..]),
        Some("bench-compare") => cmd_bench_compare(&args[1..]),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    }
}

/// Registry-derived list of accepted problem names.
fn problem_list() -> String {
    ProblemRegistry::builtin().names().join("|")
}

/// Table-derived list of accepted method names.
fn method_list() -> String {
    AlgorithmKind::all()
        .iter()
        .map(|k| k.name())
        .collect::<Vec<_>>()
        .join("|")
}

fn print_help() {
    println!(
        "dsba — decentralized stochastic backward aggregation (ICML 2018 reproduction)

USAGE:
  dsba run [--config FILE] [--problem {problems}]
           [--params JSON] [--dataset NAME]
           [--algorithm {methods}]
           [--alpha X] [--passes X] [--nodes N]
           [--topology KIND] [--samples N] [--dim N] [--seed N]
           [--engine sequential|parallel] [--threads N]
           [--mode sync|async:TAU]
           (round clock; parallel engine only. sync runs barrier
            rounds; async:TAU lets nodes run ahead with bounded
            staleness TAU — async:0 is bit-for-bit identical to sync)
           [--transport local|tcp] [--listen ADDR] [--peers N=ADDR,..]
           [--hosted SPEC]
           [--compress none|identity|topk:K|randk:K|qsgd:L]
           (wire compression with CHOCO error feedback at the transport
            boundary; parallel engine only. comm_bytes in the output
            tracks the declared bytes-on-wire next to the DOUBLE model)
           (tcp transport: every edge crosses a loopback/host socket;
            default hosts all nodes on loopback. --hosted \"0-4\" +
            --peers \"5=host:port,...\" splits one run across engine
            processes, each reporting metrics for its own nodes)
           [--fault drop:P,dup:P,delay:MS[@NODE],kill:NODE@ROUND]
           (deterministic fault injection; parallel engine only.
            drop/dup perturb MSG frames on the wire and need
            --transport tcp, whose link layer recovers them — runs
            stay bit-identical to fault-free. delay stalls a node
            per round; kill fails the run fast with a named error)
           [--telemetry FILE.jsonl] [--telemetry-max-bytes N]
           [--telemetry-keep N]
           (per-round per-node JSONL telemetry: residual, DOUBLEs,
            bytes-on-wire, staleness, stalls, link fault counters,
            and schema-v2 phase spans — wait/drain/compute/encode/send
            microseconds per round. Rotates at max-bytes, keeping N
            rotated files)
  dsba figure <1|2|3>     regenerate Figure 1 (ridge) / 2 (logistic) / 3 (AUC)
  dsba info [--dataset NAME] [--nodes N]   registry capability table, methods,
                          dataset stats (saddle / l1 / resolvent per problem)
  dsba problems           canonical problem names, one per line (for scripting)
  dsba artifacts          verify the XLA artifact directory
  dsba telemetry-check <run.jsonl>   validate a telemetry stream against the
                          versioned schema and print a summary (rows, nodes,
                          rounds, fault totals, writer drops). Exit 0 =
                          well-formed with no round gaps
  dsba report <run.jsonl> [--json]   analyze a stream: fitted geometric
                          convergence rate, per-node phase breakdown,
                          straggler attribution, bytes-vs-DOUBLEs budget
  dsba trace export <run.jsonl> [--format chrome] [--out FILE]   export a
                          stream as Chrome trace-event JSON (load in
                          Perfetto / chrome://tracing): phase spans as
                          per-node complete events, control-plane events
                          as instants. Writes stdout unless --out
  dsba watch <run.jsonl> [--interval-ms MS] [--once]   tail a growing
                          stream: one refreshing line with front round,
                          mean residual, staleness, and stall detection
                          naming the lagging node. Exits when the
                          writer's trailing summary arrives (--once
                          prints a single snapshot)
  dsba bench-compare <old.json> <new.json> [--tol PCT]   diff two bench
                          snapshots (results/BENCH_*.json); exit 1 when a
                          metric regressed beyond PCT (default 10) or a
                          sweep cell disappeared
  dsba help",
        problems = problem_list(),
        methods = method_list(),
    );
}

/// Tiny flag parser: --key value pairs.
fn flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut map = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn cmd_run(args: &[String]) -> i32 {
    let f = flags(args);
    let mut cfg = if let Some(path) = f.get("config") {
        match std::fs::read_to_string(path)
            .map_err(|e| e.to_string())
            .and_then(|s| ExperimentConfig::from_json(&s))
        {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    } else {
        ExperimentConfig::default()
    };
    if let Some(v) = f.get("problem") {
        match ProblemRegistry::builtin().canonical(v) {
            Some(name) => cfg.problem = name.to_string(),
            None => {
                eprintln!("bad --problem {v} (available: {})", problem_list());
                return 2;
            }
        }
    }
    if let Some(v) = f.get("params") {
        match json::parse(v) {
            Ok(p) => cfg.problem_params = p,
            Err(e) => {
                eprintln!("bad --params {v}: {e}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("dataset") {
        cfg.dataset = v.clone();
    }
    if let Some(v) = f.get("algorithm") {
        match AlgorithmKind::parse(v) {
            Some(a) => cfg.algorithm = a,
            None => {
                eprintln!("bad --algorithm {v} (available: {})", method_list());
                return 2;
            }
        }
    }
    if let Some(v) = f.get("topology") {
        match TopologyKind::parse(v) {
            Some(t) => cfg.topology = t,
            None => {
                eprintln!("bad --topology {v}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("engine") {
        match EngineKind::parse(v) {
            Some(e) => cfg.engine.kind = e,
            None => {
                eprintln!("bad --engine {v} (sequential|parallel)");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("transport") {
        match TransportKind::parse(v) {
            Some(t) => cfg.engine.transport = t,
            None => {
                eprintln!("bad --transport {v} (local|tcp)");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("listen") {
        cfg.engine.tcp.listen = v.clone();
    }
    if let Some(v) = f.get("peers") {
        cfg.engine.tcp.peers = v.clone();
    }
    if let Some(v) = f.get("hosted") {
        cfg.engine.tcp.hosted = v.clone();
    }
    if let Some(v) = f.get("compress") {
        match crate::comm::CompressionSpec::parse(v) {
            Ok(s) => cfg.engine.compress = s,
            Err(e) => {
                eprintln!("bad --compress: {e}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("mode") {
        match ModeSpec::parse(v) {
            Some(m) => cfg.engine.mode = m,
            None => {
                eprintln!("bad --mode {v} (sync|async:TAU)");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("fault") {
        match crate::runtime::FaultSpec::parse(v) {
            Ok(s) => cfg.engine.fault = s,
            Err(e) => {
                eprintln!("bad --fault: {e}");
                return 2;
            }
        }
    }
    if let Some(v) = f.get("telemetry") {
        cfg.engine.telemetry = crate::telemetry::TelemetrySpec::to_path(v);
    }
    macro_rules! num {
        ($key:expr, $field:expr, $ty:ty) => {
            if let Some(v) = f.get($key) {
                match v.parse::<$ty>() {
                    Ok(x) => $field = x,
                    Err(_) => {
                        eprintln!("bad --{} {v}", $key);
                        return 2;
                    }
                }
            }
        };
    }
    num!("alpha", cfg.alpha, f64);
    num!("passes", cfg.passes, f64);
    num!("nodes", cfg.nodes, usize);
    num!("samples", cfg.samples, usize);
    num!("dim", cfg.dim, usize);
    num!("seed", cfg.seed, u64);
    num!("lambda", cfg.lambda, f64);
    num!("threads", cfg.engine.threads, usize);
    num!("telemetry-max-bytes", cfg.engine.telemetry.max_bytes, u64);
    num!("telemetry-keep", cfg.engine.telemetry.keep, usize);

    println!("config: {}", cfg.to_json());
    let mut exp = match cfg.build() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("build error: {e}");
            return 1;
        }
    };
    println!(
        "graph: kappa_g = {:.2}, diameter = {}, max degree = {}",
        exp.mix.kappa_g,
        exp.topo.diameter,
        exp.topo.max_degree()
    );
    if cfg.engine.kind == EngineKind::Parallel {
        let t = if cfg.engine.threads == 0 {
            crate::runtime::engine::auto_threads(cfg.nodes)
        } else {
            cfg.engine.threads
        };
        println!(
            "engine: parallel, {t} worker thread(s), {} transport, {} clock",
            cfg.engine.transport.name(),
            cfg.engine.mode.name()
        );
    } else {
        if cfg.engine.transport == TransportKind::Tcp {
            eprintln!("note: --transport tcp only applies to --engine parallel; ignored");
        }
        if cfg.engine.mode.is_async() {
            eprintln!(
                "note: --mode {} only applies to --engine parallel; the \
                 sequential oracle is synchronous by definition",
                cfg.engine.mode.name()
            );
        }
    }
    if cfg.engine.transport == TransportKind::Local && !cfg.engine.tcp.is_empty() {
        eprintln!(
            "note: --hosted/--peers/--listen only apply to --transport tcp; \
             ignored (this process will simulate ALL nodes in-process)"
        );
    }
    if exp.problem.l1_weight() > 0.0 && !cfg.algorithm.is_proximal() {
        eprintln!(
            "note: {} is not a proximal (backward) method — the problem's l1 \
             term is resolved only by DSBA/DSBA-s/Point-SAGA; this run \
             optimizes the smooth part and is scored against the l1-aware \
             optimum",
            cfg.algorithm.name()
        );
    }
    let trace = match exp.try_run() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("run error: {e}");
            return 1;
        }
    };
    println!("{}", format_table(&trace.rows));
    // surface writer drops on the same final line scripts already scrape:
    // a nonzero count means the JSONL stream under-reports the run
    let telem = match trace.telemetry_dropped {
        Some(d) => format!(", telemetry dropped {d} row(s)"),
        None => String::new(),
    };
    println!(
        "final: suboptimality {:.3e}, comm {:.3e} doubles, {:.3e} wire bytes{telem}",
        trace.last_suboptimality(),
        trace.final_comm(),
        trace.final_comm_bytes()
    );
    0
}

fn cmd_figure(args: &[String]) -> i32 {
    let which = args.first().map(String::as_str).unwrap_or("1");
    let (title, problem, methods) = match which {
        "1" => ("Figure 1: Ridge Regression", "ridge", None),
        "2" => ("Figure 2: Logistic Regression", "logistic", None),
        "3" => (
            "Figure 3: AUC maximization",
            "auc",
            Some(vec![AlgorithmKind::Dsba, AlgorithmKind::Dsa, AlgorithmKind::Extra]),
        ),
        _ => {
            eprintln!("figure must be 1, 2 or 3");
            return 2;
        }
    };
    let mut spec = FigureSpec::defaults(problem);
    spec.title = title;
    if let Some(m) = methods {
        spec.methods = m;
    }
    let runs = spec.run();
    crate::bench_harness::summarize(&runs, spec.score_stat());
    0
}

fn cmd_info(args: &[String]) -> i32 {
    let f = flags(args);

    // problem registry and method table first: `info` is the live
    // answer to "what can this binary run?"
    println!("registered problems:");
    print!("{}", ProblemRegistry::builtin().describe());
    println!("\nmethods:");
    for k in AlgorithmKind::all() {
        let aliases = k.aliases();
        println!(
            "  {:<11} {}{}",
            k.name(),
            if k.is_stochastic() { "stochastic" } else { "deterministic" },
            if aliases.is_empty() {
                String::new()
            } else {
                format!("  (aliases: {})", aliases.join(", "))
            }
        );
    }
    println!();

    let mut cfg = ExperimentConfig::default();
    if let Some(v) = f.get("dataset") {
        cfg.dataset = v.clone();
    }
    if let Some(v) = f.get("nodes").and_then(|v| v.parse().ok()) {
        cfg.nodes = v;
    }
    match cfg.build_dataset() {
        Ok(ds) => {
            let part = ds.partition(cfg.nodes);
            println!(
                "dataset {}: Q = {}, d = {}, rho = {:.3e}, positive ratio = {:.3}",
                ds.name,
                ds.samples(),
                ds.dim(),
                ds.density(),
                ds.positive_ratio()
            );
            println!(
                "partition: N = {}, q = {}, max shard rho = {:.3e}",
                part.nodes(),
                part.q,
                part.max_shard_density()
            );
            let topo = crate::graph::Topology::generate(
                cfg.topology,
                cfg.nodes,
                cfg.edge_prob,
                cfg.seed ^ 0x109,
            );
            let mix = crate::graph::MixingMatrix::laplacian(&topo, 1.0);
            println!(
                "graph {}: diameter = {}, max degree = {}, gamma = {:.4}, kappa_g = {:.2}",
                cfg.topology.name(),
                topo.diameter,
                topo.max_degree(),
                mix.gamma,
                mix.kappa_g
            );
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

/// `dsba telemetry-check <run.jsonl>` — validate every line of a
/// telemetry stream against the versioned row schema, then print a
/// summary: row/node/round counts, cumulative fault-counter totals, and
/// the writer's written/dropped accounting.  Exit 0 means the file is
/// well-formed AND the round range has no gaps; a gap (rotation ate the
/// middle of the retained window, or a node went silent) exits 1 so CI
/// catches incomplete evidence.
fn cmd_telemetry_check(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: dsba telemetry-check <run.jsonl>");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("telemetry-check: cannot read {path}: {e}");
            return 1;
        }
    };
    match crate::telemetry::StreamSummary::from_stream(&text) {
        Ok(s) => {
            println!(
                "telemetry OK: {} row(s) from {} node(s), rounds {}..={} \
                 ({} seen), schema v{}",
                s.rows,
                s.nodes.len(),
                s.round_min,
                s.round_max,
                s.rounds_seen,
                crate::telemetry::TELEMETRY_SCHEMA_VERSION
            );
            println!(
                "  faults: {} stalls, {} retransmits, {} dedups, \
                 {} drops injected, {} dups injected",
                s.stalls, s.retransmits, s.dedups, s.drops_injected, s.dups_injected
            );
            if s.events > 0 {
                println!("  events: {} control-plane event line(s)", s.events);
            }
            match &s.writer {
                Some(w) => println!(
                    "  writer: {} row(s) written, {} dropped",
                    w.rows_written, w.rows_dropped
                ),
                None => println!("  writer: no summary line (stream truncated or pre-v2)"),
            }
            if s.truncated_tail {
                println!("  note: truncated final line tolerated (crashed run?)");
            }
            if !s.missing_rounds.is_empty() {
                eprintln!(
                    "telemetry-check: {path}: {} round(s) missing in \
                     {}..={} (first gap: round {})",
                    s.missing_rounds.len(),
                    s.round_min,
                    s.round_max,
                    s.missing_rounds[0]
                );
                return 1;
            }
            0
        }
        Err(e) => {
            eprintln!("telemetry-check: {path}: {e}");
            1
        }
    }
}

/// `dsba report <run.jsonl> [--json]` — full run analysis of a
/// telemetry stream: fitted geometric convergence rate, per-node phase
/// breakdown, straggler attribution, and the per-round
/// bytes-vs-DOUBLEs budget.
fn cmd_report(args: &[String]) -> i32 {
    let f = flags(args);
    let Some(path) = args.iter().find(|a| !a.starts_with("--")) else {
        eprintln!("usage: dsba report <run.jsonl> [--json]");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("report: cannot read {path}: {e}");
            return 1;
        }
    };
    match crate::telemetry::RunReport::from_stream(&text) {
        Ok(rep) => {
            if f.contains_key("json") {
                println!("{}", rep.to_json());
            } else {
                print!("{}", rep.render_text());
            }
            0
        }
        Err(e) => {
            eprintln!("report: {path}: {e}");
            1
        }
    }
}

/// `dsba trace export <run.jsonl> [--format chrome] [--out FILE]` —
/// export a telemetry stream as Chrome trace-event JSON: every row's
/// phase spans become per-node complete events on a cumulative
/// timeline, and control-plane event lines become instants. The output
/// loads directly in Perfetto or chrome://tracing.
fn cmd_trace(args: &[String]) -> i32 {
    let usage = "usage: dsba trace export <run.jsonl> [--format chrome] [--out FILE]";
    if args.first().map(String::as_str) != Some("export") {
        eprintln!("{usage}");
        return 2;
    }
    let mut pos = Vec::new();
    let mut format = "chrome".to_string();
    let mut out: Option<String> = None;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--format" | "--out" => {
                let Some(v) = args.get(i + 1) else {
                    eprintln!("{usage}");
                    return 2;
                };
                if args[i] == "--format" {
                    format = v.clone();
                } else {
                    out = Some(v.clone());
                }
                i += 2;
            }
            a if a.starts_with("--") => {
                eprintln!("unknown flag {a}\n{usage}");
                return 2;
            }
            _ => {
                pos.push(args[i].clone());
                i += 1;
            }
        }
    }
    if format != "chrome" {
        eprintln!("bad --format {format} (only chrome is supported)");
        return 2;
    }
    let [path] = pos.as_slice() else {
        eprintln!("{usage}");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trace: cannot read {path}: {e}");
            return 1;
        }
    };
    match crate::telemetry::chrome_trace(&text) {
        Ok(trace) => {
            let n = trace.as_arr().map_or(0, |a| a.len());
            match &out {
                Some(dest) => {
                    if let Err(e) = std::fs::write(dest, format!("{trace}\n")) {
                        eprintln!("trace: cannot write {dest}: {e}");
                        return 1;
                    }
                    println!("trace: {n} event(s) -> {dest}");
                }
                None => println!("{trace}"),
            }
            0
        }
        Err(e) => {
            eprintln!("trace: {path}: {e}");
            1
        }
    }
}

/// Read whatever `path` holds past `offset`; returns the new bytes as
/// text plus the new offset. A file shorter than `offset` (rotation
/// swapped it out underneath us) reports offset 0 so the caller can
/// restart the tail.
fn read_new_bytes(path: &str, offset: u64) -> std::io::Result<(String, u64)> {
    use std::io::{Read, Seek, SeekFrom};
    let mut file = std::fs::File::open(path)?;
    if file.metadata()?.len() < offset {
        return Ok((String::new(), 0));
    }
    file.seek(SeekFrom::Start(offset))?;
    let mut buf = Vec::new();
    file.read_to_end(&mut buf)?;
    Ok((String::from_utf8_lossy(&buf).into_owned(), offset + buf.len() as u64))
}

/// `dsba watch <run.jsonl> [--interval-ms MS] [--once]` — tail a
/// growing telemetry stream and keep one refreshing status line (front
/// round, mean residual, staleness, stall detection naming the lagging
/// node). Exits when the writer's trailing summary line arrives; with
/// `--once`, prints a single snapshot of the stream as it stands.
fn cmd_watch(args: &[String]) -> i32 {
    let usage = "usage: dsba watch <run.jsonl> [--interval-ms MS] [--once]";
    let mut pos = Vec::new();
    let mut interval_ms = 500u64;
    let mut once = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--interval-ms" => {
                let Some(v) = args.get(i + 1) else {
                    eprintln!("{usage}");
                    return 2;
                };
                match v.parse::<u64>() {
                    Ok(ms) if ms > 0 => interval_ms = ms,
                    _ => {
                        eprintln!("bad --interval-ms {v} (want a positive integer)");
                        return 2;
                    }
                }
                i += 2;
            }
            "--once" => {
                once = true;
                i += 1;
            }
            a if a.starts_with("--") => {
                eprintln!("unknown flag {a}\n{usage}");
                return 2;
            }
            _ => {
                pos.push(args[i].clone());
                i += 1;
            }
        }
    }
    let [path] = pos.as_slice() else {
        eprintln!("{usage}");
        return 2;
    };
    let mut w = crate::telemetry::WatchState::new();
    let mut offset = 0u64;
    loop {
        match read_new_bytes(path, offset) {
            Ok((chunk, new_off)) => {
                if new_off < offset {
                    // the file shrank underneath us: restart the tail
                    offset = 0;
                    w = crate::telemetry::WatchState::new();
                } else {
                    offset = new_off;
                    w.ingest(&chunk);
                }
            }
            Err(e) => {
                eprintln!("watch: cannot read {path}: {e}");
                return 1;
            }
        }
        print!("\r{}", w.status_line());
        let _ = std::io::Write::flush(&mut std::io::stdout());
        if w.finished() || once {
            println!();
            return 0;
        }
        std::thread::sleep(std::time::Duration::from_millis(interval_ms));
    }
}

/// `dsba bench-compare <old.json> <new.json> [--tol PCT]` — diff two
/// bench snapshots and exit 1 on any metric regression beyond the
/// tolerance (or a sweep cell that disappeared). The perf-trajectory
/// gate: CI runs it with `results/BENCH_*.json` as the old side.
fn cmd_bench_compare(args: &[String]) -> i32 {
    let usage = "usage: dsba bench-compare <old.json> <new.json> [--tol PCT]";
    let mut pos = Vec::new();
    let mut tol = 10.0f64;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--tol" {
            let Some(v) = args.get(i + 1) else {
                eprintln!("{usage}");
                return 2;
            };
            match v.parse::<f64>() {
                Ok(t) if t >= 0.0 => tol = t,
                _ => {
                    eprintln!("bad --tol {v} (want a non-negative percentage)");
                    return 2;
                }
            }
            i += 2;
        } else if args[i].starts_with("--") {
            eprintln!("unknown flag {}\n{usage}", args[i]);
            return 2;
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    let [old_path, new_path] = pos.as_slice() else {
        eprintln!("{usage}");
        return 2;
    };
    let load = |path: &str| -> Result<json::Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        json::parse(&text)
    };
    let (old, new) = match (load(old_path), load(new_path)) {
        (Ok(o), Ok(n)) => (o, n),
        (Err(e), _) => {
            eprintln!("bench-compare: {old_path}: {e}");
            return 1;
        }
        (_, Err(e)) => {
            eprintln!("bench-compare: {new_path}: {e}");
            return 1;
        }
    };
    let cmp = crate::telemetry::bench_compare(&old, &new, tol);
    print!("{}", cmp.render_text(tol));
    if cmp.regressed() {
        1
    } else {
        0
    }
}

fn cmd_artifacts() -> i32 {
    match crate::runtime::XlaRuntime::load_default() {
        Ok(rt) => {
            let m = rt.manifest();
            println!(
                "artifacts OK: {} entries, functions: {:?}",
                m.entries.len(),
                m.fn_names()
            );
            if !rt.has_backend() {
                println!(
                    "note: manifest validated, but the PJRT execution backend is \
                     not compiled in (build with --features pjrt to execute)"
                );
            }
            0
        }
        Err(e) => {
            eprintln!("artifacts check failed: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parser_handles_pairs_and_bools() {
        let args: Vec<String> = ["--alpha", "0.5", "--verbose", "--nodes", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = flags(&args);
        assert_eq!(f.get("alpha").unwrap(), "0.5");
        assert_eq!(f.get("verbose").unwrap(), "true");
        assert_eq!(f.get("nodes").unwrap(), "4");
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert_eq!(dispatch(&["bogus".to_string()]), 2);
    }

    #[test]
    fn telemetry_check_validates_files() {
        // no path → usage error
        assert_eq!(dispatch(&["telemetry-check".to_string()]), 2);
        // missing file → runtime error
        assert_eq!(
            dispatch(&[
                "telemetry-check".to_string(),
                "/nonexistent/definitely-not-here.jsonl".to_string()
            ]),
            1
        );
        // well-formed and corrupt streams round through validate_jsonl
        let dir = std::env::temp_dir().join(format!("dsba_cli_tc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let row = crate::telemetry::TelemetryRow {
            round: 0,
            node: 1,
            residual: 0.5,
            ..crate::telemetry::TelemetryRow::default()
        };
        let good = dir.join("good.jsonl");
        std::fs::write(&good, format!("{}\n", row.to_json_line())).unwrap();
        assert_eq!(
            dispatch(&["telemetry-check".to_string(), good.display().to_string()]),
            0
        );
        let bad = dir.join("bad.jsonl");
        std::fs::write(&bad, "{\"round\":0}\n").unwrap();
        assert_eq!(
            dispatch(&["telemetry-check".to_string(), bad.display().to_string()]),
            1
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_flags_accept_fault_and_telemetry() {
        let args: Vec<String> = ["--fault", "drop:0.05,dup:0.1", "--telemetry", "t.jsonl"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = flags(&args);
        assert_eq!(f.get("fault").unwrap(), "drop:0.05,dup:0.1");
        assert_eq!(f.get("telemetry").unwrap(), "t.jsonl");
        assert!(crate::runtime::FaultSpec::parse(f.get("fault").unwrap()).is_ok());
    }

    #[test]
    fn help_succeeds() {
        assert_eq!(dispatch(&["help".to_string()]), 0);
    }

    #[test]
    fn info_enumerates_registries() {
        // `info` must succeed with no flags, enumerating problems and
        // methods straight from the registries
        assert_eq!(dispatch(&["info".to_string()]), 0);
    }

    #[test]
    fn problems_lists_canonical_names() {
        assert_eq!(dispatch(&["problems".to_string()]), 0);
    }

    #[test]
    fn info_capability_table_covers_every_entry() {
        // the `dsba info` capability table is generated from live
        // registry metadata: every entry's resolvent kind shows up, and
        // saddle entries are marked
        let table = ProblemRegistry::builtin().describe();
        for e in ProblemRegistry::builtin().entries() {
            assert!(table.contains(e.meta.name), "{} missing", e.meta.name);
            assert!(
                table.contains(e.meta.resolvent.name()),
                "{} resolvent kind missing",
                e.meta.name
            );
        }
        for col in ["saddle", "l1", "resolvent"] {
            assert!(table.contains(col), "capability column {col} missing");
        }
    }

    #[test]
    fn listings_cover_every_registration() {
        let problems = problem_list();
        for name in ProblemRegistry::builtin().names() {
            assert!(problems.contains(name), "{name} missing from help text");
        }
        let methods = method_list();
        for k in AlgorithmKind::all() {
            assert!(methods.contains(k.name()), "{} missing from help text", k.name());
        }
    }

    #[test]
    fn report_analyzes_a_stream() {
        // no path → usage error; missing file → runtime error
        assert_eq!(dispatch(&["report".to_string()]), 2);
        assert_eq!(
            dispatch(&["report".to_string(), "/nonexistent/r.jsonl".to_string()]),
            1
        );
        let dir = std::env::temp_dir().join(format!("dsba_cli_rep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut stream = String::new();
        for (round, residual) in [(0u64, 0.8f64), (1, 0.4)] {
            let row = crate::telemetry::TelemetryRow {
                round,
                node: 0,
                residual,
                doubles_sent: 8.0,
                doubles_recv: 8.0,
                bytes_on_wire: 128,
                wall_micros: 1000,
                wait_micros: 300,
                drain_micros: 100,
                compute_micros: 500,
                encode_micros: 50,
                send_micros: 50,
                ..crate::telemetry::TelemetryRow::default()
            };
            stream.push_str(&row.to_json_line());
            stream.push('\n');
        }
        let path = dir.join("run.jsonl");
        std::fs::write(&path, &stream).unwrap();
        assert_eq!(dispatch(&["report".to_string(), path.display().to_string()]), 0);
        assert_eq!(
            dispatch(&[
                "report".to_string(),
                path.display().to_string(),
                "--json".to_string()
            ]),
            0
        );
        // an empty stream has nothing to report
        let empty = dir.join("empty.jsonl");
        std::fs::write(&empty, "").unwrap();
        assert_eq!(dispatch(&["report".to_string(), empty.display().to_string()]), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_exports_chrome_json() {
        // missing "export" subcommand / missing path → usage errors
        assert_eq!(dispatch(&["trace".to_string()]), 2);
        assert_eq!(dispatch(&["trace".to_string(), "export".to_string()]), 2);
        let dir = std::env::temp_dir().join(format!("dsba_cli_tr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let row = crate::telemetry::TelemetryRow {
            round: 0,
            node: 0,
            residual: 0.5,
            wall_micros: 1000,
            compute_micros: 800,
            ..crate::telemetry::TelemetryRow::default()
        };
        let ev = crate::telemetry::RunEvent::new(crate::telemetry::EventKind::Handshake)
            .node(0)
            .peer(1)
            .detail("link up");
        let stream = format!("{}\n{}\n", row.to_json_line(), ev.to_json_line());
        let path = dir.join("run.jsonl");
        std::fs::write(&path, &stream).unwrap();
        let out = dir.join("trace.json");
        assert_eq!(
            dispatch(&[
                "trace".to_string(),
                "export".to_string(),
                path.display().to_string(),
                "--format".to_string(),
                "chrome".to_string(),
                "--out".to_string(),
                out.display().to_string(),
            ]),
            0
        );
        let trace = json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let events = trace.as_arr().expect("chrome trace is a JSON array");
        assert!(!events.is_empty());
        for e in events {
            assert!(e.get("ph").is_some(), "every trace event carries a phase: {e}");
        }
        // unsupported format / unknown flag → usage errors; missing file → 1
        assert_eq!(
            dispatch(&[
                "trace".to_string(),
                "export".to_string(),
                path.display().to_string(),
                "--format".to_string(),
                "svg".to_string(),
            ]),
            2
        );
        assert_eq!(
            dispatch(&[
                "trace".to_string(),
                "export".to_string(),
                "/nonexistent/t.jsonl".to_string(),
            ]),
            1
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn watch_once_snapshots_a_stream() {
        // no path → usage error; missing file → runtime error
        assert_eq!(dispatch(&["watch".to_string()]), 2);
        assert_eq!(
            dispatch(&[
                "watch".to_string(),
                "/nonexistent/w.jsonl".to_string(),
                "--once".to_string()
            ]),
            1
        );
        let dir = std::env::temp_dir().join(format!("dsba_cli_w_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut stream = String::new();
        for (round, node) in [(0u64, 0u32), (0, 1), (1, 0), (1, 1)] {
            let row = crate::telemetry::TelemetryRow {
                round,
                node,
                residual: 0.5,
                ..crate::telemetry::TelemetryRow::default()
            };
            stream.push_str(&row.to_json_line());
            stream.push('\n');
        }
        let live = dir.join("live.jsonl");
        std::fs::write(&live, &stream).unwrap();
        assert_eq!(
            dispatch(&["watch".to_string(), live.display().to_string(), "--once".to_string()]),
            0
        );
        // a finished stream (trailing summary) exits without --once
        let sum = crate::telemetry::TelemetrySummary { rows_written: 4, rows_dropped: 0 };
        stream.push_str(&sum.to_json_line());
        stream.push('\n');
        let done = dir.join("done.jsonl");
        std::fs::write(&done, &stream).unwrap();
        assert_eq!(dispatch(&["watch".to_string(), done.display().to_string()]), 0);
        // bad interval → usage error
        assert_eq!(
            dispatch(&[
                "watch".to_string(),
                done.display().to_string(),
                "--interval-ms".to_string(),
                "0".to_string()
            ]),
            2
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_compare_gates_regressions() {
        assert_eq!(dispatch(&["bench-compare".to_string()]), 2);
        let dir = std::env::temp_dir().join(format!("dsba_cli_bc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let old = dir.join("old.json");
        let ok_new = dir.join("ok.json");
        let bad_new = dir.join("bad.json");
        let snap = |secs: f64| {
            format!(
                "{{\"bench\":\"engine\",\"sweep\":[{{\"mode\":\"sync\",\
                 \"nodes\":4,\"secs\":{secs},\"rounds_per_sec\":{}}}]}}",
                1.0 / secs
            )
        };
        std::fs::write(&old, snap(0.010)).unwrap();
        std::fs::write(&ok_new, snap(0.0105)).unwrap();
        std::fs::write(&bad_new, snap(0.050)).unwrap();
        let run = |new: &std::path::Path, tol: &str| {
            dispatch(&[
                "bench-compare".to_string(),
                old.display().to_string(),
                new.display().to_string(),
                "--tol".to_string(),
                tol.to_string(),
            ])
        };
        assert_eq!(run(&ok_new, "10"), 0, "5% drift within 10% tolerance");
        assert_eq!(run(&bad_new, "10"), 1, "5x slowdown must fail the gate");
        assert_eq!(run(&bad_new, "10000"), 0, "huge tolerance passes anything");
        // bad tolerance / unknown flag → usage errors
        assert_eq!(run(&ok_new, "-3"), 2);
        assert_eq!(
            dispatch(&[
                "bench-compare".to_string(),
                old.display().to_string(),
                ok_new.display().to_string(),
                "--bogus".to_string()
            ]),
            2
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
