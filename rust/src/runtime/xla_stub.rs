//! Manifest-only stand-in for the PJRT artifact executor (default build).
//!
//! Loading parses and validates `artifacts/manifest.json` exactly like the
//! real runtime, so `dsba artifacts` and shape-bucket selection work
//! offline; every execution entry point returns an error and
//! [`XlaRuntime::has_backend`] is `false`, which the XLA cross-check tests
//! use to skip cleanly. Build with `--features pjrt` (and the vendored
//! `xla` crate) for the executing runtime in `super::pjrt`.

use super::registry::{ArtifactEntry, Manifest};
use crate::linalg::CsrMatrix;
use std::path::{Path, PathBuf};

/// Manifest-backed artifact index without an execution backend.
pub struct XlaRuntime {
    manifest: Manifest,
    dir: PathBuf,
}

impl XlaRuntime {
    /// Load and validate `manifest.json` from `dir`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<XlaRuntime, String> {
        let dir = dir.as_ref().to_path_buf();
        let src = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            format!("reading {:?}/manifest.json — run `make artifacts` ({e})", dir)
        })?;
        let manifest = Manifest::parse(&src)?;
        Ok(XlaRuntime { manifest, dir })
    }

    /// Default artifact location: search upward for `artifacts/`.
    pub fn load_default() -> Result<XlaRuntime, String> {
        Self::load(super::find_artifacts_dir()?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Whether artifact *execution* is available. Always false here; true
    /// only in the `pjrt`-feature build.
    pub fn has_backend(&self) -> bool {
        false
    }

    /// Smallest (q, d) bucket of `fn_name` fitting the given shard shape.
    pub fn pick_bucket(&self, fn_name: &str, q: usize, d: usize) -> Option<&ArtifactEntry> {
        self.manifest.pick_qd(fn_name, q, d)
    }

    fn no_backend<T>(&self) -> Result<T, String> {
        Err(
            "PJRT backend not compiled in — rebuild with `--features pjrt` and \
             the vendored `xla` crate to execute artifacts"
                .to_string(),
        )
    }

    pub fn coefs_ridge(&self, _shard: &CsrMatrix, _z: &[f64], _y: &[f64]) -> Result<Vec<f64>, String> {
        self.no_backend()
    }

    pub fn coefs_logistic(&self, _shard: &CsrMatrix, _z: &[f64], _y: &[f64]) -> Result<Vec<f64>, String> {
        self.no_backend()
    }

    pub fn full_op_ridge(&self, _shard: &CsrMatrix, _z: &[f64], _y: &[f64]) -> Result<Vec<f64>, String> {
        self.no_backend()
    }

    pub fn full_op_logistic(&self, _shard: &CsrMatrix, _z: &[f64], _y: &[f64]) -> Result<Vec<f64>, String> {
        self.no_backend()
    }

    pub fn scores(&self, _shard: &CsrMatrix, _z: &[f64]) -> Result<Vec<f64>, String> {
        self.no_backend()
    }

    pub fn obj_ridge(&self, _shard: &CsrMatrix, _z: &[f64], _y: &[f64]) -> Result<f64, String> {
        self.no_backend()
    }

    pub fn obj_logistic(&self, _shard: &CsrMatrix, _z: &[f64], _y: &[f64]) -> Result<f64, String> {
        self.no_backend()
    }

    pub fn auc_full_op(
        &self,
        _shard: &CsrMatrix,
        _y: &[f64],
        _z_aug: &[f64],
        _p: f64,
    ) -> Result<Vec<f64>, String> {
        self.no_backend()
    }

    pub fn mix_step(
        &self,
        _wt: &crate::linalg::DenseMatrix,
        _z: &[Vec<f64>],
        _z_prev: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>, String> {
        self.no_backend()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_default_errs_or_stub_has_no_backend() {
        // Without artifacts the loader reports a clear skip message; with
        // artifacts present the stub still refuses execution.
        match XlaRuntime::load_default() {
            Ok(rt) => {
                assert!(!rt.has_backend());
                assert!(rt.scores(&CsrMatrix::from_rows(1, &[]), &[0.0]).is_err());
            }
            Err(e) => assert!(e.contains("artifacts"), "unexpected error: {e}"),
        }
    }
}
