//! Fault-injection configuration: `--fault drop:P,dup:P,delay:MS[@NODE],kill:NODE@ROUND`.
//!
//! [`FaultSpec`] is a parse/name inverse pair (same contract as
//! `CompressionSpec` and `ModeSpec`) describing four independent faults:
//!
//! - `drop:P` — each outgoing MSG frame is dropped on the wire with
//!   probability `P` (the reliable link layer recovers it via
//!   NACK/retransmit, so runs stay bit-identical; see
//!   `runtime::transport`).
//! - `dup:P` — each outgoing MSG frame is duplicated with probability
//!   `P` (receivers dedup by link sequence number).
//! - `delay:MS[@NODE]` — node `NODE` (or every node when omitted) sleeps
//!   `MS` milliseconds before emitting each round: a deterministic
//!   straggler that exercises the async admission path. Subsumes the
//!   legacy `DSBA_INJECT_DELAY_MS` env knob.
//! - `kill:NODE@ROUND` — node `NODE` halts at the start of round
//!   `ROUND`; the run fails fast with an error naming the node, the
//!   round, and the last-seen peer watermarks.
//!
//! Drop/dup draws use a per-edge seeded RNG ([`FaultSpec::edge_rng`]),
//! so a given `(seed, from, to)` stream injects the same fault sequence
//! on every run — fault tests are deterministic.

use crate::util::rng::Rng;

/// Transport/engine fault-injection plan. `FaultSpec::default()` is the
/// fault-free configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Per-frame drop probability on outgoing MSG frames, in [0, 1).
    pub drop: f64,
    /// Per-frame duplication probability on outgoing MSG frames, in [0, 1).
    pub dup: f64,
    /// Per-round emit delay in milliseconds (0 = off).
    pub delay_ms: u64,
    /// Node the delay applies to (`None` = every node).
    pub delay_node: Option<u32>,
    /// Halt `(node, round)`: the node fails fast at that round.
    pub kill: Option<(u32, u64)>,
}

fn parse_prob(what: &str, raw: &str) -> Result<f64, String> {
    let p: f64 = raw
        .trim()
        .parse()
        .map_err(|_| format!("bad {what} probability {raw:?}"))?;
    if !(0.0..1.0).contains(&p) {
        return Err(format!("{what} probability {p} outside [0, 1)"));
    }
    Ok(p)
}

fn parse_u64(what: &str, raw: &str) -> Result<u64, String> {
    raw.trim().parse().map_err(|_| format!("bad {what} {raw:?}"))
}

impl FaultSpec {
    /// No faults (the default).
    pub fn none() -> FaultSpec {
        FaultSpec::default()
    }

    pub fn is_none(&self) -> bool {
        *self == FaultSpec::default()
    }

    /// True when the spec injects link-layer faults (drop or dup).
    pub fn link_faults(&self) -> bool {
        self.drop > 0.0 || self.dup > 0.0
    }

    /// Emit delay (ms) for `node`, if any.
    pub fn delay_for(&self, node: usize) -> Option<u64> {
        if self.delay_ms == 0 {
            return None;
        }
        match self.delay_node {
            Some(n) if n as usize != node => None,
            _ => Some(self.delay_ms),
        }
    }

    /// Parse `drop:P,dup:P,delay:MS[@NODE],kill:NODE@ROUND` (clauses in
    /// any order, each at most once). `""` and `"none"` are fault-free.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let s = s.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("none") {
            return Ok(FaultSpec::none());
        }
        let mut f = FaultSpec::none();
        let mut seen: Vec<String> = Vec::new();
        for clause in s.split(',') {
            let clause = clause.trim();
            let (key, val) = clause
                .split_once(':')
                .ok_or_else(|| format!("bad fault clause {clause:?} (expected key:value)"))?;
            let key = key.trim().to_ascii_lowercase();
            if seen.contains(&key) {
                return Err(format!("duplicate fault clause {key:?}"));
            }
            match key.as_str() {
                "drop" => f.drop = parse_prob("drop", val)?,
                "dup" => f.dup = parse_prob("dup", val)?,
                "delay" => match val.split_once('@') {
                    Some((ms, node)) => {
                        f.delay_ms = parse_u64("delay ms", ms)?;
                        f.delay_node = Some(parse_u64("delay node", node)? as u32);
                    }
                    None => {
                        f.delay_ms = parse_u64("delay ms", val)?;
                        f.delay_node = None;
                    }
                },
                "kill" => {
                    let (node, round) = val.split_once('@').ok_or_else(|| {
                        format!("bad kill clause {val:?} (expected NODE@ROUND)")
                    })?;
                    f.kill = Some((
                        parse_u64("kill node", node)? as u32,
                        parse_u64("kill round", round)?,
                    ));
                }
                other => return Err(format!("unknown fault {other:?}")),
            }
            seen.push(key);
        }
        Ok(f)
    }

    /// Canonical name; `FaultSpec::parse(&f.name()) == Ok(f)`.
    pub fn name(&self) -> String {
        if self.is_none() {
            return "none".into();
        }
        let mut clauses = Vec::new();
        if self.drop > 0.0 {
            clauses.push(format!("drop:{}", self.drop));
        }
        if self.dup > 0.0 {
            clauses.push(format!("dup:{}", self.dup));
        }
        if self.delay_ms > 0 {
            match self.delay_node {
                Some(n) => clauses.push(format!("delay:{}@{n}", self.delay_ms)),
                None => clauses.push(format!("delay:{}", self.delay_ms)),
            }
        }
        if let Some((node, round)) = self.kill {
            clauses.push(format!("kill:{node}@{round}"));
        }
        clauses.join(",")
    }

    /// Deterministic per-edge fault stream: the draws made on directed
    /// edge `from -> to` depend only on `(seed, from, to)`.
    pub fn edge_rng(seed: u64, from: usize, to: usize) -> Rng {
        let tag = (from as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((to as u64).wrapping_mul(0xA076_1D64_78BD_642F));
        Rng::new(seed ^ tag.rotate_left(17))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_is_an_inverse_pair() {
        for s in [
            "none",
            "drop:0.05",
            "dup:0.1",
            "drop:0.05,dup:0.05",
            "delay:150",
            "delay:150@2",
            "kill:3@10",
            "drop:0.01,dup:0.02,delay:5@1,kill:0@7",
        ] {
            let f = FaultSpec::parse(s).unwrap();
            assert_eq!(FaultSpec::parse(&f.name()).unwrap(), f, "{s}");
        }
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::none());
        assert_eq!(FaultSpec::none().name(), "none");
        // canonical clause order regardless of input order
        let f = FaultSpec::parse("kill:1@2,drop:0.5").unwrap();
        assert_eq!(f.name(), "drop:0.5,kill:1@2");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "drop",            // no value
            "drop:",           // empty value
            "drop:1.0",        // out of [0, 1)
            "drop:-0.1",       // negative
            "dup:x",           // not a number
            "delay:",          // empty
            "delay:5@",        // empty node
            "kill:3",          // missing @ROUND
            "kill:@4",         // missing node
            "warp:0.5",        // unknown key
            "drop:0.1,drop:0.2", // duplicate clause
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn accessors_reflect_the_spec() {
        let f = FaultSpec::parse("drop:0.05,delay:100@2,kill:1@9").unwrap();
        assert!(f.link_faults());
        assert!(!f.is_none());
        assert_eq!(f.delay_for(2), Some(100));
        assert_eq!(f.delay_for(0), None);
        assert_eq!(f.kill, Some((1, 9)));
        let all = FaultSpec::parse("delay:50").unwrap();
        assert!(!all.link_faults());
        assert_eq!(all.delay_for(0), Some(50));
        assert_eq!(all.delay_for(7), Some(50));
        assert_eq!(FaultSpec::none().delay_for(0), None);
    }

    #[test]
    fn edge_rng_is_deterministic_and_directed() {
        let mut a1 = FaultSpec::edge_rng(42, 0, 1);
        let mut a2 = FaultSpec::edge_rng(42, 0, 1);
        let mut b = FaultSpec::edge_rng(42, 1, 0);
        let same_dir: Vec<u64> = (0..16).map(|_| a1.next_u64()).collect();
        assert_eq!(same_dir, (0..16).map(|_| a2.next_u64()).collect::<Vec<_>>());
        assert!(
            (0..16).any(|i| b.next_u64() != same_dir[i]),
            "reverse edge reuses the forward stream"
        );
    }
}
