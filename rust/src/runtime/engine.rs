//! Multi-threaded message-passing node engine.
//!
//! Executes the per-node decomposition of any method
//! ([`crate::algorithms::build_node_program`]) across worker threads, with
//! a pluggable [`Transport`] carrying typed [`Message`]s along the
//! topology's edges and `std::sync::Barrier`-synchronized rounds. The
//! engine is the *fast path*; the sequential
//! [`crate::algorithms::node::RoundDriver`] behind each `Algorithm` impl
//! is the reference oracle.
//!
//! Two transports exist today (see [`crate::runtime::transport`]):
//! [`LocalTransport`] (in-process mpsc, the default) and
//! [`crate::runtime::TcpTransport`] (per-edge loopback/host sockets with
//! the framed wire codec). The determinism contract below holds for both.
//!
//! ## Determinism contract
//!
//! Given the same seed, the engine's iterates are **bit-for-bit equal** to
//! the sequential driver's (pinned by `rust/tests/engine_parity.rs`):
//!
//! * node states are constructed on the launching thread in node order,
//!   so per-node RNG streams are forked identically;
//! * rounds are barrier-synchronized — phase A (every node emits its
//!   messages), barrier, phase B (every node drains its round inbox and
//!   runs its local step), barrier — so a round's messages are all
//!   delivered before any local step runs, exactly the synchronous
//!   model (the TCP backend additionally gates each drain on per-edge
//!   end-of-round control frames, which is what keeps *separate engine
//!   processes* in lockstep);
//! * each inbox is sorted by (sender, emit index) before delivery, so
//!   handlers see the same order the sequential driver produces;
//! * nodes may only read their own state plus received payloads, so
//!   scheduling cannot leak into the arithmetic.
//!
//! ## Accounting
//!
//! Workers log one cost event per message; after the round the launching
//! thread replays the events into the [`Network`] in canonical (sender,
//! emit index) order, so per-node sent/received DOUBLE totals equal the
//! sequential accounting exactly (dense and sparse payloads priced
//! through the same [`crate::comm::CommCostModel`]).
//!
//! ## Hosting a subset (cross-process runs)
//!
//! A transport may host only part of the node set (`--hosted` + `--peers`
//! split one topology across engine processes). The engine then steps
//! only its hosted nodes; `iterates()` rows of remote nodes stay at the
//! initial point, and `passes()` covers the hosted share. Cost accounting
//! for hosted nodes is exact in both directions: sends are charged at the
//! emitting node, and inflow from remote engines is charged via
//! receive-side cost events merged into the same canonical replay.
//! Single-process runs — both transports' default — host everything and
//! are bit-for-bit complete.

use crate::algorithms::{
    build_node_program, AlgoParams, Algorithm, AlgorithmKind, NodeProgram, NodeState,
};
use crate::comm::{Message, Network};
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use crate::runtime::transport::{LocalTransport, NodePort, Transport};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

/// Which driver executes the rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Deterministic in-order reference driver (the oracle).
    Sequential,
    /// Multi-threaded engine (bit-for-bit equal, wall-clock faster).
    Parallel,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => EngineKind::Sequential,
            "parallel" | "par" => EngineKind::Parallel,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Sequential => "sequential",
            EngineKind::Parallel => "parallel",
        }
    }
}

/// Worker count for `threads = 0` (auto): available cores capped by the
/// node count.
pub fn auto_threads(n_nodes: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    cores.clamp(1, n_nodes.max(1))
}

/// One hosted node scheduled on a worker: (node index, state machine,
/// its transport port).
type HostedNode = (usize, Box<dyn NodeState>, Box<dyn NodePort>);

#[derive(Clone, Copy, Debug)]
enum CostKind {
    Dense(usize),
    Sparse(usize, usize),
}

#[derive(Clone, Copy, Debug)]
struct CostEvent {
    from: usize,
    seq: u32,
    to: usize,
    kind: CostKind,
}

struct Shared {
    /// per-node iterate slots, written by the owning worker each round
    slots: Vec<Mutex<Vec<f64>>>,
    /// per-node cumulative component evaluations
    evals: Vec<AtomicU64>,
    /// this round's cost events (drained by the launching thread)
    costs: Mutex<Vec<CostEvent>>,
    /// which nodes this engine hosts — receive-side costs are logged for
    /// messages arriving from non-hosted (remote) senders
    hosted_mask: Vec<bool>,
    sent: AtomicU64,
    delivered: AtomicU64,
    /// set when any worker's node code panicked; workers keep honoring
    /// the barrier protocol (skipping work) so nothing deadlocks, and the
    /// launcher propagates the failure after the round
    panicked: AtomicBool,
    /// first transport failure observed by a worker (None when the
    /// poisoning was a genuine node-code panic)
    failure: Mutex<Option<String>>,
}

impl Shared {
    /// Record a transport failure (first one wins) and poison the engine
    /// via the normal panic path so the barrier protocol stays sound.
    fn transport_failure(&self, msg: String) -> ! {
        let mut slot = self.failure.lock().unwrap();
        if slot.is_none() {
            *slot = Some(msg.clone());
        }
        drop(slot);
        panic!("{msg}");
    }
}

fn worker_loop(
    mut nodes: Vec<HostedNode>,
    shared: Arc<Shared>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
) {
    let mut t = 0usize;
    loop {
        barrier.wait(); // round start
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // phase A: emit this round's messages
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_a = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut cost_batch: Vec<CostEvent> = Vec::new();
                for (idx, node, port) in nodes.iter_mut() {
                    let outs = node.outgoing(t);
                    for (seq, out) in outs.into_iter().enumerate() {
                        let kind = match &out.msg {
                            Message::Dense(v) => CostKind::Dense(v.len()),
                            Message::Sparse(d) => {
                                CostKind::Sparse(d.vec.nnz(), d.tail.len())
                            }
                        };
                        cost_batch.push(CostEvent {
                            from: *idx,
                            seq: seq as u32,
                            to: out.to,
                            kind,
                        });
                        shared.sent.fetch_add(1, Ordering::Relaxed);
                        if let Err(e) = port.send(t, out.to, seq as u32, out.msg) {
                            shared.transport_failure(e);
                        }
                    }
                    if let Err(e) = port.finish_round(t) {
                        shared.transport_failure(e);
                    }
                }
                if !cost_batch.is_empty() {
                    shared.costs.lock().unwrap().extend(cost_batch);
                }
            }));
            if phase_a.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier.wait(); // all sends complete
        // phase B: drain inboxes (canonical order), run local steps
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_b = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut recv_batch: Vec<CostEvent> = Vec::new();
                for (idx, node, port) in nodes.iter_mut() {
                    let mut msgs = match port.drain_round(t) {
                        Ok(m) => m,
                        Err(e) => shared.transport_failure(e),
                    };
                    msgs.sort_by_key(|&(from, seq, _)| (from, seq));
                    for (from, seq, msg) in msgs {
                        shared.delivered.fetch_add(1, Ordering::Relaxed);
                        // inflow from a remote engine: the sender's side
                        // can't charge it into OUR network, so log the
                        // receive-side event — merged into the same
                        // canonical (sender, emit idx) replay, keeping
                        // hosted received-DOUBLE totals exact
                        if !shared.hosted_mask[from] {
                            let kind = match &msg {
                                Message::Dense(v) => CostKind::Dense(v.len()),
                                Message::Sparse(d) => {
                                    CostKind::Sparse(d.vec.nnz(), d.tail.len())
                                }
                            };
                            recv_batch.push(CostEvent { from, seq, to: *idx, kind });
                        }
                        node.on_receive(from, msg);
                    }
                    node.local_step(t);
                    shared.slots[*idx].lock().unwrap().copy_from_slice(node.iterate());
                    shared.evals[*idx].store(node.evals(), Ordering::Relaxed);
                }
                if !recv_batch.is_empty() {
                    shared.costs.lock().unwrap().extend(recv_batch);
                }
            }));
            if phase_b.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier.wait(); // round end
        t += 1;
    }
}

/// The multi-threaded engine. Implements [`Algorithm`], so the
/// coordinator, CLI, and benches drive it exactly like the sequential
/// methods.
pub struct ParallelEngine {
    kind: AlgorithmKind,
    topo: Topology,
    threads: usize,
    /// nodes this engine hosts (all of them for single-process runs)
    hosted: Vec<usize>,
    setup: Vec<(usize, usize, usize)>,
    pass_denom: f64,
    t: usize,
    /// launching-thread mirror of the per-node iterates
    z: Vec<Vec<f64>>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
}

impl ParallelEngine {
    /// Decompose `kind` into per-node states and launch the workers over
    /// the default in-process transport. `threads = 0` selects
    /// [`auto_threads`].
    pub fn new(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program(program, topo.clone(), threads)
    }

    /// [`ParallelEngine::new`] with an explicit transport backend (e.g. a
    /// [`crate::runtime::TcpTransport`] over loopback or host sockets).
    pub fn new_with_transport(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
        transport: Box<dyn Transport>,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program_with_transport(program, topo.clone(), threads, transport)
    }

    /// Launch workers over an already-built node program (in-process
    /// transport).
    pub fn from_program(program: NodeProgram, topo: Topology, threads: usize) -> ParallelEngine {
        let n = program.nodes.len();
        Self::from_program_with_transport(
            program,
            topo,
            threads,
            Box::new(LocalTransport::new(n)),
        )
    }

    /// Launch workers over an already-built node program and a connected
    /// transport. The transport decides which nodes this engine hosts;
    /// states are still *built* for every node (in node order) so RNG
    /// forking matches the sequential oracle, then non-hosted states are
    /// dropped.
    pub fn from_program_with_transport(
        program: NodeProgram,
        topo: Topology,
        threads: usize,
        transport: Box<dyn Transport>,
    ) -> ParallelEngine {
        let n = program.nodes.len();
        assert!(n > 0, "engine needs at least one node");
        let hosted = transport.hosted().to_vec();
        assert!(
            !hosted.is_empty()
                && hosted.windows(2).all(|w| w[0] < w[1])
                && *hosted.last().unwrap() < n,
            "transport hosts an invalid node set {hosted:?} for {n} nodes"
        );
        let mut is_hosted = vec![false; n];
        for &h in &hosted {
            is_hosted[h] = true;
        }
        let h = hosted.len();
        let threads = if threads == 0 { auto_threads(h) } else { threads }.clamp(1, h);
        let z: Vec<Vec<f64>> = program.nodes.iter().map(|nd| nd.iterate().to_vec()).collect();
        let shared = Arc::new(Shared {
            slots: z.iter().map(|r| Mutex::new(r.clone())).collect(),
            evals: (0..n).map(|_| AtomicU64::new(0)).collect(),
            costs: Mutex::new(Vec::new()),
            hosted_mask: is_hosted.clone(),
            sent: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            panicked: AtomicBool::new(false),
            failure: Mutex::new(None),
        });
        let barrier = Arc::new(Barrier::new(threads + 1));
        let stop = Arc::new(AtomicBool::new(false));
        let ports = transport.into_ports();
        assert_eq!(ports.len(), h, "transport port count != hosted node count");
        // contiguous balanced buckets over the hosted nodes
        let mut buckets: Vec<Vec<HostedNode>> = (0..threads).map(|_| Vec::new()).collect();
        let mut port_iter = ports.into_iter();
        let mut k = 0;
        for (idx, node) in program.nodes.into_iter().enumerate() {
            if !is_hosted[idx] {
                continue; // built for RNG parity, stepped by a peer engine
            }
            let port = port_iter.next().unwrap();
            buckets[k * threads / h].push((idx, node, port));
            k += 1;
        }
        let mut workers = Vec::with_capacity(threads);
        for bucket in buckets {
            let shared = shared.clone();
            let barrier = barrier.clone();
            let stop = stop.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(bucket, shared, barrier, stop)
            }));
        }
        // setup accounting and effective-pass denominator cover this
        // engine's share of the nodes: keep every setup send that touches
        // a hosted endpoint so hosted sent AND received totals stay exact
        let setup: Vec<(usize, usize, usize)> = program
            .setup
            .into_iter()
            .filter(|&(from, to, _)| is_hosted[from] || is_hosted[to])
            .collect();
        let pass_denom = if h == n {
            program.pass_denom
        } else {
            program.pass_denom * h as f64 / n as f64
        };
        ParallelEngine {
            kind: program.kind,
            topo,
            threads,
            hosted,
            setup,
            pass_denom,
            t: 0,
            z,
            shared,
            workers,
            barrier,
            stop,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Nodes this engine hosts (all of them unless the transport splits
    /// the topology across processes).
    pub fn hosted(&self) -> &[usize] {
        &self.hosted
    }

    /// (messages sent, messages delivered) so far — equal unless a
    /// message was dropped, which the concurrency stress test forbids.
    pub fn message_stats(&self) -> (u64, u64) {
        (
            self.shared.sent.load(Ordering::Relaxed),
            self.shared.delivered.load(Ordering::Relaxed),
        )
    }
}

impl Algorithm for ParallelEngine {
    fn step(&mut self, net: &mut Network) {
        if self.t == 0 {
            for &(from, to, len) in &self.setup {
                net.send_dense(from, to, len);
            }
        }
        self.barrier.wait(); // release the round
        self.barrier.wait(); // phase A complete
        self.barrier.wait(); // phase B complete
        // fail fast (with an error instead of a barrier deadlock) if a
        // worker hit trouble — the engine is poisoned either way, but a
        // transport failure (peer died, drain timed out) must not be
        // reported as node code panicking
        if self.shared.panicked.load(Ordering::SeqCst) {
            let transport_err = self.shared.failure.lock().unwrap().take();
            match transport_err {
                Some(e) => panic!(
                    "ParallelEngine: transport failure during round {} of {}: {e}",
                    self.t,
                    self.kind.name()
                ),
                None => panic!(
                    "ParallelEngine: a node panicked on a worker thread during \
                     round {} of {} — engine state is poisoned",
                    self.t,
                    self.kind.name()
                ),
            }
        }
        // replay cost events in canonical (sender, emit index) order —
        // identical to the sequential driver's charging order
        let mut events = {
            let mut guard = self.shared.costs.lock().unwrap();
            std::mem::take(&mut *guard)
        };
        events.sort_by_key(|e| (e.from, e.seq));
        for e in events {
            match e.kind {
                CostKind::Dense(len) => net.send_dense(e.from, e.to, len),
                CostKind::Sparse(nnz, tail) => net.send_sparse(e.from, e.to, nnz, tail),
            }
        }
        // mirror iterates for `iterates()`
        for (n, row) in self.z.iter_mut().enumerate() {
            let slot = self.shared.slots[n].lock().unwrap();
            row.copy_from_slice(&slot);
        }
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        let evals: u64 = self.shared.evals.iter().map(|e| e.load(Ordering::Relaxed)).sum();
        evals as f64 / self.pass_denom
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        self.kind.name()
    }
}

impl Drop for ParallelEngine {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.barrier.wait(); // wake workers at the round-start barrier
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::build;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    fn tiny_world(nodes: usize) -> (Arc<dyn Problem>, MixingMatrix, Topology) {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(63);
        let part = ds.partition_seeded(nodes, 3);
        let topo = Topology::ring(nodes);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (Arc::new(RidgeProblem::new(part, 0.05)), mix, topo)
    }

    #[test]
    fn engine_matches_sequential_bitwise_smoke() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params);
        let mut par =
            ParallelEngine::new(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params, 2);
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..12 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(
                    seq.iterates()[n],
                    par.iterates()[n],
                    "round {round} node {n}"
                );
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
        assert_eq!(seq.passes(), par.passes());
    }

    #[test]
    fn engine_matches_sequential_on_tcp_loopback_smoke() {
        use crate::runtime::transport::TcpTransport;
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Extra, p.clone(), &mix, &topo, &params);
        let transport = Box::new(TcpTransport::loopback(&topo, params.seed).unwrap());
        let mut par = ParallelEngine::new_with_transport(
            AlgorithmKind::Extra,
            p.clone(),
            &mix,
            &topo,
            &params,
            2,
            transport,
        );
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..8 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(seq.iterates()[n], par.iterates()[n], "round {round} node {n}");
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
    }

    #[test]
    fn drop_without_stepping_does_not_hang() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let eng = ParallelEngine::new(AlgorithmKind::Extra, p, &mix, &topo, &params, 3);
        drop(eng);
    }

    #[test]
    fn message_stats_balance() {
        let (p, mix, topo) = tiny_world(5);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng =
            ParallelEngine::new(AlgorithmKind::DsbaSparse, p, &mix, &topo, &params, 2);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..10 {
            eng.step(&mut net);
        }
        let (sent, delivered) = eng.message_stats();
        assert_eq!(sent, delivered, "engine dropped messages");
        assert!(sent > 0);
    }

    struct PanickyNode {
        z: Vec<f64>,
        boom_at: usize,
    }

    impl NodeState for PanickyNode {
        fn outgoing(&mut self, _t: usize) -> Vec<crate::comm::Outgoing> {
            Vec::new()
        }
        fn on_receive(&mut self, _from: usize, _msg: Message) {}
        fn local_step(&mut self, t: usize) {
            if t == self.boom_at {
                panic!("boom");
            }
        }
        fn iterate(&self) -> &[f64] {
            &self.z
        }
        fn evals(&self) -> u64 {
            0
        }
    }

    #[test]
    fn worker_panic_fails_fast_instead_of_deadlocking() {
        let program = NodeProgram {
            kind: AlgorithmKind::Dsba,
            nodes: vec![Box::new(PanickyNode { z: vec![0.0], boom_at: 2 })],
            setup: Vec::new(),
            pass_denom: 1.0,
        };
        let topo = Topology::from_edges(1, &[]);
        let mut eng = ParallelEngine::from_program(program, topo.clone(), 1);
        let mut net = Network::new(topo, CommCostModel::default());
        eng.step(&mut net);
        eng.step(&mut net);
        // round t=2 panics on the worker; the launcher must surface it as
        // a panic, not a barrier deadlock
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.step(&mut net);
        }));
        assert!(result.is_err(), "expected fail-fast panic");
        drop(eng); // must not hang
    }

    #[test]
    fn auto_threads_is_bounded() {
        assert!(auto_threads(1) == 1);
        assert!(auto_threads(4) >= 1 && auto_threads(4) <= 4);
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("parallel"), Some(EngineKind::Parallel));
        assert_eq!(EngineKind::parse("SEQ"), Some(EngineKind::Sequential));
        assert_eq!(EngineKind::parse("bogus"), None);
    }
}
