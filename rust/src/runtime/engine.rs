//! Multi-threaded message-passing node engine with two round clocks.
//!
//! Executes the per-node decomposition of any method
//! ([`crate::algorithms::build_node_program`]) across worker threads, with
//! a pluggable [`Transport`] carrying typed [`Message`]s along the
//! topology's edges. The engine is the *fast path*; the sequential
//! [`crate::algorithms::node::RoundDriver`] behind each `Algorithm` impl
//! is the reference oracle.
//!
//! Two transports exist today (see [`crate::runtime::transport`]):
//! [`LocalTransport`] (in-process mpsc, the default) and
//! [`crate::runtime::TcpTransport`] (per-edge loopback/host sockets with
//! the framed wire codec). The determinism contract below holds for both.
//!
//! ## Round clocks
//!
//! [`ModeSpec`] selects how workers progress through rounds:
//!
//! * **Sync** (`RoundClock`, the default): `std::sync::Barrier`-paced
//!   phases, bit-for-bit equal to the sequential oracle.
//! * **Async(tau)** (`AsyncClock`): no barrier — a node is *admitted*
//!   into round `t` once every in-neighbor's watermark
//!   ([`crate::runtime::NodePort::poll_watermarks`]) covers round
//!   `t - tau`, and it consumes the freshest available iterate per
//!   neighbor (older dense payloads are superseded; compressed
//!   error-feedback deltas are always applied in order, never skipped,
//!   so the CHOCO replica invariant holds; sparse relay deltas are
//!   delivered exactly once, in order). `tau = 0` admits only on fully
//!   fresh data and reproduces the sync clock bit-for-bit (pinned by
//!   `rust/tests/async_engine.rs`); `tau > 0` trades bounded staleness
//!   for straggler immunity. Setting `DSBA_ASYNC_TRACE` switches the
//!   admission schedule to a fixed per-edge staleness offset
//!   (deterministic in node/neighbor indices), making async runs
//!   replayable for debugging at any thread count and on both
//!   transports.
//!
//! ## Determinism contract (sync clock)
//!
//! Given the same seed, the engine's iterates are **bit-for-bit equal** to
//! the sequential driver's (pinned by `rust/tests/engine_parity.rs`):
//!
//! * node states are constructed on the launching thread in node order,
//!   so per-node RNG streams are forked identically;
//! * rounds are barrier-synchronized — phase A (every node emits its
//!   messages), barrier, phase B (every node drains its round inbox and
//!   runs its local step), barrier — so a round's messages are all
//!   delivered before any local step runs, exactly the synchronous
//!   model (the TCP backend additionally gates each drain on per-edge
//!   end-of-round watermark frames, which is what keeps *separate engine
//!   processes* in lockstep);
//! * each inbox is sorted by (sender, emit index) before delivery, so
//!   handlers see the same order the sequential driver produces;
//! * nodes may only read their own state plus received payloads, so
//!   scheduling cannot leak into the arithmetic.
//!
//! ## Accounting
//!
//! Workers log one cost event per message; after the round the launching
//! thread replays the events into the [`Network`] in canonical (sender,
//! emit index) order, so per-node sent/received DOUBLE totals equal the
//! sequential accounting exactly (dense and sparse payloads priced
//! through the same [`crate::comm::CommCostModel`]).
//!
//! ## Hosting a subset (cross-process runs)
//!
//! A transport may host only part of the node set (`--hosted` + `--peers`
//! split one topology across engine processes). The engine then steps
//! only its hosted nodes; `iterates()` rows of remote nodes stay at the
//! initial point, and `passes()` covers the hosted share. Cost accounting
//! for hosted nodes is exact in both directions: sends are charged at the
//! emitting node, and inflow from remote engines is charged via
//! receive-side cost events merged into the same canonical replay.
//! Single-process runs — both transports' default — host everything and
//! are bit-for-bit complete.

use super::fault::FaultSpec;
use crate::algorithms::{
    build_node_program, AlgoParams, Algorithm, AlgorithmKind, NodeProgram, NodeState,
};
use crate::comm::{CompressedVec, CompressionSpec, Compressor, ErrorFeedback, Message, Network};
use crate::graph::{MixingMatrix, Topology};
use crate::metrics::{decode_stat_rows, encode_stat_rows, GlobalStats, NodeStatRow};
use crate::operators::Problem;
use crate::runtime::transport::{LinkStats, LocalTransport, NodePort, Transport};
use crate::telemetry::trace::{Phase, PhaseSpans, SpanTimer};
use crate::telemetry::{
    EventKind, EventSink, RunEvent, TelemetryRow, TelemetrySink, TelemetrySpec, TelemetryWriter,
};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

/// Which driver executes the rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Deterministic in-order reference driver (the oracle).
    Sequential,
    /// Multi-threaded engine (bit-for-bit equal, wall-clock faster).
    Parallel,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => EngineKind::Sequential,
            "parallel" | "par" => EngineKind::Parallel,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Sequential => "sequential",
            EngineKind::Parallel => "parallel",
        }
    }
}

/// Round progression discipline of the engine's workers (see the module
/// docs): the barrier-paced `RoundClock` or the watermark-driven
/// `AsyncClock` with a bounded staleness window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModeSpec {
    /// Barrier-synchronized rounds — bit-for-bit equal to the sequential
    /// oracle (the default).
    Sync,
    /// Bounded-staleness rounds: a node enters round `t` once every
    /// in-neighbor's watermark covers round `t - tau`. `Async(0)` still
    /// reproduces the sync iterates bit-for-bit; larger windows trade
    /// staleness for straggler immunity.
    Async(u32),
}

impl Default for ModeSpec {
    fn default() -> ModeSpec {
        ModeSpec::Sync
    }
}

impl ModeSpec {
    /// Accepts `sync`, `async` (window 0), or `async:TAU`.
    pub fn parse(s: &str) -> Option<ModeSpec> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "sync" => Some(ModeSpec::Sync),
            "async" => Some(ModeSpec::Async(0)),
            _ => {
                let tau = s.strip_prefix("async:")?;
                tau.trim().parse().ok().map(ModeSpec::Async)
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            ModeSpec::Sync => "sync".to_string(),
            ModeSpec::Async(tau) => format!("async:{tau}"),
        }
    }

    pub fn is_async(&self) -> bool {
        matches!(self, ModeSpec::Async(_))
    }
}

/// Worker count for `threads = 0` (auto): available cores capped by the
/// node count.
pub fn auto_threads(n_nodes: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    cores.clamp(1, n_nodes.max(1))
}

/// One hosted node scheduled on a worker.
struct HostedNode {
    /// topology node index
    idx: usize,
    state: Box<dyn NodeState>,
    port: Box<dyn NodePort>,
    /// neighbors hosted by a peer engine process — the links split-run
    /// STATS control frames cross during a metrics exchange (empty for
    /// single-process runs, so the stats phase is a no-op)
    cross: Vec<usize>,
    /// wire compression at the transport boundary (`None` = uncompressed,
    /// the `--compress none` bypass)
    comp: Option<CompState>,
    /// per-node telemetry accumulator (`None` = telemetry off)
    telem: Option<NodeTelemetry>,
}

/// Per-hosted-node compression state: the sender-side error feedback for
/// this node's dense broadcast, plus one receiver-side `x_hat` replica
/// per in-neighbor. Lives at the engine's transport boundary so both
/// [`LocalTransport`] and [`crate::runtime::TcpTransport`] carry the same
/// `COMP` frames, and node states keep seeing plain dense payloads.
struct CompState {
    comp: Box<dyn Compressor>,
    /// exact compressors assign `x_hat = x` (bit-for-bit Identity pin)
    exact: bool,
    ef: ErrorFeedback,
    /// receiver-side `x_hat` replicas, keyed by in-neighbor — they track
    /// the *sender's* `ef.x_hat` bit-for-bit because both ends apply the
    /// identical wire delta
    replicas: std::collections::HashMap<usize, ErrorFeedback>,
    /// this round's compressed broadcast, keyed on the `Arc` payload all
    /// neighbors share — compress once per round, not once per edge
    cache: Option<(Arc<Vec<f64>>, Message)>,
}

impl CompState {
    /// Sender side: turn the round's dense broadcast into a `COMP` frame.
    fn outbound(&mut self, v: &Arc<Vec<f64>>) -> Message {
        if let Some((cached, msg)) = &self.cache {
            if Arc::ptr_eq(cached, v) {
                return msg.clone();
            }
            // a second distinct payload would advance the sender x_hat
            // twice while each receiver replica absorbs only one delta
            panic!(
                "wire compression requires a uniform dense broadcast within \
                 a round (got two distinct payloads from one node)"
            );
        }
        let c = self.ef.encode(self.comp.as_mut(), v);
        let msg = Message::Comp(Arc::new(c));
        self.cache = Some((v.clone(), msg.clone()));
        msg
    }

    /// Receiver side: absorb a `COMP` frame from `from` and hand back the
    /// updated dense estimate the node state should see.
    fn inbound(&mut self, from: usize, c: &CompressedVec) -> Vec<f64> {
        let ef = self
            .replicas
            .entry(from)
            .or_insert_with(|| ErrorFeedback::new(c.dim));
        ef.apply(c, self.exact);
        ef.x_hat.clone()
    }
}

#[derive(Clone, Copy, Debug)]
enum CostKind {
    Dense(usize),
    Sparse(usize, usize),
    /// quantized support size + declared bytes-on-wire
    Comp(usize, u64),
}

fn cost_kind_of(msg: &Message) -> CostKind {
    match msg {
        Message::Dense(v) => CostKind::Dense(v.len()),
        Message::Sparse(d) => CostKind::Sparse(d.vec.nnz(), d.tail.len()),
        Message::Comp(c) => CostKind::Comp(c.nnz(), c.bytes),
    }
}

/// DOUBLEs moved and serialized bytes of one message, priced off the
/// wire form like the cost replay: dense payloads move `len` doubles,
/// sparse relay deltas `nnz + tail` (4-byte indices alongside the
/// values), compressed frames their quantized support with the codec's
/// declared byte size.
fn doubles_and_bytes(kind: CostKind) -> (f64, u64) {
    match kind {
        CostKind::Dense(len) => (len as f64, 8 * len as u64),
        CostKind::Sparse(nnz, tail) => ((nnz + tail) as f64, (12 * nnz + 8 * tail) as u64),
        CostKind::Comp(nnz, bytes) => (nnz as f64, bytes),
    }
}

/// Per-node telemetry accumulator: counts one round's traffic in the
/// worker hot path and flushes one [`TelemetryRow`] right after the
/// node's local step. All counters are per-round; the link-layer fault
/// counters are the port's *cumulative* totals snapshot at flush time,
/// and `stalls` is the engine-wide stalled-scan total.
///
/// The accumulator only exists when telemetry is enabled (it lives in
/// `HostedNode::telem: Option<_>`), so every span clock read below is
/// behind that `Option` — an uninstrumented run pays nothing.
struct NodeTelemetry {
    sink: TelemetrySink,
    /// control-plane event sink (shares the writer channel with rows)
    events: Option<EventSink>,
    /// cumulative row drops already reported via a `writer-drop` event
    drops_reported: u64,
    /// previous round's iterate — the row's `residual` is the l2 step
    /// `||x_t - x_{t-1}||`
    prev: Vec<f64>,
    /// start of this node's current round window
    since: std::time::Instant,
    doubles_sent: f64,
    doubles_recv: f64,
    bytes_on_wire: u64,
    queue_depth: u64,
    staleness: u64,
    /// per-phase monotonic-clock spans for the current round window
    spans: PhaseSpans,
}

impl NodeTelemetry {
    fn new(sink: TelemetrySink, events: Option<EventSink>, z0: &[f64]) -> NodeTelemetry {
        NodeTelemetry {
            sink,
            events,
            drops_reported: 0,
            prev: z0.to_vec(),
            since: std::time::Instant::now(),
            doubles_sent: 0.0,
            doubles_recv: 0.0,
            bytes_on_wire: 0,
            queue_depth: 0,
            staleness: 0,
            spans: PhaseSpans::new(),
        }
    }

    fn on_send(&mut self, kind: CostKind) {
        let (d, b) = doubles_and_bytes(kind);
        self.doubles_sent += d;
        self.bytes_on_wire += b;
    }

    fn on_recv(&mut self, kind: CostKind) {
        let (d, b) = doubles_and_bytes(kind);
        self.doubles_recv += d;
        self.bytes_on_wire += b;
        self.queue_depth += 1;
    }

    /// Emit the row for round `t` and reset the per-round counters.
    fn flush_row(&mut self, t: u64, node: usize, iter: &[f64], stalls: u64, link: LinkStats) {
        let residual = iter
            .iter()
            .zip(self.prev.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        self.prev.copy_from_slice(iter);
        let spans = self.spans.take();
        self.sink.emit(TelemetryRow {
            round: t,
            node: node as u32,
            residual,
            doubles_sent: self.doubles_sent,
            doubles_recv: self.doubles_recv,
            bytes_on_wire: self.bytes_on_wire,
            wall_micros: self.since.elapsed().as_micros() as u64,
            queue_depth: self.queue_depth,
            staleness: self.staleness,
            stalls,
            retransmits: link.retransmits,
            dedups: link.dedups,
            drops_injected: link.drops_injected,
            dups_injected: link.dups_injected,
            wait_micros: spans.get(Phase::Wait),
            drain_micros: spans.get(Phase::Drain),
            compute_micros: spans.get(Phase::Compute),
            encode_micros: spans.get(Phase::Encode),
            send_micros: spans.get(Phase::Send),
        });
        self.since = std::time::Instant::now();
        self.doubles_sent = 0.0;
        self.doubles_recv = 0.0;
        self.bytes_on_wire = 0;
        self.queue_depth = 0;
        self.staleness = 0;
        // surface silent row loss as a control event the moment it grows,
        // not only in the trailing summary line
        let dropped = self.sink.dropped();
        if dropped > self.drops_reported {
            self.drops_reported = dropped;
            if let Some(es) = &self.events {
                es.emit(
                    RunEvent::new(EventKind::WriterDrop)
                        .node(node as u32)
                        .round(t)
                        .detail(format!("{dropped} row(s) dropped so far")),
                );
            }
        }
    }
}

/// The per-worker slice of a [`FaultSpec`]: the delay and kill clauses
/// workers act on directly. The drop/dup link faults live in the
/// transport's link layer ([`Transport::configure_faults`]), not here.
#[derive(Clone, Copy, Debug, Default)]
struct WorkerFaults {
    /// `(node, ms)` — a `None` node delays every hosted node
    delay: Option<(Option<usize>, u64)>,
    /// fail node `.0` at the start of round `.1`
    kill: Option<(usize, u64)>,
}

impl WorkerFaults {
    /// Merge the spec's delay/kill clauses with the deprecated
    /// `DSBA_INJECT_DELAY_MS` env alias (the spec wins when both name a
    /// delay).
    fn from_spec(fault: &FaultSpec) -> WorkerFaults {
        let delay = if fault.delay_ms > 0 {
            Some((fault.delay_node.map(|n| n as usize), fault.delay_ms))
        } else {
            inject_delay().map(|(node, ms)| (Some(node), ms))
        };
        WorkerFaults { delay, kill: fault.kill.map(|(node, round)| (node as usize, round)) }
    }

    fn delay_ms_for(&self, node: usize) -> Option<u64> {
        match self.delay {
            Some((None, ms)) => Some(ms),
            Some((Some(n), ms)) if n == node => Some(ms),
            _ => None,
        }
    }
}

/// `kill:NODE@ROUND` trips here, at the start of the node's round
/// emission: a fail-fast transport failure naming the node, the round,
/// and the last watermark seen from each in-neighbor.
fn check_kill(hn: &mut HostedNode, t: u64, faults: &WorkerFaults, shared: &Shared) {
    let Some((node, round)) = faults.kill else { return };
    if hn.idx != node || t != round {
        return;
    }
    let wms = hn.port.poll_watermarks().unwrap_or_default();
    let seen = if wms.is_empty() {
        "none".to_string()
    } else {
        wms.iter()
            .map(|&(m, w)| match w {
                0 => format!("peer {m}: none"),
                w => format!("peer {m}: round {}", w - 1),
            })
            .collect::<Vec<_>>()
            .join(", ")
    };
    if let Some(es) = &shared.events {
        es.emit(
            RunEvent::new(EventKind::NodeKill)
                .node(node as u32)
                .round(round)
                .detail(format!("fault injection (last-seen watermarks: {seen})")),
        );
    }
    shared.transport_failure(format!(
        "node {node} killed by fault injection at round {round} \
         (last-seen watermarks: {seen})"
    ));
}

#[derive(Clone, Copy, Debug)]
struct CostEvent {
    /// round the message belongs to — the async clock lets fast nodes
    /// emit ahead of the launcher, so replay must hold late rounds back
    t: u64,
    from: usize,
    seq: u32,
    to: usize,
    kind: CostKind,
}

struct Shared {
    /// per-node iterate slots, written by the owning worker each round
    slots: Vec<Mutex<Vec<f64>>>,
    /// per-node cumulative component evaluations
    evals: Vec<AtomicU64>,
    /// this round's cost events (drained by the launching thread)
    costs: Mutex<Vec<CostEvent>>,
    /// which nodes this engine hosts — receive-side costs are logged for
    /// messages arriving from non-hosted (remote) senders
    hosted_mask: Vec<bool>,
    sent: AtomicU64,
    delivered: AtomicU64,
    /// set when any worker's node code panicked; workers keep honoring
    /// the barrier protocol (skipping work) so nothing deadlocks, and the
    /// launcher propagates the failure after the round
    panicked: AtomicBool,
    /// first transport failure observed by a worker (None when the
    /// poisoning was a genuine node-code panic)
    failure: Mutex<Option<String>>,
    /// when true, the next barrier cycle is a split-run stats-exchange
    /// hop instead of a compute round (set/cleared by the launcher while
    /// workers are parked at the round-start barrier)
    stats_mode: AtomicBool,
    /// hop index of the current stats exchange (stamped into frames)
    stats_hop: AtomicU32,
    /// outbound row payload for the current hop (set by the launcher)
    stats_out: Mutex<Vec<u8>>,
    /// payloads collected from peer engines during the current hop
    stats_in: Mutex<Vec<Vec<u8>>>,
    /// rounds completed per node (round `t` done ⇒ value `t + 1`) — the
    /// progress watermark [`ProgressProbe`] and the async launcher read
    completed: Vec<AtomicU64>,
    /// async clock only: workers may work on any round `< target`; the
    /// launcher advances it to `t + 1 + tau` each step, bounding how far
    /// fast nodes run ahead of the round being reported
    target: AtomicU64,
    /// async clock only: scans where some node sat emitted-but-unadmitted
    /// (waiting on a lagging in-neighbor) and no node progressed
    stalls: AtomicU64,
    /// async clock only: max rounds-behind of any consumed neighbor
    /// iterate (0 under the sync clock and `async:0` by construction)
    max_staleness: AtomicU64,
    /// control-plane event sink (`None` = telemetry off)
    events: Option<EventSink>,
}

impl Shared {
    /// Record a transport failure (first one wins) and poison the engine
    /// via the normal panic path so the barrier protocol stays sound. The
    /// first failure also dumps the flight recorder: the crash sidecar is
    /// written *before* the panic unwinds, so the forensics survive even
    /// when the telemetry writer never drains its queue.
    fn transport_failure(&self, msg: String) -> ! {
        let mut slot = self.failure.lock().unwrap();
        let first = slot.is_none();
        if first {
            *slot = Some(msg.clone());
        }
        drop(slot);
        if first {
            if let Some(es) = &self.events {
                let _ = es.crash_dump(&msg);
            }
        }
        panic!("{msg}");
    }
}

/// Straggler-injection env alias: `DSBA_INJECT_DELAY_MS=<node>:<ms>`
/// sleeps the named node for `ms` milliseconds at the start of every
/// round emission, on both clocks. Deprecated in favor of the
/// `--fault delay:MS@NODE` clause ([`FaultSpec`]), which also takes
/// precedence when both are set; the alias warns once per process but
/// keeps working. Invalid specs are ignored with a warning rather than
/// failing a run.
fn parse_inject_delay(raw: Option<&str>) -> Option<(usize, u64)> {
    let (node, ms) = raw?.trim().split_once(':')?;
    Some((node.trim().parse().ok()?, ms.trim().parse().ok()?))
}

fn inject_delay() -> Option<(usize, u64)> {
    let var = std::env::var("DSBA_INJECT_DELAY_MS").ok();
    let parsed = parse_inject_delay(var.as_deref());
    if var.is_some() {
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| match parsed {
            None => eprintln!("warning: DSBA_INJECT_DELAY_MS must be <node>:<ms>; ignoring"),
            Some(_) => eprintln!(
                "warning: DSBA_INJECT_DELAY_MS is deprecated; use --fault delay:MS@NODE"
            ),
        });
    }
    parsed
}

/// splitmix64 finalizer — mixes an edge id into the deterministic
/// per-edge staleness schedule of `DSBA_ASYNC_TRACE`.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Fixed staleness offset of the edge `from -> node` under the
/// deterministic trace: round-independent, so each node consumes exactly
/// round `r - s` from each in-neighbor regardless of thread scheduling.
fn trace_staleness(node: usize, from: usize, tau: u64) -> u64 {
    if tau == 0 {
        return 0;
    }
    mix64(((node as u64) << 32) ^ (from as u64) ^ 0x5eed_cafe) % (tau + 1)
}

/// Emit one node's round-`t` messages plus the end-of-round watermark
/// (phase A of the sync clock; the emission half of an async scan).
fn emit_round(hn: &mut HostedNode, t: usize, shared: &Shared) {
    if let Some(cs) = hn.comp.as_mut() {
        cs.cache = None; // the cache is per-round
    }
    // span clock only when this node is telemetered — hot path stays
    // clock-free otherwise
    let mut timer = hn.telem.as_ref().map(|_| SpanTimer::start());
    let outs = hn.state.outgoing(t);
    if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
        tmr.lap(&mut tm.spans, Phase::Encode);
    }
    let mut batch: Vec<CostEvent> = Vec::with_capacity(outs.len());
    for (seq, out) in outs.into_iter().enumerate() {
        // compression happens here, at the transport boundary: dense
        // broadcasts become COMP frames, sparse relay deltas (already
        // exact and compact) pass through untouched
        let msg = match (out.msg, hn.comp.as_mut()) {
            (Message::Dense(v), Some(cs)) => cs.outbound(&v),
            (m, _) => m,
        };
        let kind = cost_kind_of(&msg);
        if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
            tm.on_send(kind);
            tmr.lap(&mut tm.spans, Phase::Encode);
        }
        batch.push(CostEvent { t: t as u64, from: hn.idx, seq: seq as u32, to: out.to, kind });
        shared.sent.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = hn.port.send(t, out.to, seq as u32, msg) {
            shared.transport_failure(e);
        }
        if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
            tmr.lap(&mut tm.spans, Phase::Send);
        }
    }
    if let Err(e) = hn.port.finish_round(t) {
        shared.transport_failure(e);
    }
    if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
        tmr.lap(&mut tm.spans, Phase::Send);
    }
    if !batch.is_empty() {
        shared.costs.lock().unwrap().extend(batch);
    }
}

/// Barrier wait with the blocked time attributed to every hosted
/// node's `wait` span. Telemetry-off workers take the plain wait — no
/// clock reads on the uninstrumented path.
fn barrier_wait_timed(barrier: &Barrier, nodes: &mut [HostedNode], telem_on: bool) {
    if !telem_on {
        barrier.wait();
        return;
    }
    let t0 = std::time::Instant::now();
    barrier.wait();
    let waited = t0.elapsed();
    for hn in nodes.iter_mut() {
        if let Some(tm) = hn.telem.as_mut() {
            tm.spans.record(Phase::Wait, waited);
        }
    }
}

/// The sync clock: today's three-barrier round protocol, bit-for-bit
/// preserved.
fn round_clock_loop(
    mut nodes: Vec<HostedNode>,
    shared: Arc<Shared>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
    faults: WorkerFaults,
) {
    let telem_on = nodes.iter().any(|hn| hn.telem.is_some());
    let mut t = 0usize;
    loop {
        barrier_wait_timed(&barrier, &mut nodes, telem_on); // round (or stats hop) start
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // split-run stats-exchange hop: same three-barrier cycle as a
        // compute round, but the payload is the launcher's row set and
        // only cross-process links carry anything; `t` does not advance
        if shared.stats_mode.load(Ordering::SeqCst) {
            let hop = shared.stats_hop.load(Ordering::SeqCst);
            if !shared.panicked.load(Ordering::SeqCst) {
                let send = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let payload = shared.stats_out.lock().unwrap().clone();
                    for hn in nodes.iter_mut() {
                        for &m in &hn.cross {
                            if let Err(e) = hn.port.send_stats(t, hop, m, &payload) {
                                shared.transport_failure(e);
                            }
                        }
                    }
                }));
                if send.is_err() {
                    shared.panicked.store(true, Ordering::SeqCst);
                }
            }
            barrier.wait(); // all stats sends complete
            if !shared.panicked.load(Ordering::SeqCst) {
                let recv = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut got: Vec<Vec<u8>> = Vec::new();
                    for hn in nodes.iter_mut() {
                        for &m in &hn.cross {
                            match hn.port.recv_stats(t, hop, m) {
                                Ok(p) => got.push(p),
                                Err(e) => shared.transport_failure(e),
                            }
                        }
                    }
                    if !got.is_empty() {
                        shared.stats_in.lock().unwrap().extend(got);
                    }
                }));
                if recv.is_err() {
                    shared.panicked.store(true, Ordering::SeqCst);
                }
            }
            barrier.wait(); // hop end
            continue;
        }
        // phase A: emit this round's messages
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_a = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for hn in nodes.iter_mut() {
                    check_kill(hn, t as u64, &faults, &shared);
                    if let Some(ms) = faults.delay_ms_for(hn.idx) {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                    }
                    emit_round(hn, t, &shared);
                }
            }));
            if phase_a.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier_wait_timed(&barrier, &mut nodes, telem_on); // all sends complete
        // phase B: drain inboxes (canonical order), run local steps
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_b = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut recv_batch: Vec<CostEvent> = Vec::new();
                for hn in nodes.iter_mut() {
                    let mut timer = hn.telem.as_ref().map(|_| SpanTimer::start());
                    let mut msgs = match hn.port.drain_round(t) {
                        Ok(m) => m,
                        Err(e) => shared.transport_failure(e),
                    };
                    // a TCP port blocks on peer watermarks inside the
                    // drain call — that share of the lap is wait, not
                    // drain
                    if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
                        let blocked = hn.port.take_blocked_micros();
                        tmr.lap_split(&mut tm.spans, Phase::Drain, blocked);
                    }
                    msgs.sort_by_key(|&(from, seq, _)| (from, seq));
                    for (from, seq, msg) in msgs {
                        shared.delivered.fetch_add(1, Ordering::Relaxed);
                        let kind = cost_kind_of(&msg);
                        if let Some(tm) = hn.telem.as_mut() {
                            tm.on_recv(kind);
                        }
                        // inflow from a remote engine: the sender's side
                        // can't charge it into OUR network, so log the
                        // receive-side event — merged into the same
                        // canonical (sender, emit idx) replay, keeping
                        // hosted received-DOUBLE totals exact. COMP costs
                        // are charged on the wire form, before it is
                        // reconstructed below
                        if !shared.hosted_mask[from] {
                            recv_batch.push(CostEvent { t: t as u64, from, seq, to: hn.idx, kind });
                        }
                        // COMP frames update this node's per-sender x_hat
                        // replica; the node state sees the reconstructed
                        // dense estimate, never the wire form
                        let msg = match (msg, hn.comp.as_mut()) {
                            (Message::Comp(c), Some(cs)) => {
                                Message::Dense(Arc::new(cs.inbound(from, &c)))
                            }
                            (Message::Comp(_), None) => panic!(
                                "received a COMP frame but compression is \
                                 disabled on this engine — peer engines must \
                                 agree on --compress"
                            ),
                            (m, _) => m,
                        };
                        hn.state.on_receive(from, msg);
                    }
                    if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
                        tmr.lap(&mut tm.spans, Phase::Drain);
                    }
                    hn.state.local_step(t);
                    if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
                        tmr.lap(&mut tm.spans, Phase::Compute);
                    }
                    shared.slots[hn.idx]
                        .lock()
                        .unwrap()
                        .copy_from_slice(hn.state.iterate());
                    shared.evals[hn.idx].store(hn.state.evals(), Ordering::Relaxed);
                    shared.completed[hn.idx].store(t as u64 + 1, Ordering::SeqCst);
                    if let Some(tm) = hn.telem.as_mut() {
                        let stalls = shared.stalls.load(Ordering::Relaxed);
                        let link = hn.port.link_stats();
                        tm.flush_row(t as u64, hn.idx, hn.state.iterate(), stalls, link);
                    }
                }
                if !recv_batch.is_empty() {
                    shared.costs.lock().unwrap().extend(recv_batch);
                }
            }));
            if phase_b.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier_wait_timed(&barrier, &mut nodes, telem_on); // round end
        t += 1;
    }
}

/// Per-node bookkeeping of the async clock.
struct AsyncCtl {
    /// round this node is currently working on
    r: u64,
    /// round-`r` messages are out; the node is waiting for admission
    emitted: bool,
    /// in-neighbors (ascending, so delivery order matches the sync
    /// clock's global `(sender, emit index)` sort)
    in_nbrs: Vec<usize>,
    /// fixed per-edge staleness offsets, aligned with `in_nbrs` (all
    /// zero unless `DSBA_ASYNC_TRACE` is set)
    trace_s: Vec<u64>,
    /// received-but-unconsumed messages: sender -> round -> (seq, msg)
    pending: std::collections::HashMap<
        usize,
        std::collections::BTreeMap<u64, Vec<(u32, Message)>>,
    >,
    /// when this node first found itself blocked on admission
    wait_since: Option<std::time::Instant>,
}

/// Admission check for `ctl`'s current round: every in-neighbor's
/// watermark must cover round `r - tau` (trace mode: exactly round
/// `r - s_edge`). Blocking past `deadline` trips a transport failure
/// naming each lagging in-neighbor with its last-seen watermark.
fn async_admit(
    hn: &mut HostedNode,
    ctl: &mut AsyncCtl,
    tau: u64,
    trace: bool,
    deadline: std::time::Duration,
    shared: &Shared,
) -> bool {
    let wms = match hn.port.poll_watermarks() {
        Ok(w) => w,
        Err(e) => shared.transport_failure(e),
    };
    let wm_of =
        |m: usize| wms.iter().find(|&&(node, _)| node == m).map(|&(_, w)| w).unwrap_or(0);
    let need = |k: usize| {
        if trace {
            ctl.r.saturating_sub(ctl.trace_s[k]) + 1
        } else {
            (ctl.r + 1).saturating_sub(tau)
        }
    };
    if ctl.in_nbrs.iter().enumerate().all(|(k, &m)| wm_of(m) >= need(k)) {
        // attribute the admission block (first refusal to now) to the
        // node's wait span before clearing it
        if let (Some(tm), Some(since)) = (hn.telem.as_mut(), ctl.wait_since.take()) {
            tm.spans.record(Phase::Wait, since.elapsed());
        }
        ctl.wait_since = None;
        if let Some(es) = &shared.events {
            es.emit(RunEvent::new(EventKind::RoundAdmitted).node(hn.idx as u32).round(ctl.r));
        }
        return true;
    }
    if ctl.wait_since.is_none() {
        // first refusal for this round: record who we are waiting on
        if let Some(es) = &shared.events {
            if let Some((_, &m)) =
                ctl.in_nbrs.iter().enumerate().find(|&(k, &m)| wm_of(m) < need(k))
            {
                let d = match wm_of(m) {
                    0 => format!("peer {m} (no watermark yet)"),
                    w => format!("peer {m} (last watermark: round {})", w - 1),
                };
                es.emit(
                    RunEvent::new(EventKind::AdmissionStall)
                        .node(hn.idx as u32)
                        .peer(m as u32)
                        .round(ctl.r)
                        .detail(d),
                );
            }
        }
    }
    let since = *ctl.wait_since.get_or_insert_with(std::time::Instant::now);
    if since.elapsed() > deadline {
        let lagging: Vec<String> = ctl
            .in_nbrs
            .iter()
            .enumerate()
            .filter(|&(k, &m)| wm_of(m) < need(k))
            .map(|(_, &m)| match wm_of(m) {
                0 => format!("peer {m} (no watermark yet)"),
                w => format!("peer {m} (last watermark: round {})", w - 1),
            })
            .collect();
        shared.transport_failure(format!(
            "node {}: async round {} admission timed out after {:?} — \
             waiting on {}",
            hn.idx,
            ctl.r,
            deadline,
            lagging.join(", ")
        ));
    }
    false
}

/// Consume everything admissible at the node's current round and run the
/// local step. Per-sender rules: dense iterates are superseded (only the
/// freshest within the limit is delivered — re-delivering a stale one
/// would wrongly rotate the receiver's `NeighborBuf` generations); COMP
/// error-feedback deltas are all applied in `(round, seq)` order, never
/// skipped (the CHOCO replica invariant), with one reconstructed dense
/// delivery at the last delta's position; sparse relay deltas are
/// delivered exactly once, in order. A neighbor with nothing fresh is
/// left untouched, exactly like a quiet neighbor under the sync clock.
fn async_deliver_and_step(hn: &mut HostedNode, ctl: &mut AsyncCtl, shared: &Shared) {
    let r = ctl.r;
    let mut timer = hn.telem.as_ref().map(|_| SpanTimer::start());
    let drained = match hn.port.drain_up_to(r as usize) {
        Ok(d) => d,
        Err(e) => shared.transport_failure(e),
    };
    for (from, rt, seq, msg) in drained {
        shared.delivered.fetch_add(1, Ordering::Relaxed);
        if let Some(tm) = hn.telem.as_mut() {
            tm.on_recv(cost_kind_of(&msg));
        }
        ctl.pending.entry(from).or_default().entry(rt).or_default().push((seq, msg));
    }
    for k in 0..ctl.in_nbrs.len() {
        let m = ctl.in_nbrs[k];
        // trace mode consumes exactly round r - s per edge; the free
        // schedule consumes everything that has arrived
        let limit = r.saturating_sub(ctl.trace_s[k]);
        let Some(rounds) = ctl.pending.get_mut(&m) else { continue };
        let ready: Vec<u64> = rounds.range(..=limit).map(|(&rt, _)| rt).collect();
        if ready.is_empty() {
            continue;
        }
        let mut batch: Vec<(u64, u32, Message)> = Vec::new();
        for rt in ready {
            for (seq, msg) in rounds.remove(&rt).unwrap() {
                batch.push((rt, seq, msg));
            }
        }
        batch.sort_by_key(|&(rt, seq, _)| (rt, seq));
        let dense_last = batch
            .iter()
            .rev()
            .find(|e| matches!(e.2, Message::Dense(_)))
            .map(|e| (e.0, e.1));
        let comp_last = batch
            .iter()
            .rev()
            .find(|e| matches!(e.2, Message::Comp(_)))
            .map(|e| (e.0, e.1));
        for (rt, seq, msg) in batch {
            match msg {
                Message::Sparse(_) => hn.state.on_receive(m, msg),
                Message::Comp(c) => {
                    let cs = hn.comp.as_mut().unwrap_or_else(|| {
                        panic!(
                            "received a COMP frame but compression is \
                             disabled on this engine — peer engines must \
                             agree on --compress"
                        )
                    });
                    let v = cs.inbound(m, &c);
                    if Some((rt, seq)) == comp_last {
                        hn.state.on_receive(m, Message::Dense(Arc::new(v)));
                        shared.max_staleness.fetch_max(r - rt, Ordering::Relaxed);
                        if let Some(tm) = hn.telem.as_mut() {
                            tm.staleness = tm.staleness.max(r - rt);
                        }
                    }
                }
                Message::Dense(_) => {
                    if Some((rt, seq)) == dense_last {
                        hn.state.on_receive(m, msg);
                        shared.max_staleness.fetch_max(r - rt, Ordering::Relaxed);
                        if let Some(tm) = hn.telem.as_mut() {
                            tm.staleness = tm.staleness.max(r - rt);
                        }
                    }
                }
            }
        }
    }
    if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
        let blocked = hn.port.take_blocked_micros();
        tmr.lap_split(&mut tm.spans, Phase::Drain, blocked);
    }
    hn.state.local_step(r as usize);
    if let (Some(tm), Some(tmr)) = (hn.telem.as_mut(), timer.as_mut()) {
        tmr.lap(&mut tm.spans, Phase::Compute);
    }
    shared.slots[hn.idx].lock().unwrap().copy_from_slice(hn.state.iterate());
    shared.evals[hn.idx].store(hn.state.evals(), Ordering::Relaxed);
    shared.completed[hn.idx].store(r + 1, Ordering::SeqCst);
    if let Some(tm) = hn.telem.as_mut() {
        let stalls = shared.stalls.load(Ordering::Relaxed);
        let link = hn.port.link_stats();
        tm.flush_row(r, hn.idx, hn.state.iterate(), stalls, link);
    }
    ctl.r += 1;
    ctl.emitted = false;
}

/// The async clock: no barrier. Each scan walks the worker's nodes —
/// emitting any node whose round is below the launcher's target, then
/// admitting and stepping any node whose in-neighbor watermarks cover
/// its staleness window. A scan with no progress sleeps briefly;
/// blocked-and-idle scans are counted as stalls.
fn async_clock_loop(
    mut nodes: Vec<HostedNode>,
    mut ctls: Vec<AsyncCtl>,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    tau: u64,
    trace: bool,
    faults: WorkerFaults,
    deadline: std::time::Duration,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if shared.panicked.load(Ordering::SeqCst) {
            // poisoned: park cheaply until the launcher drops the engine
            std::thread::sleep(std::time::Duration::from_millis(1));
            continue;
        }
        let target = shared.target.load(Ordering::SeqCst);
        let mut progress = false;
        let mut blocked = false;
        let scan = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for (hn, ctl) in nodes.iter_mut().zip(ctls.iter_mut()) {
                if !ctl.emitted && ctl.r < target {
                    check_kill(hn, ctl.r, &faults, &shared);
                    if let Some(ms) = faults.delay_ms_for(hn.idx) {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                    }
                    emit_round(hn, ctl.r as usize, &shared);
                    ctl.emitted = true;
                    progress = true;
                }
                if !ctl.emitted {
                    continue; // capped by the launcher's target
                }
                if !async_admit(hn, ctl, tau, trace, deadline, &shared) {
                    blocked = true;
                    continue;
                }
                async_deliver_and_step(hn, ctl, &shared);
                progress = true;
            }
        }));
        if scan.is_err() {
            shared.panicked.store(true, Ordering::SeqCst);
            continue;
        }
        if !progress {
            if blocked {
                shared.stalls.fetch_add(1, Ordering::Relaxed);
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
}

/// The multi-threaded engine. Implements [`Algorithm`], so the
/// coordinator, CLI, and benches drive it exactly like the sequential
/// methods.
pub struct ParallelEngine {
    kind: AlgorithmKind,
    mode: ModeSpec,
    topo: Topology,
    threads: usize,
    /// nodes this engine hosts (all of them for single-process runs)
    hosted: Vec<usize>,
    setup: Vec<(usize, usize, usize)>,
    pass_denom: f64,
    /// global `N * q` (unscaled by the hosted share) — the denominator
    /// split-run metrics aggregation reports global passes with
    pass_denom_full: f64,
    t: usize,
    /// launching-thread mirror of the per-node iterates
    z: Vec<Vec<f64>>,
    /// async clock only: cost events from rounds the launcher has not
    /// reported yet (fast nodes emit up to `tau` rounds ahead)
    pending_costs: Vec<CostEvent>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
    /// telemetry writer thread — declared after `workers` so it drops
    /// (drains and joins) only once every sink-holding worker is gone
    telemetry: Option<TelemetryWriter>,
}

impl ParallelEngine {
    /// Decompose `kind` into per-node states and launch the workers over
    /// the default in-process transport. `threads = 0` selects
    /// [`auto_threads`].
    pub fn new(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program(program, topo.clone(), threads)
    }

    /// [`ParallelEngine::new`] with an explicit transport backend (e.g. a
    /// [`crate::runtime::TcpTransport`] over loopback or host sockets).
    pub fn new_with_transport(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
        transport: Box<dyn Transport>,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program_with_transport(program, topo.clone(), threads, transport)
    }

    /// The fully-general constructor: explicit transport **and** wire
    /// compression. With [`CompressionSpec::None`] this is exactly
    /// [`ParallelEngine::new_with_transport`]; otherwise every hosted
    /// node's dense broadcast crosses the transport as an error-feedback
    /// `COMP` frame (per-node compressor streams seeded from
    /// `params.seed`, so lossy runs are deterministic at any thread
    /// count and across split processes).
    pub fn new_full(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
        transport: Box<dyn Transport>,
        compress: &CompressionSpec,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program_full(
            program,
            topo.clone(),
            threads,
            transport,
            compress.clone(),
            params.seed,
        )
    }

    /// [`ParallelEngine::new_full`] plus a [`ModeSpec`] selecting the
    /// round clock. Async mode requires the transport to host every node
    /// (split-hosted runs are sync-only for now).
    #[allow(clippy::too_many_arguments)]
    pub fn new_full_mode(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
        transport: Box<dyn Transport>,
        compress: &CompressionSpec,
        mode: ModeSpec,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program_full_mode(
            program,
            topo.clone(),
            threads,
            transport,
            compress.clone(),
            params.seed,
            mode,
        )
    }

    /// Launch workers over an already-built node program (in-process
    /// transport).
    pub fn from_program(program: NodeProgram, topo: Topology, threads: usize) -> ParallelEngine {
        let n = program.nodes.len();
        Self::from_program_with_transport(
            program,
            topo,
            threads,
            Box::new(LocalTransport::new(n)),
        )
    }

    /// Launch workers over an already-built node program and a connected
    /// transport. The transport decides which nodes this engine hosts;
    /// states are still *built* for every node (in node order) so RNG
    /// forking matches the sequential oracle, then non-hosted states are
    /// dropped.
    pub fn from_program_with_transport(
        program: NodeProgram,
        topo: Topology,
        threads: usize,
        transport: Box<dyn Transport>,
    ) -> ParallelEngine {
        Self::from_program_full(program, topo, threads, transport, CompressionSpec::None, 0)
    }

    /// [`ParallelEngine::from_program_with_transport`] plus a wire
    /// [`CompressionSpec`] (see [`ParallelEngine::new_full`]).
    pub fn from_program_full(
        program: NodeProgram,
        topo: Topology,
        threads: usize,
        transport: Box<dyn Transport>,
        compress: CompressionSpec,
        seed: u64,
    ) -> ParallelEngine {
        Self::from_program_full_mode(
            program,
            topo,
            threads,
            transport,
            compress,
            seed,
            ModeSpec::Sync,
        )
    }

    /// [`ParallelEngine::from_program_full`] plus the round-clock
    /// [`ModeSpec`] (see [`ParallelEngine::new_full_mode`]).
    pub fn from_program_full_mode(
        program: NodeProgram,
        topo: Topology,
        threads: usize,
        transport: Box<dyn Transport>,
        compress: CompressionSpec,
        seed: u64,
        mode: ModeSpec,
    ) -> ParallelEngine {
        Self::from_program_faulted(
            program,
            topo,
            threads,
            transport,
            compress,
            seed,
            mode,
            &FaultSpec::none(),
            &TelemetrySpec::disabled(),
        )
        .expect("fault-free, telemetry-free engine construction cannot fail")
    }

    /// [`ParallelEngine::new_full_mode`] plus the fault-injection plan
    /// and the telemetry stream — the constructor the coordinator uses.
    #[allow(clippy::too_many_arguments)]
    pub fn new_faulted(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
        transport: Box<dyn Transport>,
        compress: &CompressionSpec,
        mode: ModeSpec,
        fault: &FaultSpec,
        telemetry: &TelemetrySpec,
    ) -> Result<ParallelEngine, String> {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program_faulted(
            program,
            topo.clone(),
            threads,
            transport,
            compress.clone(),
            params.seed,
            mode,
            fault,
            telemetry,
        )
    }

    /// The superset constructor behind every other one: explicit
    /// transport, wire compression, round clock, fault-injection plan,
    /// and telemetry stream. Fallible because faults and telemetry can
    /// be rejected up front — link faults (drop/dup) on a transport
    /// without a link layer, a kill target outside the topology, or an
    /// unwritable telemetry path all come back as `Err` before any
    /// worker spawns.
    #[allow(clippy::too_many_arguments)]
    pub fn from_program_faulted(
        program: NodeProgram,
        topo: Topology,
        threads: usize,
        mut transport: Box<dyn Transport>,
        compress: CompressionSpec,
        seed: u64,
        mode: ModeSpec,
        fault: &FaultSpec,
        telemetry: &TelemetrySpec,
    ) -> Result<ParallelEngine, String> {
        let n = program.nodes.len();
        assert!(n > 0, "engine needs at least one node");
        if let Some((node, round)) = fault.kill {
            if node as usize >= n {
                return Err(format!(
                    "fault kill:{node}@{round} names node {node}, but the topology \
                     has only {n} nodes"
                ));
            }
        }
        // link faults need the transport's reliable link layer; transports
        // without one reject them here, before any socket traffic
        transport.configure_faults(fault, seed)?;
        if let ModeSpec::Async(tau) = mode {
            // async senders may run up to tau rounds ahead of a receiver's
            // watermark, so retransmit buffers must retain that much more
            transport.set_retain_grace(tau as u64);
        }
        let writer = telemetry.spawn_writer()?;
        // one event sink per run: shared flight recorder, writer-epoch
        // timestamps, and the `<path>.crash` sidecar for fail-fast dumps.
        // Installed into the transport before the ports are taken, so the
        // link layer's reader threads see it from the first frame on.
        let events = writer
            .as_ref()
            .map(|w| EventSink::new(w.sink(), w.epoch(), telemetry.crash_path()));
        if let Some(es) = &events {
            transport.set_event_sink(es.clone());
        }
        let hosted = transport.hosted().to_vec();
        assert!(
            !hosted.is_empty()
                && hosted.windows(2).all(|w| w[0] < w[1])
                && *hosted.last().unwrap() < n,
            "transport hosts an invalid node set {hosted:?} for {n} nodes"
        );
        let mut is_hosted = vec![false; n];
        for &h in &hosted {
            is_hosted[h] = true;
        }
        let h = hosted.len();
        assert!(
            !mode.is_async() || h == n,
            "async mode requires hosting every node ({h} of {n} hosted) — \
             split-hosted runs are sync-only"
        );
        let threads = if threads == 0 { auto_threads(h) } else { threads }.clamp(1, h);
        let z: Vec<Vec<f64>> = program.nodes.iter().map(|nd| nd.iterate().to_vec()).collect();
        let shared = Arc::new(Shared {
            slots: z.iter().map(|r| Mutex::new(r.clone())).collect(),
            evals: (0..n).map(|_| AtomicU64::new(0)).collect(),
            costs: Mutex::new(Vec::new()),
            hosted_mask: is_hosted.clone(),
            sent: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            panicked: AtomicBool::new(false),
            failure: Mutex::new(None),
            stats_mode: AtomicBool::new(false),
            stats_hop: AtomicU32::new(0),
            stats_out: Mutex::new(Vec::new()),
            stats_in: Mutex::new(Vec::new()),
            completed: (0..n).map(|_| AtomicU64::new(0)).collect(),
            target: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            max_staleness: AtomicU64::new(0),
            events: events.clone(),
        });
        let barrier = Arc::new(Barrier::new(threads + 1));
        let stop = Arc::new(AtomicBool::new(false));
        let ports = transport.into_ports();
        assert_eq!(ports.len(), h, "transport port count != hosted node count");
        // contiguous balanced buckets over the hosted nodes
        let mut buckets: Vec<Vec<HostedNode>> = (0..threads).map(|_| Vec::new()).collect();
        let mut port_iter = ports.into_iter();
        let mut k = 0;
        for (idx, node) in program.nodes.into_iter().enumerate() {
            if !is_hosted[idx] {
                continue; // built for RNG parity, stepped by a peer engine
            }
            let mut port = port_iter.next().unwrap();
            let cross: Vec<usize> = topo
                .neighbors(idx)
                .iter()
                .copied()
                .filter(|&m| !is_hosted[m])
                .collect();
            let comp = compress.build_for_node(seed, idx).map(|c| CompState {
                comp: c,
                exact: compress.is_exact(),
                ef: ErrorFeedback::new(z[idx].len()),
                replicas: std::collections::HashMap::new(),
                cache: None,
            });
            let telem = writer
                .as_ref()
                .map(|w| NodeTelemetry::new(w.sink(), events.clone(), &z[idx]));
            // blocked-time tracking inside the port's drain path exists
            // only for telemetered runs (it costs two clock reads per
            // blocking receive)
            if telem.is_some() {
                port.set_wait_tracking(true);
            }
            buckets[k * threads / h]
                .push(HostedNode { idx, state: node, port, cross, comp, telem });
            k += 1;
        }
        // both env knobs are read once, at construction, so a run's
        // behavior can't change mid-flight
        let trace = std::env::var("DSBA_ASYNC_TRACE").is_ok();
        let faults = WorkerFaults::from_spec(fault);
        let mut workers = Vec::with_capacity(threads);
        for bucket in buckets {
            let shared = shared.clone();
            let stop = stop.clone();
            match mode {
                ModeSpec::Sync => {
                    let barrier = barrier.clone();
                    workers.push(std::thread::spawn(move || {
                        round_clock_loop(bucket, shared, barrier, stop, faults)
                    }));
                }
                ModeSpec::Async(tau) => {
                    let tau = tau as u64;
                    let ctls: Vec<AsyncCtl> = bucket
                        .iter()
                        .map(|hn| {
                            let mut in_nbrs = topo.neighbors(hn.idx).to_vec();
                            in_nbrs.sort_unstable();
                            let trace_s = in_nbrs
                                .iter()
                                .map(|&m| {
                                    if trace {
                                        trace_staleness(hn.idx, m, tau)
                                    } else {
                                        0
                                    }
                                })
                                .collect();
                            AsyncCtl {
                                r: 0,
                                emitted: false,
                                in_nbrs,
                                trace_s,
                                pending: std::collections::HashMap::new(),
                                wait_since: None,
                            }
                        })
                        .collect();
                    let deadline = crate::runtime::transport::drain_timeout();
                    workers.push(std::thread::spawn(move || {
                        async_clock_loop(
                            bucket, ctls, shared, stop, tau, trace, faults, deadline,
                        )
                    }));
                }
            }
        }
        // setup accounting and effective-pass denominator cover this
        // engine's share of the nodes: keep every setup send that touches
        // a hosted endpoint so hosted sent AND received totals stay exact
        let setup: Vec<(usize, usize, usize)> = program
            .setup
            .into_iter()
            .filter(|&(from, to, _)| is_hosted[from] || is_hosted[to])
            .collect();
        let pass_denom_full = program.pass_denom;
        let pass_denom = if h == n {
            program.pass_denom
        } else {
            program.pass_denom * h as f64 / n as f64
        };
        Ok(ParallelEngine {
            kind: program.kind,
            mode,
            topo,
            threads,
            hosted,
            setup,
            pass_denom,
            pass_denom_full,
            t: 0,
            z,
            pending_costs: Vec::new(),
            shared,
            workers,
            barrier,
            stop,
            telemetry: writer,
        })
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Which round clock drives the workers.
    pub fn mode(&self) -> ModeSpec {
        self.mode
    }

    /// A detached observer over the per-node progress watermarks (see
    /// [`ProgressProbe`]).
    pub fn progress_probe(&self) -> ProgressProbe {
        ProgressProbe { shared: self.shared.clone() }
    }

    /// Fail fast (with an error instead of a deadlock) if a worker hit
    /// trouble — the engine is poisoned either way, but a transport
    /// failure (peer died, drain timed out) must not be reported as node
    /// code panicking.
    fn propagate_worker_failure(&self) {
        if self.shared.panicked.load(Ordering::SeqCst) {
            let transport_err = self.shared.failure.lock().unwrap().take();
            match transport_err {
                Some(e) => panic!(
                    "ParallelEngine: transport failure during round {} of {}: {e}",
                    self.t,
                    self.kind.name()
                ),
                None => panic!(
                    "ParallelEngine: a node panicked on a worker thread during \
                     round {} of {} — engine state is poisoned",
                    self.t,
                    self.kind.name()
                ),
            }
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Nodes this engine hosts (all of them unless the transport splits
    /// the topology across processes).
    pub fn hosted(&self) -> &[usize] {
        &self.hosted
    }

    /// Rows the non-blocking telemetry channel has dropped so far
    /// (`None` when telemetry is off).
    pub fn telemetry_dropped(&self) -> Option<u64> {
        self.telemetry.as_ref().map(|w| w.sink().dropped())
    }

    /// (messages sent, messages delivered) so far — equal unless a
    /// message was dropped, which the concurrency stress test forbids.
    pub fn message_stats(&self) -> (u64, u64) {
        (
            self.shared.sent.load(Ordering::Relaxed),
            self.shared.delivered.load(Ordering::Relaxed),
        )
    }
}

impl Algorithm for ParallelEngine {
    fn step(&mut self, net: &mut Network) {
        if self.t == 0 {
            for &(from, to, len) in &self.setup {
                net.send_dense(from, to, len);
            }
        }
        match self.mode {
            ModeSpec::Sync => {
                self.barrier.wait(); // release the round
                self.barrier.wait(); // phase A complete
                self.barrier.wait(); // phase B complete
            }
            ModeSpec::Async(tau) => {
                // let workers run rounds up to t + tau; report once every
                // node's completion watermark covers round t
                self.shared
                    .target
                    .store(self.t as u64 + 1 + tau as u64, Ordering::SeqCst);
                loop {
                    self.propagate_worker_failure();
                    let t64 = self.t as u64;
                    let done = self
                        .hosted
                        .iter()
                        .all(|&nd| self.shared.completed[nd].load(Ordering::SeqCst) > t64);
                    if done {
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(100));
                }
            }
        }
        self.propagate_worker_failure();
        // replay cost events in canonical (round, sender, emit index)
        // order — identical to the sequential driver's charging order.
        // Async fast nodes may already have emitted rounds past t; those
        // events are held back for the step that reports their round
        let mut events = {
            let mut guard = self.shared.costs.lock().unwrap();
            std::mem::take(&mut *guard)
        };
        events.extend(self.pending_costs.drain(..));
        let t64 = self.t as u64;
        let (mut events, later): (Vec<CostEvent>, Vec<CostEvent>) =
            events.into_iter().partition(|e| e.t <= t64);
        self.pending_costs = later;
        events.sort_by_key(|e| (e.t, e.from, e.seq));
        for e in events {
            match e.kind {
                CostKind::Dense(len) => net.send_dense(e.from, e.to, len),
                CostKind::Sparse(nnz, tail) => net.send_sparse(e.from, e.to, nnz, tail),
                CostKind::Comp(nnz, bytes) => net.send_comp(e.from, e.to, nnz, bytes),
            }
        }
        // mirror iterates for `iterates()`
        for (n, row) in self.z.iter_mut().enumerate() {
            let slot = self.shared.slots[n].lock().unwrap();
            row.copy_from_slice(&slot);
        }
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        let evals: u64 = self.shared.evals.iter().map(|e| e.load(Ordering::Relaxed)).sum();
        evals as f64 / self.pass_denom
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        self.kind.name()
    }

    /// Surface the inherent accessor through the trait so the
    /// coordinator can report writer drops without downcasting.
    fn telemetry_dropped(&self) -> Option<u64> {
        ParallelEngine::telemetry_dropped(self)
    }

    /// `(max consumed staleness in rounds, stalled scans)` — nonzero
    /// only under the async clock with `tau > 0`.
    fn staleness_stats(&self) -> (u64, u64) {
        (
            self.shared.max_staleness.load(Ordering::Relaxed),
            self.shared.stalls.load(Ordering::Relaxed),
        )
    }

    /// Split-run metrics aggregation: flood per-node stat rows (iterate,
    /// eval count, caller-supplied received-DOUBLE totals) across the
    /// transport's STATS control frames for `diameter` lockstepped hops,
    /// so every engine process ends up with the complete global row set
    /// — even processes that share no direct topology edge. `None` when
    /// this engine hosts every node (metrics are already global).
    fn global_stats(
        &mut self,
        received: &[f64],
        received_bytes: &[f64],
    ) -> Option<GlobalStats> {
        let n = self.z.len();
        if self.hosted.len() == n {
            return None;
        }
        let mut rows: Vec<NodeStatRow> = self
            .hosted
            .iter()
            .map(|&nd| NodeStatRow {
                node: nd as u32,
                evals: self.shared.evals[nd].load(Ordering::Relaxed),
                received: received.get(nd).copied().unwrap_or(0.0),
                received_bytes: received_bytes.get(nd).copied().unwrap_or(0.0),
                z: self.z[nd].clone(),
            })
            .collect();
        // rows propagate one process hop per exchange hop; the topology
        // diameter bounds the process-graph diameter, and every peer
        // runs the same deterministic hop count, so the socket lockstep
        // that orders rounds orders the hops too
        let hops = self.topo.diameter.max(1);
        for hop in 0..hops {
            *self.shared.stats_out.lock().unwrap() = encode_stat_rows(&rows);
            self.shared.stats_hop.store(hop as u32, Ordering::SeqCst);
            self.shared.stats_mode.store(true, Ordering::SeqCst);
            self.barrier.wait(); // release the hop
            self.barrier.wait(); // stats sends complete
            self.barrier.wait(); // stats receives complete
            if self.shared.panicked.load(Ordering::SeqCst) {
                let transport_err = self.shared.failure.lock().unwrap().take();
                match transport_err {
                    Some(e) => panic!(
                        "ParallelEngine: stats exchange failed at sample round {} \
                         of {}: {e}",
                        self.t,
                        self.kind.name()
                    ),
                    None => panic!(
                        "ParallelEngine: a worker panicked during the stats \
                         exchange at round {} of {}",
                        self.t,
                        self.kind.name()
                    ),
                }
            }
            let got = {
                let mut guard = self.shared.stats_in.lock().unwrap();
                std::mem::take(&mut *guard)
            };
            for payload in got {
                let more = decode_stat_rows(&payload).unwrap_or_else(|e| {
                    panic!("ParallelEngine: corrupt STATS payload from a peer: {e}")
                });
                for r in more {
                    if !rows.iter().any(|x| x.node == r.node) {
                        rows.push(r);
                    }
                }
            }
        }
        self.shared.stats_mode.store(false, Ordering::SeqCst);
        rows.sort_by_key(|r| r.node);
        Some(GlobalStats { rows, pass_denom: self.pass_denom_full })
    }
}

/// A detached, cloneable observer over the engine's per-node progress
/// watermarks — lets a monitor (or the straggler fault-injection test)
/// sample rounds completed mid-run from another thread without borrowing
/// the engine.
#[derive(Clone)]
pub struct ProgressProbe {
    shared: Arc<Shared>,
}

impl ProgressProbe {
    /// Rounds completed per topology node (round `t` done ⇒ `t + 1`;
    /// nodes hosted by a peer engine stay at 0).
    pub fn completed_rounds(&self) -> Vec<u64> {
        self.shared.completed.iter().map(|c| c.load(Ordering::SeqCst)).collect()
    }
}

impl Drop for ParallelEngine {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if !self.mode.is_async() {
            self.barrier.wait(); // wake workers at the round-start barrier
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::build;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    fn tiny_world(nodes: usize) -> (Arc<dyn Problem>, MixingMatrix, Topology) {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(63);
        let part = ds.partition_seeded(nodes, 3);
        let topo = Topology::ring(nodes);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (Arc::new(RidgeProblem::new(part, 0.05)), mix, topo)
    }

    #[test]
    fn engine_matches_sequential_bitwise_smoke() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params);
        let mut par =
            ParallelEngine::new(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params, 2);
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..12 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(
                    seq.iterates()[n],
                    par.iterates()[n],
                    "round {round} node {n}"
                );
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
        assert_eq!(seq.passes(), par.passes());
    }

    #[test]
    fn engine_matches_sequential_on_tcp_loopback_smoke() {
        use crate::runtime::transport::TcpTransport;
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Extra, p.clone(), &mix, &topo, &params);
        let transport = Box::new(TcpTransport::loopback(&topo, params.seed).unwrap());
        let mut par = ParallelEngine::new_with_transport(
            AlgorithmKind::Extra,
            p.clone(),
            &mix,
            &topo,
            &params,
            2,
            transport,
        );
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..8 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(seq.iterates()[n], par.iterates()[n], "round {round} node {n}");
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
    }

    #[test]
    fn identity_compression_is_bit_for_bit_against_sequential() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Extra, p.clone(), &mix, &topo, &params);
        let mut par = ParallelEngine::new_full(
            AlgorithmKind::Extra,
            p.clone(),
            &mix,
            &topo,
            &params,
            2,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::Identity,
        );
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..10 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(seq.iterates()[n], par.iterates()[n], "round {round} node {n}");
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
    }

    #[test]
    fn topk_compression_moves_strictly_fewer_bytes() {
        let (p, mix, topo) = tiny_world(4);
        let d = p.dim();
        let params = AlgoParams::new(0.4, d, 5);
        let run = |compress: &CompressionSpec| {
            let mut eng = ParallelEngine::new_full(
                AlgorithmKind::Extra,
                p.clone(),
                &mix,
                &topo,
                &params,
                2,
                Box::new(LocalTransport::new(topo.n)),
                compress,
            );
            let mut net = Network::new(topo.clone(), CommCostModel::default());
            for _ in 0..8 {
                eng.step(&mut net);
            }
            (net.max_received_bytes(), net.messages())
        };
        let (dense_bytes, dense_msgs) = run(&CompressionSpec::None);
        let k = (d / 4).max(1);
        let (comp_bytes, comp_msgs) = run(&CompressionSpec::TopK(k));
        assert_eq!(dense_msgs, comp_msgs, "compression must not change the schedule");
        assert!(
            comp_bytes < dense_bytes,
            "topk:{k} moved {comp_bytes} bytes, dense moved {dense_bytes}"
        );
    }

    #[test]
    fn drop_without_stepping_does_not_hang() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let eng = ParallelEngine::new(AlgorithmKind::Extra, p, &mix, &topo, &params, 3);
        drop(eng);
    }

    #[test]
    fn message_stats_balance() {
        let (p, mix, topo) = tiny_world(5);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng =
            ParallelEngine::new(AlgorithmKind::DsbaSparse, p, &mix, &topo, &params, 2);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..10 {
            eng.step(&mut net);
        }
        let (sent, delivered) = eng.message_stats();
        assert_eq!(sent, delivered, "engine dropped messages");
        assert!(sent > 0);
    }

    struct PanickyNode {
        z: Vec<f64>,
        boom_at: usize,
    }

    impl NodeState for PanickyNode {
        fn outgoing(&mut self, _t: usize) -> Vec<crate::comm::Outgoing> {
            Vec::new()
        }
        fn on_receive(&mut self, _from: usize, _msg: Message) {}
        fn local_step(&mut self, t: usize) {
            if t == self.boom_at {
                panic!("boom");
            }
        }
        fn iterate(&self) -> &[f64] {
            &self.z
        }
        fn evals(&self) -> u64 {
            0
        }
    }

    #[test]
    fn worker_panic_fails_fast_instead_of_deadlocking() {
        let program = NodeProgram {
            kind: AlgorithmKind::Dsba,
            nodes: vec![Box::new(PanickyNode { z: vec![0.0], boom_at: 2 })],
            setup: Vec::new(),
            pass_denom: 1.0,
        };
        let topo = Topology::from_edges(1, &[]);
        let mut eng = ParallelEngine::from_program(program, topo.clone(), 1);
        let mut net = Network::new(topo, CommCostModel::default());
        eng.step(&mut net);
        eng.step(&mut net);
        // round t=2 panics on the worker; the launcher must surface it as
        // a panic, not a barrier deadlock
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.step(&mut net);
        }));
        assert!(result.is_err(), "expected fail-fast panic");
        drop(eng); // must not hang
    }

    #[test]
    fn single_process_engine_reports_no_stats_exchange() {
        // hosted == all nodes: metrics are already global, so the
        // split-run aggregation hook must be a no-op (None)
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng =
            ParallelEngine::new(AlgorithmKind::Dsba, p, &mix, &topo, &params, 2);
        assert!(eng.global_stats(&[0.0; 4], &[0.0; 4]).is_none());
    }

    #[test]
    fn auto_threads_is_bounded() {
        assert!(auto_threads(1) == 1);
        assert!(auto_threads(4) >= 1 && auto_threads(4) <= 4);
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("parallel"), Some(EngineKind::Parallel));
        assert_eq!(EngineKind::parse("SEQ"), Some(EngineKind::Sequential));
        assert_eq!(EngineKind::parse("bogus"), None);
    }

    #[test]
    fn mode_spec_parses_and_names() {
        assert_eq!(ModeSpec::parse("sync"), Some(ModeSpec::Sync));
        assert_eq!(ModeSpec::parse("SYNC"), Some(ModeSpec::Sync));
        assert_eq!(ModeSpec::parse("async"), Some(ModeSpec::Async(0)));
        assert_eq!(ModeSpec::parse("async:0"), Some(ModeSpec::Async(0)));
        assert_eq!(ModeSpec::parse("async:3"), Some(ModeSpec::Async(3)));
        assert_eq!(ModeSpec::parse("Async:2"), Some(ModeSpec::Async(2)));
        assert_eq!(ModeSpec::parse("async:"), None);
        assert_eq!(ModeSpec::parse("async:-1"), None);
        assert_eq!(ModeSpec::parse("bogus"), None);
        assert_eq!(ModeSpec::Sync.name(), "sync");
        assert_eq!(ModeSpec::Async(2).name(), "async:2");
        assert_eq!(ModeSpec::parse(&ModeSpec::Async(7).name()), Some(ModeSpec::Async(7)));
        assert_eq!(ModeSpec::default(), ModeSpec::Sync);
        assert!(!ModeSpec::Sync.is_async());
        assert!(ModeSpec::Async(0).is_async());
    }

    #[test]
    fn worker_faults_delay_matcher() {
        let all = WorkerFaults { delay: Some((None, 7)), kill: None };
        assert_eq!(all.delay_ms_for(0), Some(7));
        assert_eq!(all.delay_ms_for(3), Some(7));
        let one = WorkerFaults { delay: Some((Some(2), 9)), kill: None };
        assert_eq!(one.delay_ms_for(2), Some(9));
        assert_eq!(one.delay_ms_for(0), None);
        assert_eq!(WorkerFaults::default().delay_ms_for(0), None);
        let spec = FaultSpec::parse("delay:5@1,kill:2@8").unwrap();
        let wf = WorkerFaults::from_spec(&spec);
        assert_eq!(wf.delay_ms_for(1), Some(5));
        assert_eq!(wf.delay_ms_for(2), None);
        assert_eq!(wf.kill, Some((2, 8)));
    }

    #[test]
    fn kill_target_out_of_range_is_rejected_at_construction() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let err = ParallelEngine::new_faulted(
            AlgorithmKind::Extra,
            p,
            &mix,
            &topo,
            &params,
            2,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::None,
            ModeSpec::Sync,
            &FaultSpec::parse("kill:9@1").unwrap(),
            &TelemetrySpec::disabled(),
        )
        .err()
        .expect("kill target past the node count must be rejected");
        assert!(err.contains("only 4 nodes"), "{err}");
    }

    #[test]
    fn kill_fault_fails_fast_with_named_diagnostic() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng = ParallelEngine::new_faulted(
            AlgorithmKind::Extra,
            p,
            &mix,
            &topo,
            &params,
            2,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::None,
            ModeSpec::Sync,
            &FaultSpec::parse("kill:1@2").unwrap(),
            &TelemetrySpec::disabled(),
        )
        .unwrap();
        let mut net = Network::new(topo, CommCostModel::default());
        eng.step(&mut net);
        eng.step(&mut net);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.step(&mut net);
        }));
        let payload = result.err().expect("kill must fail the round");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("killed by fault injection"), "{msg}");
        assert!(msg.contains("node 1") && msg.contains("round 2"), "{msg}");
        drop(eng); // must not hang
    }

    #[test]
    fn telemetry_rows_cover_every_node_round() {
        let dir = std::env::temp_dir()
            .join(format!("dsba_engine_telem_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng = ParallelEngine::new_faulted(
            AlgorithmKind::Dsba,
            p,
            &mix,
            &topo,
            &params,
            2,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::None,
            ModeSpec::Sync,
            &FaultSpec::none(),
            &TelemetrySpec::to_path(path.to_str().unwrap()),
        )
        .unwrap();
        let mut net = Network::new(topo.clone(), CommCostModel::default());
        for _ in 0..6 {
            eng.step(&mut net);
        }
        assert_eq!(eng.telemetry_dropped(), Some(0));
        drop(eng); // joins the writer, flushing every emitted row
        let text = std::fs::read_to_string(&path).unwrap();
        let rows = crate::telemetry::validate_jsonl(&text).unwrap();
        assert_eq!(rows, 6 * topo.n);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn inject_delay_spec_parses() {
        assert_eq!(parse_inject_delay(None), None);
        assert_eq!(parse_inject_delay(Some("2:150")), Some((2, 150)));
        assert_eq!(parse_inject_delay(Some(" 0 : 5 ")), Some((0, 5)));
        for bad in ["", "3", "3:", ":5", "a:5", "3:b", "3;5"] {
            assert_eq!(parse_inject_delay(Some(bad)), None, "{bad:?}");
        }
    }

    #[test]
    fn trace_staleness_is_deterministic_and_bounded() {
        for tau in [0u64, 1, 2, 5] {
            for node in 0..6 {
                for from in 0..6 {
                    let s = trace_staleness(node, from, tau);
                    assert!(s <= tau, "edge {from}->{node} tau {tau} gave {s}");
                    assert_eq!(s, trace_staleness(node, from, tau));
                }
            }
        }
        // tau >= 1 should actually exercise nonzero offsets somewhere
        let spread: std::collections::HashSet<u64> = (0..8)
            .flat_map(|n| (0..8).map(move |m| trace_staleness(n, m, 2)))
            .collect();
        assert!(spread.len() > 1, "trace schedule degenerate: {spread:?}");
    }

    #[test]
    fn async_zero_matches_sync_bitwise_smoke() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut sync_eng =
            ParallelEngine::new(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params, 2);
        let mut async_eng = ParallelEngine::new_full_mode(
            AlgorithmKind::Dsba,
            p.clone(),
            &mix,
            &topo,
            &params,
            2,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::None,
            ModeSpec::Async(0),
        );
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_a = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..12 {
            sync_eng.step(&mut net_s);
            async_eng.step(&mut net_a);
            for n in 0..topo.n {
                assert_eq!(
                    sync_eng.iterates()[n],
                    async_eng.iterates()[n],
                    "round {round} node {n}"
                );
            }
        }
        assert_eq!(net_s.messages(), net_a.messages());
        assert_eq!(sync_eng.passes(), async_eng.passes());
        let (sent, delivered) = async_eng.message_stats();
        assert_eq!(sent, delivered, "async:0 left messages in flight");
        assert_eq!(async_eng.staleness_stats().0, 0, "async:0 consumed stale data");
    }

    #[test]
    fn async_drop_without_stepping_does_not_hang() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let eng = ParallelEngine::new_full_mode(
            AlgorithmKind::Extra,
            p,
            &mix,
            &topo,
            &params,
            3,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::None,
            ModeSpec::Async(2),
        );
        let probe = eng.progress_probe();
        drop(eng);
        // workers never got a target, so nothing should have run
        assert!(probe.completed_rounds().iter().all(|&c| c == 0));
    }

    #[test]
    fn async_worker_panic_fails_fast_instead_of_deadlocking() {
        let program = NodeProgram {
            kind: AlgorithmKind::Dsba,
            nodes: vec![Box::new(PanickyNode { z: vec![0.0], boom_at: 2 })],
            setup: Vec::new(),
            pass_denom: 1.0,
        };
        let topo = Topology::from_edges(1, &[]);
        let mut eng = ParallelEngine::from_program_full_mode(
            program,
            topo.clone(),
            1,
            Box::new(LocalTransport::new(1)),
            CompressionSpec::None,
            0,
            ModeSpec::Async(1),
        );
        let mut net = Network::new(topo, CommCostModel::default());
        eng.step(&mut net);
        eng.step(&mut net);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.step(&mut net);
        }));
        assert!(result.is_err(), "expected fail-fast panic");
        drop(eng); // must not hang
    }

    #[test]
    #[should_panic(expected = "async mode requires hosting every node")]
    fn async_rejects_partial_hosting() {
        // a transport claiming to host only half the ring must be turned
        // away by the async clock before any worker spawns
        struct HalfTransport {
            inner: LocalTransport,
        }
        impl Transport for HalfTransport {
            fn hosted(&self) -> &[usize] {
                &[0, 1]
            }
            fn into_ports(self: Box<Self>) -> Vec<Box<dyn NodePort>> {
                Box::new(self.inner).into_ports().into_iter().take(2).collect()
            }
            fn name(&self) -> &'static str {
                "half-local"
            }
        }
        let topo = Topology::ring(4);
        let (p, mix, _) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let program = build_node_program(AlgorithmKind::Extra, p, &mix, &topo, &params);
        let _ = ParallelEngine::from_program_full_mode(
            program,
            topo,
            1,
            Box::new(HalfTransport { inner: LocalTransport::new(4) }),
            CompressionSpec::None,
            0,
            ModeSpec::Async(1),
        );
    }
}
