//! Multi-threaded message-passing node engine.
//!
//! Executes the per-node decomposition of any method
//! ([`crate::algorithms::build_node_program`]) across worker threads, with
//! a pluggable [`Transport`] carrying typed [`Message`]s along the
//! topology's edges and `std::sync::Barrier`-synchronized rounds. The
//! engine is the *fast path*; the sequential
//! [`crate::algorithms::node::RoundDriver`] behind each `Algorithm` impl
//! is the reference oracle.
//!
//! Two transports exist today (see [`crate::runtime::transport`]):
//! [`LocalTransport`] (in-process mpsc, the default) and
//! [`crate::runtime::TcpTransport`] (per-edge loopback/host sockets with
//! the framed wire codec). The determinism contract below holds for both.
//!
//! ## Determinism contract
//!
//! Given the same seed, the engine's iterates are **bit-for-bit equal** to
//! the sequential driver's (pinned by `rust/tests/engine_parity.rs`):
//!
//! * node states are constructed on the launching thread in node order,
//!   so per-node RNG streams are forked identically;
//! * rounds are barrier-synchronized — phase A (every node emits its
//!   messages), barrier, phase B (every node drains its round inbox and
//!   runs its local step), barrier — so a round's messages are all
//!   delivered before any local step runs, exactly the synchronous
//!   model (the TCP backend additionally gates each drain on per-edge
//!   end-of-round control frames, which is what keeps *separate engine
//!   processes* in lockstep);
//! * each inbox is sorted by (sender, emit index) before delivery, so
//!   handlers see the same order the sequential driver produces;
//! * nodes may only read their own state plus received payloads, so
//!   scheduling cannot leak into the arithmetic.
//!
//! ## Accounting
//!
//! Workers log one cost event per message; after the round the launching
//! thread replays the events into the [`Network`] in canonical (sender,
//! emit index) order, so per-node sent/received DOUBLE totals equal the
//! sequential accounting exactly (dense and sparse payloads priced
//! through the same [`crate::comm::CommCostModel`]).
//!
//! ## Hosting a subset (cross-process runs)
//!
//! A transport may host only part of the node set (`--hosted` + `--peers`
//! split one topology across engine processes). The engine then steps
//! only its hosted nodes; `iterates()` rows of remote nodes stay at the
//! initial point, and `passes()` covers the hosted share. Cost accounting
//! for hosted nodes is exact in both directions: sends are charged at the
//! emitting node, and inflow from remote engines is charged via
//! receive-side cost events merged into the same canonical replay.
//! Single-process runs — both transports' default — host everything and
//! are bit-for-bit complete.

use crate::algorithms::{
    build_node_program, AlgoParams, Algorithm, AlgorithmKind, NodeProgram, NodeState,
};
use crate::comm::{CompressedVec, CompressionSpec, Compressor, ErrorFeedback, Message, Network};
use crate::graph::{MixingMatrix, Topology};
use crate::metrics::{decode_stat_rows, encode_stat_rows, GlobalStats, NodeStatRow};
use crate::operators::Problem;
use crate::runtime::transport::{LocalTransport, NodePort, Transport};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

/// Which driver executes the rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Deterministic in-order reference driver (the oracle).
    Sequential,
    /// Multi-threaded engine (bit-for-bit equal, wall-clock faster).
    Parallel,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => EngineKind::Sequential,
            "parallel" | "par" => EngineKind::Parallel,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Sequential => "sequential",
            EngineKind::Parallel => "parallel",
        }
    }
}

/// Worker count for `threads = 0` (auto): available cores capped by the
/// node count.
pub fn auto_threads(n_nodes: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    cores.clamp(1, n_nodes.max(1))
}

/// One hosted node scheduled on a worker.
struct HostedNode {
    /// topology node index
    idx: usize,
    state: Box<dyn NodeState>,
    port: Box<dyn NodePort>,
    /// neighbors hosted by a peer engine process — the links split-run
    /// STATS control frames cross during a metrics exchange (empty for
    /// single-process runs, so the stats phase is a no-op)
    cross: Vec<usize>,
    /// wire compression at the transport boundary (`None` = uncompressed,
    /// the `--compress none` bypass)
    comp: Option<CompState>,
}

/// Per-hosted-node compression state: the sender-side error feedback for
/// this node's dense broadcast, plus one receiver-side `x_hat` replica
/// per in-neighbor. Lives at the engine's transport boundary so both
/// [`LocalTransport`] and [`crate::runtime::TcpTransport`] carry the same
/// `COMP` frames, and node states keep seeing plain dense payloads.
struct CompState {
    comp: Box<dyn Compressor>,
    /// exact compressors assign `x_hat = x` (bit-for-bit Identity pin)
    exact: bool,
    ef: ErrorFeedback,
    /// receiver-side `x_hat` replicas, keyed by in-neighbor — they track
    /// the *sender's* `ef.x_hat` bit-for-bit because both ends apply the
    /// identical wire delta
    replicas: std::collections::HashMap<usize, ErrorFeedback>,
    /// this round's compressed broadcast, keyed on the `Arc` payload all
    /// neighbors share — compress once per round, not once per edge
    cache: Option<(Arc<Vec<f64>>, Message)>,
}

impl CompState {
    /// Sender side: turn the round's dense broadcast into a `COMP` frame.
    fn outbound(&mut self, v: &Arc<Vec<f64>>) -> Message {
        if let Some((cached, msg)) = &self.cache {
            if Arc::ptr_eq(cached, v) {
                return msg.clone();
            }
            // a second distinct payload would advance the sender x_hat
            // twice while each receiver replica absorbs only one delta
            panic!(
                "wire compression requires a uniform dense broadcast within \
                 a round (got two distinct payloads from one node)"
            );
        }
        let c = self.ef.encode(self.comp.as_mut(), v);
        let msg = Message::Comp(Arc::new(c));
        self.cache = Some((v.clone(), msg.clone()));
        msg
    }

    /// Receiver side: absorb a `COMP` frame from `from` and hand back the
    /// updated dense estimate the node state should see.
    fn inbound(&mut self, from: usize, c: &CompressedVec) -> Vec<f64> {
        let ef = self
            .replicas
            .entry(from)
            .or_insert_with(|| ErrorFeedback::new(c.dim));
        ef.apply(c, self.exact);
        ef.x_hat.clone()
    }
}

#[derive(Clone, Copy, Debug)]
enum CostKind {
    Dense(usize),
    Sparse(usize, usize),
    /// quantized support size + declared bytes-on-wire
    Comp(usize, u64),
}

fn cost_kind_of(msg: &Message) -> CostKind {
    match msg {
        Message::Dense(v) => CostKind::Dense(v.len()),
        Message::Sparse(d) => CostKind::Sparse(d.vec.nnz(), d.tail.len()),
        Message::Comp(c) => CostKind::Comp(c.nnz(), c.bytes),
    }
}

#[derive(Clone, Copy, Debug)]
struct CostEvent {
    from: usize,
    seq: u32,
    to: usize,
    kind: CostKind,
}

struct Shared {
    /// per-node iterate slots, written by the owning worker each round
    slots: Vec<Mutex<Vec<f64>>>,
    /// per-node cumulative component evaluations
    evals: Vec<AtomicU64>,
    /// this round's cost events (drained by the launching thread)
    costs: Mutex<Vec<CostEvent>>,
    /// which nodes this engine hosts — receive-side costs are logged for
    /// messages arriving from non-hosted (remote) senders
    hosted_mask: Vec<bool>,
    sent: AtomicU64,
    delivered: AtomicU64,
    /// set when any worker's node code panicked; workers keep honoring
    /// the barrier protocol (skipping work) so nothing deadlocks, and the
    /// launcher propagates the failure after the round
    panicked: AtomicBool,
    /// first transport failure observed by a worker (None when the
    /// poisoning was a genuine node-code panic)
    failure: Mutex<Option<String>>,
    /// when true, the next barrier cycle is a split-run stats-exchange
    /// hop instead of a compute round (set/cleared by the launcher while
    /// workers are parked at the round-start barrier)
    stats_mode: AtomicBool,
    /// hop index of the current stats exchange (stamped into frames)
    stats_hop: AtomicU32,
    /// outbound row payload for the current hop (set by the launcher)
    stats_out: Mutex<Vec<u8>>,
    /// payloads collected from peer engines during the current hop
    stats_in: Mutex<Vec<Vec<u8>>>,
}

impl Shared {
    /// Record a transport failure (first one wins) and poison the engine
    /// via the normal panic path so the barrier protocol stays sound.
    fn transport_failure(&self, msg: String) -> ! {
        let mut slot = self.failure.lock().unwrap();
        if slot.is_none() {
            *slot = Some(msg.clone());
        }
        drop(slot);
        panic!("{msg}");
    }
}

fn worker_loop(
    mut nodes: Vec<HostedNode>,
    shared: Arc<Shared>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
) {
    let mut t = 0usize;
    loop {
        barrier.wait(); // round (or stats hop) start
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // split-run stats-exchange hop: same three-barrier cycle as a
        // compute round, but the payload is the launcher's row set and
        // only cross-process links carry anything; `t` does not advance
        if shared.stats_mode.load(Ordering::SeqCst) {
            let hop = shared.stats_hop.load(Ordering::SeqCst);
            if !shared.panicked.load(Ordering::SeqCst) {
                let send = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let payload = shared.stats_out.lock().unwrap().clone();
                    for hn in nodes.iter_mut() {
                        for &m in &hn.cross {
                            if let Err(e) = hn.port.send_stats(t, hop, m, &payload) {
                                shared.transport_failure(e);
                            }
                        }
                    }
                }));
                if send.is_err() {
                    shared.panicked.store(true, Ordering::SeqCst);
                }
            }
            barrier.wait(); // all stats sends complete
            if !shared.panicked.load(Ordering::SeqCst) {
                let recv = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut got: Vec<Vec<u8>> = Vec::new();
                    for hn in nodes.iter_mut() {
                        for &m in &hn.cross {
                            match hn.port.recv_stats(t, hop, m) {
                                Ok(p) => got.push(p),
                                Err(e) => shared.transport_failure(e),
                            }
                        }
                    }
                    if !got.is_empty() {
                        shared.stats_in.lock().unwrap().extend(got);
                    }
                }));
                if recv.is_err() {
                    shared.panicked.store(true, Ordering::SeqCst);
                }
            }
            barrier.wait(); // hop end
            continue;
        }
        // phase A: emit this round's messages
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_a = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut cost_batch: Vec<CostEvent> = Vec::new();
                for hn in nodes.iter_mut() {
                    if let Some(cs) = hn.comp.as_mut() {
                        cs.cache = None; // the cache is per-round
                    }
                    let outs = hn.state.outgoing(t);
                    for (seq, out) in outs.into_iter().enumerate() {
                        // compression happens here, at the transport
                        // boundary: dense broadcasts become COMP frames,
                        // sparse relay deltas (already exact and compact)
                        // pass through untouched
                        let msg = match (out.msg, hn.comp.as_mut()) {
                            (Message::Dense(v), Some(cs)) => cs.outbound(&v),
                            (m, _) => m,
                        };
                        let kind = cost_kind_of(&msg);
                        cost_batch.push(CostEvent {
                            from: hn.idx,
                            seq: seq as u32,
                            to: out.to,
                            kind,
                        });
                        shared.sent.fetch_add(1, Ordering::Relaxed);
                        if let Err(e) = hn.port.send(t, out.to, seq as u32, msg) {
                            shared.transport_failure(e);
                        }
                    }
                    if let Err(e) = hn.port.finish_round(t) {
                        shared.transport_failure(e);
                    }
                }
                if !cost_batch.is_empty() {
                    shared.costs.lock().unwrap().extend(cost_batch);
                }
            }));
            if phase_a.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier.wait(); // all sends complete
        // phase B: drain inboxes (canonical order), run local steps
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_b = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut recv_batch: Vec<CostEvent> = Vec::new();
                for hn in nodes.iter_mut() {
                    let mut msgs = match hn.port.drain_round(t) {
                        Ok(m) => m,
                        Err(e) => shared.transport_failure(e),
                    };
                    msgs.sort_by_key(|&(from, seq, _)| (from, seq));
                    for (from, seq, msg) in msgs {
                        shared.delivered.fetch_add(1, Ordering::Relaxed);
                        // inflow from a remote engine: the sender's side
                        // can't charge it into OUR network, so log the
                        // receive-side event — merged into the same
                        // canonical (sender, emit idx) replay, keeping
                        // hosted received-DOUBLE totals exact. COMP costs
                        // are charged on the wire form, before it is
                        // reconstructed below
                        if !shared.hosted_mask[from] {
                            recv_batch.push(CostEvent {
                                from,
                                seq,
                                to: hn.idx,
                                kind: cost_kind_of(&msg),
                            });
                        }
                        // COMP frames update this node's per-sender x_hat
                        // replica; the node state sees the reconstructed
                        // dense estimate, never the wire form
                        let msg = match (msg, hn.comp.as_mut()) {
                            (Message::Comp(c), Some(cs)) => {
                                Message::Dense(Arc::new(cs.inbound(from, &c)))
                            }
                            (Message::Comp(_), None) => panic!(
                                "received a COMP frame but compression is \
                                 disabled on this engine — peer engines must \
                                 agree on --compress"
                            ),
                            (m, _) => m,
                        };
                        hn.state.on_receive(from, msg);
                    }
                    hn.state.local_step(t);
                    shared.slots[hn.idx]
                        .lock()
                        .unwrap()
                        .copy_from_slice(hn.state.iterate());
                    shared.evals[hn.idx].store(hn.state.evals(), Ordering::Relaxed);
                }
                if !recv_batch.is_empty() {
                    shared.costs.lock().unwrap().extend(recv_batch);
                }
            }));
            if phase_b.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier.wait(); // round end
        t += 1;
    }
}

/// The multi-threaded engine. Implements [`Algorithm`], so the
/// coordinator, CLI, and benches drive it exactly like the sequential
/// methods.
pub struct ParallelEngine {
    kind: AlgorithmKind,
    topo: Topology,
    threads: usize,
    /// nodes this engine hosts (all of them for single-process runs)
    hosted: Vec<usize>,
    setup: Vec<(usize, usize, usize)>,
    pass_denom: f64,
    /// global `N * q` (unscaled by the hosted share) — the denominator
    /// split-run metrics aggregation reports global passes with
    pass_denom_full: f64,
    t: usize,
    /// launching-thread mirror of the per-node iterates
    z: Vec<Vec<f64>>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
}

impl ParallelEngine {
    /// Decompose `kind` into per-node states and launch the workers over
    /// the default in-process transport. `threads = 0` selects
    /// [`auto_threads`].
    pub fn new(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program(program, topo.clone(), threads)
    }

    /// [`ParallelEngine::new`] with an explicit transport backend (e.g. a
    /// [`crate::runtime::TcpTransport`] over loopback or host sockets).
    pub fn new_with_transport(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
        transport: Box<dyn Transport>,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program_with_transport(program, topo.clone(), threads, transport)
    }

    /// The fully-general constructor: explicit transport **and** wire
    /// compression. With [`CompressionSpec::None`] this is exactly
    /// [`ParallelEngine::new_with_transport`]; otherwise every hosted
    /// node's dense broadcast crosses the transport as an error-feedback
    /// `COMP` frame (per-node compressor streams seeded from
    /// `params.seed`, so lossy runs are deterministic at any thread
    /// count and across split processes).
    pub fn new_full(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
        transport: Box<dyn Transport>,
        compress: &CompressionSpec,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program_full(
            program,
            topo.clone(),
            threads,
            transport,
            compress.clone(),
            params.seed,
        )
    }

    /// Launch workers over an already-built node program (in-process
    /// transport).
    pub fn from_program(program: NodeProgram, topo: Topology, threads: usize) -> ParallelEngine {
        let n = program.nodes.len();
        Self::from_program_with_transport(
            program,
            topo,
            threads,
            Box::new(LocalTransport::new(n)),
        )
    }

    /// Launch workers over an already-built node program and a connected
    /// transport. The transport decides which nodes this engine hosts;
    /// states are still *built* for every node (in node order) so RNG
    /// forking matches the sequential oracle, then non-hosted states are
    /// dropped.
    pub fn from_program_with_transport(
        program: NodeProgram,
        topo: Topology,
        threads: usize,
        transport: Box<dyn Transport>,
    ) -> ParallelEngine {
        Self::from_program_full(program, topo, threads, transport, CompressionSpec::None, 0)
    }

    /// [`ParallelEngine::from_program_with_transport`] plus a wire
    /// [`CompressionSpec`] (see [`ParallelEngine::new_full`]).
    pub fn from_program_full(
        program: NodeProgram,
        topo: Topology,
        threads: usize,
        transport: Box<dyn Transport>,
        compress: CompressionSpec,
        seed: u64,
    ) -> ParallelEngine {
        let n = program.nodes.len();
        assert!(n > 0, "engine needs at least one node");
        let hosted = transport.hosted().to_vec();
        assert!(
            !hosted.is_empty()
                && hosted.windows(2).all(|w| w[0] < w[1])
                && *hosted.last().unwrap() < n,
            "transport hosts an invalid node set {hosted:?} for {n} nodes"
        );
        let mut is_hosted = vec![false; n];
        for &h in &hosted {
            is_hosted[h] = true;
        }
        let h = hosted.len();
        let threads = if threads == 0 { auto_threads(h) } else { threads }.clamp(1, h);
        let z: Vec<Vec<f64>> = program.nodes.iter().map(|nd| nd.iterate().to_vec()).collect();
        let shared = Arc::new(Shared {
            slots: z.iter().map(|r| Mutex::new(r.clone())).collect(),
            evals: (0..n).map(|_| AtomicU64::new(0)).collect(),
            costs: Mutex::new(Vec::new()),
            hosted_mask: is_hosted.clone(),
            sent: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            panicked: AtomicBool::new(false),
            failure: Mutex::new(None),
            stats_mode: AtomicBool::new(false),
            stats_hop: AtomicU32::new(0),
            stats_out: Mutex::new(Vec::new()),
            stats_in: Mutex::new(Vec::new()),
        });
        let barrier = Arc::new(Barrier::new(threads + 1));
        let stop = Arc::new(AtomicBool::new(false));
        let ports = transport.into_ports();
        assert_eq!(ports.len(), h, "transport port count != hosted node count");
        // contiguous balanced buckets over the hosted nodes
        let mut buckets: Vec<Vec<HostedNode>> = (0..threads).map(|_| Vec::new()).collect();
        let mut port_iter = ports.into_iter();
        let mut k = 0;
        for (idx, node) in program.nodes.into_iter().enumerate() {
            if !is_hosted[idx] {
                continue; // built for RNG parity, stepped by a peer engine
            }
            let port = port_iter.next().unwrap();
            let cross: Vec<usize> = topo
                .neighbors(idx)
                .iter()
                .copied()
                .filter(|&m| !is_hosted[m])
                .collect();
            let comp = compress.build_for_node(seed, idx).map(|c| CompState {
                comp: c,
                exact: compress.is_exact(),
                ef: ErrorFeedback::new(z[idx].len()),
                replicas: std::collections::HashMap::new(),
                cache: None,
            });
            buckets[k * threads / h].push(HostedNode { idx, state: node, port, cross, comp });
            k += 1;
        }
        let mut workers = Vec::with_capacity(threads);
        for bucket in buckets {
            let shared = shared.clone();
            let barrier = barrier.clone();
            let stop = stop.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(bucket, shared, barrier, stop)
            }));
        }
        // setup accounting and effective-pass denominator cover this
        // engine's share of the nodes: keep every setup send that touches
        // a hosted endpoint so hosted sent AND received totals stay exact
        let setup: Vec<(usize, usize, usize)> = program
            .setup
            .into_iter()
            .filter(|&(from, to, _)| is_hosted[from] || is_hosted[to])
            .collect();
        let pass_denom_full = program.pass_denom;
        let pass_denom = if h == n {
            program.pass_denom
        } else {
            program.pass_denom * h as f64 / n as f64
        };
        ParallelEngine {
            kind: program.kind,
            topo,
            threads,
            hosted,
            setup,
            pass_denom,
            pass_denom_full,
            t: 0,
            z,
            shared,
            workers,
            barrier,
            stop,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Nodes this engine hosts (all of them unless the transport splits
    /// the topology across processes).
    pub fn hosted(&self) -> &[usize] {
        &self.hosted
    }

    /// (messages sent, messages delivered) so far — equal unless a
    /// message was dropped, which the concurrency stress test forbids.
    pub fn message_stats(&self) -> (u64, u64) {
        (
            self.shared.sent.load(Ordering::Relaxed),
            self.shared.delivered.load(Ordering::Relaxed),
        )
    }
}

impl Algorithm for ParallelEngine {
    fn step(&mut self, net: &mut Network) {
        if self.t == 0 {
            for &(from, to, len) in &self.setup {
                net.send_dense(from, to, len);
            }
        }
        self.barrier.wait(); // release the round
        self.barrier.wait(); // phase A complete
        self.barrier.wait(); // phase B complete
        // fail fast (with an error instead of a barrier deadlock) if a
        // worker hit trouble — the engine is poisoned either way, but a
        // transport failure (peer died, drain timed out) must not be
        // reported as node code panicking
        if self.shared.panicked.load(Ordering::SeqCst) {
            let transport_err = self.shared.failure.lock().unwrap().take();
            match transport_err {
                Some(e) => panic!(
                    "ParallelEngine: transport failure during round {} of {}: {e}",
                    self.t,
                    self.kind.name()
                ),
                None => panic!(
                    "ParallelEngine: a node panicked on a worker thread during \
                     round {} of {} — engine state is poisoned",
                    self.t,
                    self.kind.name()
                ),
            }
        }
        // replay cost events in canonical (sender, emit index) order —
        // identical to the sequential driver's charging order
        let mut events = {
            let mut guard = self.shared.costs.lock().unwrap();
            std::mem::take(&mut *guard)
        };
        events.sort_by_key(|e| (e.from, e.seq));
        for e in events {
            match e.kind {
                CostKind::Dense(len) => net.send_dense(e.from, e.to, len),
                CostKind::Sparse(nnz, tail) => net.send_sparse(e.from, e.to, nnz, tail),
                CostKind::Comp(nnz, bytes) => net.send_comp(e.from, e.to, nnz, bytes),
            }
        }
        // mirror iterates for `iterates()`
        for (n, row) in self.z.iter_mut().enumerate() {
            let slot = self.shared.slots[n].lock().unwrap();
            row.copy_from_slice(&slot);
        }
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        let evals: u64 = self.shared.evals.iter().map(|e| e.load(Ordering::Relaxed)).sum();
        evals as f64 / self.pass_denom
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        self.kind.name()
    }

    /// Split-run metrics aggregation: flood per-node stat rows (iterate,
    /// eval count, caller-supplied received-DOUBLE totals) across the
    /// transport's STATS control frames for `diameter` lockstepped hops,
    /// so every engine process ends up with the complete global row set
    /// — even processes that share no direct topology edge. `None` when
    /// this engine hosts every node (metrics are already global).
    fn global_stats(
        &mut self,
        received: &[f64],
        received_bytes: &[f64],
    ) -> Option<GlobalStats> {
        let n = self.z.len();
        if self.hosted.len() == n {
            return None;
        }
        let mut rows: Vec<NodeStatRow> = self
            .hosted
            .iter()
            .map(|&nd| NodeStatRow {
                node: nd as u32,
                evals: self.shared.evals[nd].load(Ordering::Relaxed),
                received: received.get(nd).copied().unwrap_or(0.0),
                received_bytes: received_bytes.get(nd).copied().unwrap_or(0.0),
                z: self.z[nd].clone(),
            })
            .collect();
        // rows propagate one process hop per exchange hop; the topology
        // diameter bounds the process-graph diameter, and every peer
        // runs the same deterministic hop count, so the socket lockstep
        // that orders rounds orders the hops too
        let hops = self.topo.diameter.max(1);
        for hop in 0..hops {
            *self.shared.stats_out.lock().unwrap() = encode_stat_rows(&rows);
            self.shared.stats_hop.store(hop as u32, Ordering::SeqCst);
            self.shared.stats_mode.store(true, Ordering::SeqCst);
            self.barrier.wait(); // release the hop
            self.barrier.wait(); // stats sends complete
            self.barrier.wait(); // stats receives complete
            if self.shared.panicked.load(Ordering::SeqCst) {
                let transport_err = self.shared.failure.lock().unwrap().take();
                match transport_err {
                    Some(e) => panic!(
                        "ParallelEngine: stats exchange failed at sample round {} \
                         of {}: {e}",
                        self.t,
                        self.kind.name()
                    ),
                    None => panic!(
                        "ParallelEngine: a worker panicked during the stats \
                         exchange at round {} of {}",
                        self.t,
                        self.kind.name()
                    ),
                }
            }
            let got = {
                let mut guard = self.shared.stats_in.lock().unwrap();
                std::mem::take(&mut *guard)
            };
            for payload in got {
                let more = decode_stat_rows(&payload).unwrap_or_else(|e| {
                    panic!("ParallelEngine: corrupt STATS payload from a peer: {e}")
                });
                for r in more {
                    if !rows.iter().any(|x| x.node == r.node) {
                        rows.push(r);
                    }
                }
            }
        }
        self.shared.stats_mode.store(false, Ordering::SeqCst);
        rows.sort_by_key(|r| r.node);
        Some(GlobalStats { rows, pass_denom: self.pass_denom_full })
    }
}

impl Drop for ParallelEngine {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.barrier.wait(); // wake workers at the round-start barrier
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::build;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    fn tiny_world(nodes: usize) -> (Arc<dyn Problem>, MixingMatrix, Topology) {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(63);
        let part = ds.partition_seeded(nodes, 3);
        let topo = Topology::ring(nodes);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (Arc::new(RidgeProblem::new(part, 0.05)), mix, topo)
    }

    #[test]
    fn engine_matches_sequential_bitwise_smoke() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params);
        let mut par =
            ParallelEngine::new(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params, 2);
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..12 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(
                    seq.iterates()[n],
                    par.iterates()[n],
                    "round {round} node {n}"
                );
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
        assert_eq!(seq.passes(), par.passes());
    }

    #[test]
    fn engine_matches_sequential_on_tcp_loopback_smoke() {
        use crate::runtime::transport::TcpTransport;
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Extra, p.clone(), &mix, &topo, &params);
        let transport = Box::new(TcpTransport::loopback(&topo, params.seed).unwrap());
        let mut par = ParallelEngine::new_with_transport(
            AlgorithmKind::Extra,
            p.clone(),
            &mix,
            &topo,
            &params,
            2,
            transport,
        );
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..8 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(seq.iterates()[n], par.iterates()[n], "round {round} node {n}");
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
    }

    #[test]
    fn identity_compression_is_bit_for_bit_against_sequential() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Extra, p.clone(), &mix, &topo, &params);
        let mut par = ParallelEngine::new_full(
            AlgorithmKind::Extra,
            p.clone(),
            &mix,
            &topo,
            &params,
            2,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::Identity,
        );
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..10 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(seq.iterates()[n], par.iterates()[n], "round {round} node {n}");
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
    }

    #[test]
    fn topk_compression_moves_strictly_fewer_bytes() {
        let (p, mix, topo) = tiny_world(4);
        let d = p.dim();
        let params = AlgoParams::new(0.4, d, 5);
        let run = |compress: &CompressionSpec| {
            let mut eng = ParallelEngine::new_full(
                AlgorithmKind::Extra,
                p.clone(),
                &mix,
                &topo,
                &params,
                2,
                Box::new(LocalTransport::new(topo.n)),
                compress,
            );
            let mut net = Network::new(topo.clone(), CommCostModel::default());
            for _ in 0..8 {
                eng.step(&mut net);
            }
            (net.max_received_bytes(), net.messages())
        };
        let (dense_bytes, dense_msgs) = run(&CompressionSpec::None);
        let k = (d / 4).max(1);
        let (comp_bytes, comp_msgs) = run(&CompressionSpec::TopK(k));
        assert_eq!(dense_msgs, comp_msgs, "compression must not change the schedule");
        assert!(
            comp_bytes < dense_bytes,
            "topk:{k} moved {comp_bytes} bytes, dense moved {dense_bytes}"
        );
    }

    #[test]
    fn drop_without_stepping_does_not_hang() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let eng = ParallelEngine::new(AlgorithmKind::Extra, p, &mix, &topo, &params, 3);
        drop(eng);
    }

    #[test]
    fn message_stats_balance() {
        let (p, mix, topo) = tiny_world(5);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng =
            ParallelEngine::new(AlgorithmKind::DsbaSparse, p, &mix, &topo, &params, 2);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..10 {
            eng.step(&mut net);
        }
        let (sent, delivered) = eng.message_stats();
        assert_eq!(sent, delivered, "engine dropped messages");
        assert!(sent > 0);
    }

    struct PanickyNode {
        z: Vec<f64>,
        boom_at: usize,
    }

    impl NodeState for PanickyNode {
        fn outgoing(&mut self, _t: usize) -> Vec<crate::comm::Outgoing> {
            Vec::new()
        }
        fn on_receive(&mut self, _from: usize, _msg: Message) {}
        fn local_step(&mut self, t: usize) {
            if t == self.boom_at {
                panic!("boom");
            }
        }
        fn iterate(&self) -> &[f64] {
            &self.z
        }
        fn evals(&self) -> u64 {
            0
        }
    }

    #[test]
    fn worker_panic_fails_fast_instead_of_deadlocking() {
        let program = NodeProgram {
            kind: AlgorithmKind::Dsba,
            nodes: vec![Box::new(PanickyNode { z: vec![0.0], boom_at: 2 })],
            setup: Vec::new(),
            pass_denom: 1.0,
        };
        let topo = Topology::from_edges(1, &[]);
        let mut eng = ParallelEngine::from_program(program, topo.clone(), 1);
        let mut net = Network::new(topo, CommCostModel::default());
        eng.step(&mut net);
        eng.step(&mut net);
        // round t=2 panics on the worker; the launcher must surface it as
        // a panic, not a barrier deadlock
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.step(&mut net);
        }));
        assert!(result.is_err(), "expected fail-fast panic");
        drop(eng); // must not hang
    }

    #[test]
    fn single_process_engine_reports_no_stats_exchange() {
        // hosted == all nodes: metrics are already global, so the
        // split-run aggregation hook must be a no-op (None)
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng =
            ParallelEngine::new(AlgorithmKind::Dsba, p, &mix, &topo, &params, 2);
        assert!(eng.global_stats(&[0.0; 4], &[0.0; 4]).is_none());
    }

    #[test]
    fn auto_threads_is_bounded() {
        assert!(auto_threads(1) == 1);
        assert!(auto_threads(4) >= 1 && auto_threads(4) <= 4);
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("parallel"), Some(EngineKind::Parallel));
        assert_eq!(EngineKind::parse("SEQ"), Some(EngineKind::Sequential));
        assert_eq!(EngineKind::parse("bogus"), None);
    }
}
