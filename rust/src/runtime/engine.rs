//! Multi-threaded message-passing node engine.
//!
//! Executes the per-node decomposition of any method
//! ([`crate::algorithms::build_node_program`]) across worker threads, with
//! `std::sync::mpsc` channels carrying typed [`Message`]s along the
//! topology's edges and `std::sync::Barrier`-synchronized rounds. The
//! engine is the *fast path*; the sequential
//! [`crate::algorithms::node::RoundDriver`] behind each `Algorithm` impl
//! is the reference oracle.
//!
//! ## Determinism contract
//!
//! Given the same seed, the engine's iterates are **bit-for-bit equal** to
//! the sequential driver's (pinned by `rust/tests/engine_parity.rs`):
//!
//! * node states are constructed on the launching thread in node order,
//!   so per-node RNG streams are forked identically;
//! * rounds are barrier-synchronized — phase A (every node emits its
//!   messages), barrier, phase B (every node drains its inbox and runs
//!   its local step), barrier — so a round's messages are all delivered
//!   before any local step runs, exactly the synchronous model;
//! * each inbox is sorted by (sender, emit index) before delivery, so
//!   handlers see the same order the sequential driver produces;
//! * nodes may only read their own state plus received payloads, so
//!   scheduling cannot leak into the arithmetic.
//!
//! ## Accounting
//!
//! Workers log one cost event per message; after the round the launching
//! thread replays the events into the [`Network`] in canonical (sender,
//! emit index) order, so per-node sent/received DOUBLE totals equal the
//! sequential accounting exactly (dense and sparse payloads priced
//! through the same [`crate::comm::CommCostModel`]).

use crate::algorithms::{
    build_node_program, AlgoParams, Algorithm, AlgorithmKind, NodeProgram, NodeState,
};
use crate::comm::{Message, Network};
use crate::graph::{MixingMatrix, Topology};
use crate::operators::Problem;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

/// Which driver executes the rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Deterministic in-order reference driver (the oracle).
    Sequential,
    /// Multi-threaded engine (bit-for-bit equal, wall-clock faster).
    Parallel,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => EngineKind::Sequential,
            "parallel" | "par" => EngineKind::Parallel,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Sequential => "sequential",
            EngineKind::Parallel => "parallel",
        }
    }
}

/// Worker count for `threads = 0` (auto): available cores capped by the
/// node count.
pub fn auto_threads(n_nodes: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    cores.clamp(1, n_nodes.max(1))
}

/// (from, emit index, payload) crossing one edge.
type Envelope = (usize, u32, Message);

#[derive(Clone, Copy, Debug)]
enum CostKind {
    Dense(usize),
    Sparse(usize, usize),
}

#[derive(Clone, Copy, Debug)]
struct CostEvent {
    from: usize,
    seq: u32,
    to: usize,
    kind: CostKind,
}

struct Shared {
    /// per-node iterate slots, written by the owning worker each round
    slots: Vec<Mutex<Vec<f64>>>,
    /// per-node cumulative component evaluations
    evals: Vec<AtomicU64>,
    /// this round's cost events (drained by the launching thread)
    costs: Mutex<Vec<CostEvent>>,
    sent: AtomicU64,
    delivered: AtomicU64,
    /// set when any worker's node code panicked; workers keep honoring
    /// the barrier protocol (skipping work) so nothing deadlocks, and the
    /// launcher propagates the failure after the round
    panicked: AtomicBool,
}

fn worker_loop(
    mut nodes: Vec<(usize, Box<dyn NodeState>, Receiver<Envelope>)>,
    txs: Vec<Sender<Envelope>>,
    shared: Arc<Shared>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
) {
    let mut t = 0usize;
    loop {
        barrier.wait(); // round start
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // phase A: emit this round's messages
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_a = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut cost_batch: Vec<CostEvent> = Vec::new();
                for (idx, node, _) in nodes.iter_mut() {
                    let outs = node.outgoing(t);
                    for (seq, out) in outs.into_iter().enumerate() {
                        let kind = match &out.msg {
                            Message::Dense(v) => CostKind::Dense(v.len()),
                            Message::Sparse(d) => {
                                CostKind::Sparse(d.vec.nnz(), d.tail.len())
                            }
                        };
                        cost_batch.push(CostEvent {
                            from: *idx,
                            seq: seq as u32,
                            to: out.to,
                            kind,
                        });
                        shared.sent.fetch_add(1, Ordering::Relaxed);
                        txs[out.to]
                            .send((*idx, seq as u32, out.msg))
                            .expect("engine inbox receiver dropped mid-round");
                    }
                }
                if !cost_batch.is_empty() {
                    shared.costs.lock().unwrap().extend(cost_batch);
                }
            }));
            if phase_a.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier.wait(); // all sends complete
        // phase B: drain inboxes (canonical order), run local steps
        if !shared.panicked.load(Ordering::SeqCst) {
            let phase_b = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for (idx, node, rx) in nodes.iter_mut() {
                    let mut msgs: Vec<Envelope> = rx.try_iter().collect();
                    msgs.sort_by_key(|&(from, seq, _)| (from, seq));
                    for (from, _seq, msg) in msgs {
                        shared.delivered.fetch_add(1, Ordering::Relaxed);
                        node.on_receive(from, msg);
                    }
                    node.local_step(t);
                    shared.slots[*idx].lock().unwrap().copy_from_slice(node.iterate());
                    shared.evals[*idx].store(node.evals(), Ordering::Relaxed);
                }
            }));
            if phase_b.is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        barrier.wait(); // round end
        t += 1;
    }
}

/// The multi-threaded engine. Implements [`Algorithm`], so the
/// coordinator, CLI, and benches drive it exactly like the sequential
/// methods.
pub struct ParallelEngine {
    kind: AlgorithmKind,
    topo: Topology,
    threads: usize,
    setup: Vec<(usize, usize, usize)>,
    pass_denom: f64,
    t: usize,
    /// launching-thread mirror of the per-node iterates
    z: Vec<Vec<f64>>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
}

impl ParallelEngine {
    /// Decompose `kind` into per-node states and launch the workers.
    /// `threads = 0` selects [`auto_threads`].
    pub fn new(
        kind: AlgorithmKind,
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        topo: &Topology,
        params: &AlgoParams,
        threads: usize,
    ) -> ParallelEngine {
        let program = build_node_program(kind, problem, mix, topo, params);
        Self::from_program(program, topo.clone(), threads)
    }

    /// Launch workers over an already-built node program.
    pub fn from_program(program: NodeProgram, topo: Topology, threads: usize) -> ParallelEngine {
        let n = program.nodes.len();
        assert!(n > 0, "engine needs at least one node");
        let threads = if threads == 0 { auto_threads(n) } else { threads }.clamp(1, n);
        let z: Vec<Vec<f64>> = program.nodes.iter().map(|nd| nd.iterate().to_vec()).collect();
        let shared = Arc::new(Shared {
            slots: z.iter().map(|r| Mutex::new(r.clone())).collect(),
            evals: (0..n).map(|_| AtomicU64::new(0)).collect(),
            costs: Mutex::new(Vec::new()),
            sent: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            panicked: AtomicBool::new(false),
        });
        let barrier = Arc::new(Barrier::new(threads + 1));
        let stop = Arc::new(AtomicBool::new(false));
        let mut txs: Vec<Sender<Envelope>> = Vec::with_capacity(n);
        let mut rxs: Vec<Receiver<Envelope>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<Envelope>();
            txs.push(tx);
            rxs.push(rx);
        }
        // contiguous balanced buckets: node idx -> worker idx*threads/n
        let mut buckets: Vec<Vec<(usize, Box<dyn NodeState>, Receiver<Envelope>)>> =
            (0..threads).map(|_| Vec::new()).collect();
        let mut rx_iter = rxs.into_iter();
        for (idx, node) in program.nodes.into_iter().enumerate() {
            let rx = rx_iter.next().unwrap();
            buckets[idx * threads / n].push((idx, node, rx));
        }
        let mut workers = Vec::with_capacity(threads);
        for bucket in buckets {
            let txs = txs.clone();
            let shared = shared.clone();
            let barrier = barrier.clone();
            let stop = stop.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(bucket, txs, shared, barrier, stop)
            }));
        }
        drop(txs); // workers hold the only senders
        ParallelEngine {
            kind: program.kind,
            topo,
            threads,
            setup: program.setup,
            pass_denom: program.pass_denom,
            t: 0,
            z,
            shared,
            workers,
            barrier,
            stop,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// (messages sent, messages delivered) so far — equal unless a
    /// message was dropped, which the concurrency stress test forbids.
    pub fn message_stats(&self) -> (u64, u64) {
        (
            self.shared.sent.load(Ordering::Relaxed),
            self.shared.delivered.load(Ordering::Relaxed),
        )
    }
}

impl Algorithm for ParallelEngine {
    fn step(&mut self, net: &mut Network) {
        if self.t == 0 {
            for &(from, to, len) in &self.setup {
                net.send_dense(from, to, len);
            }
        }
        self.barrier.wait(); // release the round
        self.barrier.wait(); // phase A complete
        self.barrier.wait(); // phase B complete
        // fail fast (with an error instead of a barrier deadlock) if any
        // node's code panicked on a worker — the engine is poisoned
        if self.shared.panicked.load(Ordering::SeqCst) {
            panic!(
                "ParallelEngine: a node panicked on a worker thread during \
                 round {} of {} — engine state is poisoned",
                self.t,
                self.kind.name()
            );
        }
        // replay cost events in canonical (sender, emit index) order —
        // identical to the sequential driver's charging order
        let mut events = {
            let mut guard = self.shared.costs.lock().unwrap();
            std::mem::take(&mut *guard)
        };
        events.sort_by_key(|e| (e.from, e.seq));
        for e in events {
            match e.kind {
                CostKind::Dense(len) => net.send_dense(e.from, e.to, len),
                CostKind::Sparse(nnz, tail) => net.send_sparse(e.from, e.to, nnz, tail),
            }
        }
        // mirror iterates for `iterates()`
        for (n, row) in self.z.iter_mut().enumerate() {
            let slot = self.shared.slots[n].lock().unwrap();
            row.copy_from_slice(&slot);
        }
        self.t += 1;
    }

    fn iterates(&self) -> &[Vec<f64>] {
        &self.z
    }

    fn passes(&self) -> f64 {
        let evals: u64 = self.shared.evals.iter().map(|e| e.load(Ordering::Relaxed)).sum();
        evals as f64 / self.pass_denom
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn name(&self) -> &'static str {
        self.kind.name()
    }
}

impl Drop for ParallelEngine {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.barrier.wait(); // wake workers at the round-start barrier
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::build;
    use crate::comm::CommCostModel;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    fn tiny_world(nodes: usize) -> (Arc<dyn Problem>, MixingMatrix, Topology) {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(63);
        let part = ds.partition_seeded(nodes, 3);
        let topo = Topology::ring(nodes);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        (Arc::new(RidgeProblem::new(part, 0.05)), mix, topo)
    }

    #[test]
    fn engine_matches_sequential_bitwise_smoke() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut seq = build(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params);
        let mut par =
            ParallelEngine::new(AlgorithmKind::Dsba, p.clone(), &mix, &topo, &params, 2);
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let mut net_p = Network::new(topo.clone(), CommCostModel::default());
        for round in 0..12 {
            seq.step(&mut net_s);
            par.step(&mut net_p);
            for n in 0..topo.n {
                assert_eq!(
                    seq.iterates()[n],
                    par.iterates()[n],
                    "round {round} node {n}"
                );
            }
        }
        assert_eq!(net_s.messages(), net_p.messages());
        assert_eq!(seq.passes(), par.passes());
    }

    #[test]
    fn drop_without_stepping_does_not_hang() {
        let (p, mix, topo) = tiny_world(4);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let eng = ParallelEngine::new(AlgorithmKind::Extra, p, &mix, &topo, &params, 3);
        drop(eng);
    }

    #[test]
    fn message_stats_balance() {
        let (p, mix, topo) = tiny_world(5);
        let params = AlgoParams::new(0.4, p.dim(), 5);
        let mut eng =
            ParallelEngine::new(AlgorithmKind::DsbaSparse, p, &mix, &topo, &params, 2);
        let mut net = Network::new(topo, CommCostModel::default());
        for _ in 0..10 {
            eng.step(&mut net);
        }
        let (sent, delivered) = eng.message_stats();
        assert_eq!(sent, delivered, "engine dropped messages");
        assert!(sent > 0);
    }

    struct PanickyNode {
        z: Vec<f64>,
        boom_at: usize,
    }

    impl NodeState for PanickyNode {
        fn outgoing(&mut self, _t: usize) -> Vec<crate::comm::Outgoing> {
            Vec::new()
        }
        fn on_receive(&mut self, _from: usize, _msg: Message) {}
        fn local_step(&mut self, t: usize) {
            if t == self.boom_at {
                panic!("boom");
            }
        }
        fn iterate(&self) -> &[f64] {
            &self.z
        }
        fn evals(&self) -> u64 {
            0
        }
    }

    #[test]
    fn worker_panic_fails_fast_instead_of_deadlocking() {
        let program = NodeProgram {
            kind: AlgorithmKind::Dsba,
            nodes: vec![Box::new(PanickyNode { z: vec![0.0], boom_at: 2 })],
            setup: Vec::new(),
            pass_denom: 1.0,
        };
        let topo = Topology::from_edges(1, &[]);
        let mut eng = ParallelEngine::from_program(program, topo.clone(), 1);
        let mut net = Network::new(topo, CommCostModel::default());
        eng.step(&mut net);
        eng.step(&mut net);
        // round t=2 panics on the worker; the launcher must surface it as
        // a panic, not a barrier deadlock
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.step(&mut net);
        }));
        assert!(result.is_err(), "expected fail-fast panic");
        drop(eng); // must not hang
    }

    #[test]
    fn auto_threads_is_bounded() {
        assert!(auto_threads(1) == 1);
        assert!(auto_threads(4) >= 1 && auto_threads(4) <= 4);
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("parallel"), Some(EngineKind::Parallel));
        assert_eq!(EngineKind::parse("SEQ"), Some(EngineKind::Sequential));
        assert_eq!(EngineKind::parse("bogus"), None);
    }
}
