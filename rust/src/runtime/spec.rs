//! Typed execution-engine configuration: which driver runs the rounds,
//! how many worker threads, which edge-channel transport, and — for TCP
//! — the endpoint strings.  One [`EngineSpec`] value travels intact from
//! JSON config / CLI flags through `ExperimentConfig` into `Experiment`,
//! instead of six loose fields leaking through every layer.

use super::engine::{EngineKind, ModeSpec};
use super::fault::FaultSpec;
use super::transport::TransportKind;
use crate::comm::CompressionSpec;
use crate::telemetry::TelemetrySpec;
use crate::util::json::Json;

/// TCP endpoint configuration for [`TransportKind::Tcp`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TcpSpec {
    /// listen address ("" = ephemeral loopback port)
    pub listen: String,
    /// comma-separated `node=host:port` addresses of remote nodes
    pub peers: String,
    /// hosted-node spec ("" = host all nodes in this process)
    pub hosted: String,
}

impl TcpSpec {
    pub fn is_empty(&self) -> bool {
        self.listen.is_empty() && self.peers.is_empty() && self.hosted.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("listen", Json::Str(self.listen.clone())),
            ("peers", Json::Str(self.peers.clone())),
            ("hosted", Json::Str(self.hosted.clone())),
        ])
    }

    /// Parse from a JSON object (missing keys keep defaults).
    pub fn from_json(v: &Json) -> Result<TcpSpec, String> {
        let mut t = TcpSpec::default();
        if let Some(s) = v.get("listen").and_then(Json::as_str) {
            t.listen = s.to_string();
        }
        if let Some(s) = v.get("peers").and_then(Json::as_str) {
            t.peers = s.to_string();
        }
        if let Some(s) = v.get("hosted").and_then(Json::as_str) {
            t.hosted = s.to_string();
        }
        Ok(t)
    }
}

/// Execution engine selection: round driver + transport + endpoints.
// not `Eq`: `FaultSpec` carries f64 probabilities
#[derive(Clone, Debug, PartialEq)]
pub struct EngineSpec {
    /// round driver: sequential reference oracle or parallel engine
    pub kind: EngineKind,
    /// parallel-engine worker threads (0 = auto: cores capped by nodes)
    pub threads: usize,
    /// parallel-engine edge channels: in-process mpsc or per-edge TCP
    pub transport: TransportKind,
    /// endpoints for [`TransportKind::Tcp`]
    pub tcp: TcpSpec,
    /// wire compression at the transport boundary (parallel engine only;
    /// the sequential oracle is always the uncompressed reference)
    pub compress: CompressionSpec,
    /// round clock (parallel engine only): barrier-synced `sync` or
    /// bounded-staleness `async:TAU`
    pub mode: ModeSpec,
    /// fault-injection plan (parallel engine only; link faults
    /// additionally require the TCP transport)
    pub fault: FaultSpec,
    /// per-round JSONL telemetry stream (parallel engine only)
    pub telemetry: TelemetrySpec,
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec {
            kind: EngineKind::Sequential,
            threads: 0,
            transport: TransportKind::Local,
            tcp: TcpSpec::default(),
            compress: CompressionSpec::None,
            mode: ModeSpec::Sync,
            fault: FaultSpec::default(),
            telemetry: TelemetrySpec::default(),
        }
    }
}

impl EngineSpec {
    /// The sequential reference oracle.
    pub fn sequential() -> EngineSpec {
        EngineSpec::default()
    }

    /// The multi-threaded engine over in-process channels
    /// (`threads = 0` = auto).
    pub fn parallel(threads: usize) -> EngineSpec {
        EngineSpec { kind: EngineKind::Parallel, threads, ..EngineSpec::default() }
    }

    pub fn with_transport(mut self, transport: TransportKind) -> EngineSpec {
        self.transport = transport;
        self
    }

    pub fn with_tcp(mut self, tcp: TcpSpec) -> EngineSpec {
        self.transport = TransportKind::Tcp;
        self.tcp = tcp;
        self
    }

    pub fn with_compress(mut self, compress: CompressionSpec) -> EngineSpec {
        self.compress = compress;
        self
    }

    pub fn with_mode(mut self, mode: ModeSpec) -> EngineSpec {
        self.mode = mode;
        self
    }

    pub fn with_fault(mut self, fault: FaultSpec) -> EngineSpec {
        self.fault = fault;
        self
    }

    pub fn with_telemetry(mut self, telemetry: TelemetrySpec) -> EngineSpec {
        self.telemetry = telemetry;
        self
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("kind", Json::Str(self.kind.name().into())),
            ("threads", Json::Num(self.threads as f64)),
            ("transport", Json::Str(self.transport.name().into())),
            ("tcp", self.tcp.to_json()),
            ("compress", Json::Str(self.compress.name())),
            ("mode", Json::Str(self.mode.name())),
            ("fault", Json::Str(self.fault.name())),
            ("telemetry", self.telemetry.to_json()),
        ])
    }

    /// Parse from JSON.  Accepts the nested object form emitted by
    /// [`EngineSpec::to_json`], or — for backward compatibility with
    /// pre-registry config files — a bare string (`"parallel"`) naming
    /// just the engine kind.
    pub fn from_json(v: &Json) -> Result<EngineSpec, String> {
        if let Some(s) = v.as_str() {
            let kind = EngineKind::parse(s).ok_or(format!("bad engine {s}"))?;
            return Ok(EngineSpec { kind, ..EngineSpec::default() });
        }
        let mut e = EngineSpec::default();
        if let Some(s) = v.get("kind").and_then(Json::as_str) {
            e.kind = EngineKind::parse(s).ok_or(format!("bad engine kind {s}"))?;
        }
        if let Some(n) = v.get("threads").and_then(Json::as_usize) {
            e.threads = n;
        }
        if let Some(s) = v.get("transport").and_then(Json::as_str) {
            e.transport =
                TransportKind::parse(s).ok_or(format!("bad transport {s}"))?;
        }
        if let Some(t) = v.get("tcp") {
            e.tcp = TcpSpec::from_json(t)?;
        }
        if let Some(s) = v.get("compress").and_then(Json::as_str) {
            e.compress = CompressionSpec::parse(s)?;
        }
        if let Some(s) = v.get("mode").and_then(Json::as_str) {
            e.mode = ModeSpec::parse(s).ok_or(format!("bad mode {s} (sync|async:TAU)"))?;
        }
        if let Some(f) = v.get("fault").and_then(Json::as_str) {
            e.fault = FaultSpec::parse(f)?;
        }
        if let Some(t) = v.get("telemetry") {
            e.telemetry = TelemetrySpec::from_json(t)?;
        }
        Ok(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn json_roundtrip_preserves_every_field() {
        let spec = EngineSpec {
            kind: EngineKind::Parallel,
            threads: 3,
            transport: TransportKind::Tcp,
            tcp: TcpSpec {
                listen: "127.0.0.1:9100".into(),
                peers: "5=10.0.0.2:9100".into(),
                hosted: "0-4".into(),
            },
            compress: CompressionSpec::TopK(7),
            mode: ModeSpec::Async(2),
            fault: FaultSpec::parse("drop:0.05,dup:0.1,kill:1@4").unwrap(),
            telemetry: TelemetrySpec { path: "results/t.jsonl".into(), max_bytes: 4096, keep: 2 },
        };
        let j = spec.to_json().to_string();
        let back = EngineSpec::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn legacy_bare_string_form_accepted() {
        let e = EngineSpec::from_json(&Json::Str("parallel".into())).unwrap();
        assert_eq!(e.kind, EngineKind::Parallel);
        assert_eq!(e, EngineSpec::parallel(0));
        assert!(EngineSpec::from_json(&Json::Str("warp".into())).is_err());
    }

    #[test]
    fn constructors_compose() {
        let e = EngineSpec::parallel(4).with_tcp(TcpSpec {
            listen: "127.0.0.1:0".into(),
            ..TcpSpec::default()
        });
        assert_eq!(e.kind, EngineKind::Parallel);
        assert_eq!(e.transport, TransportKind::Tcp);
        assert!(!e.tcp.is_empty());
        assert!(TcpSpec::default().is_empty());
        assert_eq!(EngineSpec::sequential(), EngineSpec::default());
        let a = EngineSpec::parallel(2).with_mode(ModeSpec::Async(1));
        assert_eq!(a.mode, ModeSpec::Async(1));
        assert_eq!(EngineSpec::parallel(2).mode, ModeSpec::Sync);
    }

    #[test]
    fn missing_keys_keep_defaults() {
        let e = EngineSpec::from_json(&parse("{\"kind\":\"parallel\"}").unwrap()).unwrap();
        assert_eq!(e.threads, 0);
        assert_eq!(e.transport, TransportKind::Local);
        assert!(e.tcp.is_empty());
        assert_eq!(e.compress, CompressionSpec::None);
        assert!(EngineSpec::from_json(&parse("{\"transport\":\"pigeon\"}").unwrap()).is_err());
        assert!(EngineSpec::from_json(&parse("{\"compress\":\"topk:0\"}").unwrap()).is_err());
        let q = EngineSpec::from_json(&parse("{\"compress\":\"qsgd:16\"}").unwrap()).unwrap();
        assert_eq!(q.compress, CompressionSpec::Qsgd(16));
        assert_eq!(e.mode, ModeSpec::Sync);
        let a = EngineSpec::from_json(&parse("{\"mode\":\"async:2\"}").unwrap()).unwrap();
        assert_eq!(a.mode, ModeSpec::Async(2));
        assert!(EngineSpec::from_json(&parse("{\"mode\":\"warp\"}").unwrap()).is_err());
        assert!(e.fault.is_none());
        assert!(!e.telemetry.enabled());
        let f = EngineSpec::from_json(&parse("{\"fault\":\"drop:0.1\"}").unwrap()).unwrap();
        assert_eq!(f.fault, FaultSpec::parse("drop:0.1").unwrap());
        assert!(EngineSpec::from_json(&parse("{\"fault\":\"warp:1\"}").unwrap()).is_err());
        // telemetry accepts the bare-path shorthand
        let t = EngineSpec::from_json(&parse("{\"telemetry\":\"run.jsonl\"}").unwrap()).unwrap();
        assert_eq!(t.telemetry, TelemetrySpec::to_path("run.jsonl"));
    }
}
