//! Execution runtimes.
//!
//! Two independent halves live here:
//!
//! * [`engine`] — the **parallel message-passing node engine**: one worker
//!   thread per group of nodes, a pluggable [`transport`] backend modeling
//!   the topology's edges ([`LocalTransport`] in-process mpsc channels, or
//!   [`TcpTransport`] per-edge loopback/host sockets carrying the framed
//!   wire codec), watermark-paced rounds under a [`ModeSpec`]-selected
//!   clock (barrier-synchronized `sync`, or bounded-staleness
//!   `async:TAU`), and per-edge byte accounting routed through
//!   [`crate::comm::CommCostModel`]. Drives the per-node
//!   [`crate::algorithms::NodeState`] decomposition that the sequential
//!   reference driver also runs, so sync output is bit-for-bit identical
//!   to the sequential oracle (pinned by `rust/tests/engine_parity.rs`;
//!   `async:0` is pinned too, by `rust/tests/async_engine.rs`). See
//!   `rust/src/runtime/README.md`.
//!
//! * The **XLA/PJRT artifact runtime** — loads the AOT artifacts produced
//!   by `python/compile/aot.py` (HLO text) and executes them on the PJRT
//!   CPU client. The real executor needs the vendored `xla` crate and is
//!   gated behind the `pjrt` cargo feature (`pjrt` module); the default
//!   build ships a manifest-only stub (`xla_stub`) that validates the
//!   artifact directory but reports no execution backend
//!   (`has_backend() == false`), so XLA-dependent tests and benches skip
//!   cleanly instead of hard-failing when artifacts or the backend are
//!   absent.

pub mod engine;
pub mod fault;
pub mod spec;
pub mod transport;

mod registry;

pub use engine::{EngineKind, ModeSpec, ParallelEngine, ProgressProbe};
pub use fault::FaultSpec;
pub use registry::{ArtifactEntry, Manifest};
pub use spec::{EngineSpec, TcpSpec};
pub use transport::{
    LinkCounters, LinkStats, LocalTransport, NodePort, StampedEnvelope, TcpTransport,
    Transport, TransportKind,
};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::XlaRuntime;

#[cfg(not(feature = "pjrt"))]
mod xla_stub;
#[cfg(not(feature = "pjrt"))]
pub use xla_stub::XlaRuntime;

/// Search upward from the current directory for `artifacts/manifest.json`.
pub(crate) fn find_artifacts_dir() -> Result<std::path::PathBuf, String> {
    let mut d = std::env::current_dir().map_err(|e| e.to_string())?;
    loop {
        let cand = d.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !d.pop() {
            return Err(
                "artifacts/manifest.json not found — run `make artifacts`".to_string()
            );
        }
    }
}
