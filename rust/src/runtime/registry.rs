//! Artifact manifest: the JSON index written by `python/compile/aot.py`.

use crate::util::json::{parse, Json};

/// One AOT artifact (fn at a concrete shape bucket).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub fn_name: String,
    pub file: String,
    /// argument shapes
    pub arg_shapes: Vec<Vec<usize>>,
    /// output shapes
    pub out_shapes: Vec<Vec<usize>>,
}

impl ArtifactEntry {
    /// (q, d) bucket for shard-shaped first argument.
    pub fn qd(&self) -> Option<(usize, usize)> {
        let a0 = self.arg_shapes.first()?;
        if a0.len() == 2 {
            Some((a0[0], a0[1]))
        } else {
            None
        }
    }

    /// (n, d) bucket for mixing artifacts (arg1 = (n, d)).
    pub fn nd(&self) -> Option<(usize, usize)> {
        let a1 = self.arg_shapes.get(1)?;
        if a1.len() == 2 {
            Some((a1[0], a1[1]))
        } else {
            None
        }
    }
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest, String> {
        let v = parse(src)?;
        let arr = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("manifest missing entries")?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            let shapes = |key: &str| -> Vec<Vec<usize>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .map(|args| {
                        args.iter()
                            .filter_map(|a| {
                                a.get("shape").and_then(Json::as_arr).map(|s| {
                                    s.iter().filter_map(Json::as_usize).collect()
                                })
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.push(ArtifactEntry {
                name: e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                fn_name: e.get("fn").and_then(Json::as_str).unwrap_or("").to_string(),
                file: e.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
                arg_shapes: shapes("args"),
                out_shapes: shapes("outputs"),
            });
        }
        Ok(Manifest { entries })
    }

    /// Smallest (q, d) bucket of `fn_name` with q >= q_need, d >= d_need.
    pub fn pick_qd(&self, fn_name: &str, q: usize, d: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.fn_name == fn_name)
            .filter_map(|e| e.qd().map(|qd| (qd, e)))
            .filter(|&((qb, db), _)| qb >= q && db >= d)
            .min_by_key(|&((qb, db), _)| qb * db)
            .map(|(_, e)| e)
    }

    /// Smallest mix bucket with n >= n_need, d >= d_need.
    pub fn pick_mix(&self, n: usize, d: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.fn_name == "mix")
            .filter_map(|e| e.nd().map(|nd| (nd, e)))
            .filter(|&((nb, db), _)| nb >= n && db >= d)
            .min_by_key(|&((nb, db), _)| nb * db)
            .map(|(_, e)| e)
    }

    pub fn fn_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.iter().map(|e| e.fn_name.as_str()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "dtype": "f64",
      "entries": [
        {"name": "coefs_ridge_q256_d1024", "fn": "coefs_ridge",
         "file": "coefs_ridge_q256_d1024.hlo.txt",
         "args": [{"shape": [256, 1024], "dtype": "f64"},
                  {"shape": [1024], "dtype": "f64"},
                  {"shape": [256], "dtype": "f64"}],
         "outputs": [{"shape": [256], "dtype": "float64"}]},
        {"name": "coefs_ridge_q512_d4096", "fn": "coefs_ridge",
         "file": "coefs_ridge_q512_d4096.hlo.txt",
         "args": [{"shape": [512, 4096], "dtype": "f64"},
                  {"shape": [4096], "dtype": "f64"},
                  {"shape": [512], "dtype": "f64"}],
         "outputs": [{"shape": [512], "dtype": "float64"}]},
        {"name": "mix_n16_d1024", "fn": "mix", "file": "mix_n16_d1024.hlo.txt",
         "args": [{"shape": [16, 16], "dtype": "f64"},
                  {"shape": [16, 1024], "dtype": "f64"},
                  {"shape": [16, 1024], "dtype": "f64"}],
         "outputs": [{"shape": [16, 1024], "dtype": "float64"}]}
      ]}"#;

    #[test]
    fn parses_and_picks_buckets() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.pick_qd("coefs_ridge", 100, 1000).unwrap();
        assert_eq!(e.qd(), Some((256, 1024)));
        let e2 = m.pick_qd("coefs_ridge", 300, 1000).unwrap();
        assert_eq!(e2.qd(), Some((512, 4096)));
        assert!(m.pick_qd("coefs_ridge", 9999, 10).is_none());
        let mx = m.pick_mix(10, 800).unwrap();
        assert_eq!(mx.nd(), Some((16, 1024)));
        assert_eq!(m.fn_names(), vec!["coefs_ridge", "mix"]);
    }
}
