//! XLA/PJRT runtime (requires the `pjrt` feature and a vendored `xla`
//! crate): loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — xla_extension 0.5.1 rejects jax>=0.5 serialized protos)
//! and executes them on the PJRT CPU client from the Rust side. Python
//! never runs at request time.
//!
//! Artifacts are compiled per *shape bucket* (see `python/compile/
//! shapes.py`); [`XlaRuntime`] picks the smallest bucket that fits a
//! shard, zero-pads (every exported function is padding-neutral by
//! construction — enforced by `python/tests/test_model.py`), executes,
//! and un-pads/normalizes the result.

use super::registry::{ArtifactEntry, Manifest};
use crate::linalg::CsrMatrix;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn err<E: std::fmt::Display>(e: E) -> String {
    e.to_string()
}

/// PJRT-backed executor over the artifact directory.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl XlaRuntime {
    /// Load `artifacts/manifest.json` and connect the PJRT CPU client.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<XlaRuntime, String> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_src = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            format!("reading {:?}/manifest.json — run `make artifacts` ({e})", dir)
        })?;
        let manifest = Manifest::parse(&manifest_src)?;
        let client = xla::PjRtClient::cpu().map_err(err)?;
        Ok(XlaRuntime { client, manifest, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<XlaRuntime, String> {
        Self::load(super::find_artifacts_dir()?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Whether artifact execution is available (true in this build).
    pub fn has_backend(&self) -> bool {
        true
    }

    /// Smallest (q, d) bucket of `fn_name` fitting the given shard shape.
    pub fn pick_bucket(&self, fn_name: &str, q: usize, d: usize) -> Option<&ArtifactEntry> {
        self.manifest.pick_qd(fn_name, q, d)
    }

    fn executable(&self, entry: &ArtifactEntry) -> Result<(), String> {
        if self.cache.borrow().contains_key(&entry.name) {
            return Ok(());
        }
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| "bad path".to_string())?,
        )
        .map_err(err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(err)?;
        self.cache.borrow_mut().insert(entry.name.clone(), exe);
        Ok(())
    }

    /// Execute an artifact with f64 literals; returns the flattened f64
    /// outputs (the lowering always returns a 1-tuple).
    pub fn exec_raw(
        &self,
        entry: &ArtifactEntry,
        args: &[xla::Literal],
    ) -> Result<Vec<f64>, String> {
        self.executable(entry)?;
        let cache = self.cache.borrow();
        let exe = cache.get(&entry.name).unwrap();
        let result = exe.execute::<xla::Literal>(args).map_err(err)?[0][0]
            .to_literal_sync()
            .map_err(err)?;
        let out = result.to_tuple1().map_err(err)?;
        out.to_vec::<f64>().map_err(err)
    }

    /// Dense-pad a CSR shard into a (qb x db) row-major f64 buffer.
    fn pad_shard(shard: &CsrMatrix, qb: usize, db: usize) -> Vec<f64> {
        assert!(shard.rows <= qb && shard.cols <= db);
        let mut a = vec![0.0; qb * db];
        for i in 0..shard.rows {
            for (&j, &v) in shard.row_indices(i).iter().zip(shard.row_values(i)) {
                a[i * db + j as usize] = v;
            }
        }
        a
    }

    fn pad_vec(x: &[f64], len: usize) -> Vec<f64> {
        let mut v = x.to_vec();
        v.resize(len, 0.0);
        v
    }

    fn lit1(x: &[f64]) -> Result<xla::Literal, String> {
        Ok(xla::Literal::vec1(x))
    }

    fn lit2(x: &[f64], rows: usize, cols: usize) -> Result<xla::Literal, String> {
        xla::Literal::vec1(x)
            .reshape(&[rows as i64, cols as i64])
            .map_err(err)
    }

    /// Shared driver for the `(A, z, y) -> g or sum` families.
    fn run_azy(
        &self,
        fn_name: &str,
        shard: &CsrMatrix,
        z: &[f64],
        y: &[f64],
        out_kind: OutKind,
    ) -> Result<Vec<f64>, String> {
        let (q, d) = (shard.rows, shard.cols);
        let entry = self
            .pick_bucket(fn_name, q, d)
            .ok_or_else(|| format!("no {fn_name} bucket fits q={q}, d={d}"))?;
        let (qb, db) = entry.qd().ok_or_else(|| "entry lacks qd".to_string())?;
        let a = Self::pad_shard(shard, qb, db);
        let args = vec![
            Self::lit2(&a, qb, db)?,
            Self::lit1(&Self::pad_vec(&z[..d], db))?,
            Self::lit1(&Self::pad_vec(y, qb))?,
        ];
        let out = self.exec_raw(entry, &args)?;
        Ok(match out_kind {
            OutKind::PerSample => out[..q].to_vec(),
            OutKind::FeatureVec => out[..d].to_vec(),
            OutKind::Scalar => out,
        })
    }

    /// Batched ridge coefficients `A z - y` (SAGA init path).
    pub fn coefs_ridge(&self, shard: &CsrMatrix, z: &[f64], y: &[f64]) -> Result<Vec<f64>, String> {
        self.run_azy("coefs_ridge", shard, z, y, OutKind::PerSample)
    }

    /// Batched logistic coefficients.
    pub fn coefs_logistic(
        &self,
        shard: &CsrMatrix,
        z: &[f64],
        y: &[f64],
    ) -> Result<Vec<f64>, String> {
        self.run_azy("coefs_logistic", shard, z, y, OutKind::PerSample)
    }

    /// Full (unregularized, mean) ridge operator `(1/q) A^T (A z - y)`.
    pub fn full_op_ridge(&self, shard: &CsrMatrix, z: &[f64], y: &[f64]) -> Result<Vec<f64>, String> {
        let mut out = self.run_azy("full_op_ridge", shard, z, y, OutKind::FeatureVec)?;
        crate::linalg::scale(&mut out, 1.0 / shard.rows as f64);
        Ok(out)
    }

    /// Full (unregularized, mean) logistic operator.
    pub fn full_op_logistic(
        &self,
        shard: &CsrMatrix,
        z: &[f64],
        y: &[f64],
    ) -> Result<Vec<f64>, String> {
        let mut out = self.run_azy("full_op_logistic", shard, z, y, OutKind::FeatureVec)?;
        crate::linalg::scale(&mut out, 1.0 / shard.rows as f64);
        Ok(out)
    }

    /// Raw margins `A z` (metrics path).
    pub fn scores(&self, shard: &CsrMatrix, z: &[f64]) -> Result<Vec<f64>, String> {
        let (q, d) = (shard.rows, shard.cols);
        let entry = self
            .pick_bucket("scores", q, d)
            .ok_or_else(|| format!("no scores bucket fits q={q}, d={d}"))?;
        let (qb, db) = entry.qd().unwrap();
        let a = Self::pad_shard(shard, qb, db);
        let out = self.exec_raw(
            entry,
            &[Self::lit2(&a, qb, db)?, Self::lit1(&Self::pad_vec(z, db))?],
        )?;
        Ok(out[..q].to_vec())
    }

    /// Ridge objective `0.5 ||A z - y||^2` (unnormalized sum).
    pub fn obj_ridge(&self, shard: &CsrMatrix, z: &[f64], y: &[f64]) -> Result<f64, String> {
        Ok(self.run_azy("obj_ridge", shard, z, y, OutKind::Scalar)?[0])
    }

    /// Logistic objective `sum log(1+exp(-y m))` (unnormalized sum).
    pub fn obj_logistic(&self, shard: &CsrMatrix, z: &[f64], y: &[f64]) -> Result<f64, String> {
        Ok(self.run_azy("obj_logistic", shard, z, y, OutKind::Scalar)?[0])
    }

    /// Full (unregularized, mean) AUC saddle operator over a shard.
    /// `z_aug = [w(d); a; b; theta]`, returns `(d+3,)`.
    pub fn auc_full_op(
        &self,
        shard: &CsrMatrix,
        y: &[f64],
        z_aug: &[f64],
        p: f64,
    ) -> Result<Vec<f64>, String> {
        let (q, d) = (shard.rows, shard.cols);
        let entry = self
            .pick_bucket("auc_full_op", q, d)
            .ok_or_else(|| format!("no auc bucket fits q={q}, d={d}"))?;
        let (qb, db) = entry.qd().unwrap();
        let a = Self::pad_shard(shard, qb, db);
        // pad z_aug: [w pad to db, tail(3)]
        let mut zp = Self::pad_vec(&z_aug[..d], db);
        zp.extend_from_slice(&z_aug[d..d + 3]);
        let out = self.exec_raw(
            entry,
            &[
                Self::lit2(&a, qb, db)?,
                Self::lit1(&Self::pad_vec(y, qb))?,
                Self::lit1(&zp)?,
                xla::Literal::from(p),
            ],
        )?;
        let mut res = out[..d].to_vec();
        res.extend_from_slice(&out[db..db + 3]);
        crate::linalg::scale(&mut res, 1.0 / q as f64);
        Ok(res)
    }

    /// Fused gossip mixing `Wt (2 Z - Z_prev)` for stacked iterates.
    pub fn mix_step(
        &self,
        wt: &crate::linalg::DenseMatrix,
        z: &[Vec<f64>],
        z_prev: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>, String> {
        let n = z.len();
        let d = z[0].len();
        let entry = self
            .manifest
            .pick_mix(n, d)
            .ok_or_else(|| format!("no mix bucket fits n={n}, d={d}"))?;
        let (nb, db) = entry.nd().unwrap();
        let mut w_pad = vec![0.0; nb * nb];
        for i in 0..n {
            for j in 0..n {
                w_pad[i * nb + j] = wt[(i, j)];
            }
        }
        let pad_rows = |rows: &[Vec<f64>]| {
            let mut out = vec![0.0; nb * db];
            for (i, r) in rows.iter().enumerate() {
                out[i * db..i * db + d].copy_from_slice(r);
            }
            out
        };
        let out = self.exec_raw(
            entry,
            &[
                Self::lit2(&w_pad, nb, nb)?,
                Self::lit2(&pad_rows(z), nb, db)?,
                Self::lit2(&pad_rows(z_prev), nb, db)?,
            ],
        )?;
        Ok((0..n).map(|i| out[i * db..i * db + d].to_vec()).collect())
    }
}

enum OutKind {
    PerSample,
    FeatureVec,
    Scalar,
}
