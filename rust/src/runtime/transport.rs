//! Edge-channel transports behind the parallel engine.
//!
//! [`crate::runtime::ParallelEngine`] schedules per-node state machines;
//! *how* a round's [`Message`]s physically cross the topology's edges is
//! this module's job, abstracted as a [`Transport`] that hands the engine
//! one [`NodePort`] per hosted node:
//!
//! * [`LocalTransport`] — the in-process backend (PR 1 behavior): one
//!   `std::sync::mpsc` inbox per node, structured payloads moved
//!   directly (dense broadcasts stay `Arc`-shared, delivery is pointer
//!   rotation).
//! * [`TcpTransport`] — per-edge loopback/host sockets. Every payload is
//!   run through the lossless `Message::encode`/`decode` wire codec and
//!   length-prefix-framed, so the bytes the paper's `C_n^t` accounting
//!   prices actually cross a socket. Connections start with a small
//!   handshake (edge endpoints, topology fingerprint, seed) and progress
//!   is announced with WATERMARK control frames, which is what lets two
//!   engine processes hosting disjoint node sets stay in lockstep without
//!   any shared memory — and what lets the async clock run without any
//!   lockstep at all.
//!
//! ## Wire framing (little-endian, after the handshake)
//!
//! ```text
//! MSG frame:       0x4D | link_seq: u64 | t: u64 | seq: u32 | len: u64 | len bytes (Message::encode)
//! WATERMARK frame: 0x57 | link_seq: u64 | len: u64 | len bytes (comm::Watermark::encode)
//! NACK frame:      0x4E | from_seq: u64 | to_seq: u64            (comm::Nack)
//! ```
//!
//! ## Reliable link layer (wire v3)
//!
//! Every MSG and WATERMARK frame carries a per-link, per-direction
//! `link_seq` (0, 1, 2, … in write order); NACK frames are the only
//! unsequenced family. The receiver side of each link tracks
//! `next_expected`: an already-seen sequence number is a duplicate and is
//! discarded (counted in [`LinkStats::dedups`]); a gap buffers the frame
//! and sends a `NACK [first missing, observed)` back over the same
//! socket, which the sender's reader thread services by retransmitting
//! the named frames from its retention buffer. Because watermarks are
//! sequenced too — and the fault injector only ever touches MSG frames —
//! a round's end-of-round watermark always reveals any dropped payload
//! frames before the round can complete, so under `drop:P,dup:P`
//! injection ([`FaultSpec`]) runs converge bit-identical to fault-free.
//!
//! Senders retain every sequenced frame until the peer's watermark
//! proves it was consumed: under the sync clock a peer watermark of `w`
//! implies rounds `<= w - 2` are fully drained, so frames of round `r`
//! are pruned once `r + 2 + grace <= w`, where `grace` is 0 for the sync
//! clock and `tau` for the bounded-staleness async clock
//! ([`Transport::set_retain_grace`]). A NACK naming an already-pruned
//! frame is a protocol violation and closes the link with a diagnostic.
//!
//! One caveat, accepted deliberately: a link's writer is shared (behind
//! a mutex) between the owning port and the socket's reader thread (which
//! services incoming NACKs), so two endpoints whose socket buffers are
//! *both* full while both hold their write locks could in principle
//! deadlock; the workloads this backend carries are far below the size
//! where that is reachable.
//!
//! A `WATERMARK` frame is the single versioned control frame
//! (`node | round | kind`, see [`crate::comm::Watermark`]) that subsumes
//! the legacy END and STATS frames of wire version 1: `RoundComplete`
//! delimits a sender's round-`t` emissions, and `Stats` carries the
//! split-run metric-row flood (`metrics::encode_stat_rows`) for
//! `hop = 0..diameter` sub-rounds at a sample point `t`. Per-link reader
//! threads additionally mirror every `RoundComplete` into a shared
//! per-neighbor watermark table *after* queueing the frame, so a
//! non-blocking [`NodePort::poll_watermarks`] observing `round + 1` for a
//! neighbor is guaranteed to find all of that neighbor's messages through
//! `round` already drainable via [`NodePort::drain_up_to`] (per-link FIFO
//! plus the store ordering gives the happens-before edge).
//!
//! ## Handshake (29 bytes each way, dialer first)
//!
//! ```text
//! "DSBA" | version: u8 | from: u32 | to: u32 | topology fingerprint: u64 | seed: u64
//! ```
//!
//! The acceptor validates the magic/version, that `(from, to)` is a real
//! edge whose `to` end it hosts, and that the fingerprint and seed match
//! its own experiment, then answers with the mirrored hello. A mismatch
//! drops the connection, so a mispaired engine fails fast instead of
//! silently diverging.
//!
//! The determinism contract is transport-independent: the engine sorts
//! each drained inbox by `(sender, emit index)` before delivery and the
//! codec is bit-exact, so the TCP backend reproduces the sequential
//! oracle's iterates exactly (pinned by `rust/tests/engine_parity.rs`).

use super::fault::FaultSpec;
use crate::comm::{Message, Nack, Watermark, WatermarkKind};
use crate::graph::Topology;
use crate::telemetry::{EventHub, EventKind, EventSink, RunEvent};
use crate::util::rng::Rng;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// (from, emit index, payload) crossing one edge.
pub type Envelope = (usize, u32, Message);

/// (from, round, emit index, payload) — the round-stamped envelope the
/// staleness-aware [`NodePort::drain_up_to`] surface returns, since an
/// async drain can hand back messages from several rounds at once.
pub type StampedEnvelope = (usize, u64, u32, Message);

/// Which edge-channel backend carries the engine's messages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels (structured payloads, no serialization).
    Local,
    /// Per-edge TCP sockets (encoded frames, loopback or cross-host).
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "local" | "mpsc" => TransportKind::Local,
            "tcp" => TransportKind::Tcp,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Snapshot of one node's reliable-link activity across all its links
/// (see the module docs): what the link layer did (`retransmits`,
/// `dedups`) and what the fault injector made it do (`drops_injected`,
/// `dups_injected`). All zeros on backends without a link layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Frames re-sent in response to a peer's NACK.
    pub retransmits: u64,
    /// Duplicate incoming frames discarded by sequence number.
    pub dedups: u64,
    /// Outgoing MSG frames the fault injector dropped.
    pub drops_injected: u64,
    /// Outgoing MSG frames the fault injector duplicated.
    pub dups_injected: u64,
}

/// Shared mutable form of [`LinkStats`]: one per TCP port, bumped by the
/// port's writers (injection, retransmits) and its reader threads
/// (dedups), snapshotted by the engine for telemetry and metrics.
#[derive(Debug, Default)]
pub struct LinkCounters {
    retransmits: AtomicU64,
    dedups: AtomicU64,
    drops_injected: AtomicU64,
    dups_injected: AtomicU64,
}

impl LinkCounters {
    pub fn snapshot(&self) -> LinkStats {
        LinkStats {
            retransmits: self.retransmits.load(Ordering::Relaxed),
            dedups: self.dedups.load(Ordering::Relaxed),
            drops_injected: self.drops_injected.load(Ordering::Relaxed),
            dups_injected: self.dups_injected.load(Ordering::Relaxed),
        }
    }
}

/// One node's view of its edge channels. Exactly one port exists per
/// hosted node; the engine moves it into the worker thread that owns the
/// node, so implementations need `Send` but never `Sync`.
pub trait NodePort: Send {
    /// Queue `msg` (round `t`, emit index `seq`) toward neighbor `to`.
    fn send(&mut self, t: usize, to: usize, seq: u32, msg: Message) -> Result<(), String>;

    /// Mark this node's round-`t` emissions complete (flush buffers and
    /// emit end-of-round control frames where the backend needs them).
    fn finish_round(&mut self, t: usize) -> Result<(), String>;

    /// Collect every envelope addressed to this node in round `t`.
    ///
    /// In-process backends may assume the engine's phase barrier: every
    /// hosted node's round-`t` sends complete before the first
    /// `drain_round(t)` call, so a non-blocking drain is exhaustive.
    /// Cross-process backends must instead block until each neighbor's
    /// round-`t` end-of-round marker arrives (with a failure timeout).
    fn drain_round(&mut self, t: usize) -> Result<Vec<Envelope>, String>;

    /// Send an opaque stats payload (split-run metrics piggyback) to
    /// neighbor `to` for sample point `(t, hop)` — `t` counts completed
    /// rounds. The engine only issues these toward neighbors hosted by
    /// a *peer* process, so single-process backends never see the call;
    /// the default rejects it.
    fn send_stats(&mut self, t: usize, hop: u32, to: usize, payload: &[u8]) -> Result<(), String> {
        let _ = (t, hop, to, payload);
        Err("stats exchange unsupported on this transport".to_string())
    }

    /// Block until the `(t, hop)` stats payload from neighbor `from`
    /// arrives. Same caller contract as [`NodePort::send_stats`].
    fn recv_stats(&mut self, t: usize, hop: u32, from: usize) -> Result<Vec<u8>, String> {
        let _ = (t, hop, from);
        Err("stats exchange unsupported on this transport".to_string())
    }

    /// Non-blocking snapshot of per-neighbor progress: `(node, w)` pairs
    /// where `w` counts the rounds the node has emitted through (`w = 0`
    /// means nothing yet, `w = t + 1` means its round-`t` emissions are
    /// complete and — by the watermark ordering contract — already
    /// drainable). Backends may report more nodes than the caller's
    /// in-neighborhood; the async clock filters. The default rejects the
    /// call for backends without a watermark table.
    fn poll_watermarks(&mut self) -> Result<Vec<(usize, u64)>, String> {
        Err("watermark polling unsupported on this transport".to_string())
    }

    /// Non-blocking drain of every received envelope stamped with round
    /// `<= t`; later-round envelopes stay buffered for a future call.
    /// This is the async clock's inbox surface — a port is driven either
    /// through the barrier pair `finish_round`/`drain_round` *or* through
    /// `poll_watermarks`/`drain_up_to`, never both.
    fn drain_up_to(&mut self, t: usize) -> Result<Vec<StampedEnvelope>, String> {
        let _ = t;
        Err("staleness-aware drains unsupported on this transport".to_string())
    }

    /// Snapshot of this node's reliable-link counters. Backends without
    /// a link layer report zeros.
    fn link_stats(&self) -> LinkStats {
        LinkStats::default()
    }

    /// Shared handle to the live counters behind [`NodePort::link_stats`],
    /// so the engine can observe them after the port moves into its
    /// worker thread. `None` on backends without a link layer.
    fn counters_handle(&self) -> Option<Arc<LinkCounters>> {
        None
    }

    /// Enable blocked-time tracking inside the port's drain path, so the
    /// engine's phase spans can attribute time the port spends parked on
    /// peer watermarks to `wait` rather than `drain`. Off by default —
    /// it costs two clock reads per blocking receive — and a no-op on
    /// backends whose drains never block (the in-process transport).
    fn set_wait_tracking(&mut self, on: bool) {
        let _ = on;
    }

    /// Microseconds the port spent blocked on peers inside drain calls
    /// since the last take (resets to zero). Always 0 unless
    /// [`NodePort::set_wait_tracking`] enabled tracking.
    fn take_blocked_micros(&mut self) -> u64 {
        0
    }
}

/// A connected communication backend for one engine instance: the set of
/// nodes it hosts plus one [`NodePort`] per hosted node.
pub trait Transport: Send {
    /// Nodes this endpoint hosts, sorted ascending. The engine builds
    /// and steps node states only for these (all states are still
    /// *constructed* in node order, so RNG forking stays identical to
    /// the sequential oracle).
    fn hosted(&self) -> &[usize];

    /// Consume the transport into per-node ports, aligned with
    /// [`Transport::hosted`] order.
    fn into_ports(self: Box<Self>) -> Vec<Box<dyn NodePort>>;

    fn name(&self) -> &'static str;

    /// Install the link-fault plan (`drop`/`dup` probabilities, seeded
    /// per-edge) before the engine takes the ports. Backends without a
    /// link layer accept only fault-free plans — injecting losses into a
    /// lossless in-process channel would silently test nothing.
    fn configure_faults(&mut self, fault: &FaultSpec, seed: u64) -> Result<(), String> {
        let _ = seed;
        if fault.link_faults() {
            return Err(format!(
                "link fault injection (drop/dup) is unsupported on the {} \
                 transport; use --transport tcp",
                self.name()
            ));
        }
        Ok(())
    }

    /// Widen the sender-side retention window by `rounds` (the async
    /// clock's staleness bound `tau`); see the module docs for the prune
    /// rule. No-op on backends without a retention buffer.
    fn set_retain_grace(&mut self, rounds: u64) {
        let _ = rounds;
    }

    /// Install the control-plane [`EventSink`] so the transport's link
    /// layer can emit `RunEvent`s (handshake, nack, retransmit, dedup,
    /// watermark-advance, link-closed). No-op default: backends without
    /// a link layer have nothing to report, and every backend stays
    /// zero-cost when telemetry never installs a sink.
    fn set_event_sink(&mut self, events: EventSink) {
        let _ = events;
    }
}

// ---------------------------------------------------------------------------
// Local (in-process mpsc) backend
// ---------------------------------------------------------------------------

/// The in-process backend: one mpsc inbox per node, every port holding
/// senders for all inboxes (workers may address any neighbor), plus one
/// shared watermark slot per node for the async clock.
pub struct LocalTransport {
    hosted: Vec<usize>,
    txs: Vec<Sender<StampedEnvelope>>,
    rxs: Vec<Receiver<StampedEnvelope>>,
    marks: Arc<Vec<AtomicU64>>,
}

impl LocalTransport {
    /// Channels for all `n` nodes of a single-process engine.
    pub fn new(n: usize) -> LocalTransport {
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<StampedEnvelope>();
            txs.push(tx);
            rxs.push(rx);
        }
        let marks = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
        LocalTransport { hosted: (0..n).collect(), txs, rxs, marks }
    }
}

impl Transport for LocalTransport {
    fn hosted(&self) -> &[usize] {
        &self.hosted
    }

    fn into_ports(self: Box<Self>) -> Vec<Box<dyn NodePort>> {
        let txs = self.txs;
        let marks = self.marks;
        self.rxs
            .into_iter()
            .enumerate()
            .map(|(id, rx)| {
                Box::new(LocalPort {
                    id,
                    txs: txs.clone(),
                    rx,
                    marks: marks.clone(),
                    carry: Vec::new(),
                }) as Box<dyn NodePort>
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "local"
    }
}

struct LocalPort {
    id: usize,
    txs: Vec<Sender<StampedEnvelope>>,
    rx: Receiver<StampedEnvelope>,
    /// shared per-node "rounds emitted through" table
    marks: Arc<Vec<AtomicU64>>,
    /// envelopes pulled by `drain_up_to` that belong to a future round
    carry: Vec<StampedEnvelope>,
}

impl NodePort for LocalPort {
    fn send(&mut self, t: usize, to: usize, seq: u32, msg: Message) -> Result<(), String> {
        self.txs[to]
            .send((self.id, t as u64, seq, msg))
            .map_err(|_| format!("node {to}: inbox receiver dropped mid-round"))
    }

    fn finish_round(&mut self, t: usize) -> Result<(), String> {
        // publish AFTER the round's sends: an observer of `t + 1` is
        // guaranteed (mpsc FIFO + SeqCst) to find the messages drainable
        self.marks[self.id].store(t as u64 + 1, Ordering::SeqCst);
        Ok(())
    }

    fn drain_round(&mut self, _t: usize) -> Result<Vec<Envelope>, String> {
        // exhaustive under the engine's phase barrier (all sends landed)
        Ok(self.rx.try_iter().map(|(from, _, seq, msg)| (from, seq, msg)).collect())
    }

    fn poll_watermarks(&mut self) -> Result<Vec<(usize, u64)>, String> {
        Ok(self
            .marks
            .iter()
            .enumerate()
            .map(|(node, w)| (node, w.load(Ordering::SeqCst)))
            .collect())
    }

    fn drain_up_to(&mut self, t: usize) -> Result<Vec<StampedEnvelope>, String> {
        let t64 = t as u64;
        let mut out = Vec::new();
        let mut keep = Vec::new();
        for env in self.carry.drain(..).chain(self.rx.try_iter()) {
            if env.1 <= t64 {
                out.push(env);
            } else {
                keep.push(env);
            }
        }
        self.carry = keep;
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// TCP backend
// ---------------------------------------------------------------------------

const HANDSHAKE_MAGIC: [u8; 4] = *b"DSBA";
/// v2 replaced the END (0x45) / STATS (0x53) control frames of v1 with
/// the single versioned WATERMARK frame; v3 added per-link sequence
/// numbers to every MSG/WATERMARK frame plus the NACK frame of the
/// reliable link layer. Older peers are rejected at the handshake.
const WIRE_VERSION: u8 = 3;
const FRAME_MSG: u8 = 0x4D; // 'M'
const FRAME_WATERMARK: u8 = 0x57; // 'W'
const FRAME_NACK: u8 = 0x4E; // 'N'
/// Hard upper bound on one frame's payload; a corrupt length field fails
/// fast instead of stalling the reader for gigabytes.
const MAX_FRAME_BYTES: u64 = 1 << 30;
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Accept-side limit for reading one hello. Dialers write their hello
/// immediately after connecting, so anything slower is a stray (port
/// scanner, health check) — kept much shorter than the dialer-side
/// [`HANDSHAKE_TIMEOUT`] so idle strays, which are read serially, cannot
/// exhaust the [`ACCEPT_DEADLINE`].
const ACCEPT_HELLO_TIMEOUT: Duration = Duration::from_secs(2);
const ACCEPT_DEADLINE: Duration = Duration::from_secs(30);
const DIAL_RETRIES: usize = 100;
const DIAL_BACKOFF: Duration = Duration::from_millis(100);
/// End-of-round wait before declaring a peer dead. Generous by default —
/// inner-solver-heavy methods (P-EXTRA/SSDA) can legitimately spend a
/// long time in a round on large problems. Override with
/// `DSBA_DRAIN_TIMEOUT_SECS` for faster failure detection.
const DRAIN_TIMEOUT_DEFAULT: Duration = Duration::from_secs(180);

/// Parse a `DSBA_DRAIN_TIMEOUT_SECS` override. Returns the timeout plus
/// an optional diagnostic: `0` (an instant timeout would declare every
/// peer dead on the first drain) and unparsable values both fall back to
/// the default *with a warning* instead of silently.
fn parse_drain_timeout(raw: Option<&str>) -> (Duration, Option<String>) {
    let Some(raw) = raw else {
        return (DRAIN_TIMEOUT_DEFAULT, None);
    };
    match raw.trim().parse::<u64>() {
        Ok(0) => (
            DRAIN_TIMEOUT_DEFAULT,
            Some(
                "DSBA_DRAIN_TIMEOUT_SECS=0 rejected (a zero-duration drain \
                 timeout declares peers dead instantly); using the default"
                    .to_string(),
            ),
        ),
        Ok(secs) => (Duration::from_secs(secs), None),
        Err(e) => (
            DRAIN_TIMEOUT_DEFAULT,
            Some(format!(
                "DSBA_DRAIN_TIMEOUT_SECS={raw:?} is not a number of seconds \
                 ({e}); using the default"
            )),
        ),
    }
}

pub(crate) fn drain_timeout() -> Duration {
    let var = std::env::var("DSBA_DRAIN_TIMEOUT_SECS").ok();
    let (timeout, warning) = parse_drain_timeout(var.as_deref());
    if let Some(w) = warning {
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| eprintln!("warning: {w}"));
    }
    timeout
}

/// A bound-but-not-yet-connected TCP endpoint. Binding is split from
/// [`TcpTransport::establish`] so cooperating endpoints can publish their
/// (possibly ephemeral) addresses before any of them starts dialing.
pub struct BoundListener {
    inner: TcpListener,
    addr: SocketAddr,
}

impl BoundListener {
    /// The bound address (resolves port 0 to the assigned ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

/// One decoded item crossing a link, queued toward the owning port.
enum TcpEvent {
    Msg { from: usize, t: u64, seq: u32, msg: Message },
    End { from: usize, t: u64 },
    Stats { from: usize, t: u64, hop: u32, payload: Vec<u8> },
    Closed { from: usize, reason: String },
}

/// One frame as read off a socket, before link-layer sequencing.
enum RawFrame {
    /// A sequenced MSG/WATERMARK frame.
    Seq { link_seq: u64, ev: TcpEvent },
    /// An unsequenced retransmit request for `[from_seq, to_seq)`.
    Nack { from_seq: u64, to_seq: u64 },
}

/// Optional sender-side link faults (see [`FaultSpec`]): one uniform
/// draw per outgoing MSG frame decides drop / duplicate / pass-through.
struct FaultInjector {
    drop_p: f64,
    dup_p: f64,
    rng: Rng,
}

/// One sequenced frame held for possible retransmission.
struct RetainedFrame {
    link_seq: u64,
    /// Engine round the frame belongs to (drives the prune rule).
    round: u64,
    /// Everything before the payload: tag, link_seq, per-tag meta, len.
    header: Vec<u8>,
    payload: Arc<Vec<u8>>,
}

/// The write half of one directed link. Shared (behind a mutex) between
/// the owning [`TcpPort`] — which emits the round's sequenced frames —
/// and the same socket's reader thread, which services incoming NACKs by
/// retransmitting retained frames and emits this side's own NACKs.
struct LinkWriter {
    /// Local (owning) node.
    id: usize,
    /// Node on the far end of the link.
    peer: usize,
    w: BufWriter<TcpStream>,
    /// Next link sequence number to assign.
    next_seq: u64,
    /// Sent frames not yet provably consumed, ascending `link_seq`.
    retained: VecDeque<RetainedFrame>,
    /// The peer's watermark slot (written by this socket's reader).
    peer_mark: Arc<AtomicU64>,
    /// Extra retention rounds beyond the sync-clock window (async `tau`).
    grace: u64,
    fault: Option<FaultInjector>,
    counters: Arc<LinkCounters>,
    /// Control-plane event hub shared across the transport; inert (one
    /// relaxed atomic load per emit point) until telemetry installs a
    /// sink via [`Transport::set_event_sink`].
    hub: Arc<EventHub>,
}

impl LinkWriter {
    /// Emit one sequenced frame: assign `link_seq`, run the fault draw
    /// (MSG frames only), write 0/1/2 copies, retain for retransmission,
    /// and prune retention against the peer's watermark. `msg_seq`
    /// carries the per-round emit index for MSG frames (`round` doubles
    /// as the wire `t` field); WATERMARK frames pass `None`.
    fn write_sequenced(
        &mut self,
        tag: u8,
        round: u64,
        msg_seq: Option<u32>,
        payload: Arc<Vec<u8>>,
    ) -> std::io::Result<()> {
        let link_seq = self.next_seq;
        self.next_seq += 1;
        let mut header = Vec::with_capacity(29);
        header.push(tag);
        header.extend_from_slice(&link_seq.to_le_bytes());
        if let Some(seq) = msg_seq {
            header.extend_from_slice(&round.to_le_bytes());
            header.extend_from_slice(&seq.to_le_bytes());
        }
        header.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        let mut copies = 1usize;
        if tag == FRAME_MSG {
            if let Some(f) = &mut self.fault {
                let u = f.rng.uniform();
                if u < f.drop_p {
                    copies = 0;
                    self.counters.drops_injected.fetch_add(1, Ordering::Relaxed);
                } else if u < f.drop_p + f.dup_p {
                    copies = 2;
                    self.counters.dups_injected.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        for _ in 0..copies {
            self.w.write_all(&header)?;
            self.w.write_all(&payload)?;
        }
        self.retained.push_back(RetainedFrame { link_seq, round, header, payload });
        self.prune();
        Ok(())
    }

    /// Drop retained frames the peer's watermark proves consumed: a mark
    /// of `w` means the peer is past draining round `w - 2` (sync), so
    /// frames of round `r` are dead once `r + 2 + grace <= w`.
    fn prune(&mut self) {
        let mark = self.peer_mark.load(Ordering::SeqCst);
        while let Some(front) = self.retained.front() {
            if front.round + 2 + self.grace <= mark {
                self.retained.pop_front();
            } else {
                break;
            }
        }
    }

    /// Re-send retained frames `[from_seq, to_seq)` in response to a
    /// peer NACK. A request naming an unsent or already-pruned frame is
    /// a protocol violation and fails the link with a diagnostic.
    fn retransmit(&mut self, from_seq: u64, to_seq: u64) -> Result<(), String> {
        self.hub.with(|es| {
            es.emit(
                RunEvent::new(EventKind::NackReceived)
                    .node(self.id as u32)
                    .peer(self.peer as u32)
                    .seq(from_seq)
                    .detail(format!("range [{from_seq}, {to_seq})")),
            );
        });
        if to_seq > self.next_seq {
            return Err(format!(
                "node {}: peer {} nacked unsent frame (range [{from_seq}, \
                 {to_seq}), only {} emitted)",
                self.id, self.peer, self.next_seq
            ));
        }
        for s in from_seq..to_seq {
            let j = self
                .retained
                .binary_search_by_key(&s, |f| f.link_seq)
                .map_err(|_| {
                    format!(
                        "node {}: peer {} nacked frame {s}, which is already \
                         pruned (peer watermark {}, oldest retained {:?})",
                        self.id,
                        self.peer,
                        self.peer_mark.load(Ordering::SeqCst),
                        self.retained.front().map(|f| f.link_seq)
                    )
                })?;
            let frame = &self.retained[j];
            self.w
                .write_all(&frame.header)
                .and_then(|()| self.w.write_all(&frame.payload))
                .map_err(|e| {
                    format!("node {}: retransmit {s} to {}: {e}", self.id, self.peer)
                })?;
            self.counters.retransmits.fetch_add(1, Ordering::Relaxed);
        }
        self.hub.with(|es| {
            es.emit(
                RunEvent::new(EventKind::Retransmit)
                    .node(self.id as u32)
                    .peer(self.peer as u32)
                    .seq(from_seq)
                    .detail(format!(
                        "{} frame(s) [{from_seq}, {to_seq})",
                        to_seq - from_seq
                    )),
            );
        });
        self.flush()
    }

    /// Ask the peer to retransmit `[from_seq, to_seq)`. NACK frames are
    /// unsequenced, never retained, and never fault-injected.
    fn write_nack(&mut self, from_seq: u64, to_seq: u64) -> Result<(), String> {
        self.w
            .write_all(&[FRAME_NACK])
            .and_then(|()| self.w.write_all(&Nack { from_seq, to_seq }.encode()))
            .map_err(|e| format!("node {}: nack to {}: {e}", self.id, self.peer))?;
        self.flush()
    }

    fn flush(&mut self) -> Result<(), String> {
        self.w
            .flush()
            .map_err(|e| format!("node {}: flush to {}: {e}", self.id, self.peer))
    }
}

/// Lock a shared link writer, surfacing poisoning as an error instead of
/// a propagated panic (a poisoned writer means a thread died mid-write;
/// the link is unusable either way).
fn lock_writer(w: &Arc<Mutex<LinkWriter>>) -> Result<std::sync::MutexGuard<'_, LinkWriter>, String> {
    w.lock().map_err(|_| "link writer mutex poisoned".to_string())
}

/// Per-edge socket backend. See the module docs for framing/handshake.
pub struct TcpTransport {
    hosted: Vec<usize>,
    ports: Vec<TcpPort>,
    /// Shared with every link writer and reader thread; see
    /// [`Transport::set_event_sink`].
    hub: Arc<EventHub>,
}

impl TcpTransport {
    /// Bind a listener (use port 0 for an ephemeral loopback port).
    pub fn bind(addr: &str) -> Result<BoundListener, String> {
        let inner =
            TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let addr = inner.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        Ok(BoundListener { inner, addr })
    }

    /// Single-process convenience: host every node, route every edge
    /// through a loopback socket pair.
    pub fn loopback(topo: &Topology, seed: u64) -> Result<TcpTransport, String> {
        let listener = Self::bind("127.0.0.1:0")?;
        Self::establish(listener, topo, seed, (0..topo.n).collect(), &HashMap::new())
    }

    /// Connect this endpoint's share of the topology: host `hosted`
    /// (sorted), dial the lower end of every hosted edge, accept the
    /// upper end. `peers` maps every non-hosted neighbor to the address
    /// of the endpoint hosting it.
    pub fn establish(
        listener: BoundListener,
        topo: &Topology,
        seed: u64,
        hosted: Vec<usize>,
        peers: &HashMap<usize, String>,
    ) -> Result<TcpTransport, String> {
        if hosted.is_empty() {
            return Err("tcp transport hosts no nodes".to_string());
        }
        if !hosted.windows(2).all(|w| w[0] < w[1]) {
            return Err("hosted node list must be sorted and unique".to_string());
        }
        if *hosted.last().unwrap() >= topo.n {
            return Err(format!(
                "hosted node {} out of range (N = {})",
                hosted.last().unwrap(),
                topo.n
            ));
        }
        let mut is_hosted = vec![false; topo.n];
        for &n in &hosted {
            is_hosted[n] = true;
        }
        for &n in &hosted {
            for &m in topo.neighbors(n) {
                if !is_hosted[m] && !peers.contains_key(&m) {
                    return Err(format!(
                        "neighbor {m} of hosted node {n} has no peer address \
                         (pass it via --peers {m}=host:port)"
                    ));
                }
            }
        }

        let hash = topo.fingerprint();
        let self_addr = listener.addr.to_string();
        // edges touching this endpoint, normalized (a < b)
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for a in 0..topo.n {
            for &b in topo.neighbors(a) {
                if a < b && (is_hosted[a] || is_hosted[b]) {
                    edges.push((a, b));
                }
            }
        }
        let expect_accept = edges.iter().filter(|&&(_, b)| is_hosted[b]).count();
        let edge_set: HashSet<(usize, usize)> = edges.iter().copied().collect();
        let hosted_mask = is_hosted.clone();
        let tcp_listener = listener.inner;
        let cancel = Arc::new(AtomicBool::new(false));
        let cancel_accept = cancel.clone();
        let acceptor = std::thread::spawn(move || {
            accept_all(
                tcp_listener,
                expect_accept,
                edge_set,
                hosted_mask,
                hash,
                seed,
                cancel_accept,
            )
        });

        // dial the lower end of every edge we host, in edge order;
        // self-edges (both ends hosted) loop back to our own listener
        let mut streams: HashMap<(usize, usize), TcpStream> = HashMap::new();
        for &(a, b) in &edges {
            if !is_hosted[a] {
                continue; // the endpoint hosting `a` dials this edge
            }
            let addr = if is_hosted[b] { &self_addr } else { &peers[&b] };
            let stream = match dial(addr, a, b, hash, seed) {
                Ok(s) => s,
                Err(e) => {
                    // shut the acceptor down promptly so the listener (and
                    // a user-supplied --listen port) is released now, not
                    // after the 30 s accept deadline
                    cancel.store(true, Ordering::SeqCst);
                    let _ = acceptor.join();
                    return Err(e);
                }
            };
            streams.insert((a, b), stream);
        }
        let accepted = acceptor
            .join()
            .map_err(|_| "tcp acceptor thread panicked".to_string())??;
        for (local, remote, stream) in accepted {
            if streams.insert((local, remote), stream).is_some() {
                return Err(format!(
                    "duplicate connection for edge ({remote},{local})"
                ));
            }
        }

        // assemble one port per hosted node: shared link writers plus one
        // reader thread per link feeding the node's event inbox, its slot
        // in the per-neighbor watermark table, and the link layer (the
        // reader also services NACKs against the link's writer)
        let mut ports = Vec::with_capacity(hosted.len());
        let hub = Arc::new(EventHub::new());
        for &n in &hosted {
            let (inbox_tx, inbox_rx) = channel::<TcpEvent>();
            let nbrs = topo.neighbors(n).to_vec();
            let counters = Arc::new(LinkCounters::default());
            let mut writers = Vec::with_capacity(nbrs.len());
            let mut shutdown = Vec::with_capacity(nbrs.len());
            let mut marks = Vec::with_capacity(nbrs.len());
            for &m in &nbrs {
                let stream = streams
                    .remove(&(n, m))
                    .ok_or_else(|| format!("missing stream for edge ({n},{m})"))?;
                let clone_err = |e| format!("clone stream ({n},{m}): {e}");
                shutdown.push(stream.try_clone().map_err(clone_err)?);
                let mark = Arc::new(AtomicU64::new(0));
                marks.push(mark.clone());
                let writer = Arc::new(Mutex::new(LinkWriter {
                    id: n,
                    peer: m,
                    w: BufWriter::new(stream.try_clone().map_err(clone_err)?),
                    next_seq: 0,
                    retained: VecDeque::new(),
                    peer_mark: mark.clone(),
                    grace: 0,
                    fault: None,
                    counters: counters.clone(),
                    hub: hub.clone(),
                }));
                writers.push((m, writer.clone()));
                let tx = inbox_tx.clone();
                let link_counters = counters.clone();
                let side = ReaderSide { me: n, hub: hub.clone() };
                std::thread::spawn(move || {
                    reader_loop(stream, m, tx, mark, writer, link_counters, side)
                });
            }
            ports.push(TcpPort {
                id: n,
                neighbors: nbrs,
                writers,
                inbox: inbox_rx,
                carry: Vec::new(),
                marks,
                closed: HashMap::new(),
                enc_cache: None,
                comp_cache: None,
                drain_timeout: drain_timeout(),
                shutdown,
                counters,
                track_wait: false,
                blocked_micros: 0,
            });
        }
        debug_assert!(streams.is_empty(), "unassigned streams after port assembly");
        Ok(TcpTransport { hosted, ports, hub })
    }
}

impl Transport for TcpTransport {
    fn hosted(&self) -> &[usize] {
        &self.hosted
    }

    fn into_ports(self: Box<Self>) -> Vec<Box<dyn NodePort>> {
        self.ports
            .into_iter()
            .map(|p| Box::new(p) as Box<dyn NodePort>)
            .collect()
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn configure_faults(&mut self, fault: &FaultSpec, seed: u64) -> Result<(), String> {
        if !fault.link_faults() {
            return Ok(());
        }
        for p in &mut self.ports {
            for (m, w) in &p.writers {
                let mut w = lock_writer(w)?;
                w.fault = Some(FaultInjector {
                    drop_p: fault.drop,
                    dup_p: fault.dup,
                    rng: FaultSpec::edge_rng(seed, p.id, *m),
                });
            }
        }
        Ok(())
    }

    fn set_retain_grace(&mut self, rounds: u64) {
        for p in &mut self.ports {
            for (_, w) in &p.writers {
                if let Ok(mut w) = w.lock() {
                    w.grace = rounds;
                }
            }
        }
    }

    fn set_event_sink(&mut self, events: EventSink) {
        // replay the already-completed link bring-up as handshake events
        // (establish ran before telemetry wiring), then open the hub so
        // the link layer's live emit points start firing
        for p in &self.ports {
            for (m, _) in &p.writers {
                events.emit(
                    RunEvent::new(EventKind::Handshake)
                        .node(p.id as u32)
                        .peer(*m as u32)
                        .detail("link up"),
                );
            }
        }
        self.hub.install(events);
    }
}

struct TcpPort {
    id: usize,
    /// sorted adjacency of this node
    neighbors: Vec<usize>,
    /// per-neighbor link writers, aligned with `neighbors`; shared with
    /// each link's reader thread, which services NACKs (see module docs)
    writers: Vec<(usize, Arc<Mutex<LinkWriter>>)>,
    inbox: Receiver<TcpEvent>,
    /// events already pulled that belong to a future round
    carry: Vec<TcpEvent>,
    /// per-neighbor "rounds emitted through" slots, aligned with
    /// `neighbors`, written by the link's reader thread
    marks: Vec<Arc<AtomicU64>>,
    /// links whose reader reported `Closed` during a staleness-aware
    /// drain (`drain_up_to` keeps going as long as the watermark already
    /// covers what the caller asked for; the async clock's admission
    /// deadline is what turns a genuinely dead peer into an error)
    closed: HashMap<usize, String>,
    /// last dense broadcast payload and its encoding — a degree-k
    /// broadcast encodes once, not k times (the held `Arc` keeps the
    /// allocation alive, so pointer identity can never alias a recycled
    /// address); the encoding is `Arc`-shared so retained link-layer
    /// frames alias it instead of copying
    enc_cache: Option<(Arc<Vec<f64>>, Arc<Vec<u8>>)>,
    /// same trick for `COMP` frames: the engine compresses the broadcast
    /// once per round and hands every neighbor the same `Arc`
    comp_cache: Option<(Arc<crate::comm::CompressedVec>, Arc<Vec<u8>>)>,
    /// see [`drain_timeout`]
    drain_timeout: Duration,
    /// raw clones used only to shut the links down on drop, so blocked
    /// reader threads exit promptly
    shutdown: Vec<TcpStream>,
    /// reliable-link counters shared across this port's links
    counters: Arc<LinkCounters>,
    /// measure time parked in `drain_round`'s blocking receive (set by
    /// the engine for telemetered nodes only)
    track_wait: bool,
    /// accumulated blocked receive time, drained by `take_blocked_micros`
    blocked_micros: u64,
}

impl NodePort for TcpPort {
    fn send(&mut self, t: usize, to: usize, seq: u32, msg: Message) -> Result<(), String> {
        let id = self.id;
        let j = self
            .writers
            .binary_search_by_key(&to, |(m, _)| *m)
            .map_err(|_| format!("node {id} has no link to {to}"))?;
        let bytes: Arc<Vec<u8>> = match &msg {
            Message::Dense(v) => {
                // the engine hands every neighbor the same Arc-shared
                // broadcast payload — encode it once, not once per edge
                let hit = self
                    .enc_cache
                    .as_ref()
                    .is_some_and(|(cached, _)| Arc::ptr_eq(cached, v));
                if !hit {
                    self.enc_cache = Some((v.clone(), Arc::new(msg.encode())));
                }
                Arc::clone(&self.enc_cache.as_ref().unwrap().1)
            }
            Message::Comp(c) => {
                let hit = self
                    .comp_cache
                    .as_ref()
                    .is_some_and(|(cached, _)| Arc::ptr_eq(cached, c));
                if !hit {
                    self.comp_cache = Some((c.clone(), Arc::new(msg.encode())));
                }
                Arc::clone(&self.comp_cache.as_ref().unwrap().1)
            }
            Message::Sparse(_) => Arc::new(msg.encode()),
        };
        lock_writer(&self.writers[j].1)
            .and_then(|mut w| {
                w.write_sequenced(FRAME_MSG, t as u64, Some(seq), bytes)
                    .map_err(|e| e.to_string())
            })
            .map_err(|e| format!("node {id}: send to {to} failed: {e}"))
    }

    fn finish_round(&mut self, t: usize) -> Result<(), String> {
        let id = self.id;
        let wm = Watermark {
            node: id as u32,
            round: t as u64,
            kind: WatermarkKind::RoundComplete,
        };
        let bytes = Arc::new(wm.encode());
        for (to, w) in &self.writers {
            lock_writer(w)
                .and_then(|mut w| {
                    w.write_sequenced(FRAME_WATERMARK, t as u64, None, bytes.clone())
                        .map_err(|e| e.to_string())
                        .and_then(|()| w.flush())
                })
                .map_err(|e| format!("node {id}: end-of-round to {to} failed: {e}"))?;
        }
        Ok(())
    }

    fn drain_round(&mut self, t: usize) -> Result<Vec<Envelope>, String> {
        let t64 = t as u64;
        let mut out = Vec::new();
        let mut ended = vec![false; self.neighbors.len()];
        let mut remaining = self.neighbors.len();
        // events pulled during the previous round that ran ahead
        let mut queue: VecDeque<TcpEvent> = self.carry.drain(..).collect();
        while remaining > 0 {
            let ev = match queue.pop_front() {
                Some(ev) => ev,
                None => {
                    let t0 = self.track_wait.then(std::time::Instant::now);
                    let recv = self.inbox.recv_timeout(self.drain_timeout);
                    if let Some(t0) = t0 {
                        self.blocked_micros = self
                            .blocked_micros
                            .saturating_add(t0.elapsed().as_micros() as u64);
                    }
                    match recv {
                        Ok(ev) => ev,
                        Err(_) => {
                            // name every missing peer with its last-seen
                            // watermark so straggler triage isn't guesswork
                            let missing: Vec<String> = self
                                .neighbors
                                .iter()
                                .zip(&ended)
                                .zip(&self.marks)
                                .filter(|((_, &done), _)| !done)
                                .map(|((&m, _), mark)| match mark.load(Ordering::SeqCst) {
                                    0 => format!("peer {m} (no watermark yet)"),
                                    w => format!("peer {m} (last watermark: round {})", w - 1),
                                })
                                .collect();
                            return Err(format!(
                                "node {}: round {t} never completed — waiting on {} \
                                 (remote engine dead or stalled)",
                                self.id,
                                missing.join(", ")
                            ));
                        }
                    }
                }
            };
            match ev {
                TcpEvent::Msg { from, t: et, seq, msg } => {
                    if et == t64 {
                        out.push((from, seq, msg));
                    } else if et > t64 {
                        self.carry.push(TcpEvent::Msg { from, t: et, seq, msg });
                    } else {
                        return Err(format!(
                            "node {}: stale round-{et} frame from {from} during \
                             round {t}",
                            self.id
                        ));
                    }
                }
                TcpEvent::End { from, t: et } => {
                    if et == t64 {
                        let j = self.neighbors.binary_search(&from).map_err(|_| {
                            format!(
                                "node {}: end-of-round from non-neighbor {from}",
                                self.id
                            )
                        })?;
                        if ended[j] {
                            return Err(format!(
                                "node {}: duplicate end-of-round from {from}",
                                self.id
                            ));
                        }
                        ended[j] = true;
                        remaining -= 1;
                    } else if et > t64 {
                        self.carry.push(TcpEvent::End { from, t: et });
                    } else {
                        return Err(format!(
                            "node {}: stale end-of-round {et} from {from} during \
                             round {t}",
                            self.id
                        ));
                    }
                }
                TcpEvent::Stats { from, t: et, hop, payload } => {
                    // stats frames belong to a sample point, not a round:
                    // a remote engine that finished round t may emit them
                    // while we are still draining — carry for recv_stats
                    self.carry.push(TcpEvent::Stats { from, t: et, hop, payload });
                }
                TcpEvent::Closed { from, reason } => {
                    // a peer that already delivered this round's END and
                    // then closed is tearing down, not failing — defer the
                    // event so only a drain that actually still needs the
                    // link (a future round) fails fast on it
                    let done = self
                        .neighbors
                        .binary_search(&from)
                        .map(|j| ended[j])
                        .unwrap_or(false);
                    if !done {
                        return Err(format!(
                            "node {}: link to {from} closed: {reason}",
                            self.id
                        ));
                    }
                    self.carry.push(TcpEvent::Closed { from, reason });
                }
            }
        }
        // per-link FIFO means the queue is provably drained here (a
        // sender's round-t frames precede its round-t END), but never
        // risk dropping an envelope that ran ahead; leftovers arrived
        // before anything carried during this drain, so they go first
        if !queue.is_empty() {
            self.carry.splice(0..0, queue);
        }
        Ok(out)
    }

    fn send_stats(&mut self, t: usize, hop: u32, to: usize, payload: &[u8]) -> Result<(), String> {
        let id = self.id;
        let j = self
            .writers
            .binary_search_by_key(&to, |(m, _)| *m)
            .map_err(|_| format!("node {id} has no link to {to}"))?;
        let wm = Watermark {
            node: id as u32,
            round: t as u64,
            kind: WatermarkKind::Stats { hop, payload: payload.to_vec() },
        };
        lock_writer(&self.writers[j].1)
            .and_then(|mut w| {
                w.write_sequenced(FRAME_WATERMARK, t as u64, None, Arc::new(wm.encode()))
                    .map_err(|e| e.to_string())
                    .and_then(|()| w.flush())
            })
            .map_err(|e| format!("node {id}: stats frame to {to} failed: {e}"))
    }

    fn recv_stats(&mut self, t: usize, hop: u32, from: usize) -> Result<Vec<u8>, String> {
        let t64 = t as u64;
        // carried events first (stats can arrive during a round drain)
        if let Some(pos) = self.carry.iter().position(|ev| {
            matches!(ev, TcpEvent::Stats { from: f, t: et, hop: eh, .. }
                if *f == from && *et == t64 && *eh == hop)
        }) {
            match self.carry.remove(pos) {
                TcpEvent::Stats { payload, .. } => return Ok(payload),
                _ => unreachable!("position matched a Stats event"),
            }
        }
        loop {
            let ev = self.inbox.recv_timeout(self.drain_timeout).map_err(|_| {
                format!(
                    "node {}: stats exchange ({t}, hop {hop}) timed out \
                     waiting on {from} (remote engine dead or sampling \
                     schedules diverged)",
                    self.id
                )
            })?;
            match ev {
                TcpEvent::Stats { from: f, t: et, hop: eh, payload } => {
                    if f == from && et == t64 && eh == hop {
                        return Ok(payload);
                    }
                    // another link's payload, or a later sample point
                    self.carry.push(TcpEvent::Stats { from: f, t: et, hop: eh, payload });
                }
                TcpEvent::Closed { from: f, reason } => {
                    if f == from {
                        return Err(format!(
                            "node {}: link to {from} closed during stats \
                             exchange: {reason}",
                            self.id
                        ));
                    }
                    self.carry.push(TcpEvent::Closed { from: f, reason });
                }
                // next-round MSG/END frames running ahead of our exchange
                other => self.carry.push(other),
            }
        }
    }

    fn poll_watermarks(&mut self) -> Result<Vec<(usize, u64)>, String> {
        Ok(self
            .neighbors
            .iter()
            .zip(&self.marks)
            .map(|(&m, mark)| (m, mark.load(Ordering::SeqCst)))
            .collect())
    }

    fn drain_up_to(&mut self, t: usize) -> Result<Vec<StampedEnvelope>, String> {
        let t64 = t as u64;
        let mut out = Vec::new();
        let mut keep = Vec::new();
        let mut pending: VecDeque<TcpEvent> = self.carry.drain(..).collect();
        loop {
            let ev = match pending.pop_front() {
                Some(ev) => ev,
                None => match self.inbox.try_recv() {
                    Ok(ev) => ev,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break,
                },
            };
            match ev {
                TcpEvent::Msg { from, t: et, seq, msg } => {
                    if et <= t64 {
                        out.push((from, et, seq, msg));
                    } else {
                        keep.push(TcpEvent::Msg { from, t: et, seq, msg });
                    }
                }
                // the watermark table already carries round progress;
                // the end-of-round event itself is barrier-clock only
                TcpEvent::End { .. } => {}
                ev @ TcpEvent::Stats { .. } => keep.push(ev),
                TcpEvent::Closed { from, reason } => {
                    // remember, don't fail: everything the peer sent
                    // before closing is already queued ahead of this
                    // event (per-link FIFO), and the async clock's
                    // admission deadline reports a genuinely dead peer
                    // with its last watermark
                    self.closed.insert(from, reason);
                }
            }
        }
        self.carry = keep;
        Ok(out)
    }

    fn link_stats(&self) -> LinkStats {
        self.counters.snapshot()
    }

    fn counters_handle(&self) -> Option<Arc<LinkCounters>> {
        Some(self.counters.clone())
    }

    fn set_wait_tracking(&mut self, on: bool) {
        self.track_wait = on;
    }

    fn take_blocked_micros(&mut self) -> u64 {
        std::mem::take(&mut self.blocked_micros)
    }
}

impl Drop for TcpPort {
    fn drop(&mut self) {
        for s in &self.shutdown {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

// --- connection setup ------------------------------------------------------

struct Hello {
    from: u32,
    to: u32,
    hash: u64,
    seed: u64,
}

fn write_hello(
    s: &mut TcpStream,
    from: usize,
    to: usize,
    hash: u64,
    seed: u64,
) -> std::io::Result<()> {
    let mut b = Vec::with_capacity(29);
    b.extend_from_slice(&HANDSHAKE_MAGIC);
    b.push(WIRE_VERSION);
    b.extend_from_slice(&(from as u32).to_le_bytes());
    b.extend_from_slice(&(to as u32).to_le_bytes());
    b.extend_from_slice(&hash.to_le_bytes());
    b.extend_from_slice(&seed.to_le_bytes());
    s.write_all(&b)
}

fn read_hello(s: &mut TcpStream) -> Result<Hello, String> {
    let mut b = [0u8; 29];
    s.read_exact(&mut b).map_err(|e| e.to_string())?;
    if b[0..4] != HANDSHAKE_MAGIC {
        return Err("bad handshake magic".to_string());
    }
    if b[4] != WIRE_VERSION {
        return Err(format!("wire version {} (want {WIRE_VERSION})", b[4]));
    }
    Ok(Hello {
        from: u32::from_le_bytes(b[5..9].try_into().unwrap()),
        to: u32::from_le_bytes(b[9..13].try_into().unwrap()),
        hash: u64::from_le_bytes(b[13..21].try_into().unwrap()),
        seed: u64::from_le_bytes(b[21..29].try_into().unwrap()),
    })
}

fn dial(
    addr: &str,
    from: usize,
    to: usize,
    hash: u64,
    seed: u64,
) -> Result<TcpStream, String> {
    let mut last = String::new();
    for _ in 0..DIAL_RETRIES {
        let mut s = match TcpStream::connect(addr) {
            Ok(s) => s,
            Err(e) => {
                // the peer endpoint may simply not have bound yet
                last = e.to_string();
                std::thread::sleep(DIAL_BACKOFF);
                continue;
            }
        };
        let _ = s.set_nodelay(true);
        let _ = s.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
        write_hello(&mut s, from, to, hash, seed)
            .map_err(|e| format!("edge ({from},{to}): handshake write: {e}"))?;
        let hello = read_hello(&mut s)
            .map_err(|e| format!("edge ({from},{to}): handshake ack: {e}"))?;
        if hello.hash != hash {
            return Err(format!("edge ({from},{to}): topology fingerprint mismatch"));
        }
        if hello.seed != seed {
            return Err(format!("edge ({from},{to}): experiment seed mismatch"));
        }
        if hello.from as usize != to || hello.to as usize != from {
            return Err(format!(
                "edge ({from},{to}): acceptor answered for edge ({},{})",
                hello.to, hello.from
            ));
        }
        let _ = s.set_read_timeout(None);
        return Ok(s);
    }
    Err(format!("could not connect edge ({from},{to}) via {addr}: {last}"))
}

/// Accept `expect` edge connections, validating each handshake. Returns
/// `(local node, remote node, stream)` triples. A connection that can't
/// even produce a well-formed hello (port scanner, health check, line
/// noise) is silently dropped and does not count toward `expect`; a
/// well-formed hello from a *mispaired* peer (wrong topology, seed, or
/// edge) is a hard error — dropping either way means the dialer sees EOF
/// on its ack read and fails fast.
fn accept_all(
    listener: TcpListener,
    expect: usize,
    edges: HashSet<(usize, usize)>,
    is_hosted: Vec<bool>,
    hash: u64,
    seed: u64,
    cancel: Arc<AtomicBool>,
) -> Result<Vec<(usize, usize, TcpStream)>, String> {
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("listener nonblocking: {e}"))?;
    let deadline = Instant::now() + ACCEPT_DEADLINE;
    let mut out = Vec::with_capacity(expect);
    let mut seen: HashSet<(usize, usize)> = HashSet::new();
    while out.len() < expect {
        let mut s = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if cancel.load(Ordering::SeqCst) {
                    return Err("transport setup aborted".to_string());
                }
                if Instant::now() >= deadline {
                    return Err(format!(
                        "timed out waiting for {} peer connection(s)",
                        expect - out.len()
                    ));
                }
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
            Err(e) => return Err(format!("accept: {e}")),
        };
        s.set_nonblocking(false)
            .map_err(|e| format!("accepted stream blocking mode: {e}"))?;
        let _ = s.set_nodelay(true);
        let _ = s.set_read_timeout(Some(ACCEPT_HELLO_TIMEOUT));
        let hello = match read_hello(&mut s) {
            Ok(h) => h,
            Err(_) => continue, // garbled stray connection — drop, keep waiting
        };
        let (a, b) = (hello.from as usize, hello.to as usize);
        if hello.hash != hash {
            return Err(format!(
                "dialer of edge ({a},{b}) runs a different topology \
                 (fingerprint mismatch)"
            ));
        }
        if hello.seed != seed {
            return Err(format!(
                "dialer of edge ({a},{b}) runs a different experiment \
                 (seed mismatch)"
            ));
        }
        if a >= b || !edges.contains(&(a, b)) {
            return Err(format!("handshake names non-edge ({a},{b})"));
        }
        if !is_hosted[b] {
            return Err(format!("dialer targeted node {b}, which is not hosted here"));
        }
        if !seen.insert((a, b)) {
            return Err(format!("duplicate connection for edge ({a},{b})"));
        }
        write_hello(&mut s, b, a, hash, seed)
            .map_err(|e| format!("handshake ack for edge ({a},{b}): {e}"))?;
        let _ = s.set_read_timeout(None);
        out.push((b, a, s));
    }
    Ok(out)
}

// --- framing ---------------------------------------------------------------

fn read_u32(s: &mut TcpStream) -> Result<u32, String> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b).map_err(|e| e.to_string())?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(s: &mut TcpStream) -> Result<u64, String> {
    let mut b = [0u8; 8];
    s.read_exact(&mut b).map_err(|e| e.to_string())?;
    Ok(u64::from_le_bytes(b))
}

fn read_payload(s: &mut TcpStream, len: u64, what: &str) -> Result<Vec<u8>, String> {
    if len > MAX_FRAME_BYTES {
        return Err(format!("oversized {what} frame ({len} bytes)"));
    }
    let mut payload = Vec::new();
    let got = (&mut *s)
        .take(len)
        .read_to_end(&mut payload)
        .map_err(|e| e.to_string())?;
    if got as u64 != len {
        return Err(format!("truncated {what} frame"));
    }
    Ok(payload)
}

/// Read one frame; `Ok(None)` is a clean close at a frame boundary.
fn read_frame(s: &mut TcpStream, from: usize) -> Result<Option<RawFrame>, String> {
    let mut tag = [0u8; 1];
    if s.read_exact(&mut tag).is_err() {
        return Ok(None);
    }
    match tag[0] {
        FRAME_MSG => {
            let link_seq = read_u64(s)?;
            let t = read_u64(s)?;
            let seq = read_u32(s)?;
            let len = read_u64(s)?;
            let payload = read_payload(s, len, "msg")?;
            let msg = Message::decode(&payload)
                .map_err(|e| format!("bad frame payload: {e}"))?;
            Ok(Some(RawFrame::Seq { link_seq, ev: TcpEvent::Msg { from, t, seq, msg } }))
        }
        FRAME_WATERMARK => {
            let link_seq = read_u64(s)?;
            let len = read_u64(s)?;
            let encoded = read_payload(s, len, "watermark")?;
            let wm = Watermark::decode(&encoded)
                .map_err(|e| format!("bad watermark frame: {e}"))?;
            // link identity check: a watermark must announce progress of
            // the node on the far end of this very link
            if wm.node as usize != from {
                return Err(format!(
                    "watermark names node {} on the link from {from}",
                    wm.node
                ));
            }
            let ev = match wm.kind {
                WatermarkKind::RoundComplete => TcpEvent::End { from, t: wm.round },
                WatermarkKind::Stats { hop, payload } => {
                    TcpEvent::Stats { from, t: wm.round, hop, payload }
                }
            };
            Ok(Some(RawFrame::Seq { link_seq, ev }))
        }
        FRAME_NACK => {
            let mut b = [0u8; 16];
            s.read_exact(&mut b)
                .map_err(|_| "truncated nack frame".to_string())?;
            let nack = Nack::decode(&b).map_err(|e| format!("bad nack frame: {e}"))?;
            Ok(Some(RawFrame::Nack { from_seq: nack.from_seq, to_seq: nack.to_seq }))
        }
        other => Err(format!("unknown frame tag {other:#04x}")),
    }
}

/// Queue one in-order event toward the owning port. Every `RoundComplete`
/// watermark is mirrored into `mark` *after* the inbox push: an observer
/// of `mark >= t + 1` therefore finds every round-`t` frame already
/// queued (per-link FIFO + SeqCst store/load) — the ordering contract
/// `poll_watermarks`/`drain_up_to` relies on. Returns `false` when the
/// port is gone (engine shutdown).
fn deliver(ev: TcpEvent, tx: &Sender<TcpEvent>, mark: &AtomicU64) -> bool {
    let watermark = watermark_of(&ev);
    if tx.send(ev).is_err() {
        return false;
    }
    if let Some(w) = watermark {
        mark.store(w, Ordering::SeqCst);
    }
    true
}

/// The per-neighbor watermark a delivered event advances to, if any —
/// shared between [`deliver`]'s mark store and the reader loop's
/// `watermark-advance` control event.
fn watermark_of(ev: &TcpEvent) -> Option<u64> {
    match ev {
        TcpEvent::End { t, .. } => Some(t + 1),
        _ => None,
    }
}

/// A reader thread's identity and event plumbing: which node it reads
/// for, plus the transport-wide control-plane event hub (inert until
/// telemetry installs a sink).
struct ReaderSide {
    me: usize,
    hub: Arc<EventHub>,
}

impl ReaderSide {
    /// Emit one control-plane event stamped with this link's endpoints.
    fn emit(&self, kind: EventKind, from: usize, f: impl FnOnce(RunEvent) -> RunEvent) {
        self.hub.with(|es| {
            es.emit(f(RunEvent::new(kind).node(self.me as u32).peer(from as u32)));
        });
    }
}

/// Per-link reader: decode frames, run the receive side of the reliable
/// link layer, and queue in-order events into the owning node's inbox
/// until the link closes (clean EOF and errors both surface as `Closed`;
/// the port only treats `Closed` as fatal if it is still waiting on the
/// link, so engine teardown stays silent).
///
/// Link-layer state per direction: `next_expected` is the next in-order
/// sequence number; frames below it (or already buffered) are duplicates
/// and are discarded with a `dedups` count; frames above it open a gap —
/// buffered out-of-order, with a NACK for the missing range sent at most
/// once per sequence number (`nacked_up_to`). Incoming NACKs are
/// serviced against this side's shared [`LinkWriter`].
fn reader_loop(
    mut stream: TcpStream,
    from: usize,
    tx: Sender<TcpEvent>,
    mark: Arc<AtomicU64>,
    writer: Arc<Mutex<LinkWriter>>,
    counters: Arc<LinkCounters>,
    side: ReaderSide,
) {
    let mut next_expected: u64 = 0;
    let mut nacked_up_to: u64 = 0;
    let mut ooo: BTreeMap<u64, TcpEvent> = BTreeMap::new();
    loop {
        let raw = match read_frame(&mut stream, from) {
            Ok(Some(raw)) => raw,
            Ok(None) => {
                let reason = "connection closed".to_string();
                side.emit(EventKind::LinkClosed, from, |e| e.detail(reason.clone()));
                let _ = tx.send(TcpEvent::Closed { from, reason });
                return;
            }
            Err(reason) => {
                side.emit(EventKind::LinkClosed, from, |e| e.detail(reason.clone()));
                let _ = tx.send(TcpEvent::Closed { from, reason });
                return;
            }
        };
        match raw {
            RawFrame::Nack { from_seq, to_seq } => {
                let res =
                    lock_writer(&writer).and_then(|mut w| w.retransmit(from_seq, to_seq));
                if let Err(reason) = res {
                    side.emit(EventKind::LinkClosed, from, |e| e.detail(reason.clone()));
                    let _ = tx.send(TcpEvent::Closed { from, reason });
                    return;
                }
            }
            RawFrame::Seq { link_seq, ev } => {
                if link_seq < next_expected || ooo.contains_key(&link_seq) {
                    counters.dedups.fetch_add(1, Ordering::Relaxed);
                    side.emit(EventKind::Dedup, from, |e| e.seq(link_seq));
                    continue;
                }
                if link_seq > next_expected {
                    // gap: request whatever is missing and not yet asked
                    // for (over-requesting a buffered frame is fine — the
                    // retransmit dedups on arrival), then buffer
                    if link_seq > nacked_up_to {
                        let lo = next_expected.max(nacked_up_to);
                        let res =
                            lock_writer(&writer).and_then(|mut w| w.write_nack(lo, link_seq));
                        if let Err(reason) = res {
                            side.emit(EventKind::LinkClosed, from, |e| {
                                e.detail(reason.clone())
                            });
                            let _ = tx.send(TcpEvent::Closed { from, reason });
                            return;
                        }
                        side.emit(EventKind::NackSent, from, |e| {
                            e.seq(lo).detail(format!("gap [{lo}, {link_seq})"))
                        });
                        nacked_up_to = link_seq;
                    }
                    ooo.insert(link_seq, ev);
                    continue;
                }
                // in-order: deliver, then drain buffered successors
                let adv = watermark_of(&ev);
                if !deliver(ev, &tx, &mark) {
                    return;
                }
                if let Some(w) = adv {
                    side.emit(EventKind::WatermarkAdvance, from, |e| e.round(w));
                }
                next_expected += 1;
                while let Some(ev) = ooo.remove(&next_expected) {
                    let adv = watermark_of(&ev);
                    if !deliver(ev, &tx, &mark) {
                        return;
                    }
                    if let Some(w) = adv {
                        side.emit(EventKind::WatermarkAdvance, from, |e| e.round(w));
                    }
                    next_expected += 1;
                }
            }
        }
    }
}

// --- CLI/config-level constructors -----------------------------------------

/// Parse a hosted-node spec: `""` = all `n` nodes, otherwise
/// comma-separated indices and inclusive ranges (`"0-3"`, `"0,2,5"`).
pub fn parse_hosted(spec: &str, n: usize) -> Result<Vec<usize>, String> {
    let spec = spec.trim();
    if spec.is_empty() {
        return Ok((0..n).collect());
    }
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        if let Some((lo, hi)) = part.split_once('-') {
            let lo: usize =
                lo.trim().parse().map_err(|_| format!("bad hosted range {part:?}"))?;
            let hi: usize =
                hi.trim().parse().map_err(|_| format!("bad hosted range {part:?}"))?;
            if lo > hi {
                return Err(format!("empty hosted range {part:?}"));
            }
            // bound BEFORE materializing: a typo'd range must error, not
            // allocate billions of indices
            if hi >= n {
                return Err(format!("hosted node {hi} out of range (N = {n})"));
            }
            out.extend(lo..=hi);
        } else {
            let v: usize =
                part.parse().map_err(|_| format!("bad hosted node {part:?}"))?;
            if v >= n {
                return Err(format!("hosted node {v} out of range (N = {n})"));
            }
            out.push(v);
        }
    }
    out.sort_unstable();
    out.dedup();
    if out.is_empty() {
        return Err("empty hosted spec".to_string());
    }
    Ok(out)
}

/// Parse a peers spec: comma-separated `node=host:port` entries.
pub fn parse_peers(spec: &str) -> Result<HashMap<usize, String>, String> {
    let mut map = HashMap::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (node, addr) = part
            .split_once('=')
            .ok_or_else(|| format!("bad peer entry {part:?} (want node=host:port)"))?;
        let node: usize =
            node.trim().parse().map_err(|_| format!("bad peer node in {part:?}"))?;
        if addr.trim().is_empty() {
            return Err(format!("empty peer address in {part:?}"));
        }
        if map.insert(node, addr.trim().to_string()).is_some() {
            return Err(format!("duplicate peer entry for node {node}"));
        }
    }
    Ok(map)
}

/// Validate CLI/config-level TCP specs against a topology without
/// opening any socket: parses both specs and checks that every
/// non-hosted neighbor of a hosted node has a peer address — the same
/// precondition [`TcpTransport::establish`] enforces, surfaced early on
/// the clean error path.
pub fn validate_tcp_spec(
    topo: &Topology,
    hosted_spec: &str,
    peers_spec: &str,
) -> Result<(), String> {
    let hosted = parse_hosted(hosted_spec, topo.n)?;
    let peers = parse_peers(peers_spec)?;
    for &n in &hosted {
        for &m in topo.neighbors(n) {
            if hosted.binary_search(&m).is_err() && !peers.contains_key(&m) {
                return Err(format!(
                    "neighbor {m} of hosted node {n} has no peer address \
                     (pass it via --peers {m}=host:port)"
                ));
            }
        }
    }
    Ok(())
}

/// Build a TCP transport from CLI/config-level strings: empty `hosted`
/// hosts every node (single-process loopback run), empty `listen` binds
/// an ephemeral loopback port.
pub fn tcp_from_spec(
    topo: &Topology,
    seed: u64,
    hosted_spec: &str,
    listen: &str,
    peers_spec: &str,
) -> Result<TcpTransport, String> {
    let hosted = parse_hosted(hosted_spec, topo.n)?;
    let peers = parse_peers(peers_spec)?;
    let listen = if listen.trim().is_empty() { "127.0.0.1:0" } else { listen.trim() };
    let listener = TcpTransport::bind(listen)?;
    TcpTransport::establish(listener, topo, seed, hosted, &peers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::RelayDelta;
    use crate::linalg::SparseVec;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("LOCAL"), Some(TransportKind::Local));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }

    #[test]
    fn drain_timeout_parsing() {
        // unset: default, no diagnostic
        let (t, w) = parse_drain_timeout(None);
        assert_eq!(t, DRAIN_TIMEOUT_DEFAULT);
        assert!(w.is_none());
        // valid override
        let (t, w) = parse_drain_timeout(Some("45"));
        assert_eq!(t, Duration::from_secs(45));
        assert!(w.is_none());
        let (t, _) = parse_drain_timeout(Some(" 7 "));
        assert_eq!(t, Duration::from_secs(7));
        // zero: rejected with a warning, never a zero-duration timeout
        let (t, w) = parse_drain_timeout(Some("0"));
        assert_eq!(t, DRAIN_TIMEOUT_DEFAULT);
        assert!(w.unwrap().contains("DSBA_DRAIN_TIMEOUT_SECS=0"));
        // garbage: default plus a warning, not a silent fallback
        for bad in ["ten", "-3", "1.5", ""] {
            let (t, w) = parse_drain_timeout(Some(bad));
            assert_eq!(t, DRAIN_TIMEOUT_DEFAULT, "{bad:?}");
            assert!(w.unwrap().contains("not a number"), "{bad:?}");
        }
    }

    #[test]
    fn hosted_spec_parses() {
        assert_eq!(parse_hosted("", 4).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(parse_hosted("0-2", 4).unwrap(), vec![0, 1, 2]);
        assert_eq!(parse_hosted("3,1,1", 4).unwrap(), vec![1, 3]);
        assert_eq!(parse_hosted("0,2-3", 4).unwrap(), vec![0, 2, 3]);
        assert!(parse_hosted("4", 4).is_err());
        assert!(parse_hosted("2-1", 4).is_err());
        assert!(parse_hosted("x", 4).is_err());
        assert!(parse_hosted(",", 4).is_err());
        // a typo'd range must error before materializing anything
        assert!(parse_hosted("0-4000000000", 6).is_err());
    }

    #[test]
    fn peers_spec_parses() {
        assert!(parse_peers("").unwrap().is_empty());
        let p = parse_peers("3=127.0.0.1:9001, 4=10.0.0.2:9001").unwrap();
        assert_eq!(p[&3], "127.0.0.1:9001");
        assert_eq!(p[&4], "10.0.0.2:9001");
        assert!(parse_peers("3").is_err());
        assert!(parse_peers("3=").is_err());
        assert!(parse_peers("3=a,3=b").is_err());
    }

    #[test]
    fn local_ports_deliver_within_a_round() {
        let t = Box::new(LocalTransport::new(3));
        assert_eq!(t.hosted(), &[0, 1, 2]);
        let mut ports = t.into_ports();
        ports[0].send(0, 1, 0, Message::dense(vec![1.0])).unwrap();
        ports[2].send(0, 1, 0, Message::dense(vec![2.0])).unwrap();
        ports[0].finish_round(0).unwrap();
        ports[2].finish_round(0).unwrap();
        let mut got = ports[1].drain_round(0).unwrap();
        got.sort_by_key(|&(from, seq, _)| (from, seq));
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 0);
        assert_eq!(got[1].0, 2);
        assert!(ports[0].drain_round(0).unwrap().is_empty());
    }

    #[test]
    fn tcp_loopback_ports_roundtrip_all_payload_families() {
        let topo = Topology::ring(3); // everyone neighbors everyone
        let t = Box::new(TcpTransport::loopback(&topo, 7).unwrap());
        assert_eq!(t.hosted(), &[0, 1, 2]);
        let mut ports = t.into_ports();
        let dense = Message::dense(vec![0.5, -0.0, 3.25]);
        let sparse = Message::Sparse(RelayDelta {
            src: 2,
            t: 0,
            vec: SparseVec::from_pairs(10, vec![(1, 1.5), (7, -2.0)]),
            tail: vec![9.0],
        });
        let comp = Message::Comp(Arc::new(crate::comm::CompressedVec {
            dim: 6,
            idx: vec![1, 4],
            val: vec![-0.75, 2.5],
            bytes: 24,
        }));
        ports[0].send(0, 1, 0, dense.clone()).unwrap();
        ports[2].send(0, 1, 0, sparse.clone()).unwrap();
        // send the same Arc twice to exercise the COMP encode cache
        ports[2].send(0, 1, 1, comp.clone()).unwrap();
        ports[2].send(0, 0, 2, comp.clone()).unwrap();
        for p in ports.iter_mut() {
            p.finish_round(0).unwrap();
        }
        let mut got = ports[1].drain_round(0).unwrap();
        got.sort_by_key(|&(from, seq, _)| (from, seq));
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].2, dense);
        // bit-exactness beyond PartialEq
        assert_eq!(got[0].2.encode(), dense.encode());
        assert_eq!(got[1].2, sparse);
        assert_eq!(got[2].2, comp);
        assert_eq!(got[2].2.encode(), comp.encode());
        let got0 = ports[0].drain_round(0).unwrap();
        assert_eq!(got0.len(), 1);
        assert_eq!(got0[0].2, comp);
        assert!(ports[2].drain_round(0).unwrap().is_empty());
    }

    #[test]
    fn tcp_drain_carries_early_next_round_frames() {
        let topo = Topology::path(3); // 1 neighbors {0, 2}
        let t = Box::new(TcpTransport::loopback(&topo, 1).unwrap());
        let mut ports = t.into_ports();
        // node 0 races two rounds ahead before node 1 drains anything
        ports[0].send(0, 1, 0, Message::dense(vec![1.0])).unwrap();
        ports[0].finish_round(0).unwrap();
        ports[0].send(1, 1, 0, Message::dense(vec![2.0])).unwrap();
        ports[0].finish_round(1).unwrap();
        for t in 0..2 {
            ports[2].finish_round(t).unwrap();
        }
        let r0 = ports[1].drain_round(0).unwrap();
        assert_eq!(r0.len(), 1);
        assert_eq!(r0[0].2, Message::dense(vec![1.0]));
        let r1 = ports[1].drain_round(1).unwrap();
        assert_eq!(r1.len(), 1);
        assert_eq!(r1[0].2, Message::dense(vec![2.0]));
    }

    #[test]
    fn local_watermarks_gate_staleness_aware_drains() {
        let t = Box::new(LocalTransport::new(2));
        let mut ports = t.into_ports();
        // nothing emitted yet: all watermarks zero
        assert!(ports[1].poll_watermarks().unwrap().iter().all(|&(_, w)| w == 0));
        ports[0].send(0, 1, 0, Message::dense(vec![1.0])).unwrap();
        ports[0].finish_round(0).unwrap();
        let wm = ports[1].poll_watermarks().unwrap();
        assert!(wm.contains(&(0, 1)), "{wm:?}");
        let got = ports[1].drain_up_to(0).unwrap();
        assert_eq!(got, vec![(0, 0, 0, Message::dense(vec![1.0]))]);
        assert!(ports[1].drain_up_to(0).unwrap().is_empty());
    }

    #[test]
    fn tcp_watermarks_report_progress_out_of_order_with_drains() {
        let topo = Topology::path(3); // 1 neighbors {0, 2}
        let t = Box::new(TcpTransport::loopback(&topo, 9).unwrap());
        let mut ports = t.into_ports();
        // node 0 races three rounds ahead before node 1 drains anything:
        // its watermarks arrive "out of order" with respect to node 1's
        // consumption, which must still be round-bounded
        for r in 0..3usize {
            ports[0].send(r, 1, 0, Message::dense(vec![r as f64])).unwrap();
            ports[0].finish_round(r).unwrap();
        }
        // poll until the reader thread has seen all three watermarks
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let wm = ports[1].poll_watermarks().unwrap();
            let w0 = wm.iter().find(|&&(m, _)| m == 0).unwrap().1;
            if w0 == 3 {
                // node 2 never emitted: its watermark must still be 0
                assert!(wm.contains(&(2, 0)), "{wm:?}");
                break;
            }
            assert!(Instant::now() < deadline, "watermark never reached 3: {wm:?}");
            std::thread::sleep(Duration::from_millis(1));
        }
        // watermark = 3 guarantees rounds 0..2 are already drainable,
        // but a drain bounded at round 1 must hold round 2 back
        let r01 = ports[1].drain_up_to(1).unwrap();
        assert_eq!(
            r01,
            vec![
                (0, 0, 0, Message::dense(vec![0.0])),
                (0, 1, 0, Message::dense(vec![1.0])),
            ]
        );
        let r2 = ports[1].drain_up_to(2).unwrap();
        assert_eq!(r2, vec![(0, 2, 0, Message::dense(vec![2.0]))]);
        assert!(ports[1].drain_up_to(5).unwrap().is_empty());
    }

    #[test]
    fn tcp_stats_frames_cross_links_and_interleave_with_rounds() {
        let topo = Topology::path(2);
        let t = Box::new(TcpTransport::loopback(&topo, 3).unwrap());
        let mut ports = t.into_ports();
        // round 0 traffic plus an early stats frame from node 0: the
        // stats frame must be carried across the drain, not lost
        ports[0].send(0, 1, 0, Message::dense(vec![1.0])).unwrap();
        ports[0].finish_round(0).unwrap();
        ports[1].finish_round(0).unwrap();
        ports[0].send_stats(1, 0, 1, b"rows-hop0").unwrap();
        let r0 = ports[1].drain_round(0).unwrap();
        assert_eq!(r0.len(), 1);
        assert!(ports[0].drain_round(0).unwrap().is_empty());
        assert_eq!(ports[1].recv_stats(1, 0, 0).unwrap(), b"rows-hop0");
        // hops are matched exactly, both directions cross the same link
        ports[1].send_stats(1, 1, 0, b"rows-hop1-b").unwrap();
        ports[0].send_stats(1, 1, 1, b"rows-hop1-a").unwrap();
        assert_eq!(ports[1].recv_stats(1, 1, 0).unwrap(), b"rows-hop1-a");
        assert_eq!(ports[0].recv_stats(1, 1, 1).unwrap(), b"rows-hop1-b");
        // the round channel still works after the exchange
        ports[1].send(1, 0, 0, Message::dense(vec![2.0])).unwrap();
        ports[1].finish_round(1).unwrap();
        ports[0].finish_round(1).unwrap();
        let r1 = ports[0].drain_round(1).unwrap();
        assert_eq!(r1.len(), 1);
        assert_eq!(r1[0].2, Message::dense(vec![2.0]));
        assert!(ports[1].drain_round(1).unwrap().is_empty());
    }

    #[test]
    fn local_port_rejects_stats_exchange() {
        let t = Box::new(LocalTransport::new(2));
        let mut ports = t.into_ports();
        assert!(ports[0].send_stats(0, 0, 1, b"x").is_err());
        assert!(ports[0].recv_stats(0, 0, 1).is_err());
    }

    #[test]
    fn tcp_transport_handles_edgeless_topology() {
        let topo = Topology::from_edges(1, &[]);
        let t = Box::new(TcpTransport::loopback(&topo, 5).unwrap());
        let mut ports = t.into_ports();
        ports[0].finish_round(0).unwrap();
        assert!(ports[0].drain_round(0).unwrap().is_empty());
    }

    #[test]
    fn establish_rejects_missing_peer_address() {
        let topo = Topology::path(2);
        let listener = TcpTransport::bind("127.0.0.1:0").unwrap();
        let err = TcpTransport::establish(listener, &topo, 1, vec![0], &HashMap::new())
            .unwrap_err();
        assert!(err.contains("no peer address"), "{err}");
    }

    #[test]
    fn backends_without_a_link_layer_reject_link_faults() {
        let mut t = LocalTransport::new(2);
        let err = t
            .configure_faults(&FaultSpec::parse("drop:0.1").unwrap(), 1)
            .unwrap_err();
        assert!(err.contains("local"), "{err}");
        // engine-level faults (delay/kill) are fine on any transport
        assert!(t.configure_faults(&FaultSpec::parse("delay:5,kill:0@3").unwrap(), 1).is_ok());
        assert!(t.configure_faults(&FaultSpec::none(), 1).is_ok());
        t.set_retain_grace(4); // default no-op
        let ports = Box::new(t).into_ports();
        assert_eq!(ports[0].link_stats(), LinkStats::default());
        assert!(ports[0].counters_handle().is_none());
    }

    /// Exchange one dense message in each direction for `rounds` rounds,
    /// asserting exact delivery each round, and return the summed link
    /// stats of both ports.
    fn run_two_node_rounds(ports: &mut [TcpPort], rounds: usize) -> LinkStats {
        for r in 0..rounds {
            for i in 0..2usize {
                ports[i]
                    .send(r, 1 - i, 0, Message::dense(vec![r as f64, i as f64]))
                    .unwrap();
                ports[i].finish_round(r).unwrap();
            }
            for i in 0..2usize {
                let got = ports[i].drain_round(r).unwrap();
                assert_eq!(got.len(), 1, "round {r}, node {i}");
                assert_eq!(got[0].0, 1 - i);
                assert_eq!(got[0].2, Message::dense(vec![r as f64, (1 - i) as f64]));
            }
        }
        let mut sum = LinkStats::default();
        for p in ports.iter() {
            let s = p.link_stats();
            sum.retransmits += s.retransmits;
            sum.dedups += s.dedups;
            sum.drops_injected += s.drops_injected;
            sum.dups_injected += s.dups_injected;
        }
        sum
    }

    #[test]
    fn link_layer_dedups_duplicated_frames() {
        let topo = Topology::path(2);
        let mut t = TcpTransport::loopback(&topo, 21).unwrap();
        t.configure_faults(&FaultSpec::parse("dup:0.9").unwrap(), 21).unwrap();
        let mut ports = t.ports;
        let stats = run_two_node_rounds(&mut ports, 10);
        // 20 MSG frames at dup:0.9 — duplicates fired and were discarded
        assert!(stats.dups_injected > 0, "{stats:?}");
        assert!(stats.dedups >= stats.dups_injected, "{stats:?}");
        assert_eq!(stats.drops_injected, 0, "{stats:?}");
    }

    #[test]
    fn link_layer_recovers_dropped_frames_via_nack() {
        let topo = Topology::path(2);
        let mut t = TcpTransport::loopback(&topo, 33).unwrap();
        t.configure_faults(&FaultSpec::parse("drop:0.5").unwrap(), 33).unwrap();
        let mut ports = t.ports;
        // every round still delivers exactly — the sequenced end-of-round
        // watermark exposes each dropped MSG frame and a NACK recovers it
        let stats = run_two_node_rounds(&mut ports, 10);
        assert!(stats.drops_injected > 0, "{stats:?}");
        assert!(stats.retransmits >= stats.drops_injected, "{stats:?}");
    }

    #[test]
    fn mixed_drop_dup_faults_stay_lossless() {
        let topo = Topology::path(2);
        let mut t = TcpTransport::loopback(&topo, 5).unwrap();
        t.configure_faults(&FaultSpec::parse("drop:0.2,dup:0.2").unwrap(), 5).unwrap();
        let mut ports = t.ports;
        let stats = run_two_node_rounds(&mut ports, 20);
        // 40 MSG frames at 20%/20%: overwhelmingly likely both fired
        assert!(stats.drops_injected + stats.dups_injected > 0, "{stats:?}");
    }

    #[test]
    fn retention_stays_bounded_as_watermarks_advance() {
        let topo = Topology::path(2);
        let t = TcpTransport::loopback(&topo, 11).unwrap();
        let mut ports = t.ports;
        run_two_node_rounds(&mut ports, 12);
        // wait until node 0 has observed node 1's final watermark (the
        // reader stores it just after queueing the END), then one more
        // write triggers a prune against it
        let deadline = Instant::now() + Duration::from_secs(30);
        while ports[0].marks[0].load(Ordering::SeqCst) < 12 {
            assert!(Instant::now() < deadline, "watermark never advanced");
            std::thread::sleep(Duration::from_millis(1));
        }
        ports[0].send(12, 1, 0, Message::dense(vec![0.0])).unwrap();
        let retained: usize = ports[0]
            .writers
            .iter()
            .map(|(_, w)| w.lock().unwrap().retained.len())
            .sum();
        // mark 12 prunes rounds <= 10: rounds 11 (MSG + WATERMARK each)
        // and the fresh round-12 MSG remain — not 25 frames of history
        assert!(retained >= 1 && retained <= 5, "retained {retained} frames");
    }

    #[test]
    fn nack_for_pruned_frames_fails_the_link_with_a_diagnostic() {
        let topo = Topology::path(2);
        let t = TcpTransport::loopback(&topo, 13).unwrap();
        let mut ports = t.ports;
        run_two_node_rounds(&mut ports, 1);
        // forge a NACK (from node 0) for a frame node 1 never sent: node
        // 1's retransmit path must close the link with a named
        // diagnostic, not panic — the Closed event surfaces on node 1's
        // inbox, naming node 1 and its peer
        {
            let mut w = ports[0].writers[0].1.lock().unwrap();
            w.write_nack(7, 9).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        let reason = loop {
            match ports[1].inbox.recv_timeout(Duration::from_millis(100)) {
                Ok(TcpEvent::Closed { reason, .. }) => break reason,
                Ok(_) => continue,
                Err(_) => assert!(Instant::now() < deadline, "link never closed"),
            }
        };
        assert!(reason.contains("nacked unsent frame"), "{reason}");
        assert!(reason.contains("node 1") && reason.contains("peer 0"), "{reason}");
    }
}
