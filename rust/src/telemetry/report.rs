//! Run analysis over a telemetry stream, and bench snapshot diffing.
//!
//! Two consumers live here:
//!
//! - [`RunReport`] (`dsba report <run.jsonl>`) turns a JSONL stream into
//!   answers: a fitted geometric convergence rate from the residual
//!   series, a per-node phase breakdown (where each node's round time
//!   went), straggler attribution (whose `wait` dominated, cross-
//!   referenced with staleness and link-fault counters), and the
//!   bytes-vs-DOUBLEs communication budget per round.
//! - [`bench_compare`] (`dsba bench-compare <old> <new> --tol PCT`)
//!   diffs two `results/BENCH_*.json` snapshots cell by cell and flags
//!   metric regressions beyond a tolerance — the perf-trajectory gate CI
//!   runs against the committed snapshots.
//!
//! Both read the hand-rolled [`Json`] value type, so they work on any
//! stream or snapshot this crate (or a prior schema version of it)
//! wrote. Accounting caveat worth knowing when reading budgets: a row's
//! `bytes_on_wire` counts both the node's sends and its receives, so
//! fleet byte totals count each intra-engine message twice — the
//! per-round budget reports it as-is and prices bytes against
//! sent + received DOUBLEs to keep the ratio honest.

use super::events::{EventKind, RunEvent};
use super::schema::{TelemetryLine, TelemetryRow, TelemetrySummary};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Stream-level accounting for `dsba telemetry-check`: row/node/round
/// counts, round gaps (rotation ate the middle of a run), cumulative
/// fault-counter totals, and the writer's trailing summary when present.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StreamSummary {
    /// Data rows in the stream.
    pub rows: usize,
    /// Distinct reporting nodes, ascending.
    pub nodes: Vec<u32>,
    /// Smallest round seen (0 when the stream is empty).
    pub round_min: u64,
    /// Largest round seen (0 when the stream is empty).
    pub round_max: u64,
    /// Distinct rounds seen.
    pub rounds_seen: usize,
    /// Rounds in `round_min..=round_max` with no row at all (listing
    /// capped at 10 000 entries so a corrupt round number cannot make
    /// summarization unbounded).
    pub missing_rounds: Vec<u64>,
    /// Fleet totals of the cumulative per-node counters, summed over
    /// each node's last row.
    pub stalls: u64,
    pub retransmits: u64,
    pub dedups: u64,
    pub drops_injected: u64,
    pub dups_injected: u64,
    /// Control-plane event lines interleaved with the rows.
    pub events: usize,
    /// True when the stream ends in a partial line (the tail a crashed
    /// run leaves behind); tolerated, not fatal.
    pub truncated_tail: bool,
    /// The writer's trailing summary line, when the stream has one.
    pub writer: Option<TelemetrySummary>,
}

impl StreamSummary {
    /// Parse and summarize a whole stream. Malformed lines fail, naming
    /// the line — except a truncated final line, which is tolerated and
    /// reported through [`StreamSummary::truncated_tail`]; lines with a
    /// `kind` this build does not know are skipped (forward compat).
    pub fn from_stream(text: &str) -> Result<StreamSummary, String> {
        Ok(StreamSummary::from_parsed(&parse_stream_lenient(text)?))
    }

    fn from_parsed(ps: &ParsedStream) -> StreamSummary {
        let rows = &ps.rows;
        let mut s = StreamSummary {
            rows: rows.len(),
            events: ps.events.len(),
            truncated_tail: ps.truncated_tail,
            writer: ps.writer.clone(),
            ..StreamSummary::default()
        };
        if rows.is_empty() {
            return s;
        }
        let mut rounds: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
        // the link/stall counters are cumulative per node: the node's
        // last row carries its total
        let mut last: BTreeMap<u32, &TelemetryRow> = BTreeMap::new();
        for r in rows {
            rounds.insert(r.round);
            match last.entry(r.node) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(r);
                }
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    if r.round >= e.get().round {
                        e.insert(r);
                    }
                }
            }
        }
        s.nodes = last.keys().copied().collect();
        s.round_min = *rounds.iter().next().unwrap();
        s.round_max = *rounds.iter().next_back().unwrap();
        s.rounds_seen = rounds.len();
        // walk gaps between consecutive seen rounds, capped so a corrupt
        // round number cannot make the scan unbounded
        const MISSING_CAP: usize = 10_000;
        let seen: Vec<u64> = rounds.iter().copied().collect();
        'gaps: for w in seen.windows(2) {
            let mut t = w[0] + 1;
            while t < w[1] {
                s.missing_rounds.push(t);
                if s.missing_rounds.len() >= MISSING_CAP {
                    break 'gaps;
                }
                t += 1;
            }
        }
        for r in last.values() {
            s.stalls += r.stalls;
            s.retransmits += r.retransmits;
            s.dedups += r.dedups;
            s.drops_injected += r.drops_injected;
            s.dups_injected += r.dups_injected;
        }
        s
    }
}

/// Everything a lenient pass over a stream yields: the data rows, the
/// control-plane events, the trailing writer summary, plus what had to
/// be tolerated to get there.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParsedStream {
    pub rows: Vec<TelemetryRow>,
    pub events: Vec<RunEvent>,
    /// Last writer summary wins if rotation left several.
    pub writer: Option<TelemetrySummary>,
    /// The final line was partial (no trailing newline) and unparsable.
    pub truncated_tail: bool,
    /// Well-formed lines whose `kind` this build does not know.
    pub skipped_unknown: usize,
}

/// Parse every line of a stream, tolerating a truncated final line and
/// skipping unknown `kind` lines so event-bearing (or newer) streams
/// replay through older consumers. Any other malformed line fails,
/// naming the line (1-based).
pub fn parse_stream_lenient(text: &str) -> Result<ParsedStream, String> {
    let mut ps = ParsedStream::default();
    let lines: Vec<(usize, &str)> = text.lines().enumerate().collect();
    let last_idx = lines
        .iter()
        .rev()
        .find(|(_, l)| !l.trim().is_empty())
        .map(|(i, _)| *i);
    for (i, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        match TelemetryLine::parse_lenient(line) {
            Ok(Some(TelemetryLine::Row(r))) => ps.rows.push(r),
            Ok(Some(TelemetryLine::Summary(s))) => ps.writer = Some(s),
            Ok(Some(TelemetryLine::Event(e))) => ps.events.push(e),
            Ok(None) => ps.skipped_unknown += 1,
            Err(e) => {
                if Some(i) == last_idx && !text.ends_with('\n') {
                    ps.truncated_tail = true;
                } else {
                    return Err(format!("line {}: {e}", i + 1));
                }
            }
        }
    }
    Ok(ps)
}

/// Parse every line of a stream into data rows plus the optional
/// trailing writer summary (last one wins if rotation left several).
/// Event and unknown-kind lines are skipped; see
/// [`parse_stream_lenient`] for the full picture.
pub fn parse_stream(
    text: &str,
) -> Result<(Vec<TelemetryRow>, Option<TelemetrySummary>), String> {
    let ps = parse_stream_lenient(text)?;
    Ok((ps.rows, ps.writer))
}

/// Least-squares geometric fit of the round-mean residual series:
/// `residual(t) ~ c * rate^t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConvergenceFit {
    /// Fitted per-round contraction factor (`< 1` means converging).
    pub rate: f64,
    /// Rounds for the residual to halve (infinite when `rate >= 1`).
    pub half_life: f64,
    /// Rounds with a positive mean residual used in the fit.
    pub points: usize,
}

/// One node's totals over the stream.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NodeBreakdown {
    pub node: u32,
    /// Rows (= reported rounds) from this node.
    pub rounds: u64,
    /// Phase-span totals in microseconds (all zero on a v1 stream).
    pub wait_micros: u64,
    pub drain_micros: u64,
    pub compute_micros: u64,
    pub encode_micros: u64,
    pub send_micros: u64,
    /// Total reported wall time in microseconds.
    pub wall_micros: u64,
    /// Worst staleness this node consumed.
    pub max_staleness: u64,
    /// Cumulative counters from the node's last row.
    pub stalls: u64,
    pub retransmits: u64,
    pub dedups: u64,
    pub drops_injected: u64,
    pub dups_injected: u64,
}

impl NodeBreakdown {
    /// Sum of the five attributed phase spans.
    pub fn attributed_micros(&self) -> u64 {
        self.wait_micros
            + self.drain_micros
            + self.compute_micros
            + self.encode_micros
            + self.send_micros
    }
}

/// Straggler/stall attribution: whose `wait` dominated, and what the
/// counters say about why.
#[derive(Clone, Debug, PartialEq)]
pub struct Straggler {
    /// Node with the largest total `wait` span.
    pub wait_node: u32,
    /// That node's share of the fleet's total wait, in percent.
    pub wait_share_pct: f64,
    /// Node with the largest total `compute` span — the likely cause
    /// everyone else waited on.
    pub slow_node: u32,
}

/// Control-plane event counts for one directed link `node -> peer`,
/// mined from the stream's event lines. This is the causal side of
/// straggler attribution: the row counters say *how many* retransmits a
/// node's ports performed, the link events say *which link*.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkEventCount {
    pub node: u32,
    pub peer: u32,
    pub retransmits: u64,
    pub dedups: u64,
    pub nacks_sent: u64,
}

/// The full `dsba report` analysis of one telemetry stream.
#[derive(Clone, Debug, PartialEq)]
pub struct RunReport {
    pub summary: StreamSummary,
    pub convergence: Option<ConvergenceFit>,
    /// Per-node breakdowns, ascending by node id.
    pub per_node: Vec<NodeBreakdown>,
    /// `None` when the stream has no wait spans at all (v1 rows).
    pub straggler: Option<Straggler>,
    /// Per-link retransmit/dedup/NACK counts, ascending by (node, peer);
    /// empty when the stream carries no link-scoped events.
    pub link_events: Vec<LinkEventCount>,
    /// Per-round communication budget, averaged over seen rounds.
    pub doubles_sent_per_round: f64,
    pub doubles_recv_per_round: f64,
    pub bytes_per_round: f64,
    /// Wire bytes per moved DOUBLE (sent + received); 8.0 means dense
    /// uncompressed doubles.
    pub bytes_per_double: f64,
}

impl RunReport {
    /// Analyze a whole stream. Fails on malformed lines or an empty
    /// stream (an empty run has nothing to report); a truncated final
    /// line and unknown `kind` lines are tolerated.
    pub fn from_stream(text: &str) -> Result<RunReport, String> {
        let ps = parse_stream_lenient(text)?;
        let rows = &ps.rows;
        if rows.is_empty() {
            return Err("telemetry stream has no data rows".to_string());
        }
        let summary = StreamSummary::from_parsed(&ps);
        let convergence = fit_rate(rows);

        let mut by_node: BTreeMap<u32, NodeBreakdown> = BTreeMap::new();
        let mut last_round: BTreeMap<u32, u64> = BTreeMap::new();
        for r in rows.iter() {
            let b = by_node.entry(r.node).or_insert(NodeBreakdown {
                node: r.node,
                ..NodeBreakdown::default()
            });
            b.rounds += 1;
            b.wait_micros += r.wait_micros;
            b.drain_micros += r.drain_micros;
            b.compute_micros += r.compute_micros;
            b.encode_micros += r.encode_micros;
            b.send_micros += r.send_micros;
            b.wall_micros += r.wall_micros;
            b.max_staleness = b.max_staleness.max(r.staleness);
            let lr = last_round.entry(r.node).or_insert(0);
            if r.round >= *lr {
                *lr = r.round;
                b.stalls = r.stalls;
                b.retransmits = r.retransmits;
                b.dedups = r.dedups;
                b.drops_injected = r.drops_injected;
                b.dups_injected = r.dups_injected;
            }
        }
        let per_node: Vec<NodeBreakdown> = by_node.into_values().collect();

        let fleet_wait: u64 = per_node.iter().map(|b| b.wait_micros).sum();
        let straggler = if fleet_wait == 0 {
            None
        } else {
            let wait_top = per_node.iter().max_by_key(|b| b.wait_micros).unwrap();
            let slow_top = per_node.iter().max_by_key(|b| b.compute_micros).unwrap();
            Some(Straggler {
                wait_node: wait_top.node,
                wait_share_pct: wait_top.wait_micros as f64 / fleet_wait as f64 * 100.0,
                slow_node: slow_top.node,
            })
        };

        let rounds = summary.rounds_seen.max(1) as f64;
        let sent: f64 = rows.iter().map(|r| r.doubles_sent).sum();
        let recv: f64 = rows.iter().map(|r| r.doubles_recv).sum();
        let bytes: f64 = rows.iter().map(|r| r.bytes_on_wire as f64).sum();
        let moved = sent + recv;
        Ok(RunReport {
            summary,
            convergence,
            per_node,
            straggler,
            link_events: fold_link_events(&ps.events),
            doubles_sent_per_round: sent / rounds,
            doubles_recv_per_round: recv / rounds,
            bytes_per_round: bytes / rounds,
            bytes_per_double: if moved > 0.0 { bytes / moved } else { 0.0 },
        })
    }

    /// Human-readable report (the default `dsba report` output).
    pub fn render_text(&self) -> String {
        let s = &self.summary;
        let mut out = String::new();
        out.push_str("run report\n");
        out.push_str(&format!(
            "  rows: {} over {} node(s), rounds {}..={} ({} seen, {} missing)\n",
            s.rows,
            s.nodes.len(),
            s.round_min,
            s.round_max,
            s.rounds_seen,
            s.missing_rounds.len()
        ));
        match &s.writer {
            Some(w) => out.push_str(&format!(
                "  writer: {} rows written, {} dropped\n",
                w.rows_written, w.rows_dropped
            )),
            None => out.push_str("  writer: no summary line (stream truncated or pre-v2)\n"),
        }
        if s.truncated_tail {
            out.push_str("  stream: truncated final line tolerated (crashed run?)\n");
        }
        match &self.convergence {
            Some(f) if f.rate < 1.0 => out.push_str(&format!(
                "  convergence: residual contracts {:.4}x/round \
                 (half-life {:.1} rounds, {}-point fit)\n",
                f.rate, f.half_life, f.points
            )),
            Some(f) => out.push_str(&format!(
                "  convergence: no contraction (fitted rate {:.4}/round, {}-point fit)\n",
                f.rate, f.points
            )),
            None => out.push_str(
                "  convergence: no fit (fewer than 2 rounds with positive residual)\n",
            ),
        }
        out.push_str(&format!(
            "  comm budget per round: {:.1} DOUBLEs sent, {:.1} received, \
             {:.1} wire bytes ({:.2} bytes/DOUBLE)\n",
            self.doubles_sent_per_round,
            self.doubles_recv_per_round,
            self.bytes_per_round,
            self.bytes_per_double
        ));

        let attributed: u64 = self.per_node.iter().map(|b| b.attributed_micros()).sum();
        if attributed == 0 {
            out.push_str(
                "phase breakdown: stream carries no phase spans (v1 rows)\n",
            );
            return out;
        }
        out.push_str("phase breakdown (per-node totals, % of attributed time)\n");
        out.push_str(&format!(
            "{:>6} {:>7} {:>7} {:>7} {:>8} {:>7} {:>7} {:>9}\n",
            "node", "rounds", "wait", "drain", "compute", "encode", "send", "wall(ms)"
        ));
        for b in &self.per_node {
            let total = b.attributed_micros().max(1) as f64;
            let pct = |v: u64| v as f64 / total * 100.0;
            out.push_str(&format!(
                "{:>6} {:>7} {:>6.1}% {:>6.1}% {:>7.1}% {:>6.1}% {:>6.1}% {:>9.2}\n",
                b.node,
                b.rounds,
                pct(b.wait_micros),
                pct(b.drain_micros),
                pct(b.compute_micros),
                pct(b.encode_micros),
                pct(b.send_micros),
                b.wall_micros as f64 / 1e3
            ));
        }
        match &self.straggler {
            None => out.push_str("straggler attribution: unavailable (no wait spans)\n"),
            Some(st) => {
                out.push_str("straggler attribution\n");
                out.push_str(&format!(
                    "  wait dominated by node {} ({:.1}% of fleet wait); \
                     slowest compute: node {}\n",
                    st.wait_node, st.wait_share_pct, st.slow_node
                ));
                if let Some(b) = self.per_node.iter().find(|b| b.node == st.wait_node) {
                    out.push_str(&format!(
                        "  node {} counters: max staleness {}, {} stalls, \
                         {} retransmits, {} dedups, {} drops injected, \
                         {} dups injected\n",
                        b.node,
                        b.max_staleness,
                        b.stalls,
                        b.retransmits,
                        b.dedups,
                        b.drops_injected,
                        b.dups_injected
                    ));
                }
                // events make the attribution causal: not just how many
                // retransmits a node performed, but on which link
                for le in &self.link_events {
                    out.push_str(&format!(
                        "  link {}->{}: {} retransmits, {} dedups, \
                         {} nacks sent\n",
                        le.node, le.peer, le.retransmits, le.dedups, le.nacks_sent
                    ));
                }
            }
        }
        out
    }

    /// Machine-readable form (`dsba report --json`).
    pub fn to_json(&self) -> Json {
        let s = &self.summary;
        let writer = match &s.writer {
            Some(w) => Json::from_pairs(vec![
                ("rows_written", Json::Num(w.rows_written as f64)),
                ("rows_dropped", Json::Num(w.rows_dropped as f64)),
            ]),
            None => Json::Null,
        };
        let convergence = match &self.convergence {
            Some(f) => {
                let mut pairs = vec![
                    ("rate", Json::Num(f.rate)),
                    ("points", Json::Num(f.points as f64)),
                ];
                if f.half_life.is_finite() {
                    pairs.push(("half_life_rounds", Json::Num(f.half_life)));
                }
                Json::from_pairs(pairs)
            }
            None => Json::Null,
        };
        let per_node: Vec<Json> = self
            .per_node
            .iter()
            .map(|b| {
                Json::from_pairs(vec![
                    ("node", Json::Num(b.node as f64)),
                    ("rounds", Json::Num(b.rounds as f64)),
                    ("wait_micros", Json::Num(b.wait_micros as f64)),
                    ("drain_micros", Json::Num(b.drain_micros as f64)),
                    ("compute_micros", Json::Num(b.compute_micros as f64)),
                    ("encode_micros", Json::Num(b.encode_micros as f64)),
                    ("send_micros", Json::Num(b.send_micros as f64)),
                    ("wall_micros", Json::Num(b.wall_micros as f64)),
                    ("max_staleness", Json::Num(b.max_staleness as f64)),
                    ("stalls", Json::Num(b.stalls as f64)),
                    ("retransmits", Json::Num(b.retransmits as f64)),
                    ("dedups", Json::Num(b.dedups as f64)),
                    ("drops_injected", Json::Num(b.drops_injected as f64)),
                    ("dups_injected", Json::Num(b.dups_injected as f64)),
                ])
            })
            .collect();
        let straggler = match &self.straggler {
            Some(st) => Json::from_pairs(vec![
                ("wait_node", Json::Num(st.wait_node as f64)),
                ("wait_share_pct", Json::Num(st.wait_share_pct)),
                ("slow_node", Json::Num(st.slow_node as f64)),
            ]),
            None => Json::Null,
        };
        let link_events: Vec<Json> = self
            .link_events
            .iter()
            .map(|le| {
                Json::from_pairs(vec![
                    ("node", Json::Num(le.node as f64)),
                    ("peer", Json::Num(le.peer as f64)),
                    ("retransmits", Json::Num(le.retransmits as f64)),
                    ("dedups", Json::Num(le.dedups as f64)),
                    ("nacks_sent", Json::Num(le.nacks_sent as f64)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("rows", Json::Num(s.rows as f64)),
            (
                "nodes",
                Json::Arr(s.nodes.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("round_min", Json::Num(s.round_min as f64)),
            ("round_max", Json::Num(s.round_max as f64)),
            ("rounds_seen", Json::Num(s.rounds_seen as f64)),
            (
                "missing_rounds",
                Json::Arr(s.missing_rounds.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("writer", writer),
            ("convergence", convergence),
            (
                "budget",
                Json::from_pairs(vec![
                    ("doubles_sent_per_round", Json::Num(self.doubles_sent_per_round)),
                    ("doubles_recv_per_round", Json::Num(self.doubles_recv_per_round)),
                    ("bytes_per_round", Json::Num(self.bytes_per_round)),
                    ("bytes_per_double", Json::Num(self.bytes_per_double)),
                ]),
            ),
            ("per_node", Json::Arr(per_node)),
            ("straggler", straggler),
            ("link_events", Json::Arr(link_events)),
            ("events", Json::Num(s.events as f64)),
            ("truncated_tail", Json::Bool(s.truncated_tail)),
        ])
    }
}

/// Fold link-scoped events into per-directed-link counts. Only events
/// carrying both a node and a peer count; everything else (kills,
/// rotations, admissions) is node- or stream-scoped.
fn fold_link_events(events: &[RunEvent]) -> Vec<LinkEventCount> {
    let mut by_link: BTreeMap<(u32, u32), LinkEventCount> = BTreeMap::new();
    for ev in events {
        let (Some(node), Some(peer)) = (ev.node, ev.peer) else { continue };
        let slot = by_link
            .entry((node, peer))
            .or_insert(LinkEventCount { node, peer, ..LinkEventCount::default() });
        match ev.kind {
            EventKind::Retransmit => slot.retransmits += 1,
            EventKind::Dedup => slot.dedups += 1,
            EventKind::NackSent => slot.nacks_sent += 1,
            _ => {}
        }
    }
    // keep only links that actually counted something, so handshakes
    // alone do not clutter the attribution
    by_link
        .into_values()
        .filter(|le| le.retransmits + le.dedups + le.nacks_sent > 0)
        .collect()
}

/// Least-squares fit of `ln(mean residual)` against the round index over
/// rounds with a positive mean residual. Needs at least two such rounds.
fn fit_rate(rows: &[TelemetryRow]) -> Option<ConvergenceFit> {
    let mut by_round: BTreeMap<u64, (f64, u64)> = BTreeMap::new();
    for r in rows {
        let e = by_round.entry(r.round).or_insert((0.0, 0));
        e.0 += r.residual;
        e.1 += 1;
    }
    let pts: Vec<(f64, f64)> = by_round
        .iter()
        .filter_map(|(&t, &(sum, n))| {
            let mean = sum / n as f64;
            (mean > 0.0).then(|| (t as f64, mean.ln()))
        })
        .collect();
    if pts.len() < 2 {
        return None;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom == 0.0 {
        return None;
    }
    let slope = (n * sxy - sx * sy) / denom;
    Some(ConvergenceFit {
        rate: slope.exp(),
        half_life: if slope < 0.0 { (0.5f64).ln() / slope } else { f64::INFINITY },
        points: pts.len(),
    })
}

// --- bench snapshot diffing ------------------------------------------------

/// Metrics where a larger value is a regression.
const HIGHER_WORSE: [&str; 4] = ["secs", "per_round_secs", "bytes_on_wire", "doubles"];
/// Metrics where a smaller value is a regression.
const LOWER_WORSE: [&str; 1] = ["rounds_per_sec"];
/// Non-metric numeric fields that identify a cell (alongside every
/// string-valued field).
const IDENTITY_NUM: [&str; 4] = ["nodes", "rounds", "dim", "threads"];

/// One metric that moved in the regression direction.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchDelta {
    /// `array[identity].metric`, e.g. `sweep[mode=sync,nodes=8].secs`.
    pub path: String,
    pub old: f64,
    pub new: f64,
    /// Percent worse in the metric's regression direction.
    pub worse_pct: f64,
}

/// Outcome of diffing two bench snapshots.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BenchComparison {
    /// Metric cells compared.
    pub compared: usize,
    /// Cells worse than the tolerance, sorted worst-first.
    pub regressions: Vec<BenchDelta>,
    /// Old cells with no matching cell in the new snapshot (coverage
    /// loss counts as a regression).
    pub missing: Vec<String>,
}

impl BenchComparison {
    /// True when the new snapshot regressed (metric beyond tolerance or
    /// a cell disappeared).
    pub fn regressed(&self) -> bool {
        !self.regressions.is_empty() || !self.missing.is_empty()
    }

    /// Human-readable diff, worst regressions first.
    pub fn render_text(&self, tol_pct: f64) -> String {
        let mut out = format!(
            "bench-compare: {} metric cell(s) compared, tolerance {}%\n",
            self.compared, tol_pct
        );
        for d in &self.regressions {
            out.push_str(&format!(
                "  REGRESSION {}: {} -> {} ({:+.1}% worse)\n",
                d.path,
                fmt_metric(d.old),
                fmt_metric(d.new),
                d.worse_pct
            ));
        }
        for m in &self.missing {
            out.push_str(&format!("  MISSING {} (cell absent from new snapshot)\n", m));
        }
        if self.regressed() {
            out.push_str(&format!(
                "result: {} regression(s), {} missing cell(s)\n",
                self.regressions.len(),
                self.missing.len()
            ));
        } else {
            out.push_str("result: ok (within tolerance)\n");
        }
        out
    }
}

fn fmt_metric(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

/// Identity of one sweep cell: every string field plus the config-shaped
/// numeric fields, `key=value` pairs in key order.
fn record_key(obj: &BTreeMap<String, Json>) -> String {
    let mut parts = Vec::new();
    for (k, v) in obj {
        if let Some(s) = v.as_str() {
            parts.push(format!("{k}={s}"));
        } else if IDENTITY_NUM.contains(&k.as_str()) {
            if let Some(n) = v.as_f64() {
                parts.push(format!("{k}={}", fmt_metric(n)));
            }
        }
    }
    parts.join(",")
}

/// Percent worse of `new` vs `old` in `metric`'s regression direction;
/// `None` when the metric is unknown or both sides are zero.
fn worse_pct(metric: &str, old: f64, new: f64) -> Option<f64> {
    if HIGHER_WORSE.contains(&metric) {
        if old <= 0.0 {
            return (new > 0.0).then_some(f64::INFINITY);
        }
        Some((new - old) / old * 100.0)
    } else if LOWER_WORSE.contains(&metric) {
        if new <= 0.0 {
            return (old > 0.0).then_some(f64::INFINITY);
        }
        Some((old - new) / new * 100.0)
    } else {
        None
    }
}

/// Diff two bench snapshot documents (`results/BENCH_*.json`): walk
/// every top-level array of cells in `old`, match cells in `new` by
/// [`record_key`] identity, and compare the known metric fields. A cell
/// in `old` with no counterpart in `new` is reported as missing; extra
/// cells in `new` are fine (coverage can grow freely).
pub fn bench_compare(old: &Json, new: &Json, tol_pct: f64) -> BenchComparison {
    let mut cmp = BenchComparison::default();
    let Some(old_obj) = old.as_obj() else { return cmp };
    for (arr_key, old_val) in old_obj {
        let Some(old_arr) = old_val.as_arr() else { continue };
        let new_cells: BTreeMap<String, &BTreeMap<String, Json>> = new
            .get(arr_key)
            .and_then(Json::as_arr)
            .map(|cells| {
                cells
                    .iter()
                    .filter_map(Json::as_obj)
                    .map(|o| (record_key(o), o))
                    .collect()
            })
            .unwrap_or_default();
        for cell in old_arr.iter().filter_map(Json::as_obj) {
            let key = record_key(cell);
            let Some(new_cell) = new_cells.get(&key) else {
                cmp.missing.push(format!("{arr_key}[{key}]"));
                continue;
            };
            for (metric, old_v) in cell {
                let (Some(o), Some(n)) = (
                    old_v.as_f64(),
                    new_cell.get(metric).and_then(Json::as_f64),
                ) else {
                    continue;
                };
                let Some(pct) = worse_pct(metric, o, n) else { continue };
                cmp.compared += 1;
                if pct > tol_pct {
                    cmp.regressions.push(BenchDelta {
                        path: format!("{arr_key}[{key}].{metric}"),
                        old: o,
                        new: n,
                        worse_pct: pct,
                    });
                }
            }
        }
    }
    cmp.regressions
        .sort_by(|a, b| b.worse_pct.partial_cmp(&a.worse_pct).unwrap_or(std::cmp::Ordering::Equal));
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn row(round: u64, node: u32, residual: f64) -> TelemetryRow {
        TelemetryRow {
            round,
            node,
            residual,
            doubles_sent: 16.0,
            doubles_recv: 16.0,
            bytes_on_wire: 256,
            wall_micros: 1200,
            wait_micros: 400,
            drain_micros: 100,
            compute_micros: 400,
            encode_micros: 50,
            send_micros: 50,
            ..TelemetryRow::default()
        }
    }

    fn stream(rows: &[TelemetryRow]) -> String {
        let mut s: String =
            rows.iter().map(|r| r.to_json_line() + "\n").collect();
        s.push_str(
            &TelemetrySummary {
                rows_written: rows.len() as u64,
                rows_dropped: 0,
            }
            .to_json_line(),
        );
        s.push('\n');
        s
    }

    #[test]
    fn geometric_residuals_fit_their_rate() {
        // residual halves every round, identically on both nodes
        let mut rows = Vec::new();
        for (t, r) in [(0u64, 0.8f64), (1, 0.4), (2, 0.2), (3, 0.1)] {
            rows.push(row(t, 0, r));
            rows.push(row(t, 1, r));
        }
        let rep = RunReport::from_stream(&stream(&rows)).unwrap();
        let fit = rep.convergence.expect("4 positive points fit");
        assert!((fit.rate - 0.5).abs() < 1e-12, "rate {}", fit.rate);
        assert!((fit.half_life - 1.0).abs() < 1e-9, "half-life {}", fit.half_life);
        assert_eq!(fit.points, 4);
        // budget: 2 rows/round, 16 sent + 16 recv + 256 bytes each
        assert_eq!(rep.doubles_sent_per_round, 32.0);
        assert_eq!(rep.doubles_recv_per_round, 32.0);
        assert_eq!(rep.bytes_per_round, 512.0);
        assert_eq!(rep.bytes_per_double, 8.0);
    }

    #[test]
    fn summary_counts_nodes_rounds_and_gaps() {
        // rounds 0,1,4 present: 2 and 3 are the gap rotation ate
        let rows = vec![row(0, 0, 0.5), row(1, 0, 0.4), row(4, 0, 0.1)];
        let s = StreamSummary::from_stream(&stream(&rows)).unwrap();
        assert_eq!(s.rows, 3);
        assert_eq!(s.nodes, vec![0]);
        assert_eq!((s.round_min, s.round_max, s.rounds_seen), (0, 4, 3));
        assert_eq!(s.missing_rounds, vec![2, 3]);
        assert_eq!(s.writer, Some(TelemetrySummary { rows_written: 3, rows_dropped: 0 }));
    }

    #[test]
    fn fault_counters_sum_last_row_per_node_not_all_rows() {
        // cumulative counters: node 0 ends at 5 retransmits, node 1 at 2
        let mut a0 = row(0, 0, 0.5);
        a0.retransmits = 3;
        let mut a1 = row(1, 0, 0.4);
        a1.retransmits = 5;
        let mut b0 = row(0, 1, 0.5);
        b0.retransmits = 2;
        let s = StreamSummary::from_stream(&stream(&[a0, a1, b0])).unwrap();
        assert_eq!(s.retransmits, 7, "5 (node 0 last) + 2 (node 1 last)");
    }

    #[test]
    fn straggler_is_the_dominant_waiter() {
        let mut rows = Vec::new();
        for t in 0..4u64 {
            let mut a = row(t, 0, 0.5);
            a.wait_micros = 100;
            a.compute_micros = 900; // slowest compute
            let mut b = row(t, 1, 0.5);
            b.wait_micros = 700; // dominant waiter
            b.compute_micros = 200;
            b.staleness = 2;
            rows.push(a);
            rows.push(b);
        }
        let rep = RunReport::from_stream(&stream(&rows)).unwrap();
        let st = rep.straggler.expect("wait spans present");
        assert_eq!(st.wait_node, 1);
        assert_eq!(st.slow_node, 0);
        assert!((st.wait_share_pct - 87.5).abs() < 1e-9, "{}", st.wait_share_pct);
        let b1 = rep.per_node.iter().find(|b| b.node == 1).unwrap();
        assert_eq!(b1.max_staleness, 2);
        let text = rep.render_text();
        assert!(text.contains("wait dominated by node 1"), "{text}");
        assert!(text.contains("slowest compute: node 0"), "{text}");
    }

    #[test]
    fn v1_stream_reports_without_phase_table() {
        let v1 = "{\"v\":1,\"round\":0,\"node\":0,\"residual\":0.5,\
                  \"doubles_sent\":4,\"doubles_recv\":4,\"bytes_on_wire\":64,\
                  \"wall_micros\":100,\"queue_depth\":1,\"staleness\":0,\
                  \"stalls\":0,\"retransmits\":0,\"dedups\":0,\
                  \"drops_injected\":0,\"dups_injected\":0}\n\
                  {\"v\":1,\"round\":1,\"node\":0,\"residual\":0.25,\
                  \"doubles_sent\":4,\"doubles_recv\":4,\"bytes_on_wire\":64,\
                  \"wall_micros\":100,\"queue_depth\":1,\"staleness\":0,\
                  \"stalls\":0,\"retransmits\":0,\"dedups\":0,\
                  \"drops_injected\":0,\"dups_injected\":0}\n";
        let rep = RunReport::from_stream(v1).unwrap();
        assert!(rep.straggler.is_none(), "no wait spans in v1 rows");
        let text = rep.render_text();
        assert!(text.contains("no phase spans"), "{text}");
        assert!(rep.convergence.is_some());
    }

    #[test]
    fn report_json_shape_is_stable() {
        let rows = vec![row(0, 0, 0.5), row(1, 0, 0.25)];
        let rep = RunReport::from_stream(&stream(&rows)).unwrap();
        let j = parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(j.get("rows").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("rounds_seen").and_then(Json::as_usize), Some(2));
        assert!(j.get("convergence").unwrap().get("rate").is_some());
        assert_eq!(
            j.get("per_node").and_then(Json::as_arr).map(|a| a.len()),
            Some(1)
        );
        assert_eq!(
            j.get("writer").unwrap().get("rows_written").and_then(Json::as_usize),
            Some(2)
        );
    }

    #[test]
    fn report_skips_unknown_kinds_and_tolerates_a_truncated_tail() {
        let rows = vec![row(0, 0, 0.5), row(1, 0, 0.25)];
        let mut text = stream(&rows);
        text.push_str("{\"v\":2,\"kind\":\"from-the-future\",\"x\":1}\n");
        text.push_str("{\"v\":2,\"round\":"); // partial line, no newline
        let ps = parse_stream_lenient(&text).unwrap();
        assert_eq!(ps.rows.len(), 2);
        assert_eq!(ps.skipped_unknown, 1);
        assert!(ps.truncated_tail);
        let rep = RunReport::from_stream(&text).unwrap();
        assert!(rep.summary.truncated_tail);
        assert!(rep.render_text().contains("truncated final line"), "{}", rep.render_text());
        // the same junk mid-stream still fails, naming the line
        let bad = format!("garbage\n{}", stream(&rows));
        assert!(RunReport::from_stream(&bad).unwrap_err().starts_with("line 1:"));
    }

    #[test]
    fn link_events_fold_into_straggler_attribution() {
        use super::super::events::{EventKind, RunEvent};
        let rows = vec![row(0, 0, 0.5), row(0, 1, 0.5), row(1, 0, 0.25), row(1, 1, 0.25)];
        let mut text = stream(&rows);
        for _ in 0..3 {
            text.push_str(&RunEvent::new(EventKind::Retransmit).node(0).peer(1).to_json_line());
            text.push('\n');
        }
        text.push_str(&RunEvent::new(EventKind::Dedup).node(1).peer(0).seq(4).to_json_line());
        text.push('\n');
        text.push_str(&RunEvent::new(EventKind::NackSent).node(1).peer(0).seq(4).to_json_line());
        text.push('\n');
        // handshakes carry a link but count nothing: they must not clutter
        text.push_str(&RunEvent::new(EventKind::Handshake).node(0).peer(1).to_json_line());
        text.push('\n');
        // a node-scoped kill has no peer: ignored by the fold
        text.push_str(&RunEvent::new(EventKind::NodeKill).node(0).round(1).to_json_line());
        text.push('\n');
        let rep = RunReport::from_stream(&text).unwrap();
        assert_eq!(rep.summary.events, 7);
        assert_eq!(
            rep.link_events,
            vec![
                LinkEventCount { node: 0, peer: 1, retransmits: 3, dedups: 0, nacks_sent: 0 },
                LinkEventCount { node: 1, peer: 0, retransmits: 0, dedups: 1, nacks_sent: 1 },
            ]
        );
        let textual = rep.render_text();
        assert!(textual.contains("link 0->1: 3 retransmits"), "{textual}");
        assert!(textual.contains("link 1->0: 0 retransmits, 1 dedups, 1 nacks sent"), "{textual}");
        let j = parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(
            j.get("link_events").and_then(Json::as_arr).map(|a| a.len()),
            Some(2)
        );
        assert_eq!(j.get("events").and_then(Json::as_usize), Some(7));
    }

    fn snapshot(secs: f64, rps: f64, bytes: f64) -> Json {
        parse(&format!(
            "{{\"bench\":\"engine\",\"sweep\":[\
              {{\"mode\":\"sync\",\"nodes\":8,\"secs\":{secs},\
               \"rounds_per_sec\":{rps},\"bytes_on_wire\":{bytes}}}]}}"
        ))
        .unwrap()
    }

    #[test]
    fn bench_compare_passes_within_tolerance() {
        let old = snapshot(0.010, 100.0, 4096.0);
        let new = snapshot(0.011, 95.0, 4096.0);
        let cmp = bench_compare(&old, &new, 25.0);
        assert!(!cmp.regressed(), "{:?}", cmp);
        assert_eq!(cmp.compared, 3);
    }

    #[test]
    fn bench_compare_flags_fabricated_regressions() {
        let old = snapshot(0.010, 100.0, 4096.0);
        // 3x slower, throughput collapsed, bytes doubled
        let new = snapshot(0.030, 33.0, 8192.0);
        let cmp = bench_compare(&old, &new, 25.0);
        assert!(cmp.regressed());
        assert_eq!(cmp.regressions.len(), 3, "{:?}", cmp.regressions);
        // sorted worst-first
        assert!(cmp.regressions[0].worse_pct >= cmp.regressions[1].worse_pct);
        assert!(cmp.regressions.iter().any(|d| d.path.contains(".rounds_per_sec")));
        let text = cmp.render_text(25.0);
        assert!(text.contains("REGRESSION"), "{text}");
    }

    #[test]
    fn bench_compare_improvements_are_not_regressions() {
        let old = snapshot(0.030, 33.0, 8192.0);
        let new = snapshot(0.010, 100.0, 4096.0);
        let cmp = bench_compare(&old, &new, 5.0);
        assert!(!cmp.regressed(), "{:?}", cmp.regressions);
    }

    #[test]
    fn bench_compare_reports_missing_cells() {
        let old = snapshot(0.010, 100.0, 4096.0);
        let new = parse("{\"bench\":\"engine\",\"sweep\":[]}").unwrap();
        let cmp = bench_compare(&old, &new, 25.0);
        assert!(cmp.regressed());
        assert_eq!(cmp.missing, vec!["sweep[mode=sync,nodes=8]".to_string()]);
    }
}
