//! Live run watching (`dsba watch <run.jsonl>`): tail a growing
//! telemetry stream and keep one refreshing status line.
//!
//! The CLI loop (re-reading the file and sleeping) lives in `cli`;
//! everything observable is in [`WatchState`], which is fed raw chunks
//! — split at arbitrary byte boundaries — and tracks each node's last
//! row. The status line reports the fleet's front round, residual, and
//! staleness, and flags a stall by naming the lagging node from the
//! last per-node rounds (the stream-side view of the watermarks),
//! enriched with the most recent `admission-stall` event's detail when
//! one has been seen.
//!
//! A live stream is allowed to be imperfect: unparsable or unknown
//! lines are counted, never fatal — the next refresh gets another
//! chance.

use super::events::{EventKind, RunEvent};
use super::schema::{TelemetryLine, TelemetryRow, TelemetrySummary};
use std::collections::BTreeMap;

/// Incremental state of one watched stream.
#[derive(Default)]
pub struct WatchState {
    carry: String,
    last: BTreeMap<u32, TelemetryRow>,
    rows: u64,
    events: u64,
    skipped: u64,
    last_stall: Option<RunEvent>,
    summary: Option<TelemetrySummary>,
}

impl WatchState {
    pub fn new() -> WatchState {
        WatchState::default()
    }

    /// Feed the next chunk of the file. Chunks may split lines at any
    /// byte; the partial tail is carried until its newline arrives.
    pub fn ingest(&mut self, chunk: &str) {
        self.carry.push_str(chunk);
        while let Some(pos) = self.carry.find('\n') {
            let line: String = self.carry[..pos].to_string();
            self.carry.drain(..=pos);
            self.take_line(&line);
        }
    }

    fn take_line(&mut self, line: &str) {
        if line.trim().is_empty() {
            return;
        }
        match TelemetryLine::parse_lenient(line) {
            Ok(Some(TelemetryLine::Row(r))) => {
                self.rows += 1;
                self.last.insert(r.node, r);
            }
            Ok(Some(TelemetryLine::Summary(s))) => self.summary = Some(s),
            Ok(Some(TelemetryLine::Event(e))) => {
                self.events += 1;
                if e.kind == EventKind::AdmissionStall {
                    self.last_stall = Some(e);
                }
            }
            // a live stream may hold lines this build cannot read;
            // count and keep tailing
            Ok(None) | Err(_) => self.skipped += 1,
        }
    }

    /// Data rows consumed so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// True once the trailing writer summary has been seen — the run is
    /// over and the stream will not grow.
    pub fn finished(&self) -> bool {
        self.summary.is_some()
    }

    /// The single refreshing status line.
    pub fn status_line(&self) -> String {
        if let Some(s) = &self.summary {
            return format!(
                "run complete: {} row(s) written, {} dropped, {} event(s) seen",
                s.rows_written, s.rows_dropped, self.events
            );
        }
        if self.last.is_empty() {
            return "waiting for telemetry rows...".to_string();
        }
        let front = self.last.values().map(|r| r.round).max().unwrap_or(0);
        let staleness = self.last.values().map(|r| r.staleness).max().unwrap_or(0);
        let residual = self.last.values().map(|r| r.residual).sum::<f64>()
            / self.last.len() as f64;
        let mut s = format!(
            "round {front} | residual {residual:.3e} | staleness {staleness} \
             | {} node(s) | {} event(s)",
            self.last.len(),
            self.events
        );
        // stall: a node whose last reported round trails the front by
        // 2+ — the same per-node watermarks the async clock admits on
        if let Some((lag_round, lag_node)) =
            self.last.values().map(|r| (r.round, r.node)).min()
        {
            if front >= lag_round + 2 {
                s.push_str(&format!(
                    " | STALL: node {lag_node} lagging at round {lag_round} \
                     ({} behind)",
                    front - lag_round
                ));
                if let Some(ev) = &self.last_stall {
                    if !ev.detail.is_empty() {
                        s.push_str(&format!(" — {}", ev.detail));
                    }
                }
            }
        }
        if self.skipped > 0 {
            s.push_str(&format!(" | {} unreadable line(s)", self.skipped));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: u64, node: u32, residual: f64) -> TelemetryRow {
        TelemetryRow { round, node, residual, ..TelemetryRow::default() }
    }

    #[test]
    fn chunks_split_mid_line_reassemble() {
        let mut w = WatchState::new();
        let line = row(0, 0, 0.5).to_json_line() + "\n";
        let (a, b) = line.split_at(line.len() / 2);
        w.ingest(a);
        assert_eq!(w.rows(), 0, "half a line is not a row yet");
        w.ingest(b);
        assert_eq!(w.rows(), 1);
        assert!(w.status_line().starts_with("round 0"), "{}", w.status_line());
    }

    #[test]
    fn status_tracks_front_round_and_mean_residual() {
        let mut w = WatchState::new();
        for (t, n, r) in [(0u64, 0u32, 0.8f64), (0, 1, 0.8), (1, 0, 0.4), (1, 1, 0.4)] {
            w.ingest(&(row(t, n, r).to_json_line() + "\n"));
        }
        let s = w.status_line();
        assert!(s.starts_with("round 1"), "{s}");
        assert!(s.contains("2 node(s)"), "{s}");
        assert!(s.contains("4.000e-1"), "mean residual 0.4: {s}");
        assert!(!s.contains("STALL"), "1-round spread is not a stall: {s}");
    }

    #[test]
    fn stall_names_the_lagging_node() {
        let mut w = WatchState::new();
        w.ingest(&(row(0, 1, 0.5).to_json_line() + "\n"));
        for t in 0..5u64 {
            w.ingest(&(row(t, 0, 0.5).to_json_line() + "\n"));
        }
        let stall_ev = RunEvent::new(EventKind::AdmissionStall)
            .node(0)
            .round(5)
            .detail("peer 1 (last watermark: round 0)");
        w.ingest(&(stall_ev.to_json_line() + "\n"));
        let s = w.status_line();
        assert!(s.contains("STALL: node 1 lagging at round 0 (4 behind)"), "{s}");
        assert!(s.contains("peer 1 (last watermark: round 0)"), "{s}");
    }

    #[test]
    fn summary_finishes_the_watch_and_junk_is_tolerated() {
        let mut w = WatchState::new();
        w.ingest("not json at all\n");
        w.ingest(&(row(0, 0, 0.5).to_json_line() + "\n"));
        assert!(!w.finished());
        let sum = TelemetrySummary { rows_written: 1, rows_dropped: 0 };
        w.ingest(&(sum.to_json_line() + "\n"));
        assert!(w.finished());
        assert!(w.status_line().starts_with("run complete: 1 row(s)"), "{}", w.status_line());
    }
}
