//! Size-based rotation and retention for the telemetry JSONL stream.
//!
//! A [`RotatingFile`] appends lines to `path` until the next line would
//! push the file past `max_bytes`, then shifts the retention chain
//! (`path` → `path.1` → `path.2` → …, discarding `path.keep`) and starts
//! a fresh file. Rotation happens on whole-line boundaries only, so
//! every generation is independently valid JSONL.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// An append-only line writer with size-based rotation.
pub struct RotatingFile {
    path: PathBuf,
    max_bytes: u64,
    keep: usize,
    file: File,
    written: u64,
    rotations: u64,
}

fn generation(path: &Path, i: usize) -> PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(format!(".{i}"));
    PathBuf::from(name)
}

fn open_append(path: &Path) -> Result<(File, u64), String> {
    let file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| format!("telemetry: cannot open {}: {e}", path.display()))?;
    let len = file
        .metadata()
        .map_err(|e| format!("telemetry: cannot stat {}: {e}", path.display()))?
        .len();
    Ok((file, len))
}

impl RotatingFile {
    /// Open (or continue) the live file at `path`. `max_bytes = 0`
    /// disables rotation; `keep` is the number of rotated generations
    /// retained beyond the live file.
    pub fn create(path: &Path, max_bytes: u64, keep: usize) -> Result<RotatingFile, String> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| format!("telemetry: cannot create {}: {e}", dir.display()))?;
            }
        }
        let (file, written) = open_append(path)?;
        Ok(RotatingFile {
            path: path.to_path_buf(),
            max_bytes,
            keep,
            file,
            written,
            rotations: 0,
        })
    }

    /// Append one line (a newline is added). Rotates first when the
    /// line would push a non-empty live file past `max_bytes`.
    pub fn append_line(&mut self, line: &str) -> Result<(), String> {
        let need = line.len() as u64 + 1;
        if self.max_bytes > 0 && self.written > 0 && self.written + need > self.max_bytes {
            self.rotate()?;
        }
        self.file
            .write_all(line.as_bytes())
            .and_then(|()| self.file.write_all(b"\n"))
            .map_err(|e| format!("telemetry: write to {} failed: {e}", self.path.display()))?;
        self.written += need;
        Ok(())
    }

    /// Bytes written to the current live generation.
    pub fn written(&self) -> u64 {
        self.written
    }

    /// Rotations performed since this writer opened the file.
    pub fn rotations(&self) -> u64 {
        self.rotations
    }

    pub fn flush(&mut self) -> Result<(), String> {
        self.file
            .flush()
            .map_err(|e| format!("telemetry: flush of {} failed: {e}", self.path.display()))
    }

    fn rotate(&mut self) -> Result<(), String> {
        self.flush()?;
        self.rotations += 1;
        if self.keep == 0 {
            // no retained generations: truncate the live file in place
            self.file = File::create(&self.path)
                .map_err(|e| format!("telemetry: cannot truncate {}: {e}", self.path.display()))?;
            self.written = 0;
            return Ok(());
        }
        let _ = std::fs::remove_file(generation(&self.path, self.keep));
        for i in (1..self.keep).rev() {
            let from = generation(&self.path, i);
            if from.exists() {
                std::fs::rename(&from, generation(&self.path, i + 1)).map_err(|e| {
                    format!("telemetry: rotate {} failed: {e}", from.display())
                })?;
            }
        }
        std::fs::rename(&self.path, generation(&self.path, 1))
            .map_err(|e| format!("telemetry: rotate {} failed: {e}", self.path.display()))?;
        let (file, written) = open_append(&self.path)?;
        self.file = file;
        self.written = written;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "dsba_retention_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn appends_accumulate_without_rotation() {
        let dir = tmp_dir("plain");
        let path = dir.join("t.jsonl");
        let mut f = RotatingFile::create(&path, 0, 3).unwrap();
        f.append_line("alpha").unwrap();
        f.append_line("beta").unwrap();
        f.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "alpha\nbeta\n");
        assert_eq!(f.written(), text.len() as u64);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_shifts_generations_and_respects_keep() {
        let dir = tmp_dir("rotate");
        let path = dir.join("t.jsonl");
        // every line is 6 bytes ("lineN\n"); cap at 14 => 2 lines per file
        let mut f = RotatingFile::create(&path, 14, 2).unwrap();
        for i in 0..7 {
            f.append_line(&format!("line{i}")).unwrap();
        }
        f.flush().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "line6\n");
        assert_eq!(
            std::fs::read_to_string(generation(&path, 1)).unwrap(),
            "line4\nline5\n"
        );
        assert_eq!(
            std::fs::read_to_string(generation(&path, 2)).unwrap(),
            "line2\nline3\n"
        );
        // generation 3 (lines 0..2) fell off the end of the chain
        assert!(!generation(&path, 3).exists());
        assert_eq!(f.rotations(), 3, "one rotation per filled generation");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keep_zero_truncates_in_place() {
        let dir = tmp_dir("keep0");
        let path = dir.join("t.jsonl");
        let mut f = RotatingFile::create(&path, 8, 0).unwrap();
        f.append_line("0123456").unwrap(); // 8 bytes: at cap
        f.append_line("abc").unwrap(); // forces truncation first
        f.flush().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "abc\n");
        assert!(!generation(&path, 1).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_single_line_still_lands() {
        let dir = tmp_dir("oversize");
        let path = dir.join("t.jsonl");
        let mut f = RotatingFile::create(&path, 4, 1).unwrap();
        f.append_line("this line alone exceeds max_bytes").unwrap();
        f.flush().unwrap();
        assert!(std::fs::read_to_string(&path)
            .unwrap()
            .contains("exceeds max_bytes"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
