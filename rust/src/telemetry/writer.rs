//! Non-blocking telemetry writer: engine workers hand rows to a bounded
//! channel; one dedicated thread serializes them and appends to the
//! rotating JSONL file. The hot path never blocks — when the channel is
//! full the row is dropped and counted, and the drop count is reported
//! when the writer is finished — both in-process (the return value of
//! [`TelemetryWriter::finish`]) and durably, as a trailing
//! [`TelemetrySummary`](super::schema::TelemetrySummary) line appended
//! to the stream at shutdown.

use super::events::{EventKind, RunEvent};
use super::retention::RotatingFile;
use super::schema::{TelemetryRow, TelemetrySummary};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Items buffered between the workers and the writer thread. Deep
/// enough to absorb a rotation hiccup at thousand-node scale, small
/// enough to bound memory.
pub(crate) const CHANNEL_DEPTH: usize = 4096;

/// One unit of work for the writer thread: a data row or a
/// control-plane event, both serialized to the same JSONL stream.
pub(crate) enum TelemetryItem {
    Row(TelemetryRow),
    Event(RunEvent),
}

/// Cloneable producer handle. `emit` is wait-free: a full channel drops
/// the item and bumps the matching drop counter instead of blocking.
/// Row and event drops are counted separately so the row accounting in
/// the trailing summary line stays exact.
#[derive(Clone)]
pub struct TelemetrySink {
    tx: SyncSender<TelemetryItem>,
    dropped: Arc<AtomicU64>,
    events_dropped: Arc<AtomicU64>,
}

impl TelemetrySink {
    /// Offer a row to the writer; never blocks.
    pub fn emit(&self, row: TelemetryRow) {
        if self.tx.try_send(TelemetryItem::Row(row)).is_err() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Offer a control-plane event to the writer; never blocks. A
    /// dropped event still survives in the flight recorder ring.
    pub fn emit_event(&self, ev: RunEvent) {
        if self.tx.try_send(TelemetryItem::Event(ev)).is_err() {
            self.events_dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Rows dropped because the channel was full (or the writer gone).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events dropped because the channel was full.
    pub fn events_dropped(&self) -> u64 {
        self.events_dropped.load(Ordering::Relaxed)
    }
}

/// Owns the writer thread. Items flow until [`TelemetryWriter::finish`]
/// (or drop) signals shutdown; the thread then drains what is already
/// queued and closes the file.
pub struct TelemetryWriter {
    tx: SyncSender<TelemetryItem>,
    dropped: Arc<AtomicU64>,
    events_dropped: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    epoch: Instant,
    handle: Option<std::thread::JoinHandle<Result<u64, String>>>,
}

/// Serialize one item; when the append just rotated the file, stamp a
/// `rotation` event at the head of the new generation so the stream
/// records its own retention history.
fn write_item(
    file: &mut RotatingFile,
    item: &TelemetryItem,
    rows: &mut u64,
    rotations: &mut u64,
    epoch: Instant,
) -> Result<(), String> {
    match item {
        TelemetryItem::Row(row) => {
            file.append_line(&row.to_json_line())?;
            *rows += 1;
        }
        TelemetryItem::Event(ev) => {
            file.append_line(&ev.to_json_line())?;
        }
    }
    while file.rotations() > *rotations {
        *rotations += 1;
        let ev = RunEvent {
            ts_micros: epoch.elapsed().as_micros() as u64,
            kind: EventKind::Rotation,
            detail: format!("rotation #{} after {} row(s)", *rotations, *rows),
            ..RunEvent::default()
        };
        file.append_line(&ev.to_json_line())?;
    }
    Ok(())
}

fn writer_loop(
    rx: Receiver<TelemetryItem>,
    mut file: RotatingFile,
    shutdown: Arc<AtomicBool>,
    dropped: Arc<AtomicU64>,
    epoch: Instant,
) -> Result<u64, String> {
    let mut rows = 0u64;
    let mut rotations = file.rotations();
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(item) => write_item(&mut file, &item, &mut rows, &mut rotations, epoch)?,
            Err(_) => {
                // timeout or all senders gone: exit only when asked, so
                // sinks cloned later in the run still have a live thread
                if shutdown.load(Ordering::Acquire) {
                    break;
                }
            }
        }
    }
    // drain anything that raced the shutdown flag
    loop {
        match rx.try_recv() {
            Ok(item) => write_item(&mut file, &item, &mut rows, &mut rotations, epoch)?,
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
        }
    }
    // trailing summary line: make silent row loss visible in the stream
    // itself, after the process (and its in-memory counters) is gone
    let summary = TelemetrySummary {
        rows_written: rows,
        rows_dropped: dropped.load(Ordering::Relaxed),
    };
    file.append_line(&summary.to_json_line())?;
    file.flush()?;
    Ok(rows)
}

impl TelemetryWriter {
    /// Open the rotating file and start the writer thread.
    pub fn spawn(path: &Path, max_bytes: u64, keep: usize) -> Result<TelemetryWriter, String> {
        let file = RotatingFile::create(path, max_bytes, keep)?;
        let (tx, rx) = sync_channel(CHANNEL_DEPTH);
        let shutdown = Arc::new(AtomicBool::new(false));
        let dropped = Arc::new(AtomicU64::new(0));
        let events_dropped = Arc::new(AtomicU64::new(0));
        let epoch = Instant::now();
        let flag = Arc::clone(&shutdown);
        let drop_count = Arc::clone(&dropped);
        let handle = std::thread::Builder::new()
            .name("telemetry-writer".into())
            .spawn(move || writer_loop(rx, file, flag, drop_count, epoch))
            .map_err(|e| format!("telemetry: cannot spawn writer thread: {e}"))?;
        Ok(TelemetryWriter {
            tx,
            dropped,
            events_dropped,
            shutdown,
            epoch,
            handle: Some(handle),
        })
    }

    /// A new producer handle for one worker thread.
    pub fn sink(&self) -> TelemetrySink {
        TelemetrySink {
            tx: self.tx.clone(),
            dropped: Arc::clone(&self.dropped),
            events_dropped: Arc::clone(&self.events_dropped),
        }
    }

    /// The monotonic instant event timestamps are measured from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Stop the writer thread, drain queued rows, append the trailing
    /// summary line, and report `(rows_written, rows_dropped)`.
    pub fn finish(mut self) -> Result<(u64, u64), String> {
        let written = self.join()?;
        Ok((written, self.dropped.load(Ordering::Relaxed)))
    }

    fn join(&mut self) -> Result<u64, String> {
        self.shutdown.store(true, Ordering::Release);
        match self.handle.take() {
            Some(h) => h
                .join()
                .map_err(|_| "telemetry: writer thread panicked".to_string())?,
            None => Ok(0),
        }
    }
}

impl Drop for TelemetryWriter {
    fn drop(&mut self) {
        let _ = self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::super::schema::{validate_jsonl, TelemetryLine};
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("dsba_telemetry_writer_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn row(round: u64, node: u32) -> TelemetryRow {
        TelemetryRow { round, node, ..TelemetryRow::default() }
    }

    #[test]
    fn writer_persists_all_rows_through_finish() {
        let dir = tmp_dir("basic");
        let path = dir.join("t.jsonl");
        let w = TelemetryWriter::spawn(&path, 0, 0).unwrap();
        let sink = w.sink();
        for r in 0..100 {
            sink.emit(row(r, (r % 4) as u32));
        }
        let (written, dropped) = w.finish().unwrap();
        assert_eq!(written, 100);
        assert_eq!(dropped, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_jsonl(&text), Ok(100));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overflow_drops_with_counter_instead_of_blocking() {
        let dir = tmp_dir("overflow");
        let path = dir.join("t.jsonl");
        let w = TelemetryWriter::spawn(&path, 0, 0).unwrap();
        let sink = w.sink();
        // far more rows than the channel holds, emitted as fast as
        // possible; emit must never block, so this terminates even if
        // the writer thread cannot keep up
        let total = 4 * CHANNEL_DEPTH as u64;
        for r in 0..total {
            sink.emit(row(r, 0));
        }
        let (written, dropped) = w.finish().unwrap();
        assert_eq!(written + dropped, total, "every row written or counted");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_jsonl(&text), Ok(written as usize));
        // the trailing summary line carries the same accounting
        let last = text.lines().rev().find(|l| !l.trim().is_empty()).unwrap();
        match TelemetryLine::parse(last).unwrap() {
            TelemetryLine::Summary(s) => {
                assert_eq!(s.rows_written, written);
                assert_eq!(s.rows_dropped, dropped);
            }
            other => panic!("stream must end with a summary line, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sinks_cloned_after_spawn_share_the_drop_counter() {
        let dir = tmp_dir("clone");
        let path = dir.join("t.jsonl");
        let w = TelemetryWriter::spawn(&path, 0, 0).unwrap();
        let a = w.sink();
        let b = a.clone();
        a.emit(row(0, 0));
        b.emit(row(0, 1));
        assert_eq!(a.dropped(), b.dropped());
        let (written, _) = w.finish().unwrap();
        assert_eq!(written, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn events_interleave_with_rows_but_do_not_count_as_rows() {
        use super::super::events::{EventKind, RunEvent};
        let dir = tmp_dir("events");
        let path = dir.join("t.jsonl");
        let w = TelemetryWriter::spawn(&path, 0, 0).unwrap();
        let sink = w.sink();
        for r in 0..10 {
            sink.emit(row(r, 0));
            sink.emit_event(RunEvent::new(EventKind::Dedup).node(0).peer(1).seq(r));
        }
        let (written, dropped) = w.finish().unwrap();
        assert_eq!((written, dropped), (10, 0), "events are not rows");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_jsonl(&text), Ok(10));
        let events = text
            .lines()
            .filter(|l| matches!(TelemetryLine::parse(l), Ok(TelemetryLine::Event(_))))
            .count();
        assert_eq!(events, 10, "every event landed in the stream");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
