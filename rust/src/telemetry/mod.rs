//! Run telemetry: a versioned per-round, per-node JSONL evidence stream,
//! plus the interpretation layer that turns a stream into answers.
//!
//! Split by concern:
//!
//! - [`schema`] — the versioned [`TelemetryRow`] record (v2 adds the
//!   per-round phase spans and the trailing [`TelemetrySummary`] line)
//!   and the [`validate_jsonl`] stream check (`dsba telemetry-check`).
//! - [`events`] — the control-plane [`RunEvent`] taxonomy, the bounded
//!   wait-free [`FlightRecorder`] ring ("flight recorder"), and the
//!   [`EventSink`] / [`EventHub`] plumbing that fans each event out to
//!   the ring and the stream as `{"kind":"event",...}` lines.
//! - [`trace`] — the phase-span recorder the engine worker loops use to
//!   attribute each round's time to `wait` / `drain` / `compute` /
//!   `encode` / `send` (only active when telemetry is enabled).
//! - [`writer`] — the non-blocking producer/consumer pair: workers
//!   [`TelemetrySink::emit`] into a bounded channel (drop-with-counter on
//!   overflow, never blocking the round hot path); one dedicated thread
//!   serializes and appends, closing the stream with a summary line.
//! - [`retention`] — size-based rotation of the JSONL file
//!   (`telemetry.max_bytes` / `telemetry.keep`).
//! - [`report`] — stream analysis (`dsba report`): fitted convergence
//!   rate, per-node phase breakdown, straggler attribution (with
//!   per-link event counts when the stream carries events), and the
//!   bytes-vs-DOUBLEs budget — plus the bench snapshot diff behind
//!   `dsba bench-compare`.
//! - [`chrome`] — `dsba trace export --format chrome`: the stream as
//!   Chrome trace-event JSON (Perfetto-loadable).
//! - [`watch`] — `dsba watch`: tail a growing stream into one
//!   refreshing status line with stall detection.
//!
//! [`TelemetrySpec`] is the configuration value that travels through
//! `EngineSpec` / config JSON / `--telemetry`, exactly like
//! `CompressionSpec` and `ModeSpec` before it.

pub mod chrome;
pub mod events;
pub mod report;
pub mod retention;
pub mod schema;
pub mod trace;
pub mod watch;
pub mod writer;

pub use chrome::chrome_trace;
pub use events::{EventHub, EventKind, EventSink, FlightRecorder, RunEvent};
pub use report::{
    bench_compare, parse_stream_lenient, BenchComparison, LinkEventCount, ParsedStream,
    RunReport, StreamSummary,
};
pub use retention::RotatingFile;
pub use schema::{
    validate_jsonl, validate_jsonl_detailed, TelemetryLine, TelemetryRow, TelemetrySummary,
    TELEMETRY_SCHEMA_VERSION,
};
pub use watch::WatchState;
pub use writer::{TelemetrySink, TelemetryWriter};

use crate::util::json::Json;

/// Default live-file size cap before rotation (64 MiB).
pub const DEFAULT_MAX_BYTES: u64 = 64 * 1024 * 1024;
/// Default number of rotated generations retained.
pub const DEFAULT_KEEP: usize = 3;

/// Telemetry configuration: where the JSONL stream goes and how much of
/// it is retained. An empty `path` disables telemetry entirely.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TelemetrySpec {
    /// JSONL output path ("" = telemetry off).
    pub path: String,
    /// Rotate when the live file would exceed this many bytes
    /// (0 = never rotate).
    pub max_bytes: u64,
    /// Rotated generations kept beyond the live file.
    pub keep: usize,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec { path: String::new(), max_bytes: DEFAULT_MAX_BYTES, keep: DEFAULT_KEEP }
    }
}

impl TelemetrySpec {
    /// Telemetry off (the default).
    pub fn disabled() -> TelemetrySpec {
        TelemetrySpec::default()
    }

    /// Telemetry on, writing to `path` with default retention.
    pub fn to_path(path: &str) -> TelemetrySpec {
        TelemetrySpec { path: path.to_string(), ..TelemetrySpec::default() }
    }

    pub fn enabled(&self) -> bool {
        !self.path.is_empty()
    }

    /// Sidecar path for flight-recorder crash dumps (`<path>.crash`);
    /// `None` when telemetry is off.
    pub fn crash_path(&self) -> Option<std::path::PathBuf> {
        if !self.enabled() {
            return None;
        }
        Some(std::path::PathBuf::from(format!("{}.crash", self.path)))
    }

    /// Start the writer thread for this spec (`None` when disabled).
    pub fn spawn_writer(&self) -> Result<Option<TelemetryWriter>, String> {
        if !self.enabled() {
            return Ok(None);
        }
        TelemetryWriter::spawn(std::path::Path::new(&self.path), self.max_bytes, self.keep)
            .map(Some)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("path", Json::Str(self.path.clone())),
            ("max_bytes", Json::Num(self.max_bytes as f64)),
            ("keep", Json::Num(self.keep as f64)),
        ])
    }

    /// Parse from JSON: the nested object form emitted by
    /// [`TelemetrySpec::to_json`], or a bare string naming just the path.
    pub fn from_json(v: &Json) -> Result<TelemetrySpec, String> {
        if let Some(s) = v.as_str() {
            return Ok(TelemetrySpec::to_path(s));
        }
        let mut t = TelemetrySpec::default();
        if let Some(s) = v.get("path").and_then(Json::as_str) {
            t.path = s.to_string();
        }
        if let Some(n) = v.get("max_bytes").and_then(Json::as_f64) {
            if n < 0.0 || n != n.trunc() {
                return Err(format!("telemetry.max_bytes must be a non-negative integer, got {n}"));
            }
            t.max_bytes = n as u64;
        }
        if let Some(n) = v.get("keep").and_then(Json::as_usize) {
            t.keep = n;
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn spec_defaults_are_disabled() {
        let t = TelemetrySpec::default();
        assert!(!t.enabled());
        assert_eq!(t.max_bytes, DEFAULT_MAX_BYTES);
        assert_eq!(t.keep, DEFAULT_KEEP);
        assert!(t.spawn_writer().unwrap().is_none());
    }

    #[test]
    fn spec_json_roundtrip() {
        let t = TelemetrySpec { path: "results/t.jsonl".into(), max_bytes: 1024, keep: 5 };
        let back = TelemetrySpec::from_json(&parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn spec_accepts_bare_path_string() {
        let t = TelemetrySpec::from_json(&Json::Str("run.jsonl".into())).unwrap();
        assert_eq!(t.path, "run.jsonl");
        assert_eq!(t.max_bytes, DEFAULT_MAX_BYTES);
        assert!(t.enabled());
    }

    #[test]
    fn spec_rejects_bad_max_bytes() {
        assert!(TelemetrySpec::from_json(&parse("{\"max_bytes\":-1}").unwrap()).is_err());
        assert!(TelemetrySpec::from_json(&parse("{\"max_bytes\":1.5}").unwrap()).is_err());
    }
}
