//! Control-plane event tracing: the typed [`RunEvent`] taxonomy, the
//! bounded wait-free [`FlightRecorder`] ring, and the [`EventSink`]
//! that fans each event out to both.
//!
//! Telemetry v2 rows aggregate what a round *cost*; events record what
//! the control plane *did* — which link NACKed, which frame was deduped,
//! which peer a stalled admission was waiting on — each stamped with a
//! monotonic microsecond timestamp taken from the telemetry writer's
//! epoch. Events travel two paths at once:
//!
//! 1. **The stream**: every event is offered to the non-blocking
//!    [`TelemetryWriter`](super::writer::TelemetryWriter) channel and
//!    lands as a `{"kind":"event",...}` JSONL line interleaved with the
//!    data rows. No row-schema bump: v1/v2 streams stay valid, and
//!    [`TelemetryLine::parse`](super::schema::TelemetryLine::parse)
//!    dispatches on the `kind` key.
//! 2. **The flight recorder**: a bounded ring of the last N events kept
//!    in memory. On any fail-fast path (kill fault, admission timeout,
//!    NACK-for-pruned link close) [`EventSink::crash_dump`] writes the
//!    ring to a `<stream>.crash` sidecar as black-box forensics, even
//!    when the writer thread never got to flush.
//!
//! Both paths are wait-free on the producer side: a full channel drops
//! the event (counted separately from row drops), and a contended ring
//! slot loses the event rather than block a worker or reader thread.

use super::schema::{check_version, req_u64, TELEMETRY_SCHEMA_VERSION};
use super::writer::TelemetrySink;
use crate::util::json::{parse, Json};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Events retained by the in-memory flight recorder ring.
pub const FLIGHT_RECORDER_CAPACITY: usize = 256;

/// The control-plane event taxonomy. Wire names are kebab-case and
/// stable; [`EventKind::parse`] is the inverse of [`EventKind::name`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A TCP link completed its handshake (emitted once per directed
    /// link when the event sink attaches to an established transport).
    Handshake,
    /// The async clock admitted a round on a node (bounded staleness
    /// satisfied); `detail` carries the staleness consumed.
    RoundAdmitted,
    /// A node's async admission first blocked on a lagging peer;
    /// `detail` names the peer's last-seen watermark.
    AdmissionStall,
    /// A peer's end-of-round watermark advanced on a link.
    WatermarkAdvance,
    /// The link layer detected a sequence gap and sent a NACK; `seq` is
    /// the first missing frame.
    NackSent,
    /// A NACK arrived from a peer; `seq` is the first requested frame.
    NackReceived,
    /// Retained frames were re-sent to service a NACK; `detail` carries
    /// the frame range.
    Retransmit,
    /// A duplicate link frame was discarded; `seq` is its link sequence.
    Dedup,
    /// A link was closed (clean shutdown, read error, or NACK failure);
    /// `detail` carries the reason.
    LinkClosed,
    /// A node was killed by fault injection; `round` is the kill round.
    NodeKill,
    /// The telemetry channel dropped rows on the floor since this node's
    /// previous round; `detail` carries the cumulative drop count.
    WriterDrop,
    /// The telemetry file rotated; written by the writer thread at the
    /// head of the new generation.
    Rotation,
}

impl EventKind {
    /// Every kind, in taxonomy order.
    pub const ALL: [EventKind; 12] = [
        EventKind::Handshake,
        EventKind::RoundAdmitted,
        EventKind::AdmissionStall,
        EventKind::WatermarkAdvance,
        EventKind::NackSent,
        EventKind::NackReceived,
        EventKind::Retransmit,
        EventKind::Dedup,
        EventKind::LinkClosed,
        EventKind::NodeKill,
        EventKind::WriterDrop,
        EventKind::Rotation,
    ];

    /// Stable wire name (the `event` key of the JSONL line).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Handshake => "handshake",
            EventKind::RoundAdmitted => "round-admitted",
            EventKind::AdmissionStall => "admission-stall",
            EventKind::WatermarkAdvance => "watermark-advance",
            EventKind::NackSent => "nack-sent",
            EventKind::NackReceived => "nack-received",
            EventKind::Retransmit => "retransmit",
            EventKind::Dedup => "dedup",
            EventKind::LinkClosed => "link-closed",
            EventKind::NodeKill => "node-kill",
            EventKind::WriterDrop => "writer-drop",
            EventKind::Rotation => "rotation",
        }
    }

    /// Inverse of [`EventKind::name`].
    pub fn parse(name: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.name() == name)
    }
}

impl Default for EventKind {
    fn default() -> EventKind {
        EventKind::Handshake
    }
}

/// One control-plane event: what happened, when (microseconds since the
/// telemetry writer's epoch, monotonic within a run), and to whom.
/// Optional keys are omitted from the JSONL line when absent.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunEvent {
    /// Monotonic microseconds since the writer epoch.
    pub ts_micros: u64,
    /// What happened.
    pub kind: EventKind,
    /// Topology index of the node the event happened on.
    pub node: Option<u32>,
    /// The peer on the other end of the link, when the event is
    /// link-scoped — this is the per-link attribution.
    pub peer: Option<u32>,
    /// Round the event is tied to, when round-scoped.
    pub round: Option<u64>,
    /// Link-layer frame sequence, when frame-scoped.
    pub seq: Option<u64>,
    /// Free-form context (lagging peer watermarks, close reasons, …).
    pub detail: String,
}

impl RunEvent {
    /// Start a builder-style event of the given kind.
    pub fn new(kind: EventKind) -> RunEvent {
        RunEvent { kind, ..RunEvent::default() }
    }

    /// Attach the owning node.
    pub fn node(mut self, node: u32) -> RunEvent {
        self.node = Some(node);
        self
    }

    /// Attach the link peer.
    pub fn peer(mut self, peer: u32) -> RunEvent {
        self.peer = Some(peer);
        self
    }

    /// Attach the round.
    pub fn round(mut self, round: u64) -> RunEvent {
        self.round = Some(round);
        self
    }

    /// Attach the frame sequence.
    pub fn seq(mut self, seq: u64) -> RunEvent {
        self.seq = Some(seq);
        self
    }

    /// Attach free-form detail.
    pub fn detail(mut self, detail: impl Into<String>) -> RunEvent {
        self.detail = detail.into();
        self
    }

    /// Serialize as one compact JSONL line (no trailing newline).
    /// Optional keys are omitted when unset; an empty `detail` is
    /// omitted too, so rendering is a fixed point of parsing.
    pub fn to_json_line(&self) -> String {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("v", Json::Num(TELEMETRY_SCHEMA_VERSION as f64)),
            ("kind", Json::Str("event".into())),
            ("event", Json::Str(self.kind.name().into())),
            ("ts_micros", Json::Num(self.ts_micros as f64)),
        ];
        if let Some(n) = self.node {
            pairs.push(("node", Json::Num(n as f64)));
        }
        if let Some(p) = self.peer {
            pairs.push(("peer", Json::Num(p as f64)));
        }
        if let Some(r) = self.round {
            pairs.push(("round", Json::Num(r as f64)));
        }
        if let Some(s) = self.seq {
            pairs.push(("seq", Json::Num(s as f64)));
        }
        if !self.detail.is_empty() {
            pairs.push(("detail", Json::Str(self.detail.clone())));
        }
        Json::from_pairs(pairs).to_string()
    }

    /// Parse one event line (inverse of [`to_json_line`]).
    ///
    /// [`to_json_line`]: RunEvent::to_json_line
    pub fn from_json_line(line: &str) -> Result<RunEvent, String> {
        let v = parse(line.trim())?;
        RunEvent::from_json(&v)
    }

    pub(crate) fn from_json(v: &Json) -> Result<RunEvent, String> {
        check_version(v)?;
        let name = v
            .get("event")
            .and_then(Json::as_str)
            .ok_or_else(|| "event line missing string key \"event\"".to_string())?;
        let kind = EventKind::parse(name)
            .ok_or_else(|| format!("unknown event kind {name:?}"))?;
        let opt = |key: &str| -> Result<Option<u64>, String> {
            match v.get(key) {
                None => Ok(None),
                Some(_) => req_u64(v, key).map(Some),
            }
        };
        let node = match opt("node")? {
            Some(n) if n > u32::MAX as u64 => {
                return Err(format!("node {n} out of range"));
            }
            other => other.map(|n| n as u32),
        };
        let peer = match opt("peer")? {
            Some(p) if p > u32::MAX as u64 => {
                return Err(format!("peer {p} out of range"));
            }
            other => other.map(|p| p as u32),
        };
        let detail = match v.get("detail") {
            None => String::new(),
            Some(Json::Str(s)) => s.clone(),
            Some(_) => return Err("key \"detail\" must be a string".to_string()),
        };
        Ok(RunEvent {
            ts_micros: req_u64(v, "ts_micros")?,
            kind,
            node,
            peer,
            round: opt("round")?,
            seq: opt("seq")?,
            detail,
        })
    }
}

/// A bounded wait-free ring of the most recent events — the black box.
///
/// Producers never block: each push claims a slot with one atomic
/// fetch-add and a `try_lock`; a slot contended at that instant loses
/// the event instead of stalling an engine worker or a socket reader.
/// [`FlightRecorder::dump`] returns the retained events in push order.
pub struct FlightRecorder {
    slots: Vec<Mutex<Option<(u64, RunEvent)>>>,
    head: AtomicU64,
}

impl FlightRecorder {
    /// A ring retaining up to `capacity` events (at least one).
    pub fn new(capacity: usize) -> FlightRecorder {
        let cap = capacity.max(1);
        FlightRecorder {
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
        }
    }

    /// Record an event; wait-free, may drop under slot contention.
    pub fn push(&self, ev: RunEvent) {
        let n = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        if let Ok(mut g) = slot.try_lock() {
            *g = Some((n, ev));
        }
    }

    /// Total events ever pushed (including any that wrapped or dropped).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// The retained events, oldest first.
    pub fn dump(&self) -> Vec<RunEvent> {
        let mut kept: Vec<(u64, RunEvent)> = Vec::new();
        for slot in &self.slots {
            if let Ok(g) = slot.lock() {
                if let Some((n, ev)) = g.as_ref() {
                    kept.push((*n, ev.clone()));
                }
            }
        }
        kept.sort_by_key(|&(n, _)| n);
        kept.into_iter().map(|(_, ev)| ev).collect()
    }
}

/// Cloneable producer handle: stamps each event with the monotonic
/// writer-epoch timestamp, records it in the shared [`FlightRecorder`],
/// and offers it to the writer channel. Both halves are wait-free.
#[derive(Clone)]
pub struct EventSink {
    sink: TelemetrySink,
    recorder: Arc<FlightRecorder>,
    epoch: Instant,
    crash_path: Option<PathBuf>,
}

impl EventSink {
    /// A sink feeding `sink`'s writer, timestamping against `epoch`
    /// (normally the writer's own epoch so event and row ordering
    /// agree). `crash_path` is where [`EventSink::crash_dump`] writes
    /// the black box; `None` disables the sidecar.
    pub fn new(sink: TelemetrySink, epoch: Instant, crash_path: Option<PathBuf>) -> EventSink {
        EventSink {
            sink,
            recorder: Arc::new(FlightRecorder::new(FLIGHT_RECORDER_CAPACITY)),
            epoch,
            crash_path,
        }
    }

    /// Monotonic microseconds since the epoch.
    pub fn now_micros(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Stamp and emit one event to the ring and the stream.
    pub fn emit(&self, mut ev: RunEvent) {
        ev.ts_micros = self.now_micros();
        self.recorder.push(ev.clone());
        self.sink.emit_event(ev);
    }

    /// The shared flight-recorder ring.
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Synchronously write the ring's retained events to the crash
    /// sidecar (one JSONL event line each) and return its path. Called
    /// on fail-fast paths *before* the panic unwinds, so the forensics
    /// survive even if the writer thread never drains its queue.
    pub fn crash_dump(&self, reason: &str) -> Option<PathBuf> {
        let path = self.crash_path.as_ref()?;
        let events = self.recorder.dump();
        let mut out = String::with_capacity(events.len() * 128);
        for ev in &events {
            out.push_str(&ev.to_json_line());
            out.push('\n');
        }
        match std::fs::write(path, out) {
            Ok(()) => {
                eprintln!(
                    "flight recorder: {} event(s) dumped to {} ({reason})",
                    events.len(),
                    path.display()
                );
                Some(path.clone())
            }
            Err(e) => {
                eprintln!("flight recorder: dump to {} failed: {e}", path.display());
                None
            }
        }
    }
}

/// A late-binding slot for an [`EventSink`], shared with threads that
/// outlive or predate the engine (TCP socket readers spawn at link
/// establishment, before telemetry exists). When nothing is installed,
/// [`EventHub::with`] is one relaxed atomic load — the zero-cost-off
/// guarantee for the transport hot path.
pub struct EventHub {
    active: AtomicBool,
    slot: Mutex<Option<EventSink>>,
}

impl EventHub {
    pub fn new() -> EventHub {
        EventHub { active: AtomicBool::new(false), slot: Mutex::new(None) }
    }

    /// Install the sink; subsequent [`EventHub::with`] calls see it.
    pub fn install(&self, events: EventSink) {
        if let Ok(mut g) = self.slot.lock() {
            *g = Some(events);
            self.active.store(true, Ordering::Release);
        }
    }

    /// Run `f` against the installed sink, if any.
    pub fn with(&self, f: impl FnOnce(&EventSink)) {
        if !self.active.load(Ordering::Acquire) {
            return;
        }
        if let Ok(g) = self.slot.lock() {
            if let Some(es) = g.as_ref() {
                f(es);
            }
        }
    }
}

impl Default for EventHub {
    fn default() -> EventHub {
        EventHub::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunEvent {
        RunEvent {
            ts_micros: 1234,
            kind: EventKind::NackSent,
            node: Some(2),
            peer: Some(5),
            round: Some(7),
            seq: Some(41),
            detail: "gap [41, 43)".into(),
        }
    }

    #[test]
    fn kind_names_roundtrip_and_are_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for k in EventKind::ALL {
            assert!(seen.insert(k.name()), "duplicate wire name {}", k.name());
            assert_eq!(EventKind::parse(k.name()), Some(k));
        }
        assert_eq!(EventKind::parse("no-such-event"), None);
    }

    #[test]
    fn event_lines_roundtrip() {
        let ev = sample();
        let line = ev.to_json_line();
        assert!(!line.contains('\n'), "an event must be a single line");
        assert!(line.contains("\"kind\":\"event\""), "{line}");
        assert_eq!(RunEvent::from_json_line(&line).unwrap(), ev);
        // sparse events omit their unset keys and still roundtrip
        let sparse = RunEvent::new(EventKind::Rotation);
        let line = sparse.to_json_line();
        assert!(!line.contains("\"node\""), "{line}");
        assert!(!line.contains("\"detail\""), "{line}");
        assert_eq!(RunEvent::from_json_line(&line).unwrap(), sparse);
    }

    #[test]
    fn event_parse_rejects_malformed_lines() {
        assert!(RunEvent::from_json_line("not json").is_err());
        let missing = "{\"v\":2,\"kind\":\"event\",\"ts_micros\":0}";
        assert!(RunEvent::from_json_line(missing).is_err(), "missing event key");
        let unknown = "{\"v\":2,\"kind\":\"event\",\"event\":\"warp\",\"ts_micros\":0}";
        let err = RunEvent::from_json_line(unknown).unwrap_err();
        assert!(err.contains("unknown event kind"), "{err}");
        let bad_node = sample().to_json_line().replace("\"node\":2", "\"node\":-1");
        assert!(RunEvent::from_json_line(&bad_node).is_err());
    }

    #[test]
    fn flight_recorder_keeps_the_last_n_in_order() {
        let ring = FlightRecorder::new(4);
        for i in 0..10u64 {
            ring.push(RunEvent::new(EventKind::Dedup).seq(i));
        }
        assert_eq!(ring.recorded(), 10);
        let kept = ring.dump();
        assert_eq!(kept.len(), 4);
        let seqs: Vec<u64> = kept.iter().filter_map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "oldest first, last N retained");
    }

    #[test]
    fn event_hub_is_inert_until_installed() {
        let hub = EventHub::new();
        let mut fired = false;
        hub.with(|_| fired = true);
        assert!(!fired, "no sink installed yet");
    }
}
